"""AdamW + learning-rate schedules (cosine and MiniCPM's WSD), no optax.

Moments are fp32 regardless of param dtype; updates are computed in fp32 and
cast back.  Global-norm clipping before the update.  ``schedule`` is a pure
function of the (traced) step so the whole update stays inside one jit.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

f32 = jnp.float32


@dataclass(frozen=True)
class OptimConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"      # "cosine" | "wsd" | "const"
    warmup_steps: int = 100
    total_steps: int = 10_000
    # WSD (warmup-stable-decay, MiniCPM): stable until decay_start, then
    # exponential-ish decay over the final window.
    decay_start_frac: float = 0.9


def schedule(cfg: OptimConfig, step) -> jax.Array:
    s = step.astype(f32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "const":
        return cfg.lr * warm
    t = jnp.clip((s - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        return cfg.lr * warm * (0.1 + 0.9 * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    if cfg.schedule == "wsd":
        ds = cfg.decay_start_frac
        decay = jnp.where(t < ds, 1.0,
                          0.5 ** ((t - ds) / jnp.maximum(1 - ds, 1e-6) * 4))
        return cfg.lr * warm * decay
    raise ValueError(cfg.schedule)


def init_opt_state(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, f32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(f32)))
                        for x in jax.tree.leaves(tree)))


def _is_matrix(p) -> bool:
    return p.ndim >= 2  # decay only matrices (norms/bias vectors exempt)


def apply_updates(params, grads, opt_state, cfg: OptimConfig):
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** step.astype(f32)
    c2 = 1.0 - b2 ** step.astype(f32)

    def upd(p, g, m, v):
        g = g.astype(f32) * clip
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        u = (m2 / c1) / (jnp.sqrt(v2 / c2) + cfg.eps)
        if cfg.weight_decay and _is_matrix(p):
            u = u + cfg.weight_decay * p.astype(f32)
        return (p.astype(f32) - lr * u).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}
