"""Int8 error-feedback gradient compression (distributed-optimization trick).

The data-parallel all-reduce is the dominant training collective; quantizing
gradients to int8 with per-leaf scales cuts its bytes 4x (vs fp32) / 2x (vs
bf16).  Error feedback (Karimireddy et al. '19) keeps the quantization
residual in a local buffer and re-injects it next step, preserving
convergence.

Two entry points:
 * :func:`compress_tree` / :func:`decompress_tree` — pure transforms used by
   the train loop (the all-reduce itself stays implicit in pjit; this models
   the end-to-end numerics and is what the convergence test exercises);
 * :func:`ef_allreduce` — an explicit ``shard_map`` psum over the data axes
   operating on the int32-widened int8 payload: the form that makes the
   compressed collective visible in lowered HLO (used by the dry-run variant
   and the §Perf collective experiments).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

f32 = jnp.float32


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, f32), params)


def compress_leaf(g, err):
    """Returns (q int8, scale fp32 scalar, new_err)."""
    gf = g.astype(f32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(f32) * scale
    return q, scale, gf - deq


def compress_tree(grads, err_state):
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    qs, scales, errs = [], [], []
    for g, e in zip(flat_g, flat_e):
        q, s, e2 = compress_leaf(g, e)
        qs.append(q); scales.append(s); errs.append(e2)
    return (treedef.unflatten(qs), treedef.unflatten(scales),
            treedef.unflatten(errs))


def decompress_tree(qs, scales, like=None):
    out = jax.tree.map(lambda q, s: q.astype(f32) * s, qs, scales)
    if like is not None:
        out = jax.tree.map(lambda o, l: o.astype(l.dtype), out, like)
    return out


def compressed_grads(grads, err_state):
    """grads -> (dequantized grads, new error state): the train-loop hook."""
    qs, scales, errs = compress_tree(grads, err_state)
    return decompress_tree(qs, scales, like=grads), errs


def ef_allreduce(mesh, axis_names, x_q, scale):
    """Explicit compressed all-reduce of one leaf over ``axis_names``:
    int8 payload widened to int32, psum'd, then dequantized and averaged.
    The wire format is int8 (the int32 widening models the accumulator)."""
    from jax.experimental.shard_map import shard_map

    n = 1
    for a in axis_names:
        n *= mesh.shape[a]

    @partial(shard_map, mesh=mesh,
             in_specs=(P(axis_names), P(axis_names)), out_specs=P(axis_names),
             check_rep=False)
    def _ar(q, s):
        acc = jax.lax.psum(q.astype(jnp.int32) * 1, axis_name=axis_names)
        s_max = jax.lax.pmax(s, axis_name=axis_names)
        return acc.astype(f32) * s_max / n

    return _ar(x_q, scale)
