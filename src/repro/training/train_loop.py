"""The training driver: jit-compiled update step with microbatch gradient
accumulation, optional int8 error-feedback gradient compression, sharded
state, async checkpointing, auto-resume, straggler watchdog, and failure
injection hooks.

Single-device (tests, examples) and production-mesh (launch/train.py) share
this code — the mesh only changes the shardings passed to jit.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp

from ..models.model import LM
from . import checkpoint as ckpt
from .compression import compressed_grads, init_error_state
from .fault_tolerance import FailureInjector, StragglerWatchdog
from .optimizer import OptimConfig, apply_updates, init_opt_state

f32 = jnp.float32


@dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    ckpt_async: bool = True
    grad_accum: int = 1            # microbatches per step
    compression: bool = False      # int8 error-feedback grads
    optim: OptimConfig = OptimConfig()


class Trainer:
    def __init__(self, lm: LM, train_cfg: TrainConfig,
                 state_shardings=None, batch_sharding=None):
        self.lm = lm
        self.cfg = train_cfg
        self._step_fn = self._build_step(state_shardings, batch_sharding)
        self.watchdog = StragglerWatchdog()
        self.injector = FailureInjector()
        self._ckpt = (ckpt.AsyncCheckpointer(train_cfg.ckpt_dir)
                      if train_cfg.ckpt_dir and train_cfg.ckpt_async else None)

    # ------------------------------------------------------------------ state
    def init_state(self, rng) -> dict:
        params = self.lm.init(rng)
        state = {"params": params, "opt": init_opt_state(params)}
        if self.cfg.compression:
            state["err"] = init_error_state(params)
        return state

    # ------------------------------------------------------------------- step
    def _build_step(self, state_shardings, batch_sharding):
        cfg = self.cfg
        lm = self.lm

        def loss_fn(params, batch):
            loss, metrics = lm.loss(params, batch)
            return loss, metrics

        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

        def step_fn(state, batch):
            params = state["params"]
            a = cfg.grad_accum
            if a > 1:
                # microbatch scan: per-microbatch grads accumulate in fp32;
                # the (implicit) DP all-reduce happens once on the total.
                def micro(acc, mb):
                    (l, m), g = grad_fn(params, mb)
                    acc = jax.tree.map(lambda x, y: x + y.astype(f32), acc, g)
                    return acc, l
                batch_m = jax.tree.map(
                    lambda x: x.reshape((a, x.shape[0] // a) + x.shape[1:]),
                    batch)
                zero = jax.tree.map(lambda p: jnp.zeros(p.shape, f32), params)
                grads, losses = jax.lax.scan(micro, zero, batch_m)
                grads = jax.tree.map(lambda g: g / a, grads)
                loss = jnp.mean(losses)
            else:
                (loss, _), grads = grad_fn(params, batch)

            new_state = dict(state)
            if cfg.compression:
                grads, new_state["err"] = compressed_grads(grads, state["err"])
            new_params, new_opt, info = apply_updates(
                params, grads, state["opt"], cfg.optim)
            new_state["params"] = new_params
            new_state["opt"] = new_opt
            metrics = {"loss": loss, **info}
            return new_state, metrics

        kw: dict[str, Any] = {"donate_argnums": (0,)}
        if state_shardings is not None:
            kw["in_shardings"] = (state_shardings, batch_sharding)
            kw["out_shardings"] = (state_shardings, None)
        return jax.jit(step_fn, **kw)

    # -------------------------------------------------------------------- run
    def run(self, state: Optional[dict], batches: Iterator[dict],
            resume: bool = True,
            on_step: Optional[Callable[[int, dict], None]] = None) -> dict:
        """Runs to cfg.steps; auto-resumes from the newest committed
        checkpoint when ``resume``.  Returns {"state", "history"}."""
        cfg = self.cfg
        start = 0
        if resume and cfg.ckpt_dir:
            last = ckpt.latest_step(cfg.ckpt_dir)
            if last is not None:
                assert state is not None, "need a template state to restore into"
                state, _ = ckpt.restore(cfg.ckpt_dir, last, state)
                start = last
        assert state is not None

        history: list[dict] = []
        it = iter(batches)
        # fast-forward the deterministic pipeline to the resume point
        for _ in range(start):
            next(it)
        for step in range(start, cfg.steps):
            batch = jax.tree.map(jnp.asarray, next(it))
            self.watchdog.start()
            state, metrics = self._step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = self.watchdog.stop(step)
            rec = {"step": step + 1, "loss": loss,
                   "lr": float(metrics["lr"]),
                   "grad_norm": float(metrics["grad_norm"]), "dt": dt}
            history.append(rec)
            if on_step:
                on_step(step + 1, rec)
            if cfg.log_every and (step + 1) % cfg.log_every == 0:
                print(f"step {step+1:5d} loss {loss:.4f} "
                      f"lr {rec['lr']:.2e} |g| {rec['grad_norm']:.3f} "
                      f"{dt*1e3:.0f}ms")
            if cfg.ckpt_dir and (step + 1) % cfg.ckpt_every == 0:
                self._save(step + 1, state)
            self.injector.maybe_fail(step + 1)  # after ckpt: worst-case drill
        if cfg.ckpt_dir:
            self._save(cfg.steps, state)
            if self._ckpt:
                self._ckpt.wait()
        return {"state": state, "history": history}

    def _save(self, step: int, state: dict) -> None:
        if self._ckpt is not None:
            self._ckpt.submit(step, state)
        else:
            ckpt.save(self.cfg.ckpt_dir, step, state)
