"""Fault-tolerance machinery: straggler watchdog, failure injection, elastic
re-carve policy.

At 1000+ nodes the failure model is: (a) a host crashes -> the job restarts
from the newest committed checkpoint (train loop auto-resume, exercised by
tests/test_fault_tolerance.py with an injected crash); (b) a host is slow ->
the watchdog flags it from step-time statistics so the scheduler can swap in
a spare; (c) a pod drops for good -> ``elastic_plan`` recomputes the largest
runnable (data, model) mesh from the surviving device count and the data
pipeline re-shards by construction (batches are pure functions of
(seed, step, shard)).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional


class SimulatedFailure(RuntimeError):
    """Injected crash for restart tests."""


@dataclass
class StragglerWatchdog:
    """Flags steps (or, with per-host timings, hosts) that exceed
    ``threshold`` x the running median step time."""

    threshold: float = 2.0
    window: int = 50
    times: list = field(default_factory=list)
    flagged: list = field(default_factory=list)
    _t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.monotonic()

    def stop(self, step: int) -> float:
        dt = time.monotonic() - self._t0
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)
        med = sorted(self.times)[len(self.times) // 2]
        if len(self.times) >= 5 and dt > self.threshold * med:
            self.flagged.append((step, dt, med))
        return dt

    @property
    def median(self) -> float:
        return sorted(self.times)[len(self.times) // 2] if self.times else 0.0


@dataclass(frozen=True)
class ElasticPlan:
    data: int
    model: int
    dropped_hosts: int

    @property
    def n_devices(self) -> int:
        return self.data * self.model


def elastic_plan(n_alive: int, model_parallel: int,
                 min_data: int = 1) -> ElasticPlan:
    """Largest (data, model) mesh from surviving devices, keeping the model
    axis intact (params are sharded over it; reshaping it would re-shard
    every weight, while shrinking the data axis only changes batch layout)."""
    if n_alive < model_parallel * min_data:
        raise RuntimeError(
            f"{n_alive} devices cannot host model_parallel={model_parallel}")
    data = n_alive // model_parallel
    # largest power-of-two data axis keeps per-shard batch divisibility
    p = 1
    while p * 2 <= data:
        p *= 2
    return ElasticPlan(data=p, model=model_parallel,
                       dropped_hosts=n_alive - p * model_parallel)


@dataclass
class FailureInjector:
    """Deterministically crash at a given step (tests / chaos drills)."""

    crash_at_step: Optional[int] = None
    fired: bool = False

    def maybe_fail(self, step: int) -> None:
        if (self.crash_at_step is not None and step == self.crash_at_step
                and not self.fired):
            self.fired = True
            raise SimulatedFailure(f"injected failure at step {step}")
