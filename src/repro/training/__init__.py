from .optimizer import OptimConfig, apply_updates, init_opt_state, schedule
from .train_loop import TrainConfig, Trainer
from . import checkpoint, compression, fault_tolerance

__all__ = ["OptimConfig", "apply_updates", "init_opt_state", "schedule",
           "TrainConfig", "Trainer", "checkpoint", "compression",
           "fault_tolerance"]
