"""Fault-tolerant checkpointing: atomic, manifest-verified, async-capable.

Layout: ``<dir>/step_<n>/`` containing one ``.npy``-style blob per leaf
(bf16 stored as uint16 views), ``manifest.json`` (paths, shapes, dtypes,
step, config fingerprint) and a ``COMMITTED`` marker written last after an
atomic directory rename — a crash mid-write can never produce a checkpoint
that ``latest_step`` would pick up.  On multi-host deployments each host
writes its addressable shards under ``host_<k>/`` (single-process here: one
host dir), and restore re-shards via device_put with the target sharding.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_BF16 = "bfloat16"


def _path_str(path) -> str:
    out = []
    for e in path:
        if isinstance(e, jax.tree_util.DictKey):
            out.append(str(e.key))
        elif isinstance(e, jax.tree_util.SequenceKey):
            out.append(str(e.idx))
        elif isinstance(e, jax.tree_util.GetAttrKey):
            out.append(str(e.name))
        else:
            out.append(str(e))
    return "/".join(out)


def save(ckpt_dir: str, step: int, tree, extra: Optional[dict] = None) -> str:
    """Synchronous atomic save; returns the committed directory."""
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}_{os.getpid()}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(os.path.join(tmp, "host_0"), exist_ok=True)
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest: dict[str, Any] = {"step": step, "leaves": [],
                                "extra": extra or {}, "time": time.time()}
    for i, (path, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        dt = str(leaf.dtype)
        stored = arr.view(np.uint16) if dt == _BF16 else arr
        fn = f"host_0/leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fn), stored, allow_pickle=False)
        manifest["leaves"].append({"path": _path_str(path), "file": fn,
                                   "dtype": dt, "shape": list(arr.shape)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish
    with open(os.path.join(final, "COMMITTED"), "w") as f:
        f.write(str(step))
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, d, "COMMITTED")):
            try:
                steps.append(int(d.split("_", 1)[1]))
            except ValueError:
                continue
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs); re-shards with ``shardings`` when given."""
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_meta = manifest["leaves"]
    like_leaves, treedef = jax.tree.flatten(like)
    assert len(like_leaves) == len(leaves_meta), \
        f"checkpoint has {len(leaves_meta)} leaves, expected {len(like_leaves)}"
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(like_leaves))
    out = []
    for meta, ref, shd in zip(leaves_meta, like_leaves, shard_leaves):
        arr = np.load(os.path.join(d, meta["file"]), allow_pickle=False)
        if meta["dtype"] == _BF16:
            arr = arr.view(jnp.bfloat16)
        x = jnp.asarray(arr)
        if shd is not None:
            x = jax.device_put(x, shd)
        out.append(x)
    return treedef.unflatten(out), manifest


class AsyncCheckpointer:
    """Background-thread writer: ``submit`` returns immediately after copying
    device arrays to host; at most one write in flight (subsequent submits
    queue behind a join)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.saved: list[int] = []

    def submit(self, step: int, tree, extra: Optional[dict] = None) -> None:
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self.wait()

        def work():
            save(self.ckpt_dir, step, host_tree, extra)
            self.saved.append(step)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(s for s in (latest_step_all(self.ckpt_dir)))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s}"),
                          ignore_errors=True)


def latest_step_all(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, d, "COMMITTED")):
            try:
                out.append(int(d.split("_", 1)[1]))
            except ValueError:
                pass
    return out
