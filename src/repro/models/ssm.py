"""Mamba-style selective SSM used by Hymba's parallel SSM heads.

Sequence mode runs a *chunked* selective scan: ``lax.scan`` over chunks of
``chunk`` timesteps, parallel (associative scan) within a chunk — the same
blocking the ``kernels/ssm_scan`` Pallas kernel uses on TPU (state resident in
VMEM per chunk).  Decode mode is the O(1) single-step recurrence with a conv
ring buffer.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import dense_init, f32


class SSMState(NamedTuple):
    conv: jax.Array   # (B, cw-1, di) last conv inputs
    h: jax.Array      # (B, di, n) fp32 SSM state


def init_ssm_params(rng, d_model: int, d_inner: int, n_state: int,
                    conv_width: int, dtype):
    ks = jax.random.split(rng, 8)
    dt_rank = max(16, d_model // 16)
    return {
        "w_in": dense_init(ks[0], d_model, 2 * d_inner, dtype),
        "conv_w": (jax.random.normal(ks[1], (conv_width, d_inner), f32)
                   / math.sqrt(conv_width)).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "w_dt_in": dense_init(ks[2], d_inner, dt_rank, dtype),
        "w_dt_out": dense_init(ks[3], dt_rank, d_inner, dtype),
        "dt_bias": jnp.full((d_inner,), -2.0, f32),  # softplus^-1(~0.12)
        "w_B": dense_init(ks[4], d_inner, n_state, dtype),
        "w_C": dense_init(ks[5], d_inner, n_state, dtype),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, n_state + 1, dtype=f32),
                                  (d_inner, 1))),
        "D_skip": jnp.ones((d_inner,), f32),
        "w_out": dense_init(ks[6], d_inner, d_model, dtype),
    }


def _conv_causal(x, w, b):
    """Depthwise causal conv: x (B, S, di), w (cw, di)."""
    cw = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(cw))
    return out + b


def _ssm_coeffs(p, x_c):
    """x_c (B, S, di) -> dA (B,S,di,n) decay, dBx (B,S,di,n) input, C (B,S,n)."""
    dt = jax.nn.softplus((x_c @ p["w_dt_in"] @ p["w_dt_out"]).astype(f32)
                         + p["dt_bias"])                      # (B,S,di)
    a = -jnp.exp(p["A_log"])                                  # (di,n)
    b_t = (x_c @ p["w_B"]).astype(f32)                        # (B,S,n)
    c_t = (x_c @ p["w_C"]).astype(f32)                        # (B,S,n)
    da = jnp.exp(dt[..., None] * a)                           # (B,S,di,n)
    dbx = (dt * x_c.astype(f32))[..., None] * b_t[:, :, None, :]
    return da, dbx, c_t


def pick_chunk(s: int, chunk: int) -> int:
    """Largest divisor of s that is <= chunk (exactness over padding)."""
    for c in range(min(chunk, s), 0, -1):
        if s % c == 0:
            return c
    return 1


def ssm_sequence(p, x, chunk: int = 128, h0=None):
    """x: (B, S, D) -> (y (B, S, D), final SSMState-h (B, di, n)).

    The chunk length snaps to the largest divisor of S <= ``chunk``; assigned
    shapes are powers of two so this is the identity there.
    """
    btype = x.dtype
    xz = x @ p["w_in"]
    di = xz.shape[-1] // 2
    x_in, z = xz[..., :di], xz[..., di:]
    x_c = jax.nn.silu(_conv_causal(x_in, p["conv_w"], p["conv_b"]))

    bsz, s, _ = x_c.shape
    n = p["A_log"].shape[1]
    h0 = jnp.zeros((bsz, di, n), f32) if h0 is None else h0
    chunk = pick_chunk(s, chunk)
    n_chunks = s // chunk
    xc_ch = x_c.reshape(bsz, n_chunks, chunk, di).transpose(1, 0, 2, 3)

    def scan_chunk(h_prev, xck):
        da, dbx, c_t = _ssm_coeffs(p, xck)                    # (B,T,di,n)
        # intra-chunk associative scan: (a, b) composition (a2a1, a2b1+b2)
        def comb(l, r):
            return (r[0] * l[0], r[0] * l[1] + r[1])
        a_sc, b_sc = jax.lax.associative_scan(comb, (da, dbx), axis=1)
        h_t = b_sc + a_sc * h_prev[:, None]                    # (B,T,di,n)
        y = jnp.einsum("btdn,btn->btd", h_t, c_t)
        y = y + p["D_skip"] * xck.astype(f32)
        return h_t[:, -1], y.astype(btype)

    h_fin, ys = jax.lax.scan(scan_chunk, h0, xc_ch)
    y = ys.transpose(1, 0, 2, 3).reshape(bsz, s, di)
    y = y * jax.nn.silu(z)
    return y @ p["w_out"], h_fin


def ssm_prefill_state(p, x, chunk: int = 128):
    """Run the sequence and also return the conv ring for decode."""
    y, h = ssm_sequence(p, x, chunk=chunk)
    cw = p["conv_w"].shape[0]
    xz = x @ p["w_in"]
    di = xz.shape[-1] // 2
    x_in = xz[..., :di]
    conv_ring = x_in[:, -(cw - 1):, :]
    return y, SSMState(conv=conv_ring, h=h)


def ssm_step(p, x, state: SSMState):
    """x: (B, 1, D) -> (y (B, 1, D), new state)."""
    btype = x.dtype
    xz = x @ p["w_in"]
    di = xz.shape[-1] // 2
    x_in, z = xz[..., :di], xz[..., di:]                       # (B,1,di)
    hist = jnp.concatenate([state.conv, x_in], axis=1)         # (B,cw,di)
    x_c = jax.nn.silu((hist * p["conv_w"]).sum(axis=1, keepdims=True)
                      + p["conv_b"])                           # (B,1,di)
    da, dbx, c_t = _ssm_coeffs(p, x_c)                         # (B,1,di,n)
    h = da[:, 0] * state.h + dbx[:, 0]                         # (B,di,n)
    y = jnp.einsum("bdn,bn->bd", h, c_t[:, 0])[:, None, :]
    y = y + p["D_skip"] * x_c.astype(f32)
    y = (y.astype(btype) * jax.nn.silu(z))
    return y @ p["w_out"], SSMState(conv=hist[:, 1:], h=h)


def init_ssm_state(batch: int, d_inner: int, n_state: int, conv_width: int,
                   dtype) -> SSMState:
    return SSMState(conv=jnp.zeros((batch, conv_width - 1, d_inner), dtype),
                    h=jnp.zeros((batch, d_inner, n_state), f32))
