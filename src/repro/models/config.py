"""Model configuration for the 10 assigned architectures.

A model is a *pattern* of homogeneous block stacks; each stack is scanned with
``jax.lax.scan`` over its stacked parameters (HLO-size / compile-time control
at 512-way SPMD), and heterogeneous stacks (Hymba's global-attention layers,
xLSTM's sLSTM interleave) are separate pattern entries — which also gives each
stack its own cache structure (full KV / rolling KV / SSM state / mLSTM state).

Block kinds:
  attn       full causal attention + SwiGLU FFN
  swa        sliding-window attention + SwiGLU FFN
  moe        full attention + top-k MoE FFN
  moe_swa    sliding-window attention + top-k MoE FFN
  hymba_g    parallel (full attention ∥ Mamba SSM heads) + FFN
  hymba_l    parallel (SWA attention ∥ Mamba SSM heads) + FFN
  mlstm      xLSTM matrix-memory block (chunkwise-parallel, no FFN)
  slstm      xLSTM scalar-memory block (recurrent, no FFN)
  enc        bidirectional encoder attention + FFN (no cache)
  xdec       decoder self-attention + cross-attention + FFN
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

FULL_ATTN_KINDS = ("attn", "moe", "enc", "xdec", "hymba_g")
CACHED_KINDS = ("attn", "swa", "moe", "moe_swa", "hymba_g", "hymba_l",
                "mlstm", "slstm", "xdec")


@dataclass(frozen=True)
class MoESpec:
    n_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | audio | vlm | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    pattern: tuple = ()             # ((kind, count), ...) — decoder stack
    enc_pattern: tuple = ()         # encoder stack (enc-dec archs)
    head_dim: int = 0               # 0 => d_model // n_heads
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    sliding_window: int = 4096
    moe: Optional[MoESpec] = None
    # -- SSM / hybrid --
    ssm_state: int = 0
    ssm_conv_width: int = 4
    ssm_expand: int = 1             # d_inner = expand * d_model
    # -- xLSTM --
    qk_dim: int = 0                 # mLSTM q/k head dim (0 => head_dim // 2)
    # -- VLM --
    mrope_sections: tuple = ()      # e.g. (16, 24, 24); empty => 1D RoPE
    # -- I/O --
    input_mode: str = "tokens"      # tokens | embeds | encdec
    tie_embeddings: bool = False
    embed_scale: float = 1.0        # MiniCPM scale_emb
    residual_scale: float = 1.0     # MiniCPM depth scaling (1.4/sqrt(L))
    logit_scale: float = 1.0        # MiniCPM: dim_base / d_model
    # -- numerics / structure --
    dtype: str = "bfloat16"
    remat: str = "full"             # none | dots | full
    # attention implementation (the §Perf memory-term lever):
    #   einsum   — reference: materializes (S, S) scores in fp32
    #   bf16     — bf16 score storage, fp32 softmax reductions only
    #   qchunk   — flash-style query blocking: (Sq/chunk, S) transients,
    #              block-skips fully-masked causal/window tiles
    attn_impl: str = "einsum"
    attn_chunk: int = 512
    # MoE dispatch: "global" (pjit global-view scatter — the baseline) or
    # "sharded" (shard_map-local dispatch per data shard — §Perf fix; needs
    # distributed.context.shard_context at trace time)
    moe_impl: str = "global"
    scan_chunk: int = 128           # SSM / mLSTM chunkwise length
    # dry-run accounting: unroll layer-stack & loss scans so
    # compiled.cost_analysis() sees every layer (XLA's HLO cost analysis
    # counts while-loop bodies once); inner recurrence scans stay rolled
    # and are corrected analytically (launch/roofline.py).
    scan_unroll: bool = False
    max_target_len: int = 32768     # decoder length cap for enc-dec decode

    # ------------------------------------------------------------------ props
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def qk(self) -> int:
        return self.qk_dim or max(self.hd // 2, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def decoder_layers(self) -> int:
        return sum(n for _, n in self.pattern)

    def encoder_layers(self) -> int:
        return sum(n for _, n in self.enc_pattern)

    @property
    def subquadratic(self) -> bool:
        """True iff decode state does NOT grow linearly-with-full-attention:
        every cached decoder block is windowed or recurrent."""
        return all(k in ("swa", "moe_swa", "mlstm", "slstm", "hymba_l", "hymba_g")
                   for k, _ in self.pattern) and not any(
                       k in ("attn", "moe", "xdec") for k, _ in self.pattern)

    @property
    def long_context_ok(self) -> bool:
        """Eligible for the long_500k cell: no block needs an unbounded dense
        KV cache — hymba_g (a handful of global layers) is tolerated because
        its cache is linear in exactly len(hymba_g) layers (documented)."""
        return not any(k in ("attn", "moe", "xdec", "enc") for k, _ in self.pattern)

    # ------------------------------------------------------------ param count
    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        h, kv, hd = self.n_heads, self.n_kv_heads, self.hd
        total = v * d                      # embedding
        if not self.tie_embeddings:
            total += d * v                 # lm head
        total += d                         # final norm

        def attn_params() -> int:
            return d * h * hd + 2 * d * kv * hd + h * hd * d + 2 * d  # q,k,v,o + norms

        def ffn_params() -> int:
            return 3 * d * f

        def moe_params() -> int:
            assert self.moe is not None
            return self.moe.n_experts * 3 * d * f + d * self.moe.n_experts

        def ssm_params() -> int:
            di, n = self.d_inner, self.ssm_state
            return (2 * d * di + di * self.ssm_conv_width
                    + di * (2 * n + 2) + di * n + di + di * d)

        def mlstm_params() -> int:
            hq = self.qk * self.n_heads
            hv = self.hd * self.n_heads
            return d * (2 * hq + 2 * hv) + 3 * self.n_heads * d + hv * d + 2 * d

        def slstm_params() -> int:
            hv = self.hd * self.n_heads
            return 4 * d * hv + 4 * self.n_heads * self.hd ** 2 + hv * d + 2 * d

        per_kind = {
            "attn": lambda: attn_params() + ffn_params(),
            "swa": lambda: attn_params() + ffn_params(),
            "moe": lambda: attn_params() + moe_params(),
            "moe_swa": lambda: attn_params() + moe_params(),
            "hymba_g": lambda: attn_params() + ssm_params() + ffn_params(),
            "hymba_l": lambda: attn_params() + ssm_params() + ffn_params(),
            "mlstm": mlstm_params,
            "slstm": slstm_params,
            "enc": lambda: attn_params() + ffn_params(),
            "xdec": lambda: 2 * attn_params() + ffn_params(),
        }
        for kind, n in tuple(self.pattern) + tuple(self.enc_pattern):
            total += n * per_kind[kind]()
        return total

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k of n_experts)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        n_moe_layers = sum(n for k, n in self.pattern if k.startswith("moe"))
        inactive = (self.moe.n_experts - self.moe.top_k) * 3 * self.d_model * self.d_ff
        return full - n_moe_layers * inactive


@dataclass(frozen=True)
class InputShape:
    """One assigned input-shape cell."""

    name: str            # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch          # one new token per sequence
        return self.global_batch * self.seq_len


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def uniform_pattern(kind: str, n: int) -> tuple:
    return ((kind, n),)


def grouped_pattern(groups: int, *entries: tuple) -> tuple:
    """e.g. grouped_pattern(6, ("mlstm", 7), ("slstm", 1)) -> 12 stacks."""
    return tuple(entries) * groups
