"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM (scalar
memory, sequential recurrence with block-diagonal recurrent weights).

The mLSTM sequence form follows the stabilized chunkwise algorithm (intra-
chunk parallel attention-like term + inter-chunk recurrent carry), which is
also what the ``kernels/mlstm`` Pallas kernel implements; the per-step
recurrence in :func:`mlstm_step` doubles as its correctness oracle.

Recurrence (per head, stabilizer m):
    m_t = max(logsig(f_t) + m_{t-1}, i_t)
    C_t = e^{logsig(f_t)+m_{t-1}-m_t} C_{t-1} + e^{i_t-m_t} k_t v_t^T
    n_t = e^{logsig(f_t)+m_{t-1}-m_t} n_{t-1} + e^{i_t-m_t} k_t
    h_t = o_t * (C_t^T q_t) / max(|n_t . q_t|, e^{-m_t})
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import dense_init, f32

NEG = -1e30


class MLSTMState(NamedTuple):
    c: jax.Array   # (B, H, qk, hv) fp32
    n: jax.Array   # (B, H, qk) fp32
    m: jax.Array   # (B, H) fp32


class SLSTMState(NamedTuple):
    c: jax.Array   # (B, H, hd) fp32
    n: jax.Array   # (B, H, hd) fp32
    m: jax.Array   # (B, H, hd) fp32
    h: jax.Array   # (B, H, hd) fp32


# ------------------------------------------------------------------- mLSTM
def init_mlstm_params(rng, d_model: int, n_heads: int, qk: int, hv: int, dtype):
    ks = jax.random.split(rng, 7)
    return {
        "w_q": dense_init(ks[0], d_model, n_heads * qk, dtype),
        "w_k": dense_init(ks[1], d_model, n_heads * qk, dtype),
        "w_v": dense_init(ks[2], d_model, n_heads * hv, dtype),
        "w_i": dense_init(ks[3], d_model, n_heads, dtype),
        "w_f": dense_init(ks[4], d_model, n_heads, dtype),
        "w_og": dense_init(ks[5], d_model, n_heads * hv, dtype),
        "gn_scale": jnp.zeros((n_heads * hv,), f32),
        "w_out": dense_init(ks[6], n_heads * hv, d_model, dtype,
                            scale=1.0 / math.sqrt(2.0)),
    }


def _mlstm_qkvif(p, x, n_heads: int, qk: int, hv: int):
    b, s, _ = x.shape
    q = (x @ p["w_q"]).reshape(b, s, n_heads, qk).transpose(0, 2, 1, 3)
    k = (x @ p["w_k"]).reshape(b, s, n_heads, qk).transpose(0, 2, 1, 3)
    v = (x @ p["w_v"]).reshape(b, s, n_heads, hv).transpose(0, 2, 1, 3)
    i_g = (x @ p["w_i"]).astype(f32).transpose(0, 2, 1)         # (B,H,S)
    f_g = (x @ p["w_f"]).astype(f32).transpose(0, 2, 1)
    q = q / math.sqrt(qk)
    return q, k, v, i_g, f_g


def _group_norm(h, scale, n_heads: int):
    """Per-head RMS norm over the value dim; h (B, S, H*hv)."""
    b, s, dh = h.shape
    hv = dh // n_heads
    hf = h.reshape(b, s, n_heads, hv).astype(f32)
    var = jnp.mean(jnp.square(hf), axis=-1, keepdims=True)
    hf = hf * jax.lax.rsqrt(var + 1e-6)
    hf = hf.reshape(b, s, dh) * (1.0 + scale)
    return hf


def mlstm_sequence(p, x, n_heads: int, qk: int, hv: int, chunk: int = 128,
                   state: MLSTMState | None = None):
    """x: (B, S, D) -> (y, final MLSTMState).  Chunk snaps to a divisor of S."""
    from .ssm import pick_chunk
    btype = x.dtype
    b, s, d = x.shape
    q, k, v, i_g, f_g = _mlstm_qkvif(p, x, n_heads, qk, hv)
    if state is None:
        state = init_mlstm_state(b, n_heads, qk, hv)

    t = pick_chunk(s, chunk)
    nck = s // t
    # (nck, B, H, t, ...)
    qc = q.reshape(b, n_heads, nck, t, qk).transpose(2, 0, 1, 3, 4)
    kc = k.reshape(b, n_heads, nck, t, qk).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, n_heads, nck, t, hv).transpose(2, 0, 1, 3, 4)
    ic = i_g.reshape(b, n_heads, nck, t).transpose(2, 0, 1, 3)
    fc = f_g.reshape(b, n_heads, nck, t).transpose(2, 0, 1, 3)

    def scan_fn(carry, inp):
        c_prev, n_prev, m_prev = carry
        qq, kk, vv, ii, ff = inp
        lf = jax.nn.log_sigmoid(ff)                      # (B,H,t)
        bcum = jnp.cumsum(lf, axis=-1)                   # b_t
        g_tot = bcum[..., -1]
        # intra-chunk log decay matrix D[t,s] = b_t - b_s + i_s  (s <= t)
        dmat = bcum[..., :, None] - bcum[..., None, :] + ii[..., None, :]
        tri = jnp.tril(jnp.ones((t, t), bool))
        dmat = jnp.where(tri, dmat, NEG)
        inter_log = bcum + m_prev[..., None]             # (B,H,t)
        m_row = jnp.maximum(jnp.max(dmat, axis=-1), inter_log)
        m_row = jnp.maximum(m_row, -m_prev[..., None] * 0 - 50.0)  # floor
        w_intra = jnp.exp(dmat - m_row[..., None])       # (B,H,t,t)
        w_inter = jnp.exp(inter_log - m_row)             # (B,H,t)
        scores = jnp.einsum("bhtk,bhsk->bhts", qq.astype(f32), kk.astype(f32))
        h_intra = jnp.einsum("bhts,bhsv->bhtv", w_intra * scores, vv.astype(f32))
        h_inter = jnp.einsum("bhtk,bhkv->bhtv", qq.astype(f32), c_prev) * w_inter[..., None]
        n_comb = (jnp.einsum("bhts,bhsk->bhtk", w_intra, kk.astype(f32))
                  + n_prev[:, :, None, :] * w_inter[..., None])
        denom = jnp.maximum(jnp.abs(jnp.einsum("bhtk,bhtk->bht",
                                               n_comb, qq.astype(f32))),
                            jnp.exp(-m_row))
        h_t = (h_intra + h_inter) / denom[..., None]

        # chunk-end carry
        m_new = jnp.maximum(g_tot + m_prev,
                            jnp.max(g_tot[..., None] - bcum + ii, axis=-1))
        src_w = jnp.exp(g_tot[..., None] - bcum + ii - m_new[..., None])
        c_new = (jnp.exp(g_tot + m_prev - m_new)[..., None, None] * c_prev
                 + jnp.einsum("bhs,bhsk,bhsv->bhkv", src_w,
                              kk.astype(f32), vv.astype(f32)))
        n_new = (jnp.exp(g_tot + m_prev - m_new)[..., None] * n_prev
                 + jnp.einsum("bhs,bhsk->bhk", src_w, kk.astype(f32)))
        return (c_new, n_new, m_new), h_t

    (c_f, n_f, m_f), hs = jax.lax.scan(scan_fn, (state.c, state.n, state.m),
                                       (qc, kc, vc, ic, fc))
    # (nck, B, H, t, hv) -> (B, S, H*hv)
    h = hs.transpose(1, 0, 3, 2, 4).reshape(b, s, n_heads * hv)
    o = jax.nn.sigmoid((x @ p["w_og"]).astype(f32))
    h = _group_norm(h, p["gn_scale"], n_heads) * o
    return (h.astype(btype) @ p["w_out"]), MLSTMState(c_f, n_f, m_f)


def mlstm_step(p, x, n_heads: int, qk: int, hv: int, state: MLSTMState):
    """x: (B, 1, D) -> (y, state).  The per-step oracle recurrence."""
    btype = x.dtype
    b = x.shape[0]
    q, k, v, i_g, f_g = _mlstm_qkvif(p, x, n_heads, qk, hv)
    qq, kk, vv = (a[:, :, 0].astype(f32) for a in (q, k, v))   # (B,H,dim)
    ii, ff = i_g[:, :, 0], f_g[:, :, 0]                        # (B,H)
    lf = jax.nn.log_sigmoid(ff)
    m_new = jnp.maximum(lf + state.m, ii)
    decay = jnp.exp(lf + state.m - m_new)
    inject = jnp.exp(ii - m_new)
    c_new = decay[..., None, None] * state.c + inject[..., None, None] * (
        kk[..., :, None] * vv[..., None, :])
    n_new = decay[..., None] * state.n + inject[..., None] * kk
    num = jnp.einsum("bhkv,bhk->bhv", c_new, qq)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, qq)),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(b, 1, n_heads * hv)
    o = jax.nn.sigmoid((x @ p["w_og"]).astype(f32))
    h = _group_norm(h, p["gn_scale"], n_heads) * o
    return (h.astype(btype) @ p["w_out"]), MLSTMState(c_new, n_new, m_new)


def init_mlstm_state(batch: int, n_heads: int, qk: int, hv: int) -> MLSTMState:
    return MLSTMState(c=jnp.zeros((batch, n_heads, qk, hv), f32),
                      n=jnp.zeros((batch, n_heads, qk), f32),
                      m=jnp.full((batch, n_heads), 0.0, f32))


# ------------------------------------------------------------------- sLSTM
def init_slstm_params(rng, d_model: int, n_heads: int, hd: int, dtype):
    ks = jax.random.split(rng, 10)
    dh = n_heads * hd
    p = {"gn_scale": jnp.zeros((dh,), f32),
         "w_out": dense_init(ks[8], dh, d_model, dtype,
                             scale=1.0 / math.sqrt(2.0))}
    for i, g in enumerate(("z", "i", "f", "o")):
        p[f"w_{g}"] = dense_init(ks[i], d_model, dh, dtype)
        p[f"r_{g}"] = (jax.random.normal(ks[4 + i], (n_heads, hd, hd), f32)
                       / math.sqrt(hd)).astype(dtype)
        p[f"b_{g}"] = jnp.zeros((dh,), f32)
    return p


def _slstm_cell(p, xw, state: SLSTMState, n_heads: int, hd: int):
    """xw: dict gate -> (B, H, hd) input contributions (x @ w_g)."""
    def rec(g):
        return jnp.einsum("bhd,hde->bhe", state.h.astype(p[f"r_{g}"].dtype),
                          p[f"r_{g}"]).astype(f32)
    b = state.h.shape[0]
    bias = {g: p[f"b_{g}"].reshape(n_heads, hd) for g in "zifo"}
    z = jnp.tanh(xw["z"] + rec("z") + bias["z"])
    i_t = xw["i"] + rec("i") + bias["i"]
    f_t = xw["f"] + rec("f") + bias["f"]
    o = jax.nn.sigmoid(xw["o"] + rec("o") + bias["o"])
    lf = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(lf + state.m, i_t)
    decay = jnp.exp(lf + state.m - m_new)
    inject = jnp.exp(i_t - m_new)
    c_new = decay * state.c + inject * z
    n_new = decay * state.n + inject
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return SLSTMState(c_new, n_new, m_new, h_new)


def slstm_sequence(p, x, n_heads: int, hd: int, state: SLSTMState | None = None):
    btype = x.dtype
    b, s, d = x.shape
    if state is None:
        state = init_slstm_state(b, n_heads, hd)
    xw = {g: (x @ p[f"w_{g}"]).astype(f32).reshape(b, s, n_heads, hd)
          for g in "zifo"}
    xw_t = jnp.stack([xw[g] for g in "zifo"], axis=0).transpose(2, 0, 1, 3, 4)

    def step(st, xin):
        gates = {g: xin[i] for i, g in enumerate("zifo")}
        st2 = _slstm_cell(p, gates, st, n_heads, hd)
        return st2, st2.h

    st_f, hs = jax.lax.scan(step, state, xw_t)               # hs (S,B,H,hd)
    h = hs.transpose(1, 0, 2, 3).reshape(b, s, n_heads * hd)
    h = _group_norm(h, p["gn_scale"], n_heads)
    return (h.astype(btype) @ p["w_out"]), st_f


def slstm_step(p, x, n_heads: int, hd: int, state: SLSTMState):
    btype = x.dtype
    b = x.shape[0]
    xw = {g: (x[:, 0] @ p[f"w_{g}"]).astype(f32).reshape(b, n_heads, hd)
          for g in "zifo"}
    st = _slstm_cell(p, xw, state, n_heads, hd)
    h = st.h.reshape(b, 1, n_heads * hd)
    h = _group_norm(h, p["gn_scale"], n_heads)
    return (h.astype(btype) @ p["w_out"]), st


def init_slstm_state(batch: int, n_heads: int, hd: int) -> SLSTMState:
    z = jnp.zeros((batch, n_heads, hd), f32)
    return SLSTMState(c=z, n=z, m=z, h=z)
