"""Model substrate: unified LM API over the 10 assigned architectures."""
from .config import InputShape, ModelConfig, MoESpec, SHAPES
from .model import LM

__all__ = ["InputShape", "ModelConfig", "MoESpec", "SHAPES", "LM"]
