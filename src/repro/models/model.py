"""Unified LM API over the block-stack patterns.

    lm = LM(cfg)
    params = lm.init(rng)                       # or jax.eval_shape for dry-run
    loss, metrics = lm.loss(params, batch)      # training objective
    logits, caches = lm.prefill(params, batch)  # serve: context ingestion
    logits, caches = lm.decode_step(params, caches, token, position)

Batch dict keys:
  tokens      (B, S) int32          decoder token ids
  embeds      (B, S, D) bf16        precomputed frontend embeddings (vlm/audio
                                    stubs) — used instead of tokens
  enc_embeds  (B, S_enc, D) bf16    encoder input (enc-dec archs)
  positions   (B, S) or (3, B, S)   optional; default arange (M-RoPE archs
                                    take the 3D form)

Cross-entropy is computed in sequence chunks (never materializing the full
(B, S, V) logits) with the vocab dim sharded over the ``model`` mesh axis.
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..distributed.context import constrain, pin_rows
from .blocks import apply_stack, init_block_cache, init_stack
from .config import ModelConfig
from .layers import dtype_of, f32, rms_norm, rope_angles

LOSS_CHUNK = 128


class LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ init
    def init(self, rng) -> dict:
        cfg = self.cfg
        dtype = dtype_of(cfg.dtype)
        n_stacks = len(cfg.pattern) + len(cfg.enc_pattern)
        ks = jax.random.split(rng, n_stacks + 3)
        params: dict[str, Any] = {
            "embed": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model), f32)
                      / math.sqrt(cfg.d_model)).astype(dtype),
            "final_norm": jnp.zeros((cfg.d_model,), f32),
            "stacks": [init_stack(ks[i + 1], kind, n, cfg)
                       for i, (kind, n) in enumerate(cfg.pattern)],
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = (
                jax.random.normal(ks[n_stacks + 1],
                                  (cfg.d_model, cfg.vocab_size), f32)
                / math.sqrt(cfg.d_model)).astype(dtype)
        if cfg.enc_pattern:
            off = len(cfg.pattern)
            params["enc_stacks"] = [
                init_stack(ks[off + i + 1], kind, n, cfg)
                for i, (kind, n) in enumerate(cfg.enc_pattern)]
            params["enc_norm"] = jnp.zeros((cfg.d_model,), f32)
        return params

    # ------------------------------------------------------------- embedding
    def _embed_in(self, params, batch) -> jax.Array:
        cfg = self.cfg
        if batch.get("embeds") is not None:
            return batch["embeds"].astype(dtype_of(cfg.dtype))
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        return x * cfg.embed_scale

    def _angles(self, positions, seq: int, batch_dim: int):
        cfg = self.cfg
        if not any(k not in ("mlstm", "slstm") for k, _ in
                   tuple(cfg.pattern) + tuple(cfg.enc_pattern)):
            return None  # pure-recurrent arch: no RoPE anywhere
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32),
                                         (batch_dim, seq))
            if cfg.mrope_sections:
                positions = jnp.broadcast_to(positions, (3, batch_dim, seq))
        return rope_angles(positions, cfg.hd, cfg.rope_theta,
                           cfg.mrope_sections)

    def _encode(self, params, batch, ctx_base) -> Optional[jax.Array]:
        cfg = self.cfg
        if not cfg.enc_pattern:
            return None
        xe = batch["enc_embeds"].astype(dtype_of(cfg.dtype))
        be, se, _ = xe.shape
        enc_ctx = dict(ctx_base)
        enc_ctx["angles"] = self._angles(None, se, be)
        for stack, (kind, n) in zip(params["enc_stacks"], cfg.enc_pattern):
            xe, _ = apply_stack(kind, cfg, stack, xe, enc_ctx, None, "train")
        return rms_norm(xe, params["enc_norm"], cfg.norm_eps)

    def _head(self, params, x) -> jax.Array:
        cfg = self.cfg
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        return (x @ head) * cfg.logit_scale

    # --------------------------------------------------------------- forward
    def forward(self, params, batch, mode: str = "train", caches=None,
                position=None, reserve: int = 0):
        """Returns (hidden (B,S,D), new_caches_or_None)."""
        cfg = self.cfg
        # the embedding lookup's output sharding is ambiguous under a mesh
        # (vocab-parallel table vs row-split tokens): pin it to the serving
        # context's row split so GSPMD starts every stack from the batch
        # split, then apply any launcher-imposed activation spec
        x = constrain(pin_rows(self._embed_in(params, batch)))
        b, s, _ = x.shape
        ctx: dict[str, Any] = {"reserve": reserve}
        if mode == "decode":
            pos_arr = jnp.full((b, 1), position, jnp.int32)
            if cfg.mrope_sections:
                pos_arr = jnp.broadcast_to(pos_arr, (3, b, 1))
            ctx["angles"] = self._angles(pos_arr, 1, b)
            ctx["position"] = position
        elif mode == "prefill_cont":
            # continued prefill: the new tokens sit at absolute positions
            # [cached_len, cached_len + s); cached length is static from the
            # cache shape (stacked KVCache leaves are (n, B, S_cached, KV, hd))
            pos = batch.get("positions")
            if pos is None:
                start = caches[0].k.shape[2]
                pos = jnp.broadcast_to(
                    start + jnp.arange(s, dtype=jnp.int32), (b, s))
            ctx["angles"] = self._angles(pos, s, b)
        else:
            ctx["angles"] = self._angles(batch.get("positions"), s, b)
        enc_out = self._encode(params, batch, ctx) if mode != "decode" else None
        if enc_out is not None:
            ctx["enc_out"] = enc_out

        new_caches = []
        for i, (stack, (kind, n)) in enumerate(zip(params["stacks"], cfg.pattern)):
            c = caches[i] if caches is not None else None
            x, c2 = apply_stack(kind, cfg, stack, x, ctx, c, mode)
            new_caches.append(c2)
        return x, (new_caches if mode != "train" else None)

    # ------------------------------------------------------------------ loss
    def loss(self, params, batch):
        """Next-token CE (enc-dec: over decoder tokens), chunked over S."""
        cfg = self.cfg
        x, _ = self.forward(params, batch, mode="train")
        tokens = batch["tokens"]
        b, s = tokens.shape
        inputs_h = x[:, :-1]
        targets = tokens[:, 1:]
        sl = s - 1
        chunk = min(LOSS_CHUNK, sl)
        n_chunks = sl // chunk
        rem = sl - n_chunks * chunk

        head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])

        def ce(h, t):
            logits = (h @ head).astype(f32) * cfg.logit_scale
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
            return jnp.sum(logz - gold)

        def body(tot, i):
            h = jax.lax.dynamic_slice_in_dim(inputs_h, i * chunk, chunk, axis=1)
            t = jax.lax.dynamic_slice_in_dim(targets, i * chunk, chunk, axis=1)
            return tot + ce(h, t), None

        total, _ = jax.lax.scan(body, jnp.zeros((), f32), jnp.arange(n_chunks),
                                unroll=True if cfg.scan_unroll else 1)
        if rem:
            total = total + ce(inputs_h[:, n_chunks * chunk:],
                               targets[:, n_chunks * chunk:])
        ntok = b * sl
        loss = total / ntok
        return loss, {"loss": loss, "tokens": jnp.asarray(ntok, f32)}

    # ------------------------------------------------------------- serving
    def init_caches(self, batch_size: int, cache_len: int, enc_len: int = 0):
        cfg = self.cfg
        caches = []
        for kind, n in cfg.pattern:
            one = init_block_cache(kind, cfg, batch_size, cache_len, enc_len)
            caches.append(jax.tree.map(
                lambda leaf: jnp.broadcast_to(leaf[None], (n,) + leaf.shape), one))
        return caches

    def prefill(self, params, batch, reserve: int = 0):
        """Ingest the full context; returns (last_logits (B, V), caches).
        ``reserve`` extra full-attention cache slots for subsequent decode."""
        x, caches = self.forward(params, batch, mode="prefill", reserve=reserve)
        logits = self._head(params, x[:, -1:, :])[:, 0]
        return logits, caches

    def prefill_cont(self, params, caches, batch, reserve: int = 0):
        """Continue a prefill on top of cached KV (prefix-KV reuse): ingest
        ``batch`` (S new tokens per row) at absolute positions starting at
        the cached length; returns (last_logits (B, V), caches over the full
        prefix+suffix sequence).  ``caches`` must come from a prior
        :meth:`prefill` with ``reserve=0`` (exact-length full-attention
        caches); batch-1 caches broadcast over the batch dim — the
        shared-prefix case.  Pure-'attn' decoder stacks with einsum/bf16
        attention only — anything whose monolithic prefill is not a pure
        per-row function (MoE capacity ranking, qchunk reduction order,
        recurrent state) raises NotImplementedError instead of silently
        breaking the chunked-prefill-equals-monolithic contract."""
        x, caches = self.forward(params, batch, mode="prefill_cont",
                                 caches=caches, reserve=reserve)
        logits = self._head(params, x[:, -1:, :])[:, 0]
        return logits, caches

    def decode_step(self, params, caches, token_or_embed, position):
        """One token: token ids (B, 1) int32 or embeds (B, 1, D).
        Returns (logits (B, V), caches)."""
        if token_or_embed.dtype in (jnp.int32, jnp.int64):
            batch = {"tokens": token_or_embed}
        else:
            batch = {"embeds": token_or_embed}
        x, caches = self.forward(params, batch, mode="decode", caches=caches,
                                 position=position)
        logits = self._head(params, x)[:, 0]
        return logits, caches

    def decode_step_paged(self, params, caches, tokens, positions, tables,
                          *, block_size: int, impl: str = "dense"):
        """One decode token per row against the block-paged KV pool.

        caches: list (one per stack) of :class:`~.layers.PagedKV` with leaves
        (n_layers, num_blocks, block_size, KV, hd) — the SHARED arena, not
        per-sequence storage; tokens (B, 1) int32; positions (B,) int32
        per-row absolute positions (continuous batching mixes admission
        times, so there is no shared scalar position); tables (B, MAXB)
        int32 per-row block tables (0-padded — block 0 is the dummy block).

        Returns (logits (B, V), caches with the step's K/V written).
        ``impl`` picks the attention implementation: ``"dense"`` (default)
        is the gather+attend XLA path, bit-identical per row to
        :meth:`decode_step` over a dense ring cache holding the same tokens
        (tests/test_paged_decode.py); ``"kernel"`` runs the Pallas paged
        flash-decode (kernels/paged_attention.py) whose online-softmax
        reduction order trades bitwise identity for allclose (the engine's
        ``paged_kernel`` deployment switch).  Pure full-attention
        token-input stacks only."""
        cfg = self.cfg
        assert cfg.input_mode == "tokens" and not cfg.mrope_sections, (
            "paged decode supports token-input, non-M-RoPE archs only")
        x = pin_rows(jnp.take(params["embed"], tokens, axis=0)
                     * cfg.embed_scale)
        b = tokens.shape[0]
        ctx: dict[str, Any] = {
            "angles": self._angles(positions[:, None], 1, b),
            "paged_tables": tables, "paged_positions": positions,
            "paged_block_size": block_size, "paged_impl": impl,
        }
        new_caches = []
        for stack, c, (kind, n) in zip(params["stacks"], caches, cfg.pattern):
            x, c2 = apply_stack(kind, cfg, stack, x, ctx, c, "decode_paged")
            new_caches.append(c2)
        logits = self._head(params, x)[:, 0]
        return logits, new_caches

    def score_hidden(self, params, batch):
        """Mean-pooled final hidden state — the scoring read-out used by the
        ModelOracle's pointwise path."""
        x, _ = self.forward(params, batch, mode="train")
        return jnp.mean(x.astype(f32), axis=1)
