"""Shared neural-net layers: RMSNorm, RoPE / M-RoPE, GQA attention (full /
sliding-window / cross / decode-with-cache), SwiGLU.

Conventions:
 * activations bf16, softmax and norms accumulate in fp32;
 * attention caches are rings: ``slot = position % cache_len`` with an
   absolute-position array ``pos`` per slot (-1 = empty), which makes full and
   sliding-window caches the same code path (a full cache is a ring that never
   wraps);
 * all shapes (B, S, ...); heads split as (B, S, n_heads, head_dim).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

f32 = jnp.float32


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


# --------------------------------------------------------------- init helpers
def dense_init(rng, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale / math.sqrt(d_in)
    return (jax.random.normal(rng, (d_in, d_out), f32) * std).astype(dtype)


def stacked_dense_init(rng, n: int, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale / math.sqrt(d_in)
    return (jax.random.normal(rng, (n, d_in, d_out), f32) * std).astype(dtype)


# --------------------------------------------------------------------- norms
def rms_norm(x, scale, eps: float = 1e-5):
    xf = x.astype(f32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(f32))).astype(x.dtype)


# ---------------------------------------------------------------------- RoPE
def rope_angles(positions, rot_dim: int, theta: float, sections=()):
    """positions: (B, S) int32, or (3, B, S) for M-RoPE with ``sections``
    (t, h, w) frequency-group sizes summing to rot_dim // 2.
    Returns (B, S, rot_dim//2) fp32 angles."""
    half = rot_dim // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=f32) / half))
    if positions.ndim == 2:
        return positions.astype(f32)[..., None] * inv_freq  # (B, S, half)
    assert positions.ndim == 3 and sections, "M-RoPE needs (3,B,S) + sections"
    sec_ids = jnp.concatenate([
        jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)
    ])  # (half,)
    pos = jnp.take(positions, sec_ids, axis=0)        # (half, B, S)
    pos = jnp.moveaxis(pos, 0, -1).astype(f32)        # (B, S, half)
    return pos * inv_freq


def apply_rope(x, angles):
    """x: (B, S, N, hd); angles: (B, S, hd//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ----------------------------------------------------------------- attention
NEG_INF = -1e30


def gqa_attention(q, k, v, mask):
    """Reference attention: q (B, Sq, H, hd); k,v (B, Sk, KV, hd); mask
    broadcastable to (B, KV, G, Sq, Sk).  Materializes fp32 scores."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(f32)
    scores = scores / math.sqrt(hd)
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return out.reshape(b, sq, h, hd)


def gqa_attention_bf16(q, k, v, mask):
    """bf16 score storage, single-pass softmax.

    v1 of this function upcast scores to fp32 around max/exp separately,
    which MATERIALIZED extra fp32 copies and made HBM traffic 16% WORSE than
    the fp32 baseline (§Perf C1, refuted).  v2 keeps the whole softmax in
    bf16 (max is exact in any dtype; exp/sum lose <1e-2 relative, validated
    against the fp32 path in tests), so the (Sq, Sk) transient is touched in
    2-byte precision end to end."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = (q / math.sqrt(hd)).reshape(b, sq, kv, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k)           # bf16
    scores = jnp.where(mask, scores, jnp.asarray(-3e38, scores.dtype))
    w = jax.nn.softmax(scores, axis=-1)                       # bf16 softmax
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return out.reshape(b, sq, h, hd)


def gqa_attention_qchunk(q, k, v, *, causal: bool, window: int,
                         chunk: int = 512, unroll: bool = False):
    """Flash-style query blocking at the XLA level (the dry-run-visible proxy
    for the Pallas flash_attention kernel): scan over query blocks so the
    score transient is (chunk, Sk) not (Sq, Sk), with bf16 storage.  For
    sliding-window attention each query block additionally SLICES its live
    KV range — O(S*(window+chunk)) flops/bytes instead of O(S^2).
    Self-attention only (Sq == Sk)."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    c = min(chunk, sq)
    while sq % c:
        c -= 1
    n_blocks = sq // c
    qg = (q / math.sqrt(hd)).reshape(b, sq, kv, g, hd)
    qb = qg.reshape(b, n_blocks, c, kv, g, hd).transpose(1, 0, 2, 3, 4, 5)

    # static live-KV width per block: window + chunk (rounded to full array)
    wlen = min(sq, window + c) if window else sq
    rows_base = jnp.arange(c)

    def block(_, args):
        qi, qc = args
        rows = qi * c + rows_base                         # absolute q rows
        if window and wlen < sq:
            start = jnp.clip(qi * c + c - wlen, 0, sq - wlen)
            ks = jax.lax.dynamic_slice_in_dim(k, start, wlen, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, start, wlen, axis=1)
            cols = start + jnp.arange(wlen)               # absolute kv cols
        else:
            ks, vs = k, v
            cols = jnp.arange(sq)
        m = jnp.ones((c, cols.shape[0]), bool)
        if causal:
            m &= cols[None, :] <= rows[:, None]
        if window:
            m &= cols[None, :] > rows[:, None] - window
        scores = jnp.einsum("bqkgd,bskd->bkgqs", qc, ks)   # (B,KV,G,c,wlen)
        scores = jnp.where(m[None, None, None], scores,
                           jnp.asarray(-3e38 if scores.dtype == jnp.bfloat16
                                       else NEG_INF, scores.dtype))
        w = jax.nn.softmax(scores, axis=-1)                # native-dtype
        out = jnp.einsum("bkgqs,bskd->bqkgd", w, vs)       # (B,c,KV,G,hd)
        return None, out

    _, outs = jax.lax.scan(block, None, (jnp.arange(n_blocks), qb),
                           unroll=True if unroll else 1)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, h * hd)
    return out.reshape(b, sq, h, hd)


def causal_mask(sq: int, sk: int, window: int = 0, q_offset: int = 0):
    """(1, 1, 1, sq, sk) bool; window=0 => unbounded causal."""
    qi = jnp.arange(sq)[:, None] + q_offset
    kj = jnp.arange(sk)[None, :]
    m = kj <= qi
    if window:
        m &= kj > qi - window
    return m[None, None, None]


def full_mask(sq: int, sk: int):
    return jnp.ones((1, 1, 1, sq, sk), dtype=bool)


# ------------------------------------------------------------------ KV cache
class KVCache(NamedTuple):
    """Ring cache.  k/v: (B, S_c, KV, hd); pos: (S_c,) absolute positions,
    -1 where empty.  Full attention uses S_c = max_len (ring never wraps);
    sliding window uses S_c = window."""

    k: jax.Array
    v: jax.Array
    pos: jax.Array

    @staticmethod
    def init(batch: int, cache_len: int, n_kv: int, hd: int, dtype) -> "KVCache":
        return KVCache(
            k=jnp.zeros((batch, cache_len, n_kv, hd), dtype),
            v=jnp.zeros((batch, cache_len, n_kv, hd), dtype),
            pos=jnp.full((cache_len,), -1, jnp.int32),
        )

    @staticmethod
    def from_prefill(k, v, window: int = 0, reserve: int = 0) -> "KVCache":
        """Build a cache from prefill-computed k/v (B, S, KV, hd).  For SWA,
        keep only the trailing ``window`` positions, ring-placed.  For full
        attention, allocate ``reserve`` extra slots so subsequent decode
        positions never wrap the ring."""
        b, s, n_kv, hd = k.shape
        if window and window < s:
            slots = jnp.arange(s - window, s) % window
            kr = jnp.zeros((b, window, n_kv, hd), k.dtype).at[:, slots].set(k[:, s - window:])
            vr = jnp.zeros((b, window, n_kv, hd), v.dtype).at[:, slots].set(v[:, s - window:])
            pr = jnp.full((window,), -1, jnp.int32).at[slots].set(jnp.arange(s - window, s))
            return KVCache(kr, vr, pr)
        if window and window >= s:
            kr = jnp.pad(k, ((0, 0), (0, window - s), (0, 0), (0, 0)))
            vr = jnp.pad(v, ((0, 0), (0, window - s), (0, 0), (0, 0)))
            pr = jnp.concatenate([jnp.arange(s, dtype=jnp.int32),
                                  jnp.full((window - s,), -1, jnp.int32)])
            return KVCache(kr, vr, pr)
        if reserve:
            k = jnp.pad(k, ((0, 0), (0, reserve), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, reserve), (0, 0), (0, 0)))
            pr = jnp.concatenate([jnp.arange(s, dtype=jnp.int32),
                                  jnp.full((reserve,), -1, jnp.int32)])
            return KVCache(k, v, pr)
        return KVCache(k, v, jnp.arange(s, dtype=jnp.int32))

    def update(self, k_new, v_new, position) -> "KVCache":
        """Insert one token (B, 1, KV, hd) at absolute ``position`` (scalar)."""
        s_c = self.k.shape[1]
        slot = position % s_c
        k = jax.lax.dynamic_update_slice(self.k, k_new, (0, slot, 0, 0))
        v = jax.lax.dynamic_update_slice(self.v, v_new, (0, slot, 0, 0))
        pos = jax.lax.dynamic_update_slice(self.pos, position[None].astype(jnp.int32), (slot,))
        return KVCache(k, v, pos)

    def decode_mask(self):
        """(1, 1, 1, 1, S_c) validity mask: ring invariant guarantees every
        non-empty slot is in-window."""
        return (self.pos >= 0)[None, None, None, None, :]


# ------------------------------------------------------------- paged KV pool
class PagedKV(NamedTuple):
    """One layer-stack's slice of the block-paged KV arena (see
    serving/kv_pool.py for the allocator that owns block lifetimes).

    k/v: (num_blocks, block_size, KV, hd) — block 0 is the permanent dummy
    target for padded block-table slots and bucket-dummy rows; it is never
    allocated, so garbage written there is never read unmasked.  A sequence
    occupies an ordered run of blocks: block ``i`` of its table holds
    absolute positions ``[i*block_size, (i+1)*block_size)``, which keeps the
    gathered key order identical to a dense ring cache's."""

    k: jax.Array
    v: jax.Array


def paged_decode_attention_dense(q, paged: PagedKV, tables, positions,
                                 block_size: int):
    """Gather-then-attend paged decode: one query token per row against the
    row's block run.  Writes the step's K/V into ``tables[row, pos // bs]``
    slot ``pos % bs``, gathers each row's run into a dense (B, MAXB*bs)
    view, and runs the SAME fp32 :func:`gqa_attention` as the dense ring
    path.  Positions ``>= pos+1`` are masked to NEG_INF, whose softmax
    weights are exactly 0.0 in fp32 — so logits are bit-identical to the
    dense decode whatever the table padding or pool size (asserted in
    tests/test_paged_decode.py; DESIGN.md "Paged KV pool").

    q/k/v of the new token: (B, 1, ·, hd); tables (B, MAXB) int32;
    positions (B,) int32 absolute write position per row."""
    q_new, k_new, v_new = q
    b = k_new.shape[0]
    blk = tables[jnp.arange(b), positions // block_size]
    slot = positions % block_size
    k_pool = paged.k.at[blk, slot].set(k_new[:, 0])
    v_pool = paged.v.at[blk, slot].set(v_new[:, 0])
    maxb = tables.shape[1]
    flat = tables.reshape(-1)
    kg = jnp.take(k_pool, flat, axis=0).reshape(b, maxb * block_size,
                                                *k_pool.shape[2:])
    vg = jnp.take(v_pool, flat, axis=0).reshape(b, maxb * block_size,
                                                *v_pool.shape[2:])
    valid = (jnp.arange(maxb * block_size, dtype=jnp.int32)[None, :]
             <= positions[:, None])
    out = gqa_attention(q_new, kg, vg, valid[:, None, None, None, :])
    return out, PagedKV(k_pool, v_pool)


# -------------------------------------------------------------------- SwiGLU
def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down
