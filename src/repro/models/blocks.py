"""Block kinds and scanned stacks.

Every model is a sequence of homogeneous *stacks* (see config.py); a stack of
``n`` layers is executed as ``jax.lax.scan`` over stacked parameters with the
activation as carry and per-layer caches as xs/ys.  All kinds share one
signature::

    apply_block(kind, cfg, p, x, ctx, cache, mode) -> (x', cache')

``mode``: "train" (no cache), "prefill" (emit cache), "decode" (one token,
read+update cache).  ``ctx`` carries rope angles, encoder output, and the
scalar decode position.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (KVCache, PagedKV, apply_rope, causal_mask, dense_init,
                     dtype_of, f32, full_mask, gqa_attention,
                     paged_decode_attention_dense, rms_norm, swiglu)
from .moe import init_moe_params, moe_ffn
from .ssm import (init_ssm_params, init_ssm_state, ssm_prefill_state,
                  ssm_sequence, ssm_step)
from .xlstm import (init_mlstm_params, init_mlstm_state, init_slstm_params,
                    init_slstm_state, mlstm_sequence, mlstm_step,
                    slstm_sequence, slstm_step)

WINDOWED = {"swa", "moe_swa", "hymba_l"}
HAS_FFN = {"attn", "swa", "moe", "moe_swa", "hymba_g", "hymba_l", "enc", "xdec"}


# ----------------------------------------------------------------- init: one
def _init_attn(rng, cfg: ModelConfig, dtype, prefix=""):
    ks = jax.random.split(rng, 4)
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    depth_scale = 1.0 / math.sqrt(2.0 * max(cfg.decoder_layers(), 1))
    return {
        f"{prefix}wq": dense_init(ks[0], d, h * hd, dtype),
        f"{prefix}wk": dense_init(ks[1], d, kv * hd, dtype),
        f"{prefix}wv": dense_init(ks[2], d, kv * hd, dtype),
        f"{prefix}wo": dense_init(ks[3], h * hd, d, dtype, scale=depth_scale),
    }


def _init_ffn(rng, cfg: ModelConfig, dtype):
    ks = jax.random.split(rng, 3)
    d, f = cfg.d_model, cfg.d_ff
    depth_scale = 1.0 / math.sqrt(2.0 * max(cfg.decoder_layers(), 1))
    return {
        "w_gate": dense_init(ks[0], d, f, dtype),
        "w_up": dense_init(ks[1], d, f, dtype),
        "w_down": dense_init(ks[2], f, d, dtype, scale=depth_scale),
    }


def init_block(rng, kind: str, cfg: ModelConfig):
    dtype = dtype_of(cfg.dtype)
    d = cfg.d_model
    ks = jax.random.split(rng, 6)
    p: dict[str, Any] = {"norm1": jnp.zeros((d,), f32)}
    if kind in ("attn", "swa", "enc"):
        p.update(_init_attn(ks[0], cfg, dtype))
        p["norm2"] = jnp.zeros((d,), f32)
        p["ffn"] = _init_ffn(ks[1], cfg, dtype)
    elif kind in ("moe", "moe_swa"):
        p.update(_init_attn(ks[0], cfg, dtype))
        p["norm2"] = jnp.zeros((d,), f32)
        p["moe"] = init_moe_params(ks[1], d, cfg.d_ff, cfg.moe, dtype)
    elif kind in ("hymba_g", "hymba_l"):
        p.update(_init_attn(ks[0], cfg, dtype))
        p["ssm"] = init_ssm_params(ks[1], d, cfg.d_inner, cfg.ssm_state,
                                   cfg.ssm_conv_width, dtype)
        p["fuse_a"] = jnp.zeros((d,), f32)
        p["fuse_s"] = jnp.zeros((d,), f32)
        p["norm2"] = jnp.zeros((d,), f32)
        p["ffn"] = _init_ffn(ks[2], cfg, dtype)
    elif kind == "xdec":
        p.update(_init_attn(ks[0], cfg, dtype))
        p["norm_x"] = jnp.zeros((d,), f32)
        p.update(_init_attn(ks[1], cfg, dtype, prefix="x_"))
        p["norm2"] = jnp.zeros((d,), f32)
        p["ffn"] = _init_ffn(ks[2], cfg, dtype)
    elif kind == "mlstm":
        p.update(init_mlstm_params(ks[0], d, cfg.n_heads, cfg.qk, cfg.hd, dtype))
    elif kind == "slstm":
        p.update(init_slstm_params(ks[0], d, cfg.n_heads, cfg.hd, dtype))
    else:
        raise ValueError(f"unknown block kind {kind}")
    return p


def init_stack(rng, kind: str, n: int, cfg: ModelConfig):
    return jax.vmap(lambda r: init_block(r, kind, cfg))(jax.random.split(rng, n))


# ------------------------------------------------------------------- caches
def init_block_cache(kind: str, cfg: ModelConfig, batch: int, cache_len: int,
                     enc_len: int = 0):
    """Cache pytree for ONE layer of ``kind`` (stacked by vmap for a stack)."""
    dtype = dtype_of(cfg.dtype)
    kv, hd = cfg.n_kv_heads, cfg.hd
    if kind in ("attn", "moe"):
        return KVCache.init(batch, cache_len, kv, hd, dtype)
    if kind in ("swa", "moe_swa"):
        return KVCache.init(batch, min(cfg.sliding_window, cache_len), kv, hd, dtype)
    if kind == "hymba_g":
        return (KVCache.init(batch, cache_len, kv, hd, dtype),
                init_ssm_state(batch, cfg.d_inner, cfg.ssm_state,
                               cfg.ssm_conv_width, dtype))
    if kind == "hymba_l":
        return (KVCache.init(batch, min(cfg.sliding_window, cache_len), kv, hd, dtype),
                init_ssm_state(batch, cfg.d_inner, cfg.ssm_state,
                               cfg.ssm_conv_width, dtype))
    if kind == "xdec":
        return (KVCache.init(batch, cache_len, kv, hd, dtype),
                jnp.zeros((batch, enc_len, kv, hd), dtype),   # cross K
                jnp.zeros((batch, enc_len, kv, hd), dtype))   # cross V
    if kind == "mlstm":
        return init_mlstm_state(batch, cfg.n_heads, cfg.qk, cfg.hd)
    if kind == "slstm":
        return init_slstm_state(batch, cfg.n_heads, cfg.hd)
    if kind == "enc":
        return ()
    raise ValueError(kind)


# ---------------------------------------------------------------- attention
def _qkv(p, x, cfg: ModelConfig, angles, prefix=""):
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p[f"{prefix}wq"]).reshape(b, s, h, hd)
    k = (x @ p[f"{prefix}wk"]).reshape(b, s, kv, hd)
    v = (x @ p[f"{prefix}wv"]).reshape(b, s, kv, hd)
    if angles is not None:
        q = apply_rope(q, angles)
        k = apply_rope(k, angles)
    return q, k, v


def _attn_seq(p, x, cfg, angles, window: int, bidir: bool = False):
    from .layers import gqa_attention_bf16, gqa_attention_qchunk
    q, k, v = _qkv(p, x, cfg, angles)
    s = x.shape[1]
    if cfg.attn_impl == "qchunk" and not bidir:
        out = gqa_attention_qchunk(q, k, v, causal=True, window=window,
                                   chunk=cfg.attn_chunk,
                                   unroll=cfg.scan_unroll)
    else:
        mask = full_mask(s, s) if bidir else causal_mask(s, s, window)
        fn = gqa_attention_bf16 if cfg.attn_impl in ("bf16", "qchunk") \
            else gqa_attention
        out = fn(q, k, v, mask)
    return out.reshape(*x.shape[:2], -1) @ p["wo"], (k, v)


def _attn_decode(p, x, cfg, angles, cache: KVCache, position):
    q, k, v = _qkv(p, x, cfg, angles)
    cache = cache.update(k, v, position)
    out = gqa_attention(q, cache.k, cache.v, cache.decode_mask())
    return out.reshape(*x.shape[:2], -1) @ p["wo"], cache


def _attn_decode_paged(p, x, cfg, angles, cache: PagedKV, ctx):
    """One decode token per row against the row's block run in the paged KV
    pool.  Each row carries its OWN absolute position (continuous batching
    mixes rows admitted at different times), unlike the lockstep decode's
    shared scalar.  The default dense path is bit-identical to
    :func:`_attn_decode` per row (see layers.paged_decode_attention_dense);
    ``ctx["paged_impl"] == "kernel"`` swaps in the Pallas flash-decode over
    scalar-prefetched block tables (allclose, not bitwise — the engine's
    deployment switch)."""
    qkv = _qkv(p, x, cfg, angles)
    if ctx.get("paged_impl", "dense") == "kernel":
        out, cache = _paged_decode_kernel(qkv, cache, ctx)
    else:
        out, cache = paged_decode_attention_dense(
            qkv, cache, ctx["paged_tables"], ctx["paged_positions"],
            ctx["paged_block_size"])
    # mesh serving (engine shard_context): the gather-through-block-tables
    # leaves the attention output's row sharding ambiguous to GSPMD — the
    # table gather mixes the row-split tables with the block-replicated
    # arena — so re-pin the rows before the output projection
    from ..distributed.context import pin_rows
    a = pin_rows(out.reshape(*x.shape[:2], -1) @ p["wo"])
    return a, cache


def _paged_decode_kernel(qkv, paged: PagedKV, ctx):
    """Pallas flash-decode step: write the new token's K/V into the pool
    (same scatter as the dense path), then attend through the block table
    with kernels.ops.paged_decode_attention.  Valid context length per row
    is position + 1 (the token just written)."""
    from ..kernels.ops import paged_decode_attention
    q_new, k_new, v_new = qkv
    tables = ctx["paged_tables"]
    positions = ctx["paged_positions"]
    bs = ctx["paged_block_size"]
    b = k_new.shape[0]
    blk = tables[jnp.arange(b), positions // bs]
    slot = positions % bs
    k_pool = paged.k.at[blk, slot].set(k_new[:, 0])
    v_pool = paged.v.at[blk, slot].set(v_new[:, 0])
    out = paged_decode_attention(q_new[:, 0], k_pool, v_pool, tables,
                                 positions + 1)
    return out[:, None], PagedKV(k_pool, v_pool)


def _attn_cont(p, x, cfg, angles, cache: KVCache, reserve: int = 0):
    """Continued (chunked) prefill over prepended cached KV — the prefix-KV
    reuse path: the new tokens' queries attend causally over
    ``[cached KV; own KV]`` with absolute query offset = cached length.
    Cached KV may be batch-1 (a shared prefix broadcast over the batch);
    causality makes this exactly the attention each new position would see in
    a monolithic prefill of the full sequence.  Full attention only (the
    ring placement of sliding-window caches is not supported here), and
    einsum/bf16 impls only: qchunk's scan-blocked softmax has a different
    reduction order, so silently substituting bf16 here would break the
    bitwise chunked-prefill-equals-monolithic contract."""
    from .layers import gqa_attention_bf16
    if cfg.attn_impl not in ("einsum", "bf16"):
        raise NotImplementedError(
            f"prefill_cont requires attn_impl 'einsum' or 'bf16', got "
            f"{cfg.attn_impl!r}")
    q, k, v = _qkv(p, x, cfg, angles)
    b, s = x.shape[:2]
    start = cache.k.shape[1]
    kc, vc = cache.k, cache.v
    if kc.shape[0] != b:
        kc = jnp.broadcast_to(kc, (b,) + kc.shape[1:])
        vc = jnp.broadcast_to(vc, (b,) + vc.shape[1:])
    k_all = jnp.concatenate([kc, k], axis=1)
    v_all = jnp.concatenate([vc, v], axis=1)
    mask = causal_mask(s, start + s, 0, q_offset=start)
    fn = gqa_attention_bf16 if cfg.attn_impl == "bf16" else gqa_attention
    out = fn(q, k_all, v_all, mask)
    return (out.reshape(b, s, -1) @ p["wo"],
            KVCache.from_prefill(k_all, v_all, 0, reserve))


def _cross_attn(p, x, cfg, enc_kv=None, enc_out=None):
    """Cross-attention: q from x (no rope), k/v from encoder output (cached
    after prefill)."""
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["x_wq"]).reshape(b, s, h, hd)
    if enc_kv is None:
        se = enc_out.shape[1]
        k = (enc_out @ p["x_wk"]).reshape(b, se, kv, hd)
        v = (enc_out @ p["x_wv"]).reshape(b, se, kv, hd)
    else:
        k, v = enc_kv
    mask = full_mask(s, k.shape[1])
    out = gqa_attention(q, k, v, mask)
    return out.reshape(b, s, -1) @ p["x_wo"], (k, v)


# ------------------------------------------------------------------- apply
def apply_block(kind: str, cfg: ModelConfig, p, x, ctx, cache, mode: str):
    rs = cfg.residual_scale
    eps = cfg.norm_eps
    angles = ctx.get("angles")
    window = cfg.sliding_window if kind in WINDOWED else 0

    def resid(x, branch):
        return x + rs * branch

    new_cache = cache
    if mode in ("prefill_cont", "decode_paged") and kind != "attn":
        # 'moe' is full-attention but its expert capacity is ranked ACROSS
        # the batch, so suffix-only dispatch would differ from a monolithic
        # prefill — reject rather than silently break equivalence; the paged
        # pool likewise only holds full-attention KV (no ring placement,
        # no recurrent state)
        raise NotImplementedError(
            f"{mode} (paged/prefix KV reuse) supports pure full-attention "
            f"'attn' stacks only, got {kind!r}")
    if kind in ("attn", "swa", "moe", "moe_swa", "enc"):
        h = rms_norm(x, p["norm1"], eps)
        if mode == "decode":
            a, new_cache = _attn_decode(p, h, cfg, angles, cache, ctx["position"])
        elif mode == "decode_paged":
            a, new_cache = _attn_decode_paged(p, h, cfg, angles, cache, ctx)
        elif mode == "prefill_cont":
            a, new_cache = _attn_cont(p, h, cfg, angles, cache,
                                      ctx.get("reserve", 0))
        else:
            a, (k, v) = _attn_seq(p, h, cfg, angles, window, bidir=(kind == "enc"))
            if mode == "prefill":
                new_cache = KVCache.from_prefill(k, v, window,
                                                 ctx.get("reserve", 0))
        x = resid(x, a)
        h = rms_norm(x, p["norm2"], eps)
        if kind in ("moe", "moe_swa"):
            from ..distributed.context import get_shard_context
            sctx = get_shard_context()
            if cfg.moe_impl == "sharded" and sctx is not None:
                from .moe import moe_ffn_sharded
                mesh, dp_axes, model_axis = sctx
                x = resid(x, moe_ffn_sharded(p["moe"], h, cfg.moe, mesh,
                                             dp_axes, model_axis))
            else:
                x = resid(x, moe_ffn(p["moe"], h, cfg.moe))
        else:
            x = resid(x, swiglu(h, **p["ffn"]))
        return x, new_cache

    if kind in ("hymba_g", "hymba_l"):
        h = rms_norm(x, p["norm1"], eps)
        if mode == "decode":
            kvc, sst = cache
            a, kvc = _attn_decode(p, h, cfg, angles, kvc, ctx["position"])
            s_out, sst = ssm_step(p["ssm"], h, sst)
            new_cache = (kvc, sst)
        else:
            a, (k, v) = _attn_seq(p, h, cfg, angles, window)
            if mode == "prefill":
                s_out, sst = ssm_prefill_state(p["ssm"], h, chunk=cfg.scan_chunk)
                new_cache = (KVCache.from_prefill(k, v, window,
                                                  ctx.get("reserve", 0)), sst)
            else:
                s_out, _ = ssm_sequence(p["ssm"], h, chunk=cfg.scan_chunk)
        fused = 0.5 * (rms_norm(a, p["fuse_a"], eps) + rms_norm(s_out, p["fuse_s"], eps))
        x = resid(x, fused)
        h = rms_norm(x, p["norm2"], eps)
        x = resid(x, swiglu(h, **p["ffn"]))
        return x, new_cache

    if kind == "xdec":
        h = rms_norm(x, p["norm1"], eps)
        if mode == "decode":
            kvc, xk, xv = cache
            a, kvc = _attn_decode(p, h, cfg, angles, kvc, ctx["position"])
            x = resid(x, a)
            h = rms_norm(x, p["norm_x"], eps)
            a, _ = _cross_attn(p, h, cfg, enc_kv=(xk, xv))
            new_cache = (kvc, xk, xv)
        else:
            a, (k, v) = _attn_seq(p, h, cfg, angles, 0)
            x = resid(x, a)
            h = rms_norm(x, p["norm_x"], eps)
            a, (xk, xv) = _cross_attn(p, h, cfg, enc_out=ctx["enc_out"])
            if mode == "prefill":
                new_cache = (KVCache.from_prefill(k, v, 0, ctx.get("reserve", 0)),
                             xk, xv)
        x = resid(x, a)
        h = rms_norm(x, p["norm2"], eps)
        x = resid(x, swiglu(h, **p["ffn"]))
        return x, new_cache

    if kind == "mlstm":
        h = rms_norm(x, p["norm1"], eps)
        if mode == "decode":
            y, new_cache = mlstm_step(p, h, cfg.n_heads, cfg.qk, cfg.hd, cache)
        else:
            st0 = cache if mode == "prefill" else None
            y, st = mlstm_sequence(p, h, cfg.n_heads, cfg.qk, cfg.hd,
                                   chunk=cfg.scan_chunk, state=st0)
            if mode == "prefill":
                new_cache = st
        return resid(x, y), new_cache

    if kind == "slstm":
        h = rms_norm(x, p["norm1"], eps)
        if mode == "decode":
            y, new_cache = slstm_step(p, h, cfg.n_heads, cfg.hd, cache)
        else:
            st0 = cache if mode == "prefill" else None
            y, st = slstm_sequence(p, h, cfg.n_heads, cfg.hd, state=st0)
            if mode == "prefill":
                new_cache = st
        return resid(x, y), new_cache

    raise ValueError(kind)


# ------------------------------------------------------------------- stacks
def apply_stack(kind: str, cfg: ModelConfig, stack, x, ctx, cache=None,
                mode: str = "train"):
    """Scan ``apply_block`` over a stacked-parameter stack.

    cache: stacked (leading dim n) cache pytree or None.  Returns
    (x, new_cache_stacked_or_None).
    """
    from ..distributed.context import constrain

    def body(xc, layer):
        p, c = layer
        x2, c2 = apply_block(kind, cfg, p, xc, ctx, c, mode)
        return constrain(x2), c2

    if mode == "train" and cfg.remat == "dots":
        # saves weight-matmul outputs but NOT attention scores / other
        # batch-dim dots (flash-attention-compatible activation budget)
        body = jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    elif mode == "train" and cfg.remat == "full":
        body = jax.checkpoint(body)

    unroll = True if cfg.scan_unroll else 1
    if mode in ("decode", "prefill_cont", "decode_paged"):
        return jax.lax.scan(body, x, (stack, cache), unroll=unroll)
    # train & prefill start cache-less; prefill emits per-layer caches as ys
    x_out, ys = jax.lax.scan(lambda xc, p: body(xc, (p, None)), x, stack,
                             unroll=unroll)
    return x_out, (ys if mode == "prefill" else None)
