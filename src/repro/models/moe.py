"""Top-k MoE FFN (Mixtral-style) with sort-based, capacity-bounded dispatch.

TPU adaptation: instead of the GShard (T, E, C) one-hot dispatch einsum —
whose FLOPs/memory dwarf the expert compute — tokens are routed with an
argsort over expert assignments plus scatter/gather, which XLA costs as data
movement, not FLOPs.  Expert weights are tensor-parallel over ``d_ff`` (the
``model`` mesh axis): with 8 experts on a 16-wide model axis, expert-sharding
would pad 8→16 (2x compute waste), so F-sharding is the clean layout; the
collective pattern matches a dense Megatron FFN (documented in DESIGN.md).
Tokens over capacity are dropped (gates renormalized) — standard for
capacity-bounded routing.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from .config import MoESpec
from .layers import f32


def init_moe_params(rng, d_model: int, d_ff: int, spec: MoESpec, dtype):
    ks = jax.random.split(rng, 4)
    e = spec.n_experts
    std_in = 1.0 / math.sqrt(d_model)
    std_out = 1.0 / math.sqrt(d_ff)
    return {
        "router": (jax.random.normal(ks[0], (d_model, e), f32) * std_in).astype(dtype),
        "w_gate": (jax.random.normal(ks[1], (e, d_model, d_ff), f32) * std_in).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d_model, d_ff), f32) * std_in).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, d_ff, d_model), f32) * std_out).astype(dtype),
    }


def moe_ffn(p, x, spec: MoESpec, capacity: Optional[int] = None):
    """x: (B, S, D) -> (B, S, D).  Router in fp32; top-k softmax-of-topk."""
    btype = x.dtype
    b, s, d = x.shape
    e, k = spec.n_experts, spec.top_k
    t = b * s
    xt = x.reshape(t, d)

    logits = (xt @ p["router"]).astype(f32)                 # (T, E)
    top_vals, top_idx = jax.lax.top_k(logits, k)            # (T, k)
    gates = jax.nn.softmax(top_vals, axis=-1)               # (T, k)

    cap = capacity or int(math.ceil(spec.capacity_factor * k * t / e))
    cap = max(cap, 1)

    # flatten assignments and compute each token-slot's rank within its expert
    flat_e = top_idx.reshape(-1)                            # (T*k,)
    order = jnp.argsort(flat_e, stable=True)                # group by expert
    sorted_e = flat_e[order]
    run_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_sorted = jnp.arange(t * k) - run_start               # rank within expert
    pos = jnp.zeros_like(pos_sorted).at[order].set(pos_sorted)  # undo sort
    keep = pos < cap

    tok_of = jnp.arange(t).repeat(k)                        # (T*k,) token index
    safe_pos = jnp.where(keep, pos, cap - 1)

    # dispatch: (E, cap, D)
    disp = jnp.zeros((e, cap, d), btype)
    disp = disp.at[flat_e, safe_pos].add(jnp.where(keep[:, None], xt[tok_of], 0))

    # expert FFN
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", disp, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", disp, p["w_up"])
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"])       # (E, cap, D)

    # combine: gather back and weight by gate
    gathered = out_e[flat_e, safe_pos]                       # (T*k, D)
    gathered = jnp.where(keep[:, None], gathered, 0)
    gk = (gates.reshape(-1) * keep).astype(btype)
    combined = jnp.zeros((t, d), btype).at[tok_of].add(gathered * gk[:, None])

    # renormalize for dropped tokens
    denom = jnp.zeros((t,), f32).at[tok_of].add(gk.astype(f32))
    combined = combined / jnp.maximum(denom, 1e-9)[:, None].astype(btype)
    return combined.reshape(b, s, d)


def moe_ffn_sharded(p, x, spec: MoESpec, mesh, dp_axes, model_axis: str):
    """shard_map-local MoE dispatch (the §Perf collective fix).

    The global-view ``moe_ffn`` builds one (E, C_global, D) dispatch buffer
    with data-dependent scatter indices; GSPMD cannot shard that scatter, so
    it replicates the buffer per data shard and all-reduces it — tens of GB
    per layer at mixtral-8x22b scale.  Here each data shard dispatches its
    OWN tokens into a local (E, C_local, D) buffer (C_local = capacity of the
    local token count — per-shard capacity is what production routers use),
    and only the F-sharded expert contraction is reduced over the model axis.
    """
    from functools import partial as _partial

    from jax.sharding import PartitionSpec as P

    if hasattr(jax, "shard_map"):                 # jax >= 0.6
        _shard_map = jax.shard_map
    else:                                         # jax 0.4.x fallback
        from jax.experimental.shard_map import shard_map as _shard_map
    import inspect
    _sig = inspect.signature(_shard_map).parameters
    _nocheck = ({"check_vma": False} if "check_vma" in _sig
                else {"check_rep": False} if "check_rep" in _sig else {})

    x_spec = P(dp_axes, None, None)
    w_col = P(None, None, model_axis)   # (E, D, F): F sharded
    w_row = P(None, model_axis, None)   # (E, F, D): F sharded

    @_partial(_shard_map, mesh=mesh,
              in_specs=(x_spec, P(), w_col, w_col, w_row),
              out_specs=x_spec, **_nocheck)
    def _local(xs, router, w_gate, w_up, w_down):
        params = {"router": router, "w_gate": w_gate, "w_up": w_up,
                  "w_down": w_down}
        out = moe_ffn(params, xs, spec)
        # w_down contracted a model-sharded F: finish the reduction here
        return jax.lax.psum(out, axis_name=model_axis)

    return _local(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])


def router_aux_loss(p, x, spec: MoESpec) -> jax.Array:
    """Load-balancing auxiliary loss (Switch-style): E * sum(f_e * p_e)."""
    b, s, d = x.shape
    logits = (x.reshape(-1, d) @ p["router"]).astype(f32)
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(logits, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, spec.n_experts, dtype=f32), axis=0)
    return spec.n_experts * jnp.sum(frac * jnp.mean(probs, axis=0))
