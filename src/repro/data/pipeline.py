"""Deterministic sharded token pipeline.

Design point (matters at 1000+ nodes): batches are a pure function of
``(seed, step, shard)`` — any host can regenerate any step's shard without
coordination, so restarts and elastic re-sharding never need a data-state
checkpoint beyond the step counter.  Backends:

 * ``synthetic`` — Zipfian token stream with local n-gram structure (gives a
   learnable signal so loss curves actually go down in the examples),
 * ``corpus``   — byte-tokenized documents from an in-memory corpus or text
   file, packed into fixed-length rows with EOS separators.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional, Sequence

import numpy as np

from .tokenizer import EOS, ByteTokenizer


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    backend: str = "synthetic"      # synthetic | corpus
    zipf_a: float = 1.2


class DataPipeline:
    def __init__(self, cfg: DataConfig, corpus: Optional[Sequence[str]] = None,
                 n_shards: int = 1, shard_id: int = 0):
        assert cfg.global_batch % n_shards == 0
        self.cfg = cfg
        self.n_shards = n_shards
        self.shard_id = shard_id
        self._tok = ByteTokenizer()
        self._packed: Optional[np.ndarray] = None
        if cfg.backend == "corpus":
            assert corpus is not None, "corpus backend needs documents"
            ids: list[int] = []
            for doc in corpus:
                ids.extend(self._tok.encode(doc, bos=False) + [EOS])
            n = max(len(ids) // cfg.seq_len, 1)
            ids = (ids * (cfg.seq_len * n // max(len(ids), 1) + 2))[: n * cfg.seq_len]
            self._packed = np.asarray(ids, np.int32).reshape(n, cfg.seq_len)

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, self.shard_id]))

    def batch(self, step: int) -> dict:
        """Shard-local batch for ``step``: {"tokens": (B_local, S) int32}."""
        cfg = self.cfg
        b_local = cfg.global_batch // self.n_shards
        rng = self._rng(step)
        if cfg.backend == "corpus":
            idx = rng.integers(0, self._packed.shape[0], size=b_local)
            return {"tokens": self._packed[idx]}
        # synthetic: Zipf unigram + shift-by-one bigram structure
        base = rng.zipf(cfg.zipf_a, size=(b_local, cfg.seq_len)).astype(np.int64)
        toks = (base % (cfg.vocab_size - 2)) + 1
        # inject predictable continuation: with p=0.5, t[i+1] = t[i] + 1
        copy_mask = rng.random((b_local, cfg.seq_len - 1)) < 0.5
        nxt = (toks[:, :-1] + 1) % cfg.vocab_size
        toks[:, 1:] = np.where(copy_mask, nxt, toks[:, 1:])
        return {"tokens": toks.astype(np.int32)}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
