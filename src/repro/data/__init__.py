from .pipeline import DataConfig, DataPipeline
from .tokenizer import BOS, EOS, PAD, ByteTokenizer

__all__ = ["DataConfig", "DataPipeline", "BOS", "EOS", "PAD", "ByteTokenizer"]
