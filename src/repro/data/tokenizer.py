"""Byte-fallback tokenizer: bytes 0-255 + specials.  Deterministic, offline,
vocab-safe for every assigned arch (all vocabs >= 256 + specials)."""
from __future__ import annotations

PAD, BOS, EOS = 256, 257, 258
N_SPECIAL = 3


class ByteTokenizer:
    vocab_size = 256 + N_SPECIAL

    def encode(self, text: str, bos: bool = True, eos: bool = False) -> list[int]:
        ids = list(text.encode("utf-8"))
        if bos:
            ids = [BOS] + ids
        if eos:
            ids = ids + [EOS]
        return ids

    def decode(self, ids) -> str:
        return bytes(i for i in ids if i < 256).decode("utf-8", errors="replace")

    def pad_to(self, ids: list[int], length: int) -> list[int]:
        ids = ids[:length]
        return ids + [PAD] * (length - len(ids))
