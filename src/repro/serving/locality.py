"""Locality-creating probe scheduling (GGR-shaped group-and-reorder).

PR 2's prefix-KV cache and PR 5's unified loop made prefix reuse
*reactive*: a step gap's merged probe set is executed in arrival order and
whatever regions happen to recur get cached.  This module actively
*creates* reuse, following the greedy group-and-reorder idea from the
relational LLM-workload optimizers (PAPERS.md: "Optimizing LLM Queries in
Relational Data Analytics Workloads"; Sema's operator runtime): given the
structured rows of one padded-length class, it

 1. **clusters rows by prefix region** — the engine's canonical
    ``_region_key`` (prefix token ids, absolute start position) — so every
    row that can share a cached region sits adjacent in one submission;
 2. **gives each region group its own suffix-prefill window** — the
    power-of-two bucket of the group's longest suffix, instead of one
    class-global window sized by the round's worst row, so short-suffix
    groups stop recomputing prefix tail tokens they could read from KV;
 3. **merges equal-window groups into jobs capped at the LRU capacity** —
    a single job never touches more distinct regions than
    ``prefix_cache_size`` can hold, so a job's working set cannot thrash
    the LRU mid-round;
 4. **orders jobs cold-first / warm-last** — jobs whose regions are
    already LRU-resident run last, leaving recurring regions most-recent
    in the LRU for the NEXT round (greedy eviction-distance maximization).

Invariants (asserted by tests/test_locality.py and benchmarks
table5/table9): reordering is *serving-side only*.  Results are fanned
back by row id, every row's logits stay bit-identical to monolithic
prefill (causal KV slicing is exact at any split — the PR 2 contract), so
orderings and oracle ledgers are byte-identical (``==``) under any
grouping.  Only ``ServeStats`` (prefill tokens, hits, tokens saved) move.

``prefetch_candidates`` is the prefetch-pipelining half: given the probe
prompts a plan will submit NEXT, it selects the structured prompts whose
region is (a) shared by at least two rows — the engine's routing policy
would run singletons monolithically anyway, so warming them would change
routing and waste fill work — and (b) not already LRU-resident (warming a
resident region would just count a free hit).  The executor enqueues the
survivors as ``PrefixFill`` work so the warm-up rides an earlier step gap
of the unified loop, overlapping in-flight decode instead of serializing
with the round's own fills.
"""
from __future__ import annotations

from typing import Sequence


def _next_pow2(x: int) -> int:
    return 1 << max(x - 1, 0).bit_length()


def group_rows_by_region(selected: Sequence[tuple]) -> list[tuple]:
    """Cluster ``(idx, region_key, suffix_len)`` rows by region key, first
    appearance order, keeping each group's rows in submission order.
    Returns ``[(key, [(idx, suffix_len), ...])]``."""
    groups: dict[tuple, list] = {}
    order: list[tuple] = []
    for idx, key, slen in selected:
        if key not in groups:
            groups[key] = []
            order.append(key)
        groups[key].append((idx, slen))
    return [(key, groups[key]) for key in order]


def group_window(rows: Sequence[tuple], bucket: bool) -> int:
    """One region group's suffix-prefill window: the power-of-two bucket
    (floor 8, matching the engine's class-global scheme) of the group's
    longest suffix — exact when shape bucketing is off."""
    w = max(slen for _, slen in rows)
    return _next_pow2(max(w, 8)) if bucket else w


def plan_window_jobs(selected: Sequence[tuple], *, lru_keys,
                     cache_size: int, bucket: bool = True) -> list[tuple]:
    """The GGR pass for one padded-length class.

    ``selected`` rows are ``(idx, region_key, suffix_len)`` triples already
    chosen for the prefix path (the engine's routing policy).  Returns an
    ordered list of window jobs ``(window, [(idx, region_key), ...])``:
    region-clustered rows, per-group windows merged by equal window size,
    at most ``cache_size`` distinct regions per job, cold jobs before warm
    jobs (see module docstring).  Pure function of its inputs — the engine
    owns all KV state."""
    lru_keys = set(lru_keys)
    by_window: dict[int, list] = {}
    for key, rows in group_rows_by_region(selected):
        by_window.setdefault(group_window(rows, bucket), []).append(
            (key, rows))
    jobs: list[tuple[bool, int, list]] = []   # (warm, window, rows)
    cap = max(cache_size, 1)
    for w in sorted(by_window):
        groups = by_window[w]
        for i in range(0, len(groups), cap):
            chunk = groups[i:i + cap]
            rows = [(idx, key) for key, grp in chunk for idx, _ in grp]
            warm = any(key in lru_keys for key, _ in chunk)
            jobs.append((warm, w, rows))
    # cold-first / warm-last, stable: warm jobs touch the LRU last, so the
    # regions a recurring workload reuses stay most-recent for next round
    jobs.sort(key=lambda j: j[0])
    return [(w, rows) for _, w, rows in jobs]


def prefetch_candidates(engine, prompts: Sequence) -> list:
    """Select the structured prompts of a FUTURE probe round whose prefix
    regions are worth warming ahead of time: regions shared by >= 2
    prompts of the round (singletons would be routed monolithically — the
    engine's routing policy — so a fill would be pure waste AND would flip
    their routing) and not already LRU-resident.  Returns one
    representative prompt per candidate region, ready for
    ``BatchScheduler.submit_prefix_fill``."""
    if not getattr(engine, "prefix_cache_enabled", False):
        return []
    counts: dict[tuple, int] = {}
    rep: dict[tuple, object] = {}
    seen: set = set()
    for p in prompts:
        prefix, suffix = engine._parts(p)
        if prefix is None or (prefix, suffix) in seen:
            # identical prompts are deduplicated by the scheduler before
            # they reach the engine, so region sharing must be counted
            # over UNIQUE prompts — otherwise a duplicated singleton
            # would be warmed and its routing flipped vs no-prefetch
            continue
        seen.add((prefix, suffix))
        pids = tuple(engine.tok.encode(prefix))
        sids = engine.tok.encode(suffix, bos=False)
        cls = engine._pad_class(len(pids) + len(sids))
        key = engine._region_key(pids, sids, cls)
        counts[key] = counts.get(key, 0) + 1
        rep.setdefault(key, p)
    return [rep[key] for key, c in counts.items()
            if c >= 2 and key not in engine._prefix_lru]
