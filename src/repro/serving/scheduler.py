"""Request scheduler: queue + length-bucketed batching over the engine.

Batch-level continuous batching: requests are drained in arrival order,
grouped into (max_batch)-sized batches sorted by prompt length (minimizes
padding waste), and each batch runs prefill+decode to completion.  Token-
level interleaving (paged attention) is documented as out of scope in
DESIGN.md; batch-level scheduling is what the ORDER BY workloads need — the
access paths submit many short, similar-length scoring prompts.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Optional

from .engine import ServeEngine

_ids = itertools.count()


@dataclass
class Request:
    rid: int
    prompt: str
    max_new: int
    output: Optional[str] = None

    @property
    def done(self) -> bool:
        return self.output is not None


class BatchScheduler:
    def __init__(self, engine: ServeEngine, max_batch: int = 16):
        self.engine = engine
        self.max_batch = max_batch
        self.queue: list[Request] = []
        self.completed: dict[int, Request] = {}

    def submit(self, prompt: str, max_new: int = 32) -> int:
        r = Request(next(_ids), prompt, max_new)
        self.queue.append(r)
        return r.rid

    def run(self) -> dict[int, str]:
        """Drain the queue; returns {rid: output}."""
        while self.queue:
            batch = self.queue[: self.max_batch]
            self.queue = self.queue[self.max_batch:]
            batch.sort(key=lambda r: len(r.prompt))
            outs = self.engine.generate([r.prompt for r in batch],
                                        max_new=max(r.max_new for r in batch))
            for r, o in zip(batch, outs):
                r.output = o
                self.completed[r.rid] = r
        return {rid: r.output for rid, r in self.completed.items()}
