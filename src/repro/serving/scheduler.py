"""Unified token-granularity serving loop: ONE step loop over a typed
work queue, co-scheduling decode rows, probe rounds, and prefix fills.

``BatchScheduler`` owns a single admission queue of typed work items:

 * **decode work** (``submit`` / ``generate`` / ``run``) — prefill + greedy
   decode rows that live across many steps in the paged pool;
 * **probe work** (``submit_probe`` / ``submit_probe_round``) — single-token
   read-out prefills (score / compare / yes-no) that complete the step they
   are serviced in; a *round* groups the probes of one oracle round behind a
   :class:`RoundFuture` that resolves when every member has logits;
 * **prefix-fill work** (``submit_prefix_fill``) — prefix-KV region
   prefills scheduled ahead of need, so a round's shared prefix can be
   warmed in a step gap while decode rows keep streaming.

Every :meth:`step` runs one pass of the admission policy and ONE decode
step: queued decode items are admitted FIFO into free pool/row capacity,
then ALL pending fills and probe work are serviced (probe submissions ride
the step gap — merged across submitters into length-bucketed submissions
with identical prompts deduplicated), then every active decode row advances
one token and retiring rows free their blocks.  The ordering gives both
fairness bounds by construction: a probe round submitted at any point is
answered before the NEXT decode step (a long rationale cannot delay it by
more than one step), and a probe storm cannot stall decode rows because
each step decodes exactly once regardless of probe volume.

Clients of the loop:

 * ``run()`` drains the scheduler's own backlog by pumping :meth:`step`
   until no decode work remains (``on_step`` fires between steps and may
   submit more work mid-drain);
 * ``generate()`` submits rows and pumps until THOSE rows finish — queued
   probe rounds and other drivers' rows advance alongside, which is how a
   judge rationale generation co-schedules with ORDER BY probes;
 * the probe-plan executor (``core/executor.py``) begins every suspended
   plan's deferred round (``ModelOracle.begin_probe_round`` →
   ``submit_probe_round``) and pumps ONE step — all plans' probes land in
   that step's gap, and their futures resolve between decode steps.

**Multi-tenant serving**: every work item carries a tenant name, and
registered :class:`TenantSpec`s turn the admission policy into a weighted
one — decode admission walks tenants by priority (FIFO within a tenant,
head-of-line protection across priority levels), per-class
``reserved_rows`` are held back from lower classes while a reserved tenant
has queued decode work, ``probe_quota`` bounds a tenant's probe rows per
step gap (with an aging bound so deferred rounds always drain), and
``token_budget`` rejects new submissions once a tenant's served tokens
exceed it.  When a strictly-higher-priority request cannot be admitted,
the scheduler *preempts* lower-priority preemptible rows: the engine
suspends them to a host-side stash (``ServeEngine.paged_suspend``) and
they re-enter the queue head as resumable requests whose continuation is
byte-identical (``paged_resume``).  With no tenants registered every item
is the implicit default class and the policy reduces exactly to the FIFO
loop above.  See DESIGN.md "Multi-tenant serving".

Engines without paged support (recurrent/MoE archs) fall back to
batch-level scheduling: the drain sorts the WHOLE backlog by prompt length,
chunks it into (max_batch)-sized batches, and runs each batch prefill +
lockstep decode to completion; probe work is serviced whenever the loop is
pumped (there are no step gaps to interleave into).  See DESIGN.md
"Unified step loop".
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from .engine import ServeEngine
from .kv_pool import PoolExhausted

_ids = itertools.count()


# ------------------------------------------------------------ tenant classes
class TenantBudgetExceeded(RuntimeError):
    """A submission would exceed its tenant's serving-token budget."""


@dataclass(frozen=True)
class TenantSpec:
    """One tenant/priority class of the serving loop.

    ``priority`` orders admission (higher first; ties FIFO by arrival) and
    gates preemption: a waiting request may suspend active rows only of
    strictly lower-priority, ``preemptible`` classes.  ``reserved_rows``
    decode rows are withheld from OTHER classes while this tenant has
    queued decode work (a soft guarantee: liveness beats reservations when
    nothing is in flight).  ``probe_quota`` caps the tenant's probe rows
    serviced per step gap — whole rounds are deferred past the cap and
    force-serviced once they age ``starvation_bound`` steps.
    ``token_budget`` bounds SERVED tokens (decode row-steps + probe rows);
    ``ledger_budget`` bounds BILLED oracle tokens and is enforced by the
    probe-plan executor (core/executor.py), which cancels the tenant's
    plans once their ledger slices exceed it."""
    name: str
    priority: int = 0
    reserved_rows: int = 0
    probe_quota: Optional[int] = None
    token_budget: Optional[int] = None
    ledger_budget: Optional[int] = None
    preemptible: bool = True


_DEFAULT_TENANT = TenantSpec("default")


@dataclass
class TenantStats:
    """Per-tenant serving accounting (scheduler-side; the engine-side
    preemption/starvation counters live in ``ServeStats``).  Billing
    convention for preempted rows: ``tokens_served`` charges one token per
    ACTIVE owned row per decode step, so a suspended row is not billed
    while parked and a suspend/resume cycle bills exactly the tokens a
    never-preempted run would — no double-billing."""
    submitted: int = 0
    admitted: int = 0
    finished: int = 0
    preemptions: int = 0
    resumes: int = 0
    probe_rows: int = 0
    rounds_serviced: int = 0
    tokens_served: int = 0
    max_admission_wait: int = 0   # steps a decode item waited, worst case
    max_round_wait: int = 0       # steps a probe unit was deferred, worst case


# ------------------------------------------------------- typed work items
@dataclass
class Request:
    """Decode work: one generate request (prefill + greedy decode row).
    ``max_new`` 0 is a genuine zero budget; None means engine default."""
    rid: int
    prompt: object           # str or (shared_prefix, per_key_suffix) pair
    max_new: Optional[int]
    output: Optional[str] = None
    block_need: Optional[int] = None     # memoized KV-pool block budget
    tenant: str = "default"
    wait_steps: int = 0                  # steps spent waiting for admission
    suspended: object = None             # engine SuspendedRow when preempted

    @property
    def done(self) -> bool:
        return self.output is not None


class RoundFuture:
    """Resolves when every probe of one round has its logits.  ``result()``
    returns the logits aligned with the round's submission order."""

    __slots__ = ("_vals", "_left")

    def __init__(self, n: int):
        self._vals: list = [None] * n
        self._left = n

    @property
    def done(self) -> bool:
        return self._left == 0

    def _set(self, slot: int, logits) -> None:
        assert self._vals[slot] is None, "probe slot resolved twice"
        self._vals[slot] = logits
        self._left -= 1

    def result(self) -> list:
        assert self.done, "round future read before resolution"
        return self._vals


class CascadeFuture(RoundFuture):
    """Round future resolving in TWO waves inside one step gap: wave 1
    answers every slot on the draft engine, then ``escalate`` (an
    oracle-layer callback: it owns the margin rule AND the large-tier
    billing) picks the low-confidence slots, which re-run on the large
    engine before the future completes.  Clients see an ordinary
    :class:`RoundFuture` — same ``done``/``result()``, same executor
    fairness (a cascade round still resolves within one pump)."""

    __slots__ = ("escalate", "escalated")

    def __init__(self, n: int, escalate: Callable):
        super().__init__(n)
        self.escalate = escalate
        self.escalated: set = set()


@dataclass
class ProbeRequest:
    """Probe work: one single-token read-out prompt.  Stand-alone probes
    (``future is None``) deliver into ``scheduler.probe_results``; round
    members deliver into their :class:`RoundFuture` slot.  ``tier`` routes
    the probe's engine lane: "large" (the default lane) or "draft" (wave 1
    of a cascade round, served by ``draft_engine``)."""
    rid: int
    prompt: object           # str or (shared_prefix, per_key_suffix) pair
    logits: Optional[np.ndarray] = None
    future: Optional[RoundFuture] = None
    slot: int = 0
    tenant: str = "default"
    wait_steps: int = 0                  # step gaps this probe was deferred
    tier: str = "large"


@dataclass
class PrefixFill:
    """Prefix-fill work: warm the engine's prefix-KV LRU for structured
    prompts BEFORE the round or generate wave that needs them, so the fill
    submission rides an earlier step gap."""
    rid: int
    prompts: list = field(default_factory=list)   # (prefix, suffix) pairs


def _probe_key(prompt) -> tuple:
    """Dedup key for a probe prompt.  Structured pairs are keyed as-is and
    plain strings separately — the two forms produce bit-identical logits,
    but keeping them distinct makes dedup a pure no-new-bits optimization
    (a fanned-out result is exactly the result the duplicate's own
    submission row would have computed)."""
    if isinstance(prompt, str):
        return ("s", prompt)
    return ("p", tuple(prompt))


class BatchScheduler:
    def __init__(self, engine: ServeEngine, max_batch: int = 16,
                 paged: Optional[bool] = None,
                 probe_batch: Optional[int] = None,
                 starvation_bound: int = 8,
                 draft_engine: Optional[ServeEngine] = None):
        self.engine = engine
        # optional second engine lane for model-cascade probe rounds
        # (submit_cascade_round): wave-1 draft probes run here, sharing the
        # work queue but NOT the large engine's KV pool — each lane owns
        # its engine's pool/prefix cache outright
        self.draft_engine = draft_engine
        self.max_batch = max_batch
        # multi-tenant policy: specs by name; unregistered tenants (and
        # everything, when none are registered) run as the default class —
        # priority 0, no reservations, no quotas, preemptible
        self.tenants: dict[str, TenantSpec] = {}
        self.tenant_stats: dict[str, TenantStats] = {}
        # a probe unit deferred by quota this many step gaps is serviced
        # regardless; a priority-class (> 0) unit aging out, or a decode
        # item of such a class waiting past the bound, trips the
        # ServeStats starvation alarms
        self.starvation_bound = starvation_bound
        # probe drains chunk by the ENGINE's probe memory ceiling
        # (max_probe_batch), not by max_batch: probes are single-token
        # prefills, so the decode-batch cap has no bearing on them.  Pass
        # ``probe_batch`` to override.  On a sharded engine the chunk size
        # is additionally rounded up to a multiple of the engine's
        # data-shard count (:meth:`_probe_chunk`) so every chunk of a
        # sliced drain fills all shards' row slices.
        self.probe_batch = probe_batch
        # paged=None: continuous loop whenever the engine supports it;
        # False pins the lockstep batch path (the benchmark baseline)
        self.paged = (engine.paged_enabled if paged is None
                      else paged and engine.paged_enabled)
        # THE unified admission queue: typed work items in arrival order
        self.work: list = []
        self.completed: dict[int, Request] = {}
        self.probe_results: dict[int, np.ndarray] = {}
        self.probes_deduped = 0    # duplicate prompts served by fan-out
        self.probes_drafted = 0    # cascade wave-1 rows served by the draft
        self.probes_escalated = 0  # cascade rows re-run on the large engine
        self.fills_serviced = 0    # PrefixFill work items serviced
        self.regions_prefetched = 0   # prefix regions ensured resident
        self.steps = 0             # unified steps taken (decode or probe-only)
        self._rid_of_engine: dict[int, Request] = {}
        # outputs finished by step() and not yet claimed by a driver
        # (run() claims everything; generate() claims only its own rids)
        self._fresh: dict[int, str] = {}

    # ------------------------------------------------- queue introspection
    @property
    def queue(self) -> list:
        """Pending decode work items (admission order)."""
        return [w for w in self.work if isinstance(w, Request)]

    @property
    def probe_queue(self) -> list:
        """Pending probe work items (round members and stand-alones)."""
        return [w for w in self.work if isinstance(w, ProbeRequest)]

    @property
    def work_remaining(self) -> bool:
        return bool(self.work) or bool(self._rid_of_engine)

    # ----------------------------------------------------------- tenants
    def register_tenant(self, spec: TenantSpec) -> TenantSpec:
        """Install (or replace) a tenant class.  Reservations are a soft
        guarantee: their sum may exceed the row budget, in which case
        liveness wins — an empty loop always admits the highest-priority
        head regardless of debt."""
        assert spec.reserved_rows >= 0, "reserved_rows must be >= 0"
        assert spec.reserved_rows <= self.engine.max_decode_rows, (
            f"reserved_rows {spec.reserved_rows} exceeds the engine's "
            f"{self.engine.max_decode_rows} decode rows")
        self.tenants[spec.name] = spec
        self._tstats(spec.name)
        return spec

    def _spec(self, name: str) -> TenantSpec:
        return self.tenants.get(name, _DEFAULT_TENANT)

    def _tstats(self, name: str) -> TenantStats:
        ts = self.tenant_stats.get(name)
        if ts is None:
            ts = self.tenant_stats[name] = TenantStats()
        return ts

    def _check_budget(self, tenant: str, cost: int) -> None:
        """Serving-token admission control: reject a submission whose
        known-upfront cost (probe rows; 0 for open-ended decode work) would
        cross the tenant's ``token_budget`` given what it has already been
        served.  Ledger-token budgets are the executor's business."""
        spec = self._spec(tenant)
        if spec.token_budget is None:
            return
        served = self._tstats(tenant).tokens_served
        # open-ended decode work (cost 0) still needs at least one token
        # of headroom: an exhausted tenant admits nothing
        if served + max(cost, 1) > spec.token_budget:
            raise TenantBudgetExceeded(
                f"tenant {tenant!r}: {served} tokens served + {cost} "
                f"requested > budget {spec.token_budget}")

    # ------------------------------------------------------------ submit
    def submit(self, prompt, max_new: Optional[int] = 32,
               tenant: str = "default") -> int:
        """Enqueue decode work.  ``max_new`` is this REQUEST's budget: 0 is
        a genuine zero budget (PR-3 contract), ``None`` means the engine
        default."""
        self._check_budget(tenant, 0)
        r = Request(next(_ids), prompt, max_new, tenant=tenant)
        self.work.append(r)
        self._tstats(tenant).submitted += 1
        return r.rid

    def submit_probe(self, prompt, tenant: str = "default") -> int:
        self._check_budget(tenant, 1)
        r = ProbeRequest(next(_ids), prompt, tenant=tenant)
        self.work.append(r)
        return r.rid

    def submit_probe_round(self, prompts,
                           tenant: str = "default") -> RoundFuture:
        """Enqueue one oracle round's probes as a unit; returns the
        :class:`RoundFuture` that resolves — logits aligned with
        ``prompts`` — when the loop services the round in a step gap."""
        self._check_budget(tenant, len(prompts))
        fut = RoundFuture(len(prompts))
        for i, p in enumerate(prompts):
            self.work.append(ProbeRequest(next(_ids), p, future=fut, slot=i,
                                          tenant=tenant))
        return fut

    def submit_cascade_round(self, prompts, escalate: Callable,
                             tenant: str = "default") -> CascadeFuture:
        """Enqueue one cascade round: every prompt enters the DRAFT lane;
        after wave 1 resolves, ``escalate(draft_logits: {slot: logits})``
        returns the slots to re-run on the large engine — both waves are
        serviced in the SAME step gap, so fairness bounds match a plain
        round.  Admission control charges the draft wave upfront;
        escalated rows bill ``tokens_served`` as they are served (their
        count is not knowable at submit time).  Escalations also bypass
        per-tenant probe quotas: they belong to a unit the gap already
        admitted."""
        assert self.draft_engine is not None, (
            "cascade rounds need a draft engine lane "
            "(BatchScheduler(engine, draft_engine=...))")
        self._check_budget(tenant, len(prompts))
        fut = CascadeFuture(len(prompts), escalate)
        for i, p in enumerate(prompts):
            self.work.append(ProbeRequest(next(_ids), p, future=fut, slot=i,
                                          tenant=tenant, tier="draft"))
        return fut

    def submit_prefix_fill(self, prompts) -> int:
        """Enqueue a prefix-KV warm-up for structured ``(prefix, suffix)``
        prompts; the fill submission runs in the next step gap."""
        f = PrefixFill(next(_ids), [p for p in prompts
                                    if not isinstance(p, str)])
        self.work.append(f)
        return f.rid

    # ------------------------------------------------------ the step loop
    def step(self) -> dict[int, str]:
        """ONE unified scheduling step (paged engines only):

          1. admit queued decode work into free pool/row capacity —
             priority-weighted across tenants (FIFO within each, per-class
             reservations honored), preempting strictly-lower-priority
             rows when a higher class cannot fit;
          2. service pending prefix fills, then pending probe work within
             per-tenant quotas (merged submissions, cross-submitter dedup,
             futures resolve; unregistered config services everything);
          3. one paged decode step — active rows advance one token, rows
             that finish retire and free their blocks.

        Returns {rid: output} for decode work finished this step (also
        recorded in ``completed`` and claimable via ``_fresh``)."""
        assert self.paged, "step() requires a paged-capable engine"
        eng = self.engine
        self.steps += 1
        # -- 1. decode admission (probe and fill items never block it —
        # they hold no persistent capacity)
        decode_items = []
        rest: list = []
        for w in self.work:
            (decode_items if isinstance(w, Request) else rest).append(w)
        try:
            if decode_items:
                self._admit_decode(decode_items)
        finally:
            # reassign even when admission raises mid-wave: admitted items
            # were removed from decode_items in place (and failed
            # resumes/preemptions reinserted), so the queue never holds a
            # request that already owns an engine row
            self.work = rest + decode_items   # unadmitted decode items wait

        # -- 2. fills then probes ride the step gap
        self._service_fills()
        self._service_probes()

        # serving-token billing: one token per ACTIVE owned row per decode
        # step (suspended rows are parked, not billed — a preemption cycle
        # bills exactly what a never-preempted run would)
        for erid, req in self._rid_of_engine.items():
            if erid in eng._paged_rows:
                self._tstats(req.tenant).tokens_served += 1

        # -- 3. one decode step (a no-op when no rows are active, so a
        # probe storm burns probe submissions, never decode progress)
        finished: dict[int, str] = {}
        for erid, text in eng.paged_step().items():
            req = self._rid_of_engine.pop(erid, None)
            if req is None:               # a concurrent driver's row — e.g.
                eng._paged_finished[erid] = text   # a nested generate
                continue
            req.output = text
            self.completed[req.rid] = req
            self._fresh[req.rid] = text
            finished[req.rid] = text
            self._tstats(req.tenant).finished += 1
        return finished

    # ------------------------------------------------- weighted admission
    def _need(self, w: Request) -> int:
        if w.suspended is not None:
            return w.suspended.n_blocks
        if w.block_need is None:          # tokenize once per request
            w.block_need = self.engine.paged_block_need(w.prompt, w.max_new)
        return w.block_need

    def _owned_rows_by_tenant(self) -> dict[str, int]:
        eng = self.engine
        out: dict[str, int] = {}
        for erid, req in self._rid_of_engine.items():
            if erid in eng._paged_rows:
                out[req.tenant] = out.get(req.tenant, 0) + 1
        return out

    def _admit_decode(self, items: list) -> int:
        """Admit what fits (weighted pass), preempt for the head of the
        highest waiting class if that frees enough, then admit again.
        Mirrors ``ServeEngine._paged_admit_wave``'s stuck handling: an
        empty loop that still cannot admit evicts cold prefix runs, then
        drops reservations (liveness), then raises ``PoolExhausted``."""
        eng = self.engine
        n = self._admission_pass(items)
        if items and self._preempt_for_head(items):
            n += self._admission_pass(items)
        if n == 0 and items and not eng._paged_rows:
            # stuck iff nothing IN FLIGHT can still free blocks (finished
            # rows freed theirs at retirement) — same contract as
            # _paged_admit_wave, extended with a reservation-debt fallback
            if eng._prefix_lru:           # cold prefix runs yield to decode
                eng.clear_prefix_cache()
                n = self._admission_pass(items)
            if n == 0 and items:
                n = self._admission_pass(items, ignore_reservations=True)
            if n == 0 and items:
                raise PoolExhausted(
                    f"request needs {self._need(items[0])} blocks but an "
                    f"empty pool frees only {eng.pool.free_blocks}")
        for w in items:                   # starvation accounting on waiters
            w.wait_steps += 1
            if (w.wait_steps == self.starvation_bound + 1
                    and self._spec(w.tenant).priority > 0):
                eng.stats.starved_admissions += 1
        return n

    def _admission_pass(self, items: list,
                        ignore_reservations: bool = False) -> int:
        """One weighted admission wave over the pending decode items:
        priority order (stable — FIFO by arrival within a class), each
        tenant's own queue strictly FIFO (its first non-fitting item blocks
        the rest), and a blocked class blocks every STRICTLY LOWER class
        too (head-of-line protection: freed capacity must not leak past a
        waiting high-priority head to bulk work).  ``reserved_rows`` of
        other tenants with queued decode work are held back as debt.
        Admits the wave (resumes under their original rid, fresh requests
        as one batched ``paged_admit``) and removes it from ``items``."""
        eng = self.engine
        order = sorted(range(len(items)),
                       key=lambda i: -self._spec(items[i].tenant).priority)
        active_of = self._owned_rows_by_tenant()
        queued = {w.tenant for w in items}
        taken_rows = taken_blocks = 0
        taken_of: dict[str, int] = {}
        blocked: set = set()
        floor: Optional[int] = None
        wave_idx: list[int] = []
        for i in order:
            if len(wave_idx) >= self.max_batch:
                break
            w = items[i]
            t = w.tenant
            pr = self._spec(t).priority
            if t in blocked:
                continue
            if floor is not None and pr < floor and (
                    ignore_reservations
                    or self._spec(t).reserved_rows
                    <= active_of.get(t, 0) + taken_of.get(t, 0)):
                # the floor keeps freed capacity from leaking past a
                # blocked high class to bulk work — but capacity withheld
                # by a tenant's OWN reservation is exactly theirs, so they
                # pass the floor until the reservation is filled
                continue
            need = self._need(w)
            debt = 0
            if not ignore_reservations:
                debt = sum(max(0, self._spec(u).reserved_rows
                               - active_of.get(u, 0) - taken_of.get(u, 0))
                           for u in queued if u != t)
            if not (eng.paged_room(need, rows_pending=taken_rows,
                                   blocks_pending=taken_blocks)
                    and eng.paged_active + taken_rows + debt
                    < eng.max_decode_rows):
                blocked.add(t)
                if floor is None:
                    floor = pr
                continue
            wave_idx.append(i)
            taken_rows += 1
            taken_blocks += need
            taken_of[t] = taken_of.get(t, 0) + 1
        if not wave_idx:
            return 0
        wave = [items[i] for i in wave_idx]
        for i in sorted(wave_idx, reverse=True):
            del items[i]
        fresh: list = []
        try:
            for w in wave:
                if w.suspended is not None:
                    erid = eng.paged_resume(w.suspended)
                    w.suspended = None    # cleared ONLY on success
                    self._rid_of_engine[erid] = w
                    self._tstats(w.tenant).resumes += 1
                else:
                    fresh.append(w)
            if fresh:
                rids = eng.paged_admit([(w.prompt, w.max_new)
                                        for w in fresh])
                for w, erid in zip(fresh, rids):
                    self._rid_of_engine[erid] = w
        except BaseException:
            # a failed resume rolled its allocation back and kept its stash;
            # return every wave member not yet owning an engine row to the
            # queue head (original order) so a later step retries cleanly
            owned = set(map(id, self._rid_of_engine.values()))
            items[0:0] = [w for w in wave if id(w) not in owned]
            raise
        for w in wave:
            ts = self._tstats(w.tenant)
            ts.admitted += 1
            ts.max_admission_wait = max(ts.max_admission_wait, w.wait_steps)
        return len(wave)

    def _preempt_for_head(self, items: list) -> bool:
        """Suspend the smallest set of strictly-lower-priority preemptible
        owned rows (lowest class first, newest row first within a class)
        that lets the highest-priority waiting item fit; no-op unless the
        whole set suffices.  Suspended requests re-enter the queue HEAD as
        resumable items, so the next admission pass brings them back the
        moment capacity allows."""
        eng = self.engine
        head = max(items, key=lambda w: self._spec(w.tenant).priority)
        pr = self._spec(head.tenant).priority
        victims = []
        for erid, req in self._rid_of_engine.items():
            if erid not in eng._paged_rows:
                continue
            vspec = self._spec(req.tenant)
            if vspec.preemptible and vspec.priority < pr:
                victims.append((vspec.priority, erid))
        if not victims:
            return False
        victims.sort(key=lambda v: (v[0], -v[1]))
        need = self._need(head)

        def fits(n_chosen: int, freed: int) -> bool:
            return (eng.paged_active - n_chosen < eng.max_decode_rows
                    and eng.pool.free_blocks + freed >= need)

        chosen: list[int] = []
        freed = 0
        for _p, erid in victims:
            if fits(len(chosen), freed):
                break
            chosen.append(erid)
            freed += eng.pool.freeable(eng._paged_rows[erid].blocks)
        if not fits(len(chosen), freed):
            return False                  # even everything is not enough
        for erid in chosen:
            s = eng.paged_suspend(erid)   # stash-first: a raise leaves the
            req = self._rid_of_engine.pop(erid)   # row active and owned
            req.suspended = s
            self._tstats(req.tenant).preemptions += 1
            items.insert(0, req)
        return bool(chosen)

    def pump(self) -> bool:
        """Advance the loop once: one unified :meth:`step` on paged
        engines; on lockstep engines there are no step gaps, so pending
        probe work is serviced directly.  Returns True while work remains."""
        if self.paged:
            self.step()
        else:
            self._service_fills()
            self.probe_results.update(self.run_probes())
        return self.work_remaining

    def resolve(self, future: RoundFuture) -> RoundFuture:
        """Pump the loop until ``future`` resolves (probes are serviced
        every step, so this takes at most one step — during which in-flight
        decode rows advance one token alongside)."""
        while not future.done:
            progressed = self.pump()
            if not future.done and not progressed:
                raise RuntimeError("round future cannot resolve: its probe "
                                   "work is no longer queued")
        return future

    # ----------------------------------------------------------- generate
    def generate(self, prompts, max_new: Optional[int] = None,
                 tenant: str = "default") -> list[str]:
        """Run generate requests THROUGH the live loop: submit them and
        pump until they finish.  Other queued work — probe rounds from
        concurrent plans, other drivers' decode rows — advances in the same
        steps, which is what lets a judge-rationale generation overlap
        ORDER BY probes at token granularity.  Outputs are claimed by this
        call only (an enclosing ``run`` drain keeps its own rows)."""
        if not self.paged:
            return self.engine.generate(prompts, max_new=max_new)
        # scalar max_new follows ServeEngine.generate's contract: 0/None
        # means "engine default" (a per-request zero budget is submit()'s
        # business), so the paged and lockstep branches agree
        rids = [self.submit(p, max_new or None, tenant=tenant)
                for p in prompts]
        pending = set(rids)
        while pending:
            self.step()
            pending -= self._fresh.keys()
        return [self._fresh.pop(r) for r in rids]

    # ---------------------------------------------------------------- run
    def run(self, on_step: Optional[Callable] = None) -> dict[int, str]:
        """Drain the queue; returns {rid: output} for THIS drain only.
        (Earlier drains remain queryable via ``self.completed``.)

        Continuous mode (paged engines): pumps the unified step loop until
        no decode work remains; ``on_step(self)`` runs after every step, so
        callers can submit NEW requests mid-drain — they are admitted into
        slots vacated by retiring rows while long rows keep decoding.
        Queued probe work is answered between steps.

        Lockstep mode: the whole backlog is sorted by prompt length BEFORE
        chunking into batches, so each padded batch contains similar-length
        prompts."""
        if self.paged:
            return self._run_continuous(on_step)
        drained: dict[int, str] = {}
        pending = [w for w in self.work if isinstance(w, Request)]
        self.work = [w for w in self.work if not isinstance(w, Request)]
        # sort by ENCODED length: tuple (prefix, suffix) prompts would all
        # sort as len == 2 and defeat the length grouping
        pending.sort(key=lambda r: len(self.engine._encode_prompt(r.prompt)))
        for i in range(0, len(pending), self.max_batch):
            batch = pending[i:i + self.max_batch]
            limits = [r.max_new if r.max_new is not None
                      else self.engine.max_new for r in batch]
            outs = self.engine.generate_lockstep(
                [r.prompt for r in batch],
                max_new=max(limits), max_new_per=limits)
            for r, o in zip(batch, outs):
                r.output = o
                self.completed[r.rid] = r
                drained[r.rid] = o
        return drained

    def _run_continuous(self, on_step: Optional[Callable]) -> dict[int, str]:
        drained: dict[int, str] = {}

        def claim() -> None:
            for rid in [r for r in self._fresh if r in self.completed]:
                drained[rid] = self._fresh.pop(rid)

        while any(isinstance(w, Request) for w in self.work) \
                or self._rid_of_engine:
            self.step()
            claim()
            if on_step is not None:
                on_step(self)
        claim()
        return drained

    # --------------------------------------------------------------- probes
    def run_probes(self) -> dict[int, np.ndarray]:
        """Service ALL pending probe work through length-bucketed padded
        submissions; returns {rid: last-position logits} for stand-alone
        probes of this drain (round members resolve into their futures).
        Quotas do not apply here — this is the lockstep pump path and the
        direct-call escape hatch; the step loop's gap servicing
        (:meth:`_service_probes`) is where per-tenant shares bind."""
        pending = [w for w in self.work if isinstance(w, ProbeRequest)]
        if not pending:
            return {}
        self.work = [w for w in self.work if not isinstance(w, ProbeRequest)]
        return self._service_probe_items(pending)

    def _service_probes(self) -> None:
        """Step-gap probe servicing under per-tenant quotas: pending work
        is grouped into *units* (one round's members, or a stand-alone
        probe), units are taken in (priority, arrival) order, and a unit
        past its tenant's ``probe_quota`` rows for this gap is deferred —
        unless it has aged ``starvation_bound`` gaps, which forces service
        (and trips ``starved_rounds`` for priority classes: an SLO class
        should never need the aging escape).  With no quota-bearing
        tenants registered this is exactly "service everything"."""
        pending = [w for w in self.work if isinstance(w, ProbeRequest)]
        if not pending:
            return
        eng = self.engine
        if not any(s.probe_quota is not None for s in self.tenants.values()):
            take = pending
        else:
            units: list[list[ProbeRequest]] = []
            by_future: dict[int, int] = {}
            for w in pending:
                if w.future is not None and id(w.future) in by_future:
                    units[by_future[id(w.future)]].append(w)
                    continue
                if w.future is not None:
                    by_future[id(w.future)] = len(units)
                units.append([w])
            units.sort(key=lambda u: (-self._spec(u[0].tenant).priority,
                                      u[0].rid))
            used: dict[str, int] = {}
            take = []
            for u in units:
                t = u[0].tenant
                spec = self._spec(t)
                wait = max(w.wait_steps for w in u)
                aged = wait >= self.starvation_bound
                if (spec.probe_quota is None or aged
                        or used.get(t, 0) + len(u) <= spec.probe_quota):
                    take.extend(u)
                    used[t] = used.get(t, 0) + len(u)
                    ts = self._tstats(t)
                    ts.max_round_wait = max(ts.max_round_wait, wait)
                    if aged and spec.priority > 0:
                        eng.stats.starved_rounds += 1
                else:
                    eng.stats.probe_rounds_deferred += 1
                    for w in u:
                        w.wait_steps += 1
        if not take:
            return
        taken = set(map(id, take))
        self.work = [w for w in self.work if id(w) not in taken]
        self.probe_results.update(self._service_probe_items(take))

    def _probe_chunk(self, eng) -> Optional[int]:
        """Probe-submission chunk size for ``eng``'s lane: the configured
        ``probe_batch`` (or the engine's memory ceiling), rounded UP to a
        multiple of the engine's data-shard count.  A merged drain on a
        sharded engine executes each chunk as per-data-shard row slices
        (engine ``_put_rows``); a chunk below the shard count would stay
        replicated — every shard recomputing all rows — so the gap
        servicer never hands the engine a deliberately misaligned chunk.
        Chunking only splits round MEMBERSHIP, never row content, so the
        alignment cannot change any row's bits (same-class rows pad
        identically in either chunk)."""
        mb = (self.probe_batch if self.probe_batch is not None
              else eng.max_probe_batch)
        shards = getattr(eng, "data_shards", 1)
        if mb is None or shards <= 1:
            return mb
        return -(-mb // shards) * shards

    def _service_probe_items(self, pending: list) -> dict[int, np.ndarray]:
        """Run one merged probe submission over ``pending`` (already
        removed from the queue).

        Cross-client dedup: concurrent operators draining through one
        scheduler routinely submit IDENTICAL prompts in the same drain
        (e.g. ASC and DESC queries over the same criteria — direction is
        folded client-side, so their probe streams coincide).  Each
        distinct prompt is executed once and its logits fanned out to
        every requester; the saved rows are counted in
        ``probes_deduped``.  Ledger billing is untouched — billing is a
        function of the logical prompt and happens at the oracle layer,
        so serving-side dedup follows the prefix-cache convention: fewer
        forward-pass rows, identical accounting.

        Cascade rounds run their draft wave FIRST (on the draft-engine
        lane); their escalations join this gap's large-lane submission, so
        both waves complete before the gap closes."""
        draft = [w for w in pending if w.tier == "draft"]
        if draft:
            pending = [w for w in pending if w.tier != "draft"]
            try:
                pending = pending + self._run_draft_wave(draft)
            except BaseException:
                # the draft wave re-queued its own items; large-lane items
                # of this drain were never touched, so they wait alongside
                self.work[0:0] = pending
                raise
            if not pending:
                return {}
        slot_of: dict[tuple, int] = {}
        uniq: list = []
        slots: list[int] = []
        for r in pending:
            key = _probe_key(r.prompt)
            if key not in slot_of:
                slot_of[key] = len(uniq)
                uniq.append(r.prompt)
            slots.append(slot_of[key])
        try:
            logits = self.engine.submit_probes(
                uniq, max_batch=self._probe_chunk(self.engine))
        except BaseException:
            # transient engine failure: the items must stay resolvable, so
            # they return to the queue head and the next pump retries (the
            # engine's probe path is stateless per submission — a retry
            # recomputes bit-identical logits)
            self.work[0:0] = pending
            raise
        self.probes_deduped += len(pending) - len(uniq)
        rounds_seen: set = set()
        out: dict[int, np.ndarray] = {}
        for r, s in zip(pending, slots):
            ts = self._tstats(r.tenant)
            ts.probe_rows += 1
            ts.tokens_served += 1
            key = id(r.future) if r.future is not None else id(r)
            if key not in rounds_seen:
                rounds_seen.add(key)
                # cascade rounds were counted as serviced at draft time
                if not isinstance(r.future, CascadeFuture):
                    ts.rounds_serviced += 1
            r.logits = logits[s]
            if r.future is not None:
                r.future._set(r.slot, r.logits)
            else:
                out[r.rid] = r.logits
        return out

    def _run_draft_wave(self, items: list) -> list:
        """Wave 1 of this gap's cascade rounds: one merged (deduped)
        submission on the draft engine, then each round's ``escalate``
        callback splits its slots — non-escalated slots resolve with their
        draft logits, escalated slots return as fresh large-lane
        :class:`ProbeRequest`\\ s (same prompt, same future) for the caller
        to service in the SAME gap.  Only the engine submission is
        retryable (re-queue + raise); a raising ``escalate`` is an
        oracle-layer bug, not a transient."""
        eng = self.draft_engine
        slot_of: dict[tuple, int] = {}
        uniq: list = []
        slots: list[int] = []
        for r in items:
            key = _probe_key(r.prompt)
            if key not in slot_of:
                slot_of[key] = len(uniq)
                uniq.append(r.prompt)
            slots.append(slot_of[key])
        try:
            logits = eng.submit_probes(uniq,
                                       max_batch=self._probe_chunk(eng))
        except BaseException:
            self.work[0:0] = items
            raise
        self.probes_deduped += len(items) - len(uniq)
        self.probes_drafted += len(items)
        groups: dict[int, list] = {}
        futs: dict[int, CascadeFuture] = {}
        for r, s in zip(items, slots):
            assert isinstance(r.future, CascadeFuture), \
                "draft-tier probes exist only inside cascade rounds"
            r.logits = logits[s]
            ts = self._tstats(r.tenant)
            ts.probe_rows += 1
            ts.tokens_served += 1
            if id(r.future) not in groups:
                ts.rounds_serviced += 1
            groups.setdefault(id(r.future), []).append(r)
            futs[id(r.future)] = r.future
        escalated: list = []
        for fid, members in groups.items():
            fut = futs[fid]
            esc = set(fut.escalate({w.slot: w.logits for w in members}))
            fut.escalated |= esc
            for w in members:
                if w.slot in esc:
                    escalated.append(ProbeRequest(next(_ids), w.prompt,
                                                  future=fut, slot=w.slot,
                                                  tenant=w.tenant))
                else:
                    fut._set(w.slot, w.logits)
        self.probes_escalated += len(escalated)
        return escalated

    def _service_fills(self) -> None:
        fills = [w for w in self.work if isinstance(w, PrefixFill)]
        if not fills:
            return
        self.work = [w for w in self.work if not isinstance(w, PrefixFill)]
        prompts = [p for f in fills for p in f.prompts]
        if not prompts:
            return
        try:
            n = self.engine.prefetch_prefixes(prompts)
        except BaseException:
            self.work[0:0] = fills        # transient failure: keep the work
            raise
        self.fills_serviced += len(fills)
        self.regions_prefetched += n
