"""Unified token-granularity serving loop: ONE step loop over a typed
work queue, co-scheduling decode rows, probe rounds, and prefix fills.

``BatchScheduler`` owns a single admission queue of typed work items:

 * **decode work** (``submit`` / ``generate`` / ``run``) — prefill + greedy
   decode rows that live across many steps in the paged pool;
 * **probe work** (``submit_probe`` / ``submit_probe_round``) — single-token
   read-out prefills (score / compare / yes-no) that complete the step they
   are serviced in; a *round* groups the probes of one oracle round behind a
   :class:`RoundFuture` that resolves when every member has logits;
 * **prefix-fill work** (``submit_prefix_fill``) — prefix-KV region
   prefills scheduled ahead of need, so a round's shared prefix can be
   warmed in a step gap while decode rows keep streaming.

Every :meth:`step` runs one pass of the admission policy and ONE decode
step: queued decode items are admitted FIFO into free pool/row capacity,
then ALL pending fills and probe work are serviced (probe submissions ride
the step gap — merged across submitters into length-bucketed submissions
with identical prompts deduplicated), then every active decode row advances
one token and retiring rows free their blocks.  The ordering gives both
fairness bounds by construction: a probe round submitted at any point is
answered before the NEXT decode step (a long rationale cannot delay it by
more than one step), and a probe storm cannot stall decode rows because
each step decodes exactly once regardless of probe volume.

Clients of the loop:

 * ``run()`` drains the scheduler's own backlog by pumping :meth:`step`
   until no decode work remains (``on_step`` fires between steps and may
   submit more work mid-drain);
 * ``generate()`` submits rows and pumps until THOSE rows finish — queued
   probe rounds and other drivers' rows advance alongside, which is how a
   judge rationale generation co-schedules with ORDER BY probes;
 * the probe-plan executor (``core/executor.py``) begins every suspended
   plan's deferred round (``ModelOracle.begin_probe_round`` →
   ``submit_probe_round``) and pumps ONE step — all plans' probes land in
   that step's gap, and their futures resolve between decode steps.

Engines without paged support (recurrent/MoE archs) fall back to
batch-level scheduling: the drain sorts the WHOLE backlog by prompt length,
chunks it into (max_batch)-sized batches, and runs each batch prefill +
lockstep decode to completion; probe work is serviced whenever the loop is
pumped (there are no step gaps to interleave into).  See DESIGN.md
"Unified step loop".
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from .engine import ServeEngine

_ids = itertools.count()


# ------------------------------------------------------- typed work items
@dataclass
class Request:
    """Decode work: one generate request (prefill + greedy decode row).
    ``max_new`` 0 is a genuine zero budget; None means engine default."""
    rid: int
    prompt: object           # str or (shared_prefix, per_key_suffix) pair
    max_new: Optional[int]
    output: Optional[str] = None
    block_need: Optional[int] = None     # memoized KV-pool block budget

    @property
    def done(self) -> bool:
        return self.output is not None


class RoundFuture:
    """Resolves when every probe of one round has its logits.  ``result()``
    returns the logits aligned with the round's submission order."""

    __slots__ = ("_vals", "_left")

    def __init__(self, n: int):
        self._vals: list = [None] * n
        self._left = n

    @property
    def done(self) -> bool:
        return self._left == 0

    def _set(self, slot: int, logits) -> None:
        assert self._vals[slot] is None, "probe slot resolved twice"
        self._vals[slot] = logits
        self._left -= 1

    def result(self) -> list:
        assert self.done, "round future read before resolution"
        return self._vals


@dataclass
class ProbeRequest:
    """Probe work: one single-token read-out prompt.  Stand-alone probes
    (``future is None``) deliver into ``scheduler.probe_results``; round
    members deliver into their :class:`RoundFuture` slot."""
    rid: int
    prompt: object           # str or (shared_prefix, per_key_suffix) pair
    logits: Optional[np.ndarray] = None
    future: Optional[RoundFuture] = None
    slot: int = 0


@dataclass
class PrefixFill:
    """Prefix-fill work: warm the engine's prefix-KV LRU for structured
    prompts BEFORE the round or generate wave that needs them, so the fill
    submission rides an earlier step gap."""
    rid: int
    prompts: list = field(default_factory=list)   # (prefix, suffix) pairs


def _probe_key(prompt) -> tuple:
    """Dedup key for a probe prompt.  Structured pairs are keyed as-is and
    plain strings separately — the two forms produce bit-identical logits,
    but keeping them distinct makes dedup a pure no-new-bits optimization
    (a fanned-out result is exactly the result the duplicate's own
    submission row would have computed)."""
    if isinstance(prompt, str):
        return ("s", prompt)
    return ("p", tuple(prompt))


class BatchScheduler:
    def __init__(self, engine: ServeEngine, max_batch: int = 16,
                 paged: Optional[bool] = None,
                 probe_batch: Optional[int] = None):
        self.engine = engine
        self.max_batch = max_batch
        # probe drains chunk by the ENGINE's probe memory ceiling
        # (max_probe_batch), not by max_batch: probes are single-token
        # prefills, so the decode-batch cap has no bearing on them.  Pass
        # ``probe_batch`` to override.
        self.probe_batch = probe_batch
        # paged=None: continuous loop whenever the engine supports it;
        # False pins the lockstep batch path (the benchmark baseline)
        self.paged = (engine.paged_enabled if paged is None
                      else paged and engine.paged_enabled)
        # THE unified admission queue: typed work items in arrival order
        self.work: list = []
        self.completed: dict[int, Request] = {}
        self.probe_results: dict[int, np.ndarray] = {}
        self.probes_deduped = 0    # duplicate prompts served by fan-out
        self.fills_serviced = 0    # PrefixFill work items serviced
        self.regions_prefetched = 0   # prefix regions ensured resident
        self.steps = 0             # unified steps taken (decode or probe-only)
        self._rid_of_engine: dict[int, Request] = {}
        # outputs finished by step() and not yet claimed by a driver
        # (run() claims everything; generate() claims only its own rids)
        self._fresh: dict[int, str] = {}

    # ------------------------------------------------- queue introspection
    @property
    def queue(self) -> list:
        """Pending decode work items (admission order)."""
        return [w for w in self.work if isinstance(w, Request)]

    @property
    def probe_queue(self) -> list:
        """Pending probe work items (round members and stand-alones)."""
        return [w for w in self.work if isinstance(w, ProbeRequest)]

    @property
    def work_remaining(self) -> bool:
        return bool(self.work) or bool(self._rid_of_engine)

    # ------------------------------------------------------------ submit
    def submit(self, prompt, max_new: Optional[int] = 32) -> int:
        """Enqueue decode work.  ``max_new`` is this REQUEST's budget: 0 is
        a genuine zero budget (PR-3 contract), ``None`` means the engine
        default."""
        r = Request(next(_ids), prompt, max_new)
        self.work.append(r)
        return r.rid

    def submit_probe(self, prompt) -> int:
        r = ProbeRequest(next(_ids), prompt)
        self.work.append(r)
        return r.rid

    def submit_probe_round(self, prompts) -> RoundFuture:
        """Enqueue one oracle round's probes as a unit; returns the
        :class:`RoundFuture` that resolves — logits aligned with
        ``prompts`` — when the loop services the round in a step gap."""
        fut = RoundFuture(len(prompts))
        for i, p in enumerate(prompts):
            self.work.append(ProbeRequest(next(_ids), p, future=fut, slot=i))
        return fut

    def submit_prefix_fill(self, prompts) -> int:
        """Enqueue a prefix-KV warm-up for structured ``(prefix, suffix)``
        prompts; the fill submission runs in the next step gap."""
        f = PrefixFill(next(_ids), [p for p in prompts
                                    if not isinstance(p, str)])
        self.work.append(f)
        return f.rid

    # ------------------------------------------------------ the step loop
    def step(self) -> dict[int, str]:
        """ONE unified scheduling step (paged engines only):

          1. admit queued decode work FIFO into free pool/row capacity;
          2. service pending prefix fills, then ALL pending probe work
             (merged submissions, cross-submitter dedup, futures resolve);
          3. one paged decode step — active rows advance one token, rows
             that finish retire and free their blocks.

        Returns {rid: output} for decode work finished this step (also
        recorded in ``completed`` and claimable via ``_fresh``)."""
        assert self.paged, "step() requires a paged-capable engine"
        eng = self.engine

        def get_req(r: Request):
            if r.block_need is None:      # tokenize once per request
                r.block_need = eng.paged_block_need(r.prompt, r.max_new)
            return r.prompt, r.max_new, r.block_need

        self.steps += 1
        # -- 1. decode admission (FIFO among decode items; probe and fill
        # items never block it — they hold no persistent capacity)
        decode_items = []
        rest: list = []
        for w in self.work:
            (decode_items if isinstance(w, Request) else rest).append(w)
        if decode_items:
            for req, erid in eng._paged_admit_wave(decode_items, get_req,
                                                   max_wave=self.max_batch):
                self._rid_of_engine[erid] = req
        self.work = rest + decode_items       # unadmitted decode items wait

        # -- 2. fills then probes ride the step gap
        self._service_fills()
        if any(isinstance(w, ProbeRequest) for w in self.work):
            self.probe_results.update(self.run_probes())

        # -- 3. one decode step (a no-op when no rows are active, so a
        # probe storm burns probe submissions, never decode progress)
        finished: dict[int, str] = {}
        for erid, text in eng.paged_step().items():
            req = self._rid_of_engine.pop(erid, None)
            if req is None:               # a concurrent driver's row — e.g.
                eng._paged_finished[erid] = text   # a nested generate
                continue
            req.output = text
            self.completed[req.rid] = req
            self._fresh[req.rid] = text
            finished[req.rid] = text
        return finished

    def pump(self) -> bool:
        """Advance the loop once: one unified :meth:`step` on paged
        engines; on lockstep engines there are no step gaps, so pending
        probe work is serviced directly.  Returns True while work remains."""
        if self.paged:
            self.step()
        else:
            self._service_fills()
            self.probe_results.update(self.run_probes())
        return self.work_remaining

    def resolve(self, future: RoundFuture) -> RoundFuture:
        """Pump the loop until ``future`` resolves (probes are serviced
        every step, so this takes at most one step — during which in-flight
        decode rows advance one token alongside)."""
        while not future.done:
            progressed = self.pump()
            if not future.done and not progressed:
                raise RuntimeError("round future cannot resolve: its probe "
                                   "work is no longer queued")
        return future

    # ----------------------------------------------------------- generate
    def generate(self, prompts, max_new: Optional[int] = None) -> list[str]:
        """Run generate requests THROUGH the live loop: submit them and
        pump until they finish.  Other queued work — probe rounds from
        concurrent plans, other drivers' decode rows — advances in the same
        steps, which is what lets a judge-rationale generation overlap
        ORDER BY probes at token granularity.  Outputs are claimed by this
        call only (an enclosing ``run`` drain keeps its own rows)."""
        if not self.paged:
            return self.engine.generate(prompts, max_new=max_new)
        # scalar max_new follows ServeEngine.generate's contract: 0/None
        # means "engine default" (a per-request zero budget is submit()'s
        # business), so the paged and lockstep branches agree
        rids = [self.submit(p, max_new or None) for p in prompts]
        pending = set(rids)
        while pending:
            self.step()
            pending -= self._fresh.keys()
        return [self._fresh.pop(r) for r in rids]

    # ---------------------------------------------------------------- run
    def run(self, on_step: Optional[Callable] = None) -> dict[int, str]:
        """Drain the queue; returns {rid: output} for THIS drain only.
        (Earlier drains remain queryable via ``self.completed``.)

        Continuous mode (paged engines): pumps the unified step loop until
        no decode work remains; ``on_step(self)`` runs after every step, so
        callers can submit NEW requests mid-drain — they are admitted into
        slots vacated by retiring rows while long rows keep decoding.
        Queued probe work is answered between steps.

        Lockstep mode: the whole backlog is sorted by prompt length BEFORE
        chunking into batches, so each padded batch contains similar-length
        prompts."""
        if self.paged:
            return self._run_continuous(on_step)
        drained: dict[int, str] = {}
        pending = [w for w in self.work if isinstance(w, Request)]
        self.work = [w for w in self.work if not isinstance(w, Request)]
        # sort by ENCODED length: tuple (prefix, suffix) prompts would all
        # sort as len == 2 and defeat the length grouping
        pending.sort(key=lambda r: len(self.engine._encode_prompt(r.prompt)))
        for i in range(0, len(pending), self.max_batch):
            batch = pending[i:i + self.max_batch]
            limits = [r.max_new if r.max_new is not None
                      else self.engine.max_new for r in batch]
            outs = self.engine.generate_lockstep(
                [r.prompt for r in batch],
                max_new=max(limits), max_new_per=limits)
            for r, o in zip(batch, outs):
                r.output = o
                self.completed[r.rid] = r
                drained[r.rid] = o
        return drained

    def _run_continuous(self, on_step: Optional[Callable]) -> dict[int, str]:
        drained: dict[int, str] = {}

        def claim() -> None:
            for rid in [r for r in self._fresh if r in self.completed]:
                drained[rid] = self._fresh.pop(rid)

        while any(isinstance(w, Request) for w in self.work) \
                or self._rid_of_engine:
            self.step()
            claim()
            if on_step is not None:
                on_step(self)
        claim()
        return drained

    # --------------------------------------------------------------- probes
    def run_probes(self) -> dict[int, np.ndarray]:
        """Service ALL pending probe work through length-bucketed padded
        submissions; returns {rid: last-position logits} for stand-alone
        probes of this drain (round members resolve into their futures).

        Cross-client dedup: concurrent operators draining through one
        scheduler routinely submit IDENTICAL prompts in the same drain
        (e.g. ASC and DESC queries over the same criteria — direction is
        folded client-side, so their probe streams coincide).  Each
        distinct prompt is executed once and its logits fanned out to
        every requester; the saved rows are counted in
        ``probes_deduped``.  Ledger billing is untouched — billing is a
        function of the logical prompt and happens at the oracle layer,
        so serving-side dedup follows the prefix-cache convention: fewer
        forward-pass rows, identical accounting."""
        pending = [w for w in self.work if isinstance(w, ProbeRequest)]
        self.work = [w for w in self.work if not isinstance(w, ProbeRequest)]
        if not pending:
            return {}
        slot_of: dict[tuple, int] = {}
        uniq: list = []
        slots: list[int] = []
        for r in pending:
            key = _probe_key(r.prompt)
            if key in slot_of:
                self.probes_deduped += 1
            else:
                slot_of[key] = len(uniq)
                uniq.append(r.prompt)
            slots.append(slot_of[key])
        logits = self.engine.submit_probes(
            uniq, max_batch=(self.probe_batch if self.probe_batch is not None
                             else self.engine.max_probe_batch))
        out: dict[int, np.ndarray] = {}
        for r, s in zip(pending, slots):
            r.logits = logits[s]
            if r.future is not None:
                r.future._set(r.slot, r.logits)
            else:
                out[r.rid] = r.logits
        return out

    def _service_fills(self) -> None:
        fills = [w for w in self.work if isinstance(w, PrefixFill)]
        if not fills:
            return
        self.work = [w for w in self.work if not isinstance(w, PrefixFill)]
        prompts = [p for f in fills for p in f.prompts]
        if prompts:
            self.fills_serviced += len(fills)
            self.regions_prefetched += self.engine.prefetch_prefixes(prompts)
