"""Request scheduler: queue + length-bucketed batching over the engine.

Batch-level continuous batching: each drain sorts the WHOLE backlog by
prompt length and then chunks it into (max_batch)-sized batches, so
similar-length prompts share a batch and padding waste is minimized (an
earlier version sorted only within arrival-order chunks, which padded every
mixed-length batch up to its longest straggler).  Each batch runs
prefill+decode to completion.  Token-level interleaving (paged attention)
is documented as out of scope in DESIGN.md; batch-level scheduling is what
the ORDER BY workloads need — the access paths submit many short,
similar-length scoring prompts.

Two request classes share the queue discipline:

 * **generate** requests (``submit`` / ``run``) — prefill + greedy decode,
   each request honoring its own ``max_new`` even when batched with longer
   requests (the engine masks per-row decode budgets);
 * **probe** requests (``submit_probe`` / ``run_probes``) — single-token
   read-outs (score / compare / yes-no), drained through
   :meth:`ServeEngine.submit_probes` in length-bucketed submissions.  The
   ModelOracle's round-batched verbs call ``engine.submit_probes``
   directly (one operator, one round, no queueing needed); this queue is
   the multi-client front for the same pathway — concurrent ORDER BY
   operators sharing one engine submit probes here and get them coalesced
   across operators.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Optional

import numpy as np

from .engine import ServeEngine

_ids = itertools.count()


@dataclass
class Request:
    rid: int
    prompt: str
    max_new: int
    output: Optional[str] = None

    @property
    def done(self) -> bool:
        return self.output is not None


@dataclass
class ProbeRequest:
    rid: int
    prompt: str
    logits: Optional[np.ndarray] = None


class BatchScheduler:
    def __init__(self, engine: ServeEngine, max_batch: int = 16):
        self.engine = engine
        self.max_batch = max_batch
        self.queue: list[Request] = []
        self.probe_queue: list[ProbeRequest] = []
        self.completed: dict[int, Request] = {}

    # ------------------------------------------------------------- generate
    def submit(self, prompt: str, max_new: int = 32) -> int:
        r = Request(next(_ids), prompt, max_new)
        self.queue.append(r)
        return r.rid

    def run(self) -> dict[int, str]:
        """Drain the queue; returns {rid: output} for THIS drain only.
        (Earlier drains remain queryable via ``self.completed``.)  The whole
        backlog is sorted by prompt length BEFORE chunking into batches, so
        each padded batch contains similar-length prompts."""
        drained: dict[int, str] = {}
        pending, self.queue = self.queue, []
        pending.sort(key=lambda r: len(r.prompt))
        for i in range(0, len(pending), self.max_batch):
            batch = pending[i:i + self.max_batch]
            outs = self.engine.generate([r.prompt for r in batch],
                                        max_new=max(r.max_new for r in batch),
                                        max_new_per=[r.max_new for r in batch])
            for r, o in zip(batch, outs):
                r.output = o
                self.completed[r.rid] = r
                drained[r.rid] = o
        return drained

    # --------------------------------------------------------------- probes
    def submit_probe(self, prompt: str) -> int:
        r = ProbeRequest(next(_ids), prompt)
        self.probe_queue.append(r)
        return r.rid

    def run_probes(self) -> dict[int, np.ndarray]:
        """Drain the probe queue through length-bucketed padded submissions;
        returns {rid: last-position logits} for this drain."""
        pending, self.probe_queue = self.probe_queue, []
        if not pending:
            return {}
        logits = self.engine.submit_probes([r.prompt for r in pending],
                                           max_batch=self.max_batch)
        for r, l in zip(pending, logits):
            r.logits = l
        return {r.rid: r.logits for r in pending}
