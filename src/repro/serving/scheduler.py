"""Request scheduler: queue + continuous-batching decode over the engine.

On paged-pool-capable engines a drain runs the **token-level continuous
step loop**: queued requests are admitted into free pool/row capacity,
every decode step advances all active rows at their own positions, rows
that finish retire and free their blocks immediately, and the queue is
re-polled BETWEEN steps — so a late-submitted short request completes while
a long judge generation is still decoding instead of waiting for the whole
batch (no head-of-line blocking; see DESIGN.md "Paged KV pool").  Probe
rounds queued via ``submit_probe`` are likewise drained between steps into
``probe_results``.  Engines without paged support (recurrent/MoE archs)
fall back to batch-level scheduling: the drain sorts the WHOLE backlog by
prompt length, chunks it into (max_batch)-sized batches, and runs each
batch prefill + lockstep decode to completion.

Two request classes share the queue discipline:

 * **generate** requests (``submit`` / ``run``) — prefill + greedy decode,
   each request honoring its own ``max_new`` even when batched with longer
   requests;
 * **probe** requests (``submit_probe`` / ``run_probes``) — single-token
   read-outs (score / compare / yes-no), drained through
   :meth:`ServeEngine.submit_probes` in length-bucketed submissions.  The
   ModelOracle's round-batched verbs call ``engine.submit_probes``
   directly (one operator, one round, no queueing needed); this queue is
   the multi-client front for the same pathway — the probe-plan executor
   (``core/executor.py``) defers every suspended plan's round into it and
   drains once per scheduling tick, so concurrent ORDER BY operators and
   optimizer pilots sharing one engine get their probes coalesced across
   operators, with identical prompts deduplicated per drain (executed
   once, results fanned out; see DESIGN.md "Probe-plan executor").
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from .engine import ServeEngine

_ids = itertools.count()


@dataclass
class Request:
    rid: int
    prompt: object           # str or (shared_prefix, per_key_suffix) pair
    max_new: int
    output: Optional[str] = None
    block_need: Optional[int] = None     # memoized KV-pool block budget

    @property
    def done(self) -> bool:
        return self.output is not None


@dataclass
class ProbeRequest:
    rid: int
    prompt: object           # str or (shared_prefix, per_key_suffix) pair
    logits: Optional[np.ndarray] = None


def _probe_key(prompt) -> tuple:
    """Dedup key for a probe prompt.  Structured pairs are keyed as-is and
    plain strings separately — the two forms produce bit-identical logits,
    but keeping them distinct makes dedup a pure no-new-bits optimization
    (a fanned-out result is exactly the result the duplicate's own
    submission row would have computed)."""
    if isinstance(prompt, str):
        return ("s", prompt)
    return ("p", tuple(prompt))


class BatchScheduler:
    def __init__(self, engine: ServeEngine, max_batch: int = 16,
                 paged: Optional[bool] = None,
                 probe_batch: Optional[int] = None):
        self.engine = engine
        self.max_batch = max_batch
        # probe drains chunk by the ENGINE's probe memory ceiling
        # (max_probe_batch), not by max_batch: probes are single-token
        # prefills, so the decode-batch cap has no bearing on them.  Pass
        # ``probe_batch`` to override.
        self.probe_batch = probe_batch
        # paged=None: continuous loop whenever the engine supports it;
        # False pins the lockstep batch path (the benchmark baseline)
        self.paged = (engine.paged_enabled if paged is None
                      else paged and engine.paged_enabled)
        self.queue: list[Request] = []
        self.probe_queue: list[ProbeRequest] = []
        self.completed: dict[int, Request] = {}
        self.probe_results: dict[int, np.ndarray] = {}
        self.probes_deduped = 0    # duplicate prompts served by fan-out
        self._rid_of_engine: dict[int, Request] = {}

    # ------------------------------------------------------------- generate
    def submit(self, prompt, max_new: int = 32) -> int:
        r = Request(next(_ids), prompt, max_new)
        self.queue.append(r)
        return r.rid

    def run(self, on_step: Optional[Callable] = None) -> dict[int, str]:
        """Drain the queue; returns {rid: output} for THIS drain only.
        (Earlier drains remain queryable via ``self.completed``.)

        Continuous mode (paged engines): FIFO admission into free capacity
        between decode steps; ``on_step(self)`` runs after every step, so
        callers can submit NEW requests mid-drain — they are admitted into
        slots vacated by retiring rows while long rows keep decoding.
        Queued probes are answered between steps into ``probe_results``.

        Lockstep mode: the whole backlog is sorted by prompt length BEFORE
        chunking into batches, so each padded batch contains similar-length
        prompts."""
        if self.paged:
            return self._run_continuous(on_step)
        drained: dict[int, str] = {}
        pending, self.queue = self.queue, []
        # sort by ENCODED length: tuple (prefix, suffix) prompts would all
        # sort as len == 2 and defeat the length grouping
        pending.sort(key=lambda r: len(self.engine._encode_prompt(r.prompt)))
        for i in range(0, len(pending), self.max_batch):
            batch = pending[i:i + self.max_batch]
            outs = self.engine.generate_lockstep(
                [r.prompt for r in batch],
                max_new=max(r.max_new for r in batch),
                max_new_per=[r.max_new for r in batch])
            for r, o in zip(batch, outs):
                r.output = o
                self.completed[r.rid] = r
                drained[r.rid] = o
        return drained

    def _run_continuous(self, on_step: Optional[Callable]) -> dict[int, str]:
        eng = self.engine

        def get_req(r: Request):
            if r.block_need is None:      # tokenize once per request
                r.block_need = eng.paged_block_need(r.prompt, r.max_new)
            return r.prompt, r.max_new, r.block_need

        drained: dict[int, str] = {}
        while self.queue or self._rid_of_engine:
            for req, erid in eng._paged_admit_wave(self.queue, get_req,
                                                   max_wave=self.max_batch):
                self._rid_of_engine[erid] = req
            if self.probe_queue:          # probe rounds ride the step gaps
                self.probe_results.update(self.run_probes())
            for erid, text in eng.paged_step().items():
                req = self._rid_of_engine.pop(erid, None)
                if req is None:           # a concurrent driver's row — e.g.
                    eng._paged_finished[erid] = text   # on_step ran generate
                    continue
                req.output = text
                self.completed[req.rid] = req
                drained[req.rid] = text
            if on_step is not None:
                on_step(self)
        return drained

    # --------------------------------------------------------------- probes
    def submit_probe(self, prompt) -> int:
        r = ProbeRequest(next(_ids), prompt)
        self.probe_queue.append(r)
        return r.rid

    def run_probes(self) -> dict[int, np.ndarray]:
        """Drain the probe queue through length-bucketed padded submissions;
        returns {rid: last-position logits} for this drain.

        Cross-client dedup: concurrent operators draining through one
        scheduler routinely submit IDENTICAL prompts in the same drain
        (e.g. ASC and DESC queries over the same criteria — direction is
        folded client-side, so their probe streams coincide).  Each
        distinct prompt is executed once and its logits fanned out to
        every requester; the saved rows are counted in
        ``probes_deduped``.  Ledger billing is untouched — billing is a
        function of the logical prompt and happens at the oracle layer,
        so serving-side dedup follows the prefix-cache convention: fewer
        forward-pass rows, identical accounting."""
        pending, self.probe_queue = self.probe_queue, []
        if not pending:
            return {}
        slot_of: dict[tuple, int] = {}
        uniq: list = []
        slots: list[int] = []
        for r in pending:
            key = _probe_key(r.prompt)
            if key in slot_of:
                self.probes_deduped += 1
            else:
                slot_of[key] = len(uniq)
                uniq.append(r.prompt)
            slots.append(slot_of[key])
        logits = self.engine.submit_probes(
            uniq, max_batch=(self.probe_batch if self.probe_batch is not None
                             else self.engine.max_probe_batch))
        for r, s in zip(pending, slots):
            r.logits = logits[s]
        return {r.rid: r.logits for r in pending}
