"""Block-paged KV pool: the ONE memory scheme behind serving.

A fixed arena of per-layer KV blocks (one :class:`~..models.layers.PagedKV`
per decoder stack, leaves (n_layers, num_blocks, block_size, KV, hd)) with a
host-side free-list allocator, per-sequence block tables, and ref-counted
block sharing.  Two previously unrelated memory schemes ride it:

 * **prefix-cache entries** (engine LRU) hold their region KV as a *pinned
   block run* — probe window jobs gather the run into the dense view the
   suffix-only prefill consumes, and decode sequences whose prompt shares
   the prefix incref the run's full blocks and append private blocks after
   it instead of re-materializing the prefix;
 * **decode sequences** (continuous-batching rows) own an ordered run of
   blocks covering positions ``[0, class + budget)``; a finished row frees
   its private blocks *immediately* (decref — shared prefix blocks survive
   while the LRU or other rows still hold them), so vacated memory admits
   queued requests between decode steps.

Block 0 is a permanent dummy: padded block-table slots and bucket-dummy
rows point (and may write) there, and it is never allocated, so its garbage
is only ever read through a NEG_INF mask.  Allocation/refcounts are plain
Python/numpy (the scheduler is host-side anyway); only the arenas live on
device, updated functionally by the jitted decode step and the eager
scatter/gather helpers here.  See DESIGN.md "Paged KV pool".
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp
import numpy as np

from ..models.layers import KVCache, PagedKV, dtype_of


class PoolExhausted(RuntimeError):
    """Raised when an allocation cannot be satisfied even after the caller
    has evicted everything it is willing to evict."""


class KVBlockPool:
    def __init__(self, lm, num_blocks: int, block_size: int = 16,
                 mesh=None, plan=None):
        cfg = lm.cfg
        assert num_blocks >= 2, "need at least one real block beyond dummy 0"
        assert all(kind == "attn" for kind, _ in cfg.pattern), (
            "the paged pool holds full-attention KV only")
        self.block_size = block_size
        self.num_blocks = num_blocks
        dt = dtype_of(cfg.dtype)
        kv, hd = cfg.n_kv_heads, cfg.hd
        self.arenas = [
            PagedKV(k=jnp.zeros((n, num_blocks, block_size, kv, hd), dt),
                    v=jnp.zeros((n, num_blocks, block_size, kv, hd), dt))
            for kind, n in cfg.pattern]
        # Serving mesh (ServeEngine(mesh=...)): arenas become NamedSharding'd
        # arrays in the FEATURE layout — kv-heads over `model`, block dim
        # replicated — so everything below this line (free list, refcounts,
        # stashes) is mesh-oblivious: a block id addresses the same arena
        # slice on every device.  ``_pin`` re-commits eager scatter/gather
        # results to the canonical layout (a no-op when already there).
        self.arena_shardings = None
        if mesh is not None:
            import jax
            from ..distributed.sharding import (ShardingPlan, arena_specs,
                                                named)
            self.arena_shardings = named(
                mesh, arena_specs(self.arenas, mesh, plan or ShardingPlan()))
            self.arenas = jax.device_put(self.arenas, self.arena_shardings)
        # LIFO free list, block 0 (dummy) excluded for good
        self._free = list(range(num_blocks - 1, 0, -1))
        self._ref = np.zeros(num_blocks, np.int64)
        self.peak_in_use = 0
        self.total_allocs = 0
        # probe-row leases (see ServeEngine._lease_probe_blocks): transient
        # single-submission holds that arbitrate the same budget as decode
        # rows; counted separately so capacity reports can split persistent
        # occupancy from probe traffic
        self.total_leased = 0
        self.lease_shortfalls = 0
        # preemption traffic (see ServeEngine.paged_suspend/paged_resume):
        # blocks copied out to host stashes and scattered back
        self.total_stashed = 0
        self.total_unstashed = 0

    def _pin(self, si: int, arena):
        """Re-commit an eagerly-updated arena to the canonical sharding.
        Eager scatter (`.at[ids].set`) lets XLA pick the result layout; a
        device_put to the known NamedSharding is a no-op when it already
        matches and a reshard otherwise, so the donated decode step always
        sees identically-laid-out input."""
        if self.arena_shardings is None:
            return arena
        import jax
        return jax.device_put(arena, self.arena_shardings[si])

    # ---------------------------------------------------------- allocator
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def blocks_in_use(self) -> int:
        return self.num_blocks - 1 - len(self._free)

    def blocks_for(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 0) // self.block_size)

    def alloc(self, n: int) -> list[int]:
        """Allocate ``n`` blocks with refcount 1."""
        if n > len(self._free):
            raise PoolExhausted(
                f"need {n} blocks, {len(self._free)} free "
                f"(pool {self.num_blocks}, block_size {self.block_size})")
        ids = [self._free.pop() for _ in range(n)]
        self._ref[ids] = 1
        self.total_allocs += n
        self.peak_in_use = max(self.peak_in_use, self.blocks_in_use)
        return ids

    def lease(self, n: int) -> "list[int] | None":
        """Best-effort transient allocation: ``n`` blocks with refcount 1
        when the free list can host them, ``None`` otherwise (the caller
        proceeds with unpooled transient memory — a lease never raises and
        never evicts).  Released via :meth:`decref` like any run."""
        if n > len(self._free):
            self.lease_shortfalls += 1
            return None
        # ownership transfers to the lease holder, who decrefs the run
        ids = self.alloc(n)  # lint: disable=kv-pairing
        self.total_leased += n
        return ids

    def freeable(self, ids: Sequence[int]) -> int:
        """How many of ``ids`` would return to the free list on one decref
        (refcount 1 — not shared with an LRU entry or another row).  The
        preemption policy uses this to size victim sets honestly: suspending
        a row whose run is mostly shared prefix frees little."""
        return sum(1 for i in ids if self._ref[i] == 1)

    def incref(self, ids: Sequence[int]) -> None:
        for i in ids:
            assert self._ref[i] > 0, f"incref of free block {i}"
            self._ref[i] += 1

    def decref(self, ids: Sequence[int]) -> None:
        """Drop one reference per id; blocks reaching 0 return to the free
        list (this IS ``free`` — owners simply drop their reference)."""
        for i in ids:
            assert self._ref[i] > 0, f"decref of free block {i}"
            self._ref[i] -= 1
            if self._ref[i] == 0:
                self._free.append(int(i))

    # ------------------------------------------------- preemption stashes
    def stash_blocks(self, ids: Sequence[int]) -> list:
        """Copy the contents of ``ids`` to a host-side stash (the suspend
        half of decode-row preemption): per decoder stack, the (n, len(ids),
        block_size, KV, hd) K/V slabs as numpy arrays.  A stash is a plain
        value — it holds no pool references, so the caller decides when the
        source blocks are released."""
        idx = jnp.asarray(np.asarray(list(ids), np.int32))
        stash = [(np.asarray(jnp.take(a.k, idx, axis=1)),
                  np.asarray(jnp.take(a.v, idx, axis=1)))
                 for a in self.arenas]
        self.total_stashed += len(ids)
        return stash

    def unstash_blocks(self, stash: list, ids: Sequence[int]) -> None:
        """Scatter a stash back into ``ids`` (the resume half): the blocks
        need not be the ones stashed from — block contents are
        position-independent, the row's block TABLE carries the ordering —
        and a gather-out/scatter-back round trip is a copy of the stored
        bits, so a resumed row decodes bit-identically to one never
        suspended."""
        ids = list(ids)
        assert stash and all(k.shape[1] == len(ids) for k, _ in stash), (
            "stash block count must match the destination run")
        idx = jnp.asarray(np.asarray(ids, np.int32))
        for si, (k, v) in enumerate(stash):
            arena = self.arenas[si]
            self.arenas[si] = self._pin(si, PagedKV(
                k=arena.k.at[:, idx].set(jnp.asarray(k)),
                v=arena.v.at[:, idx].set(jnp.asarray(v))))
        self.total_unstashed += len(ids)

    # ------------------------------------------------------ device arenas
    def write(self, stack_caches, row_blocks: Sequence[Sequence[int]],
              start: int = 0) -> None:
        """Scatter prefill-computed KV into block runs: positions
        ``[start, S)`` of row ``r`` of ``stack_caches`` (a per-stack list of
        stacked :class:`KVCache`, leaves (n, B, S, KV, hd)) land in
        ``row_blocks[r]`` in order.  ``start`` must be block-aligned (a row
        appending after shared prefix blocks starts at their boundary);
        trailing bucket-dummy rows of the prefill batch (B > len(row_blocks))
        are dropped.  The partial last block is zero-padded — readers mask by
        valid length, never by block occupancy."""
        if not row_blocks:
            return
        bs = self.block_size
        assert start % bs == 0, "write start must be block-aligned"
        nb = len(row_blocks[0])
        assert all(len(b) == nb for b in row_blocks), (
            "rows of one write must cover equal block counts")
        ids = jnp.asarray(np.concatenate(
            [np.asarray(b, np.int32) for b in row_blocks]))
        rows = len(row_blocks)
        for si, cache in enumerate(stack_caches):
            k, v = cache.k, cache.v                  # (n, B, S, kv, hd)
            n, _, s = k.shape[:3]
            span = s - start
            pad = nb * bs - span
            assert pad >= 0, f"run of {nb} blocks < {span} positions"

            def to_blocks(leaf):
                leaf = leaf[:, :rows, start:]
                if pad:
                    leaf = jnp.pad(leaf, ((0, 0), (0, 0), (0, pad),
                                          (0, 0), (0, 0)))
                return leaf.reshape(n, rows * nb, bs, *leaf.shape[3:])

            arena = self.arenas[si]
            self.arenas[si] = self._pin(si, PagedKV(
                k=arena.k.at[:, ids].set(to_blocks(k)),
                v=arena.v.at[:, ids].set(to_blocks(v))))

    def gather_stacked(self, block_ids: Sequence[int], length: int):
        """Materialize a block run as the dense per-stack cache pytree the
        chunked-prefill path consumes: a list of :class:`KVCache` with
        k/v (n, 1, length, KV, hd) and pos (n, length).  A gather is a copy
        of the stored bits, so downstream compute is bit-identical to
        holding the dense cache directly."""
        ids = jnp.asarray(np.asarray(block_ids, np.int32))
        out = []
        for arena in self.arenas:
            n = arena.k.shape[0]

            def dense(leaf):
                g = jnp.take(leaf, ids, axis=1)      # (n, nb, bs, kv, hd)
                g = g.reshape(n, 1, -1, *g.shape[3:])
                return g[:, :, :length]

            pos = jnp.broadcast_to(jnp.arange(length, dtype=jnp.int32),
                                   (n, length))
            out.append(KVCache(dense(arena.k), dense(arena.v), pos))
        return out
