from .engine import ServeEngine, ServeStats
from .kv_pool import KVBlockPool, PoolExhausted
from .locality import plan_window_jobs, prefetch_candidates
from .scheduler import BatchScheduler, Request, RoundFuture

__all__ = ["ServeEngine", "ServeStats", "KVBlockPool", "PoolExhausted",
           "BatchScheduler", "Request", "RoundFuture",
           "plan_window_jobs", "prefetch_candidates"]
