from .engine import ServeEngine, ServeStats, SuspendedRow
from .kv_pool import KVBlockPool, PoolExhausted
from .locality import plan_window_jobs, prefetch_candidates
from .scheduler import (BatchScheduler, Request, RoundFuture,
                        TenantBudgetExceeded, TenantSpec, TenantStats)

__all__ = ["ServeEngine", "ServeStats", "SuspendedRow", "KVBlockPool",
           "PoolExhausted", "BatchScheduler", "Request", "RoundFuture",
           "TenantSpec", "TenantStats", "TenantBudgetExceeded",
           "plan_window_jobs", "prefetch_candidates"]
