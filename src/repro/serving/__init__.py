from .engine import ServeEngine, ServeStats
from .scheduler import BatchScheduler, Request

__all__ = ["ServeEngine", "ServeStats", "BatchScheduler", "Request"]
