from .engine import ServeEngine, ServeStats
from .kv_pool import KVBlockPool, PoolExhausted
from .scheduler import BatchScheduler, Request, RoundFuture

__all__ = ["ServeEngine", "ServeStats", "KVBlockPool", "PoolExhausted",
           "BatchScheduler", "Request", "RoundFuture"]
