"""Batched serving engine: prefill + greedy decode over the unified LM API,
plus the ranking read-outs the ModelOracle needs (score / compare /
rank-window / yes-no), all funneled through ONE probe pathway
(:meth:`ServeEngine.submit_probes`) so a round of independent logical calls
costs a single padded prefill submission (``stats.calls`` counts
submissions).  Submission shapes are bucketed to powers of two to bound XLA
compiles under variable round sizes (see DESIGN.md).

Prompts are byte-tokenized, left-padded per batch, and executed with two
jit-compiled programs (prefill, decode_step) shared across calls; on the
production mesh the same functions are lowered with sharded params/caches by
launch/serve.py.  Read-outs follow standard logit-probe practice:

 * score(text)      -> logit('9') - logit('0') after a "Rating:" prompt,
 * compare(a, b)    -> logit('A') vs logit('B') after a comparison prompt,
 * rank_window(ks)  -> scores computed in one shared-prefix batch (this is
   what makes listwise calls cheaper than k pointwise calls — the shared
   instruction prefix is tokenized/prefilled once per row, exactly the
   batching economics the paper's external paths exploit).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..data.tokenizer import BOS, EOS, PAD, ByteTokenizer
from ..models.model import LM

TOK_A, TOK_B = ord("A"), ord("B")
TOK_HI, TOK_LO = ord("9"), ord("0")
TOK_YES, TOK_NO = ord("Y"), ord("N")


@dataclass
class ServeStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    calls: int = 0


def _next_pow2(x: int) -> int:
    return 1 << max(x - 1, 0).bit_length()


class ServeEngine:
    def __init__(self, lm: LM, params, max_new_tokens: int = 32,
                 bucket_shapes: bool = True, max_probe_batch: int = 256):
        self.lm = lm
        self.params = params
        self.tok = ByteTokenizer()
        assert lm.cfg.vocab_size >= self.tok.vocab_size, (
            f"model vocab {lm.cfg.vocab_size} < tokenizer vocab "
            f"{self.tok.vocab_size}: special ids would index out of range")
        self.max_new = max_new_tokens
        # Shape bucketing: round (rows, seq_len) of every submission up to the
        # next power of two, so the round-batched access paths — whose batch
        # size varies call to call — reuse a handful of compiled programs
        # instead of triggering an XLA compile per novel shape.  Dummy rows
        # are all-PAD and their logits are discarded.
        self.bucket_shapes = bucket_shapes
        # Memory ceiling for one probe submission: a round of N logical
        # calls becomes ceil(N / max_probe_batch) submissions, so huge
        # rounds (pointwise over thousands of keys) cannot build one
        # device-filling prefill batch.
        self.max_probe_batch = max_probe_batch
        self.stats = ServeStats()
        self._prefill = jax.jit(partial(lm.prefill, reserve=max_new_tokens))
        self._decode = jax.jit(lm.decode_step)
        self._embed_cache: dict = {}

    # ------------------------------------------------------------- tokenize
    def _batch_tokens(self, prompts: Sequence[str]) -> np.ndarray:
        ids = [self.tok.encode(p) for p in prompts]
        maxlen = max(len(i) for i in ids)
        rows = len(ids)
        if self.bucket_shapes:
            maxlen = _next_pow2(max(maxlen, 16))
            rows = _next_pow2(rows)
        arr = np.full((rows, maxlen), PAD, np.int32)
        for r, i in enumerate(ids):
            arr[r, maxlen - len(i):] = i          # left-pad: last pos = live
        return arr

    def _make_batch(self, tokens: np.ndarray) -> dict:
        cfg = self.lm.cfg
        batch: dict = {"tokens": jnp.asarray(tokens)}
        if cfg.input_mode == "embeds":
            # VLM stub frontend: embed text bytes through the text table
            batch = {"embeds": jnp.take(self.params["embed"],
                                        jnp.asarray(tokens), axis=0),
                     "tokens": jnp.asarray(tokens)}
            batch = {"embeds": batch["embeds"]}
        elif cfg.input_mode == "encdec":
            emb = jnp.take(self.params["embed"], jnp.asarray(tokens), axis=0)
            batch = {"enc_embeds": emb, "tokens": jnp.asarray(tokens)}
        return batch

    # --------------------------------------------------------------- probes
    def submit_probes(self, prompts: Sequence[str],
                      max_batch: Optional[int] = None) -> np.ndarray:
        """THE probe pathway: run a round of independent single-token probes
        as one (or, when ``max_batch`` bounds padded batch size, a few
        length-bucketed) padded prefill submissions; returns last-position
        logits aligned with ``prompts``.  Every oracle read-out (score /
        compare / yes-no / judge) funnels through here, so ``stats.calls``
        counts *serving submissions*, not logical LLM calls.  ``max_batch``
        defaults to the engine's ``max_probe_batch`` memory ceiling.

        Prompts are grouped by PADDED-LENGTH CLASS (the power-of-two bucket
        with ``bucket_shapes``, exact token length without), never mixing
        classes in one submission.  The model has no PAD attention mask, so
        a row's logits depend on its padded length; same-class grouping
        makes each prompt's padding a function of its own length only —
        batched results are bit-identical to sequential point submissions."""
        n = len(prompts)
        if n == 0:
            return np.zeros((0, self.lm.cfg.vocab_size), np.float32)
        if max_batch is None:
            max_batch = self.max_probe_batch
        by_class: dict[int, list[int]] = {}
        for i, p in enumerate(prompts):
            ln = len(self.tok.encode(p))
            cls = _next_pow2(max(ln, 16)) if self.bucket_shapes else ln
            by_class.setdefault(cls, []).append(i)
        groups = []
        for cls in sorted(by_class):
            idx = by_class[cls]
            # max_batch None here means the engine was built with
            # max_probe_batch=None: explicitly unbounded submissions
            step = len(idx) if max_batch is None else max_batch
            groups.extend(idx[i:i + step] for i in range(0, len(idx), step))
        out = np.zeros((n, self.lm.cfg.vocab_size), np.float32)
        for g in groups:
            tokens = self._batch_tokens([prompts[i] for i in g])
            logits, _ = self._prefill(self.params, self._make_batch(tokens))
            self.stats.prefill_tokens += int(tokens.size)
            self.stats.calls += 1
            out[np.asarray(g)] = np.asarray(
                logits.astype(jnp.float32))[:len(g)]  # drop bucket-pad rows
        return out

    def last_logits(self, prompts: Sequence[str]) -> np.ndarray:
        return self.submit_probes(prompts)

    def score(self, texts: Sequence[str], criteria: str) -> list[float]:
        prompts = [f"Criteria: {criteria}\nItem: {t}\nRating:" for t in texts]
        logits = self.submit_probes(prompts)
        return [float(l[TOK_HI] - l[TOK_LO]) for l in logits]

    def _compare_prompt(self, a: str, b: str, criteria: str) -> str:
        return (f"Criteria: {criteria}\nPassage A: {a}\nPassage B: {b}\n"
                f"Which ranks higher? Answer:")

    def compare(self, a: str, b: str, criteria: str) -> int:
        return self.compare_many([(a, b)], criteria)[0]

    def compare_many(self, pairs: Sequence[tuple[str, str]],
                     criteria: str) -> list[int]:
        """A round of independent comparisons in one probe submission."""
        logits = self.submit_probes(
            [self._compare_prompt(a, b, criteria) for a, b in pairs])
        return [1 if l[TOK_A] > l[TOK_B] else -1 for l in logits]

    def yes_no(self, prompt: str) -> bool:
        return self.yes_no_many([prompt])[0]

    def yes_no_many(self, prompts: Sequence[str]) -> list[bool]:
        """A round of independent Y/N probes in one probe submission."""
        logits = self.submit_probes(prompts)
        return [bool(l[TOK_YES] > l[TOK_NO]) for l in logits]

    def rank_window(self, texts: Sequence[str], criteria: str) -> list[int]:
        """Permutation (ascending by score) from one shared-criteria batch."""
        scores = self.score(texts, criteria)
        return list(np.argsort(np.asarray(scores), kind="stable"))

    # ------------------------------------------------------------- generate
    def generate(self, prompts: Sequence[str], max_new: Optional[int] = None,
                 max_new_per: Optional[Sequence[int]] = None) -> list[str]:
        """Batched greedy decode.  ``max_new_per`` gives each row its own
        decode budget (the scheduler batches requests with differing
        ``max_new``); rows that hit their budget are masked done and emit
        EOS while the rest of the batch keeps decoding."""
        max_new = min(max_new or self.max_new, self.max_new)
        n = len(prompts)
        tokens = self._batch_tokens(prompts)
        b, s = tokens.shape                       # b >= n with bucket_shapes
        if max_new_per is None:
            limits = np.full((n,), max_new, np.int64)
        else:
            assert len(max_new_per) == n
            limits = np.minimum(np.asarray(max_new_per, np.int64), self.max_new)
        limits = np.concatenate([limits, np.zeros((b - n,), np.int64)])
        horizon = int(limits.max(initial=0))
        logits, caches = self._prefill(self.params, self._make_batch(tokens))
        self.stats.prefill_tokens += int(tokens.size)
        self.stats.calls += 1
        out = np.full((b, horizon), EOS, np.int64)  # unwritten tail decodes empty
        cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        done = limits <= 0
        for t in range(horizon):
            out[:, t] = np.where(done, EOS, np.asarray(cur[:, 0]))
            done |= np.asarray(cur[:, 0]) == EOS
            done |= (t + 1) >= limits
            if done.all():
                break
            logits, caches = self._decode(self.params, caches, cur,
                                          jnp.int32(s + t))
            self.stats.decode_tokens += int((~done).sum())
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return [self.tok.decode(row) for row in out[:n]]
