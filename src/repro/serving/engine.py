"""Batched serving engine: prefill + greedy decode over the unified LM API,
plus the three ranking read-outs the ModelOracle needs (score / compare /
rank-window).

Prompts are byte-tokenized, right-padded per batch, and executed with two
jit-compiled programs (prefill, decode_step) shared across calls; on the
production mesh the same functions are lowered with sharded params/caches by
launch/serve.py.  Read-outs follow standard logit-probe practice:

 * score(text)      -> logit('9') - logit('0') after a "Rating:" prompt,
 * compare(a, b)    -> logit('A') vs logit('B') after a comparison prompt,
 * rank_window(ks)  -> scores computed in one shared-prefix batch (this is
   what makes listwise calls cheaper than k pointwise calls — the shared
   instruction prefix is tokenized/prefilled once per row, exactly the
   batching economics the paper's external paths exploit).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..data.tokenizer import BOS, EOS, PAD, ByteTokenizer
from ..models.model import LM

TOK_A, TOK_B = ord("A"), ord("B")
TOK_HI, TOK_LO = ord("9"), ord("0")
TOK_YES, TOK_NO = ord("Y"), ord("N")


@dataclass
class ServeStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    calls: int = 0


class ServeEngine:
    def __init__(self, lm: LM, params, max_new_tokens: int = 32):
        self.lm = lm
        self.params = params
        self.tok = ByteTokenizer()
        assert lm.cfg.vocab_size >= self.tok.vocab_size, (
            f"model vocab {lm.cfg.vocab_size} < tokenizer vocab "
            f"{self.tok.vocab_size}: special ids would index out of range")
        self.max_new = max_new_tokens
        self.stats = ServeStats()
        self._prefill = jax.jit(partial(lm.prefill, reserve=max_new_tokens))
        self._decode = jax.jit(lm.decode_step)
        self._embed_cache: dict = {}

    # ------------------------------------------------------------- tokenize
    def _batch_tokens(self, prompts: Sequence[str]) -> np.ndarray:
        ids = [self.tok.encode(p) for p in prompts]
        maxlen = max(len(i) for i in ids)
        arr = np.full((len(ids), maxlen), PAD, np.int32)
        for r, i in enumerate(ids):
            arr[r, maxlen - len(i):] = i          # left-pad: last pos = live
        return arr

    def _make_batch(self, tokens: np.ndarray) -> dict:
        cfg = self.lm.cfg
        batch: dict = {"tokens": jnp.asarray(tokens)}
        if cfg.input_mode == "embeds":
            # VLM stub frontend: embed text bytes through the text table
            batch = {"embeds": jnp.take(self.params["embed"],
                                        jnp.asarray(tokens), axis=0),
                     "tokens": jnp.asarray(tokens)}
            batch = {"embeds": batch["embeds"]}
        elif cfg.input_mode == "encdec":
            emb = jnp.take(self.params["embed"], jnp.asarray(tokens), axis=0)
            batch = {"enc_embeds": emb, "tokens": jnp.asarray(tokens)}
        return batch

    # --------------------------------------------------------------- probes
    def last_logits(self, prompts: Sequence[str]) -> np.ndarray:
        tokens = self._batch_tokens(prompts)
        logits, _ = self._prefill(self.params, self._make_batch(tokens))
        self.stats.prefill_tokens += int(tokens.size)
        self.stats.calls += 1
        return np.asarray(logits.astype(jnp.float32))

    def score(self, texts: Sequence[str], criteria: str) -> list[float]:
        prompts = [f"Criteria: {criteria}\nItem: {t}\nRating:" for t in texts]
        logits = self.last_logits(prompts)
        return [float(l[TOK_HI] - l[TOK_LO]) for l in logits]

    def compare(self, a: str, b: str, criteria: str) -> int:
        p = (f"Criteria: {criteria}\nPassage A: {a}\nPassage B: {b}\n"
             f"Which ranks higher? Answer:")
        logits = self.last_logits([p])[0]
        return 1 if logits[TOK_A] > logits[TOK_B] else -1

    def yes_no(self, prompt: str) -> bool:
        logits = self.last_logits([prompt])[0]
        return bool(logits[TOK_YES] > logits[TOK_NO])

    def rank_window(self, texts: Sequence[str], criteria: str) -> list[int]:
        """Permutation (ascending by score) from one shared-criteria batch."""
        scores = self.score(texts, criteria)
        return list(np.argsort(np.asarray(scores), kind="stable"))

    # ------------------------------------------------------------- generate
    def generate(self, prompts: Sequence[str], max_new: Optional[int] = None
                 ) -> list[str]:
        max_new = min(max_new or self.max_new, self.max_new)
        tokens = self._batch_tokens(prompts)
        b, s = tokens.shape
        logits, caches = self._prefill(self.params, self._make_batch(tokens))
        self.stats.prefill_tokens += int(tokens.size)
        self.stats.calls += 1
        out = np.zeros((b, max_new), np.int64)
        cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        done = np.zeros((b,), bool)
        for t in range(max_new):
            out[:, t] = np.where(done, EOS, np.asarray(cur[:, 0]))
            done |= np.asarray(cur[:, 0]) == EOS
            if done.all():
                break
            logits, caches = self._decode(self.params, caches, cur,
                                          jnp.int32(s + t))
            self.stats.decode_tokens += b
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return [self.tok.decode(row) for row in out]
