"""Batched serving engine: prefill + greedy decode over the unified LM API,
plus the ranking read-outs the ModelOracle needs (score / compare /
rank-window / yes-no), all funneled through ONE probe pathway
(:meth:`ServeEngine.submit_probes`) so a round of independent logical calls
costs a single padded prefill submission (``stats.calls`` counts
submissions).  Submission shapes are bucketed to powers of two to bound XLA
compiles under variable round sizes (see DESIGN.md).

Prompts are byte-tokenized, left-padded per batch, and executed with two
jit-compiled programs (prefill, decode_step) shared across calls; on the
production mesh the same functions are lowered with sharded params/caches by
launch/serve.py.  Read-outs follow standard logit-probe practice:

 * score(text)      -> logit('9') - logit('0') after a "Rating:" prompt,
 * compare(a, b)    -> logit('A') vs logit('B') after a comparison prompt,
 * rank_window(ks)  -> scores computed in one shared-prefix batch (this is
   what makes listwise calls cheaper than k pointwise calls — the shared
   instruction prefix is tokenized/prefilled once per row, exactly the
   batching economics the paper's external paths exploit).

Prefix-KV cache: probe prompts arrive as ``(shared_prefix, per_key_suffix)``
pairs (plain strings still work, uncached).  The engine prefills each
distinct ``(prefix token ids, absolute start position)`` region ONCE, holds
its per-layer KV in an LRU, and runs suffix-only prefill on top of the
broadcast cached KV — so a quicksort partition round prefills its pivot
block once instead of once per row.  Because the model has no PAD attention
mask, a row's logits depend on its left-padded length; keying the cache on
the absolute start position (equivalently the PAD count of the row's
padded-length class) keeps cached execution bit-identical to monolithic
prefill.  See DESIGN.md "Prefix-KV cache".
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from functools import partial
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..data.tokenizer import BOS, EOS, PAD, ByteTokenizer
from ..models.model import LM

TOK_A, TOK_B = ord("A"), ord("B")
TOK_HI, TOK_LO = ord("9"), ord("0")
TOK_YES, TOK_NO = ord("Y"), ord("N")

# a probe prompt: plain string, or a (shared_prefix, per_key_suffix) pair —
# core.oracles.base.PromptParts is such a pair (the full prompt is the
# concatenation; the pair form additionally enables prefix-KV reuse)
Prompt = Union[str, tuple]


@dataclass
class ServeStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    calls: int = 0
    # prefix-KV cache counters: hits/misses are per entry lookup;
    # fill_submissions counts the region-prefill forward passes (kept out
    # of ``calls``, which counts PROBE submissions); tokens_saved is the
    # padded prefill token count avoided vs monolithic whole-prompt
    # submissions, net of fill costs.
    prefix_hits: int = 0
    prefix_misses: int = 0
    prefix_fill_submissions: int = 0
    prefix_tokens_saved: int = 0

    @property
    def prefix_hit_rate(self) -> float:
        total = self.prefix_hits + self.prefix_misses
        return self.prefix_hits / total if total else 0.0


def _next_pow2(x: int) -> int:
    return 1 << max(x - 1, 0).bit_length()


class ServeEngine:
    def __init__(self, lm: LM, params, max_new_tokens: int = 32,
                 bucket_shapes: bool = True, max_probe_batch: int = 256,
                 prefix_cache_size: int = 64):
        self.lm = lm
        self.params = params
        self.tok = ByteTokenizer()
        assert lm.cfg.vocab_size >= self.tok.vocab_size, (
            f"model vocab {lm.cfg.vocab_size} < tokenizer vocab "
            f"{self.tok.vocab_size}: special ids would index out of range")
        self.max_new = max_new_tokens
        # Shape bucketing: round (rows, seq_len) of every submission up to the
        # next power of two, so the round-batched access paths — whose batch
        # size varies call to call — reuse a handful of compiled programs
        # instead of triggering an XLA compile per novel shape.  Dummy rows
        # are all-PAD and their logits are discarded.
        self.bucket_shapes = bucket_shapes
        # Memory ceiling for one probe submission: a round of N logical
        # calls becomes ceil(N / max_probe_batch) submissions, so huge
        # rounds (pointwise over thousands of keys) cannot build one
        # device-filling prefill batch.
        self.max_probe_batch = max_probe_batch
        # Prefix-KV cache: LRU of per-layer KV for distinct
        # (prefix token ids, absolute start position) regions; 0 disables.
        # Only full-attention token-input decoder stacks qualify — other
        # archs silently fall back to monolithic prefill.
        self.prefix_cache_size = prefix_cache_size
        self.prefix_cache_enabled = (
            prefix_cache_size > 0 and self._supports_prefix_cache())
        self._prefix_lru: OrderedDict[tuple, object] = OrderedDict()
        self.stats = ServeStats()
        self._prefill = jax.jit(partial(lm.prefill, reserve=max_new_tokens))
        self._decode = jax.jit(lm.decode_step)
        # prefix regions need exact-length caches (reserve=0) so the suffix
        # lands at the right absolute positions
        self._prefill_exact = jax.jit(partial(lm.prefill, reserve=0))
        self._prefill_cont = jax.jit(lm.prefill_cont)
        self._embed_cache: dict = {}

    def _supports_prefix_cache(self) -> bool:
        # bit-identity requires every layer's output for a row to be a pure
        # function of that row and its own sequence: einsum/bf16 attention
        # maps 1:1 onto _attn_cont, but qchunk's scan-blocked softmax has a
        # different reduction order, and MoE dispatch is capacity-ranked
        # ACROSS the batch (a row's logits depend on its batch-mates), so
        # both fall back to monolithic prefill, like non-attention kinds
        cfg = self.lm.cfg
        return (cfg.input_mode == "tokens" and not cfg.enc_pattern
                and not cfg.mrope_sections
                and cfg.attn_impl in ("einsum", "bf16")
                and all(kind == "attn" for kind, _ in cfg.pattern))

    # ------------------------------------------------------------- tokenize
    def _pad_class(self, length: int) -> int:
        return _next_pow2(max(length, 16)) if self.bucket_shapes else length

    def _pad_ids(self, ids: Sequence[Sequence[int]],
                 maxlen: Optional[int] = None) -> np.ndarray:
        """Left-pad token-id rows into a (rows, maxlen) array, bucketing both
        dims to powers of two when ``bucket_shapes``."""
        if maxlen is None:
            maxlen = max(len(i) for i in ids)
            if self.bucket_shapes:
                maxlen = _next_pow2(max(maxlen, 16))
        rows = len(ids)
        if self.bucket_shapes:
            rows = _next_pow2(rows)
        arr = np.full((rows, maxlen), PAD, np.int32)
        for r, i in enumerate(ids):
            arr[r, maxlen - len(i):] = i          # left-pad: last pos = live
        return arr

    def _batch_tokens(self, prompts: Sequence[str]) -> np.ndarray:
        return self._pad_ids([self.tok.encode(p) for p in prompts])

    def _make_batch(self, tokens: np.ndarray) -> dict:
        cfg = self.lm.cfg
        batch: dict = {"tokens": jnp.asarray(tokens)}
        if cfg.input_mode == "embeds":
            # VLM stub frontend: embed text bytes through the text table
            batch = {"embeds": jnp.take(self.params["embed"],
                                        jnp.asarray(tokens), axis=0),
                     "tokens": jnp.asarray(tokens)}
            batch = {"embeds": batch["embeds"]}
        elif cfg.input_mode == "encdec":
            emb = jnp.take(self.params["embed"], jnp.asarray(tokens), axis=0)
            batch = {"enc_embeds": emb, "tokens": jnp.asarray(tokens)}
        return batch

    # --------------------------------------------------------------- probes
    @staticmethod
    def _parts(prompt: Prompt) -> tuple[Optional[str], str]:
        """Normalize a probe prompt to (shared_prefix_or_None, suffix)."""
        if isinstance(prompt, str):
            return None, prompt
        prefix, suffix = prompt
        if not prefix or not suffix:
            return None, prefix + suffix
        return prefix, suffix

    def submit_probes(self, prompts: Sequence[Prompt],
                      max_batch: Optional[int] = None) -> np.ndarray:
        """THE probe pathway: run a round of independent single-token probes
        as one (or, when ``max_batch`` bounds padded batch size, a few
        length-bucketed) padded prefill submissions; returns last-position
        logits aligned with ``prompts``.  Every oracle read-out (score /
        compare / yes-no / judge) funnels through here, so ``stats.calls``
        counts *serving submissions*, not logical LLM calls.  ``max_batch``
        defaults to the engine's ``max_probe_batch`` memory ceiling.

        Prompts are grouped by PADDED-LENGTH CLASS (the power-of-two bucket
        with ``bucket_shapes``, exact token length without), never mixing
        classes in one submission.  The model has no PAD attention mask, so
        a row's logits depend on its padded length; same-class grouping
        makes each prompt's padding a function of its own length only —
        batched results are bit-identical to sequential point submissions.

        Structured ``(prefix, suffix)`` prompts additionally ride the
        prefix-KV cache (when enabled): rows sharing (class, prefix ids,
        total length) — and therefore the same absolute prefix start — are
        executed as suffix-only prefill over one cached prefix region."""
        n = len(prompts)
        if n == 0:
            return np.zeros((0, self.lm.cfg.vocab_size), np.float32)
        if max_batch is None:
            max_batch = self.max_probe_batch
        plain: dict[int, list[int]] = {}           # class -> indices
        structured: dict[int, list[tuple]] = {}    # class -> (idx, pids, sids)
        enc: list = [None] * n                     # per-index full token ids
        for i, p in enumerate(prompts):
            prefix, suffix = self._parts(p)
            if prefix is not None and self.prefix_cache_enabled:
                pids = tuple(self.tok.encode(prefix))
                sids = self.tok.encode(suffix, bos=False)
                enc[i] = list(pids) + sids
                structured.setdefault(
                    self._pad_class(len(enc[i])), []).append((i, pids, sids))
            else:
                enc[i] = self.tok.encode(suffix if prefix is None
                                         else prefix + suffix)
                plain.setdefault(self._pad_class(len(enc[i])), []).append(i)
        out = np.zeros((n, self.lm.cfg.vocab_size), np.float32)

        # Prefix-cache routing policy (per padded-length class): a row rides
        # the prefix path only when its (prefix, start) entry is already
        # cached or at least one class-mate shares it — otherwise the fill
        # would cost as much as the monolithic row.  Demoted rows join the
        # class's plain submission; both pathways are bit-identical to
        # monolithic prefill, so routing never changes results.
        window_jobs: list[tuple] = []              # (cls, lw, rows)
        for cls in sorted(structured):
            rows = structured[cls]
            counts: dict[tuple, int] = {}
            for _i, pids, sids in rows:
                key = (pids, cls - len(pids) - len(sids))
                counts[key] = counts.get(key, 0) + 1
            selected, lw = [], 0
            for i, pids, sids in rows:
                key = (pids, cls - len(pids) - len(sids))
                if key in self._prefix_lru or counts[key] >= 2:
                    selected.append((i, key))
                    lw = max(lw, len(sids))
                else:
                    plain.setdefault(cls, []).append(i)
            if not selected:
                continue
            # uniform per-class window: bucket the suffix span so a handful
            # of compiled (rows, lw) shapes serve every round; rows shorter
            # than lw recompute a few of their own prefix-tail tokens, which
            # is bit-identical (causal KV slicing is exact at any split)
            lw = _next_pow2(max(lw, 8)) if self.bucket_shapes else lw
            if lw >= cls:                          # no cached span left
                plain.setdefault(cls, []).extend(i for i, _ in selected)
                continue
            window_jobs.append((cls, lw, selected))

        def chunked(idx):
            # max_batch None here means the engine was built with
            # max_probe_batch=None: explicitly unbounded submissions
            step = len(idx) if max_batch is None else max_batch
            return (idx[i:i + step] for i in range(0, len(idx), step))

        for cls in sorted(plain):
            for g in chunked(sorted(plain[cls])):
                tokens = self._pad_ids([enc[i] for i in g], maxlen=cls)
                logits, _ = self._prefill(self.params,
                                          self._make_batch(tokens))
                self.stats.prefill_tokens += int(tokens.size)
                self.stats.calls += 1
                out[np.asarray(g)] = np.asarray(
                    logits.astype(jnp.float32))[:len(g)]  # drop bucket-pad rows
        for cls, lw, selected in window_jobs:
            entries = self._fill_prefix_entries(cls,
                                                {key for _, key in selected})
            for g in chunked(selected):
                idx = [i for i, _ in g]
                logits = self._run_window(cls, lw, [enc[i] for i in idx],
                                          [key for _, key in g], entries)
                out[np.asarray(idx)] = logits
        return out

    def _fill_prefix_entries(self, cls: int, keys: set) -> dict:
        """Prefill every missing (prefix ids, start) region of a class once,
        batching fills of equal region length into one submission; cache the
        per-entry KV in the LRU.  A region is ``PAD * pad + prefix`` — the
        exact content of positions [0, start) of every padded row using it,
        which is what makes cached execution bit-identical.  Returns
        {key: caches} DIRECT references for every requested key, so a round
        needing more entries than ``prefix_cache_size`` survives its own
        LRU evictions."""
        refs: dict[tuple, object] = {}
        by_len: dict[int, list[tuple]] = {}
        for key in sorted(keys):
            if key in self._prefix_lru:
                self._prefix_lru.move_to_end(key)
                refs[key] = self._prefix_lru[key]
                self.stats.prefix_hits += 1
                continue
            pids, pad = key
            by_len.setdefault(pad + len(pids), []).append(key)
        step = self.max_probe_batch or max(
            (len(b) for b in by_len.values()), default=1)
        for region_len in sorted(by_len):
            # honor the engine's memory ceiling, then bucket the fill's row
            # count like every other submission, so varying miss counts
            # reuse one compiled program per region length (the length
            # itself must stay exact — it IS the suffix start position);
            # dummy all-PAD rows are discarded
            pending = by_len[region_len]
            for batch in (pending[i:i + step]
                          for i in range(0, len(pending), step)):
                self.stats.prefix_misses += len(batch)
                self.stats.prefix_fill_submissions += 1
                rows_p = (_next_pow2(len(batch)) if self.bucket_shapes
                          else len(batch))
                arr = np.full((rows_p, region_len), PAD, np.int32)
                for r, (pids, pad) in enumerate(batch):
                    arr[r, pad:] = pids
                _, caches = self._prefill_exact(self.params,
                                               self._make_batch(arr))
                self.stats.prefill_tokens += int(arr.size)
                self.stats.prefix_tokens_saved -= int(arr.size)
                for r, key in enumerate(batch):
                    entry = jax.tree.map(
                        lambda l, r=r: l if l.ndim == 2 else l[:, r:r + 1],
                        caches)
                    self._prefix_lru[key] = entry
                    refs[key] = entry
                while len(self._prefix_lru) > self.prefix_cache_size:
                    self._prefix_lru.popitem(last=False)
        return refs

    def _run_window(self, cls: int, lw: int, full_ids: list,
                    keys: list, entries: dict) -> np.ndarray:
        """One suffix-window submission: every row attends over its own
        cached-KV slice [0, cls - lw) (gathered per row from the round's
        ``entries`` references) plus the recomputed window tokens
        [cls - lw, cls).  Bit-identical to a monolithic padded prefill of
        the full rows."""
        r_star = cls - lw
        uniq: list = []
        uniq_of: dict[tuple, int] = {}
        for key in keys:
            if key not in uniq_of:
                uniq_of[key] = len(uniq)
                uniq.append(entries[key])
        rows = len(full_ids)
        rows_p = _next_pow2(rows) if self.bucket_shapes else rows
        arr = np.full((rows_p, lw), PAD, np.int32)
        for r, ids in enumerate(full_ids):
            row = [PAD] * (cls - len(ids)) + list(ids)  # left-padded full row
            arr[r] = row[r_star:]
        eidx = np.zeros((rows_p,), np.int32)
        eidx[:rows] = [uniq_of[k] for k in keys]   # dummy rows reuse entry 0

        def cat(*leaves):
            if leaves[0].ndim == 2:                # stacked pos: arange(R)
                return leaves[0][:, :r_star]
            return jnp.concatenate([l[:, :, :r_star] for l in leaves], axis=1)

        assembled = jax.tree.map(cat, *uniq)
        idx = jnp.asarray(eidx)
        assembled = jax.tree.map(
            lambda l: l if l.ndim == 2 else jnp.take(l, idx, axis=1),
            assembled)
        logits, _ = self._prefill_cont(self.params, assembled,
                                       self._make_batch(arr))
        self.stats.prefill_tokens += int(arr.size)
        self.stats.calls += 1
        # monolithic baseline: cls tokens per padded row of this submission
        self.stats.prefix_tokens_saved += rows_p * cls - int(arr.size)
        return np.asarray(logits.astype(jnp.float32))[:rows]

    def last_logits(self, prompts: Sequence[Prompt]) -> np.ndarray:
        return self.submit_probes(prompts)

    def score(self, texts: Sequence[str], criteria: str) -> list[float]:
        prompts = [(f"Criteria: {criteria}\nItem:", f" {t}\nRating:")
                   for t in texts]
        logits = self.submit_probes(prompts)
        return [float(l[TOK_HI] - l[TOK_LO]) for l in logits]

    def _compare_parts(self, a: str, b: str, criteria: str) -> tuple[str, str]:
        # the shared block (criteria + Passage B — quicksort's pivot) leads,
        # so every row of a partition round reuses one prefix-KV entry
        return (f"Criteria: {criteria}\nPassage B: {b}\n",
                f"Passage A: {a}\nWhich ranks higher? Answer:")

    def _compare_prompt(self, a: str, b: str, criteria: str) -> str:
        prefix, suffix = self._compare_parts(a, b, criteria)
        return prefix + suffix

    def compare(self, a: str, b: str, criteria: str) -> int:
        return self.compare_many([(a, b)], criteria)[0]

    def compare_many(self, pairs: Sequence[tuple[str, str]],
                     criteria: str) -> list[int]:
        """A round of independent comparisons in one probe submission."""
        logits = self.submit_probes(
            [self._compare_parts(a, b, criteria) for a, b in pairs])
        return [1 if l[TOK_A] > l[TOK_B] else -1 for l in logits]

    def yes_no(self, prompt: Prompt) -> bool:
        return self.yes_no_many([prompt])[0]

    def yes_no_many(self, prompts: Sequence[Prompt]) -> list[bool]:
        """A round of independent Y/N probes in one probe submission."""
        logits = self.submit_probes(prompts)
        return [bool(l[TOK_YES] > l[TOK_NO]) for l in logits]

    def rank_window(self, texts: Sequence[str], criteria: str) -> list[int]:
        """Permutation (ascending by score) from one shared-prefix batch."""
        scores = self.score(texts, criteria)
        return list(np.argsort(np.asarray(scores), kind="stable"))

    # ------------------------------------------------------------- generate
    def generate(self, prompts: Sequence[str], max_new: Optional[int] = None,
                 max_new_per: Optional[Sequence[int]] = None) -> list[str]:
        """Batched greedy decode.  ``max_new_per`` gives each row its own
        decode budget (the scheduler batches requests with differing
        ``max_new``); rows that hit their budget are masked done and emit
        EOS while the rest of the batch keeps decoding."""
        max_new = min(max_new or self.max_new, self.max_new)
        n = len(prompts)
        tokens = self._batch_tokens(prompts)
        b, s = tokens.shape                       # b >= n with bucket_shapes
        if max_new_per is None:
            limits = np.full((n,), max_new, np.int64)
        else:
            assert len(max_new_per) == n
            limits = np.minimum(np.asarray(max_new_per, np.int64), self.max_new)
        limits = np.concatenate([limits, np.zeros((b - n,), np.int64)])
        horizon = int(limits.max(initial=0))
        logits, caches = self._prefill(self.params, self._make_batch(tokens))
        self.stats.prefill_tokens += int(tokens.size)
        self.stats.calls += 1
        out = np.full((b, horizon), EOS, np.int64)  # unwritten tail decodes empty
        cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        done = limits <= 0
        for t in range(horizon):
            out[:, t] = np.where(done, EOS, np.asarray(cur[:, 0]))
            done |= np.asarray(cur[:, 0]) == EOS
            done |= (t + 1) >= limits
            if done.all():
                break
            logits, caches = self._decode(self.params, caches, cur,
                                          jnp.int32(s + t))
            self.stats.decode_tokens += int((~done).sum())
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return [self.tok.decode(row) for row in out[:n]]
