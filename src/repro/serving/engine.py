"""Batched serving engine: prefill + greedy decode over the unified LM API,
plus the ranking read-outs the ModelOracle needs (score / compare /
rank-window / yes-no), all funneled through ONE probe pathway
(:meth:`ServeEngine.submit_probes`) so a round of independent logical calls
costs a single padded prefill submission (``stats.calls`` counts
submissions).  Submission shapes are bucketed to powers of two to bound XLA
compiles under variable round sizes (see DESIGN.md).

Prompts are byte-tokenized, left-padded per batch, and executed with two
jit-compiled programs (prefill, decode_step) shared across calls.  Passing
``mesh=`` lowers those SAME programs under a ("data", "model") device mesh:
params/arenas are committed to NamedShardings, probe submissions are
row-sliced over the data axes (``dp_probe_slices``), decode runs
tensor-parallel over the model axis, and logits gather host-side — with
identity to the single-device engine (bitwise when the model axis is 1; see
DESIGN.md "Sharded serving").  Read-outs follow standard logit-probe
practice:

 * score(text)      -> logit('9') - logit('0') after a "Rating:" prompt,
 * compare(a, b)    -> logit('A') vs logit('B') after a comparison prompt,
 * rank_window(ks)  -> scores computed in one shared-prefix batch (this is
   what makes listwise calls cheaper than k pointwise calls — the shared
   instruction prefix is tokenized/prefilled once per row, exactly the
   batching economics the paper's external paths exploit).

Prefix-KV cache: probe prompts arrive as ``(shared_prefix, per_key_suffix)``
pairs (plain strings still work, uncached).  The engine prefills each
distinct ``(prefix token ids, absolute start position)`` region ONCE, holds
its per-layer KV in an LRU, and runs suffix-only prefill on top of the
broadcast cached KV — so a quicksort partition round prefills its pivot
block once instead of once per row.  Because the model has no PAD attention
mask, a row's logits depend on its left-padded length; keying the cache on
the absolute start position (equivalently the PAD count of the row's
padded-length class) keeps cached execution bit-identical to monolithic
prefill.  See DESIGN.md "Prefix-KV cache".

Paged continuous-batching decode: all serve-side KV lives in ONE block-paged
pool (serving/kv_pool.py).  Prefix-cache entries are pinned block runs, and
``generate`` runs a continuous step loop (``paged_admit`` / ``paged_step``)
instead of a padded lockstep batch: every active row decodes each step at
its OWN position, finished rows retire and free their blocks immediately,
and queued requests are admitted into the vacated slots between steps.  Each
row prefills at its own padded-length class, so its greedy output is
token-identical to a solo lockstep ``generate_lockstep([prompt])`` run — a
row's result no longer depends on its batch-mates at all.  Unsupported
archs (non-attention blocks, MoE, qchunk, enc-dec) fall back to the
lockstep loop.  See DESIGN.md "Paged KV pool".

Probe submissions are pool citizens too: their transient prompt KV holds a
block *lease* for the duration of the forward pass (capacity arbitration +
peak accounting; shortfalls degrade to unpooled memory, never stall), and
``prefetch_prefixes`` exposes region warming as a schedulable primitive —
the scheduler's prefix-fill work items ride it.  The decode step's
attention has a deployment-time Pallas switch (``paged_kernel``): default
dense keeps the ``==`` contract, ``True`` runs the flash-decode kernel
(allclose at PAGED_KERNEL_RTOL/ATOL), ``"check"`` runs both and asserts.
See DESIGN.md "Unified step loop".
"""
from __future__ import annotations

import itertools
from collections import OrderedDict
from dataclasses import dataclass, field
from functools import partial
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from ..data.tokenizer import EOS, PAD, ByteTokenizer
from ..distributed.context import shard_context
from ..distributed.sharding import (ShardingPlan, data_axes, named,
                                    param_specs, rows_spec)
from ..models.model import LM
from .kv_pool import KVBlockPool, PoolExhausted
from .locality import plan_window_jobs

TOK_A, TOK_B = ord("A"), ord("B")
TOK_HI, TOK_LO = ord("9"), ord("0")
TOK_YES, TOK_NO = ord("Y"), ord("N")

# Pallas paged flash-decode vs the dense gather+attend path: the kernel's
# online-softmax reduction order differs from the dense einsum softmax
# (and the kernel keeps its softmax weights/accumulator in fp32 where the
# dense path casts weights back to the cache dtype), so per-step logits
# agree to these tolerances, not bitwise.  On bf16 stacks the drift is
# ~1 bf16 ulp through the residual stream — measured worst-case ~0.034
# absolute on the reduced configs, with large RELATIVE error only on
# near-zero logits — so the bound is absolute-dominated with ~4x headroom;
# pure-fp32 stacks land near 1e-6.  Greedy argmax agreement is the
# operational contract the tolerance test checks alongside.
PAGED_KERNEL_RTOL = 5e-2
PAGED_KERNEL_ATOL = 1.2e-1

# Tensor-parallel serving (mesh with model axis > 1): the row-parallel
# contractions (wo, w_down) become psums whose reduction order differs from
# the single-device dot, so probe logits drift by ~1 bf16 ulp through the
# residual stream (measured worst-case 0.03125 absolute on the reduced
# configs — same mechanism and headroom as the Pallas kernel bound above).
# Greedy argmax agreement holds, so decode outputs stay token-identical
# (``==``); data-parallel-only meshes (model == 1) never reduce across
# devices and keep full bitwise identity.
TP_PSUM_RTOL = 5e-2
TP_PSUM_ATOL = 1.2e-1

# a probe prompt: plain string, or a (shared_prefix, per_key_suffix) pair —
# core.oracles.base.PromptParts is such a pair (the full prompt is the
# concatenation; the pair form additionally enables prefix-KV reuse)
Prompt = Union[str, tuple]


# ---- logit read-outs ------------------------------------------------------
# Single-token probe interpretation, shared by the engine's synchronous
# verbs (score / compare_many / yes_no_many) and by the ModelOracle's
# deferred rounds, which enqueue prompts into a BatchScheduler's probe queue
# and read the drained logits back themselves.
def read_score(logits) -> float:
    return float(logits[TOK_HI] - logits[TOK_LO])


def read_compare(logits) -> int:
    return 1 if logits[TOK_A] > logits[TOK_B] else -1


def read_yes_no(logits) -> bool:
    return bool(logits[TOK_YES] > logits[TOK_NO])


@dataclass
class ServeStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    # physical row-slots occupied across decode steps (padded batch rows per
    # step, whether or not the row produced a useful token).  Lockstep holds
    # finished rows until the batch straggler ends; the paged loop retires
    # them, so ``decode_row_steps - decode_tokens`` is the straggler waste
    # benchmarks/table6_paged_decode.py measures.
    decode_row_steps: int = 0
    calls: int = 0
    # prefix-KV cache counters: hits/misses are per entry lookup;
    # fill_submissions counts the region-prefill forward passes (kept out
    # of ``calls``, which counts PROBE submissions); tokens_saved is the
    # padded prefill token count avoided vs monolithic whole-prompt
    # submissions, net of fill costs.
    prefix_hits: int = 0
    prefix_misses: int = 0
    prefix_fill_submissions: int = 0
    prefix_tokens_saved: int = 0
    # probe-submission row occupancy: ``probe_rows`` counts live prompts,
    # ``probe_row_slots`` the padded rows actually prefetched (shape
    # bucketing rounds each submission's row count up to a power of two).
    # The difference is the padding slack a probe workload wastes — small
    # serialized rounds burn proportionally more of it than merged drains
    # (benchmarks/table7_executor.py).
    probe_rows: int = 0
    probe_row_slots: int = 0
    # probe-row pool citizenship: a probe submission's rows LEASE pool
    # blocks covering their transient prompt KV for the duration of the
    # forward pass, so probe memory shares the decode rows' budget and
    # shows up in pool peak accounting.  A shortfall (decode rows hold the
    # blocks) degrades to unpooled transient memory, never to a stall.
    probe_blocks_leased: int = 0
    probe_lease_shortfalls: int = 0
    # multi-tenant serving (scheduler.TenantSpec): preemption traffic and
    # starvation accounting.  ``preempt_suspends``/``preempt_resumes`` count
    # decode rows suspended to a host stash and re-admitted;
    # ``preempt_blocks_stashed`` the blocks copied out.  The starvation
    # counters are SLO alarms, bumped by the scheduler when work of a
    # priority class (> 0) waits beyond its starvation bound: deferrals of
    # probe rounds under per-tenant quotas are benign
    # (``probe_rounds_deferred``); a starved round/admission is one that
    # the weighted-admission policy should have protected and did not.
    preempt_suspends: int = 0
    preempt_resumes: int = 0
    preempt_blocks_stashed: int = 0
    probe_rounds_deferred: int = 0
    starved_rounds: int = 0
    starved_admissions: int = 0
    # data-parallel probe slicing (mesh serving): prefill submissions whose
    # padded row count divided the data axes and therefore executed as
    # per-data-shard row slices, vs submissions that stayed replicated
    # (tiny rounds below the shard count, or the dp_probe_slices=False
    # ablation benchmarks/table12_sharding.py measures against)
    dp_sharded_submissions: int = 0
    dp_replicated_submissions: int = 0

    @property
    def prefix_hit_rate(self) -> float:
        total = self.prefix_hits + self.prefix_misses
        return self.prefix_hits / total if total else 0.0


def _next_pow2(x: int) -> int:
    return 1 << max(x - 1, 0).bit_length()


@dataclass
class PrefixEntry:
    """One prefix-cache region: ``PAD*pad + prefix`` at positions
    [0, length).  Pool-backed entries hold their KV as a pinned block run
    (``blocks``, one LRU-owned reference); when the pool is absent or full,
    ``caches`` holds the dense per-stack KV directly (PR 2 scheme)."""
    length: int
    blocks: Optional[list] = None
    caches: Optional[list] = None


@dataclass
class _PagedRow:
    """One in-flight continuous-batching decode row."""
    rid: int
    cls: int                 # padded prompt class == prefill length
    limit: int               # greedy decode budget (tokens to emit)
    blocks: list             # ordered block run: shared prefix + private
    n_shared: int            # leading blocks borrowed from a PrefixEntry
    cur: int                 # next token to record (already generated)
    t: int = 0               # decode steps taken
    emitted: list = field(default_factory=list)


@dataclass
class SuspendedRow:
    """A preempted decode row evicted to host memory: everything needed to
    re-admit it with byte-identical continuation.  The stash holds the
    row's FULL block run (shared prefix included — the resumed row owns
    private copies, so its lifetime is decoupled from the prefix LRU); no
    pool references are held while suspended."""
    rid: int
    cls: int
    limit: int
    cur: int
    t: int
    emitted: list
    n_blocks: int
    stash: list              # KVBlockPool.stash_blocks payload


class ServeEngine:
    def __init__(self, lm: LM, params, max_new_tokens: int = 32,
                 bucket_shapes: bool = True, max_probe_batch: int = 256,
                 prefix_cache_size: int = 64, pool_blocks: int = 768,
                 block_size: int = 16, max_decode_rows: int = 32,
                 paged_kernel: object = False, locality: bool = True,
                 mesh=None, plan: Optional[ShardingPlan] = None,
                 dp_probe_slices: bool = True):
        self.lm = lm
        self.params = params
        # Sharded serving (``mesh=...``): params lowered through the
        # name-based rules of distributed/sharding.py (tensor-parallel over
        # `model`, optionally fsdp over the data axes), the paged arena as a
        # NamedSharding'd array (feature layout, block dim replicated), and
        # every prefill/decode program jitted under the mesh.  Probe rounds
        # become data-parallel through ``_put_rows``: each merged submission
        # is committed row-sliced over the data axes, every shard executes
        # its contiguous slice, and the host-side logits read-back gathers —
        # ``dp_probe_slices=False`` keeps the mesh but replicates rows (the
        # ablation table12 measures the slicing win against).
        self.mesh = mesh
        self.plan = plan
        self._daxes: tuple = ()
        self.data_shards = 1
        self.dp_probe_slices = dp_probe_slices
        if mesh is not None:
            self.plan = plan = plan if plan is not None else ShardingPlan()
            self._daxes = data_axes(mesh)
            self.data_shards = int(np.prod(
                [mesh.shape[a] for a in self._daxes], dtype=np.int64)) or 1
            self.params = jax.device_put(
                params, named(mesh, param_specs(params, mesh, plan)))
        self.tok = ByteTokenizer()
        assert lm.cfg.vocab_size >= self.tok.vocab_size, (
            f"model vocab {lm.cfg.vocab_size} < tokenizer vocab "
            f"{self.tok.vocab_size}: special ids would index out of range")
        self.max_new = max_new_tokens
        # Shape bucketing: round (rows, seq_len) of every submission up to the
        # next power of two, so the round-batched access paths — whose batch
        # size varies call to call — reuse a handful of compiled programs
        # instead of triggering an XLA compile per novel shape.  Dummy rows
        # are all-PAD and their logits are discarded.
        self.bucket_shapes = bucket_shapes
        # Memory ceiling for one probe submission: a round of N logical
        # calls becomes ceil(N / max_probe_batch) submissions, so huge
        # rounds (pointwise over thousands of keys) cannot build one
        # device-filling prefill batch.
        self.max_probe_batch = max_probe_batch
        # Prefix-KV cache: LRU of per-layer KV for distinct
        # (prefix token ids, absolute start position) regions; 0 disables.
        # Only full-attention token-input decoder stacks qualify — other
        # archs silently fall back to monolithic prefill.
        self.prefix_cache_size = prefix_cache_size
        self.prefix_cache_enabled = (
            prefix_cache_size > 0 and self._supports_prefix_cache())
        self._prefix_lru: OrderedDict[tuple, PrefixEntry] = OrderedDict()
        # Locality-creating probe scheduling (serving/locality.py): window
        # jobs are region-clustered with per-group suffix windows, capped
        # at the LRU capacity, and ordered cold-first/warm-last.  False
        # restores the reactive PR 2 scheme (one class-global window job)
        # — the benchmarks' baseline.  Either way results are bit-identical
        # to monolithic prefill; only serving stats move.
        self.locality = locality
        # Block-paged KV pool + continuous-batching decode (same arch gate as
        # the prefix cache: the pool holds full-attention KV, and chunked
        # prefill must be a pure per-row function); pool_blocks=0 disables
        # and generate() falls back to the lockstep loop.
        self.max_decode_rows = max_decode_rows
        self.paged_enabled = pool_blocks > 0 and self._supports_prefix_cache()
        self.pool: Optional[KVBlockPool] = (
            KVBlockPool(lm, pool_blocks, block_size, mesh=mesh, plan=self.plan)
            if self.paged_enabled else None)
        self._paged_rows: dict[int, _PagedRow] = {}
        self._paged_finished: dict[int, str] = {}
        self._paged_ids = itertools.count()
        self.stats = ServeStats()
        if mesh is None:
            self._prefill = jax.jit(partial(lm.prefill,
                                            reserve=max_new_tokens))
            self._decode = jax.jit(lm.decode_step)
            # prefix regions need exact-length caches (reserve=0) so the
            # suffix lands at the right absolute positions
            self._prefill_exact = jax.jit(partial(lm.prefill, reserve=0))
            self._prefill_cont = jax.jit(lm.prefill_cont)
        else:
            # mesh-jitted closures: shard_context is read at TRACE time, so
            # it must wrap the traced body (not the jax.jit construction) —
            # every pin_rows/shard-aware layer inside the model then sees
            # the serving mesh's data/model axes.  The replicated-rows
            # ablation hands the context EMPTY data axes so model-side row
            # pinning never fires.
            daxes = self._daxes if dp_probe_slices else ()

            def _prefill_sharded(params, batch):
                with shard_context(mesh, daxes):
                    return lm.prefill(params, batch, reserve=max_new_tokens)

            def _prefill_exact_sharded(params, batch):
                with shard_context(mesh, daxes):
                    return lm.prefill(params, batch, reserve=0)

            def _prefill_cont_sharded(params, caches, batch):
                with shard_context(mesh, daxes):
                    return lm.prefill_cont(params, caches, batch)

            def _decode_sharded(params, caches, tokens, position):
                with shard_context(mesh, daxes):
                    return lm.decode_step(params, caches, tokens, position)

            self._prefill = jax.jit(_prefill_sharded)
            self._decode = jax.jit(_decode_sharded)
            self._prefill_exact = jax.jit(_prefill_exact_sharded)
            self._prefill_cont = jax.jit(_prefill_cont_sharded)
        # Deployment-time Pallas switch for the decode step's attention:
        #   False   — dense gather+attend (the default; keeps the `==`
        #             bit-identity contract vs solo lockstep),
        #   True    — kernels/paged_attention.py flash-decode (pod serving;
        #             online-softmax reduction order trades `==` for
        #             allclose at PAGED_KERNEL_RTOL/ATOL),
        #   "check" — run BOTH each step, assert allclose, return the dense
        #             result (deployment validation mode).
        self.paged_kernel = paged_kernel
        if paged_kernel and mesh is not None:
            # the Pallas flash-decode kernel is a per-device program: under
            # a mesh it would need an explicit shard_map lowering (head-dim
            # blocking per model shard), which does not exist yet — fail
            # loudly rather than silently running the kernel un-sharded
            raise ValueError(
                "paged_kernel is not supported on a sharded engine "
                "(mesh=...): the flash-decode kernel has no shard_map "
                "lowering; use the dense paged path")
        if paged_kernel and not self.paged_enabled:
            # an inert validation/deployment switch is worse than an error:
            # the operator would believe the kernel was validated when it
            # never ran a single step
            raise ValueError(
                f"paged_kernel={paged_kernel!r} requires a paged-capable "
                f"engine (pool_blocks > 0 and a pure full-attention "
                f"token-input stack); this arch/config falls back to "
                f"lockstep decode, so the kernel would never execute")
        if self.paged_enabled:
            # the arena is the whole serve memory: donate it through the
            # step so the backend aliases it in place — on every backend,
            # including CPU (XLA:CPU honors the aliasing; the previous
            # CPU carve-out paid a full arena copy per decode step)
            donate = (1,)
            if mesh is None:
                self._decode_paged = jax.jit(
                    partial(lm.decode_step_paged, block_size=block_size),
                    donate_argnums=donate)
            else:
                arena_shardings = self.pool.arena_shardings
                daxes = self._daxes if dp_probe_slices else ()

                def _decode_paged_sharded(params, arenas, tokens, positions,
                                          tables):
                    with shard_context(mesh, daxes):
                        logits, out = lm.decode_step_paged(
                            params, arenas, tokens, positions, tables,
                            block_size=block_size)
                    # donation requires the output arena sharding to match
                    # the (donated) input arena: pin it to the canonical
                    # layout so the backend can alias in place
                    out = jax.lax.with_sharding_constraint(
                        out, arena_shardings)
                    return logits, out

                self._decode_paged = jax.jit(_decode_paged_sharded,
                                             donate_argnums=donate)
            if paged_kernel:
                # "check" must NOT donate the arena into the kernel call —
                # the dense source-of-truth call consumes it right after
                self._decode_paged_kernel = jax.jit(
                    partial(lm.decode_step_paged, block_size=block_size,
                            impl="kernel"),
                    donate_argnums=(() if paged_kernel == "check"
                                    else donate))
        self._embed_cache: dict = {}

    def _supports_prefix_cache(self) -> bool:
        # bit-identity requires every layer's output for a row to be a pure
        # function of that row and its own sequence: einsum/bf16 attention
        # maps 1:1 onto _attn_cont, but qchunk's scan-blocked softmax has a
        # different reduction order, and MoE dispatch is capacity-ranked
        # ACROSS the batch (a row's logits depend on its batch-mates), so
        # both fall back to monolithic prefill, like non-attention kinds
        cfg = self.lm.cfg
        return (cfg.input_mode == "tokens" and not cfg.enc_pattern
                and not cfg.mrope_sections
                and cfg.attn_impl in ("einsum", "bf16")
                and all(kind == "attn" for kind, _ in cfg.pattern))

    # ------------------------------------------------------------- tokenize
    def _pad_class(self, length: int) -> int:
        return _next_pow2(max(length, 16)) if self.bucket_shapes else length

    def _pad_ids(self, ids: Sequence[Sequence[int]],
                 maxlen: Optional[int] = None) -> np.ndarray:
        """Left-pad token-id rows into a (rows, maxlen) array, bucketing both
        dims to powers of two when ``bucket_shapes``."""
        if maxlen is None:
            maxlen = max(len(i) for i in ids)
            if self.bucket_shapes:
                maxlen = _next_pow2(max(maxlen, 16))
        rows = len(ids)
        if self.bucket_shapes:
            rows = _next_pow2(rows)
        arr = np.full((rows, maxlen), PAD, np.int32)
        for r, i in enumerate(ids):
            arr[r, maxlen - len(i):] = i          # left-pad: last pos = live
        return arr

    def _put_rows(self, arr, axis: int = 0, count: bool = False):
        """Data-parallel row split (mesh serving): commit a padded
        submission's row dim to contiguous per-data-shard slices, so each
        shard executes only its rows and the host-side ``np.asarray``
        logits read-back is the gather.  Identity argument: a row's logits
        depend only on its own (padded) sequence — the same row-count
        independence the batched==sequential ``==`` contract relies on
        repo-wide — so slicing the row dim never changes bits.  Row counts
        are already bucketed to powers of two, so any submission at or
        above the shard count divides exactly; smaller ones (and the
        ``dp_probe_slices=False`` ablation) stay replicated."""
        arr = jnp.asarray(arr)
        if self.mesh is None:
            return arr
        spec = rows_spec(arr.shape[axis], arr.ndim, self.mesh, axis=axis)
        sharded = self.dp_probe_slices and spec[axis] is not None
        if count:
            if sharded:
                self.stats.dp_sharded_submissions += 1
            else:
                self.stats.dp_replicated_submissions += 1
        if not sharded:
            spec = rows_spec(0, arr.ndim, self.mesh, axis=axis)
        return jax.device_put(arr, NamedSharding(self.mesh, spec))

    def _make_batch(self, tokens: np.ndarray) -> dict:
        cfg = self.lm.cfg
        toks = self._put_rows(tokens, count=True)
        batch: dict = {"tokens": toks}
        if cfg.input_mode == "embeds":
            # VLM stub frontend: embed text bytes through the text table
            batch = {"embeds": jnp.take(self.params["embed"], toks, axis=0)}
        elif cfg.input_mode == "encdec":
            emb = jnp.take(self.params["embed"], toks, axis=0)
            batch = {"enc_embeds": emb, "tokens": toks}
        return batch

    # --------------------------------------------------------------- probes
    @staticmethod
    def _region_key(pids: tuple, sids: Sequence[int], cls: int) -> tuple:
        """THE prefix-cache key of a structured row in padded class
        ``cls``: (prefix token ids, absolute start position) — the region
        ``PAD*start + prefix`` is a pure function of it (DESIGN.md
        "Prefix-KV cache", Keying and bit-identity).  Every prefix-cache
        client (probe routing, paged admission, prefetch) MUST key through
        here so fills and lookups can never drift apart."""
        return (pids, cls - len(pids) - len(sids))

    @staticmethod
    def _parts(prompt: Prompt) -> tuple[Optional[str], str]:
        """Normalize a probe prompt to (shared_prefix_or_None, suffix)."""
        if isinstance(prompt, str):
            return None, prompt
        prefix, suffix = prompt
        if not prefix or not suffix:
            return None, prefix + suffix
        return prefix, suffix

    def submit_probes(self, prompts: Sequence[Prompt],
                      max_batch: Optional[int] = None) -> np.ndarray:
        """THE probe pathway: run a round of independent single-token probes
        as one (or, when ``max_batch`` bounds padded batch size, a few
        length-bucketed) padded prefill submissions; returns last-position
        logits aligned with ``prompts``.  Every oracle read-out (score /
        compare / yes-no / judge) funnels through here, so ``stats.calls``
        counts *serving submissions*, not logical LLM calls.  ``max_batch``
        defaults to the engine's ``max_probe_batch`` memory ceiling.

        Prompts are grouped by PADDED-LENGTH CLASS (the power-of-two bucket
        with ``bucket_shapes``, exact token length without), never mixing
        classes in one submission.  The model has no PAD attention mask, so
        a row's logits depend on its padded length; same-class grouping
        makes each prompt's padding a function of its own length only —
        batched results are bit-identical to sequential point submissions.

        Structured ``(prefix, suffix)`` prompts additionally ride the
        prefix-KV cache (when enabled): rows sharing (class, prefix ids,
        total length) — and therefore the same absolute prefix start — are
        executed as suffix-only prefill over one cached prefix region."""
        n = len(prompts)
        if n == 0:
            return np.zeros((0, self.lm.cfg.vocab_size), np.float32)
        if max_batch is None:
            max_batch = self.max_probe_batch
        plain: dict[int, list[int]] = {}           # class -> indices
        structured: dict[int, list[tuple]] = {}    # class -> (idx, pids, sids)
        enc: list = [None] * n                     # per-index full token ids
        for i, p in enumerate(prompts):
            prefix, suffix = self._parts(p)
            if prefix is not None and self.prefix_cache_enabled:
                pids = tuple(self.tok.encode(prefix))
                sids = self.tok.encode(suffix, bos=False)
                enc[i] = list(pids) + sids
                structured.setdefault(
                    self._pad_class(len(enc[i])), []).append((i, pids, sids))
            else:
                enc[i] = self.tok.encode(suffix if prefix is None
                                         else prefix + suffix)
                plain.setdefault(self._pad_class(len(enc[i])), []).append(i)
        out = np.zeros((n, self.lm.cfg.vocab_size), np.float32)

        # Prefix-cache routing policy (per padded-length class): a row rides
        # the prefix path only when its (prefix, start) entry is already
        # cached or at least one class-mate shares it — otherwise the fill
        # would cost as much as the monolithic row.  Demoted rows join the
        # class's plain submission; both pathways are bit-identical to
        # monolithic prefill, so routing never changes results.
        window_jobs: list[tuple] = []              # (cls, lw, rows)
        for cls in sorted(structured):
            rows = structured[cls]
            counts: dict[tuple, int] = {}
            for _i, pids, sids in rows:
                key = self._region_key(pids, sids, cls)
                counts[key] = counts.get(key, 0) + 1
            selected = []
            for i, pids, sids in rows:
                key = self._region_key(pids, sids, cls)
                if key in self._prefix_lru or counts[key] >= 2:
                    selected.append((i, key, len(sids)))
                else:
                    plain.setdefault(cls, []).append(i)
            if not selected:
                continue
            if self.locality:
                # GGR pass (serving/locality.py): region-clustered jobs
                # with per-group suffix windows, <= prefix_cache_size
                # regions per job, cold jobs before warm jobs
                jobs = plan_window_jobs(selected,
                                        lru_keys=self._prefix_lru.keys(),
                                        cache_size=self.prefix_cache_size,
                                        bucket=self.bucket_shapes)
            else:
                # reactive baseline: one class-global window sized by the
                # round's worst row; rows shorter than lw recompute a few
                # of their own prefix-tail tokens, which is bit-identical
                # (causal KV slicing is exact at any split)
                lw = max(s for _, _, s in selected)
                lw = _next_pow2(max(lw, 8)) if self.bucket_shapes else lw
                jobs = [(lw, [(i, key) for i, key, _ in selected])]
            for lw, sel in jobs:
                if lw >= cls:                      # no cached span left
                    plain.setdefault(cls, []).extend(i for i, _ in sel)
                    continue
                window_jobs.append((cls, lw, sel))

        def chunked(idx):
            # max_batch None here means the engine was built with
            # max_probe_batch=None: explicitly unbounded submissions
            return _chunks(idx, max_batch)

        for cls in sorted(plain):
            for g in chunked(sorted(plain[cls])):
                lease = self._lease_probe_blocks(len(g), cls)
                try:
                    tokens = self._pad_ids([enc[i] for i in g], maxlen=cls)
                    logits, _ = self._prefill(self.params,
                                              self._make_batch(tokens))
                    self.stats.prefill_tokens += int(tokens.size)
                    self.stats.calls += 1
                    self.stats.probe_rows += len(g)
                    self.stats.probe_row_slots += int(tokens.shape[0])
                    out[np.asarray(g)] = np.asarray(
                        logits.astype(jnp.float32))[:len(g)]  # drop pad rows
                finally:
                    self._release_lease(lease)
        for cls, lw, selected in window_jobs:
            entries, pins = self._fill_prefix_entries(
                cls, {key for _, key in selected})
            try:
                # materialize each entry's dense view ONCE per window job —
                # pool-backed entries gather device KV, which must not
                # repeat per max_probe_batch chunk
                dense = {key: self._entry_caches(e)
                         for key, e in entries.items()}
                for g in chunked(selected):
                    idx = [i for i, _ in g]
                    lease = self._lease_probe_blocks(len(g), cls)
                    try:
                        logits = self._run_window(cls, lw,
                                                  [enc[i] for i in idx],
                                                  [key for _, key in g],
                                                  dense)
                    finally:
                        self._release_lease(lease)
                    out[np.asarray(idx)] = logits
            finally:
                self._release_pins(pins)
        return out

    def _lease_probe_blocks(self, rows: int, cls: int) -> Optional[list]:
        """Lease pool blocks covering ``rows`` probe rows of padded class
        ``cls`` for the duration of one probe submission.  Probe KV is
        transient (read the last-position logits, discard), so its pool
        citizenship is a capacity *lease*: the blocks arbitrate one memory
        budget with decode rows and prefix runs — pool peak/alloc
        accounting sees probe traffic — and are returned the moment the
        forward pass ends.  When decode rows hold the blocks the lease
        degrades to unpooled transient memory (counted in
        ``stats.probe_lease_shortfalls``) instead of stalling the round:
        a probe storm must never block on its own accounting."""
        if self.pool is None:
            return None
        # ownership transfers to the caller, which releases via
        # _release_lease in its own try/finally
        ids = self.pool.lease(rows * self.pool.blocks_for(cls))  # lint: disable=kv-pairing
        if ids is None:
            self.stats.probe_lease_shortfalls += 1
        else:
            self.stats.probe_blocks_leased += len(ids)
        return ids

    def _release_lease(self, ids: Optional[list]) -> None:
        if ids is not None:
            self.pool.decref(ids)

    def prefetch_prefixes(self, prompts: Sequence[Prompt]) -> int:
        """Warm the prefix-KV LRU for structured ``(prefix, suffix)``
        prompts ahead of the round or generate wave that needs them — the
        serving-side primitive behind the scheduler's prefix-fill work
        items.  Regions land pinned by the LRU only (no round pins), so a
        later submission hits the cache and evictions stay safe.  Returns
        the number of regions ensured resident."""
        if not self.prefix_cache_enabled:
            return 0
        by_cls: dict[int, set] = {}
        for p in prompts:
            prefix, suffix = self._parts(p)
            if prefix is None:
                continue
            pids = tuple(self.tok.encode(prefix))
            sids = self.tok.encode(suffix, bos=False)
            cls = self._pad_class(len(pids) + len(sids))
            by_cls.setdefault(cls, set()).add(
                self._region_key(pids, sids, cls))
        ensured = 0
        for cls in sorted(by_cls):
            entries, pins = self._fill_prefix_entries(cls, by_cls[cls])
            try:
                ensured += len(entries)
            finally:
                self._release_pins(pins)
        return ensured

    def _fill_prefix_entries(self, cls: int, keys: set) -> tuple[dict, list]:
        """Prefill every missing (prefix ids, start) region of a class once,
        batching fills of equal region length into one submission; cache the
        per-entry KV in the LRU.  A region is ``PAD * pad + prefix`` — the
        exact content of positions [0, start) of every padded row using it,
        which is what makes cached execution bit-identical.

        Entries are stored as pinned block runs in the paged pool (dense
        fallback when the pool is absent or cannot be freed up).  Returns
        ({key: PrefixEntry} DIRECT references for every requested key, so a
        round needing more entries than ``prefix_cache_size`` survives its
        own LRU evictions, plus the round's pin list for
        :meth:`_release_pins` — pool-backed entries hold one extra block
        reference for the round so an eviction cannot free KV mid-use)."""
        refs: dict[tuple, PrefixEntry] = {}
        pins: list[list] = []

        def pin(entry: PrefixEntry) -> None:
            if entry.blocks is not None:
                # ownership transfers to the caller via the returned pin
                # list (released with _release_pins in a try/finally there)
                self.pool.incref(entry.blocks)  # lint: disable=kv-pairing
                pins.append(entry.blocks)

        by_len: dict[int, list[tuple]] = {}
        for key in sorted(keys):
            if key in self._prefix_lru:
                self._prefix_lru.move_to_end(key)
                refs[key] = self._prefix_lru[key]
                pin(refs[key])
                self.stats.prefix_hits += 1
                continue
            pids, pad = key
            by_len.setdefault(pad + len(pids), []).append(key)
        step = self.max_probe_batch or max(
            (len(b) for b in by_len.values()), default=1)
        for region_len in sorted(by_len):
            # honor the engine's memory ceiling, then bucket the fill's row
            # count like every other submission, so varying miss counts
            # reuse one compiled program per region length (the length
            # itself must stay exact — it IS the suffix start position);
            # dummy all-PAD rows are discarded
            pending = by_len[region_len]
            for batch in (pending[i:i + step]
                          for i in range(0, len(pending), step)):
                self.stats.prefix_misses += len(batch)
                self.stats.prefix_fill_submissions += 1
                rows_p = (_next_pow2(len(batch)) if self.bucket_shapes
                          else len(batch))
                arr = np.full((rows_p, region_len), PAD, np.int32)
                for r, (pids, pad) in enumerate(batch):
                    arr[r, pad:] = pids
                _, caches = self._prefill_exact(self.params,
                                               self._make_batch(arr))
                self.stats.prefill_tokens += int(arr.size)
                self.stats.prefix_tokens_saved -= int(arr.size)
                row_blocks = self._pool_rows(len(batch), region_len)
                if row_blocks is not None:
                    self.pool.write(caches, row_blocks)
                for r, key in enumerate(batch):
                    if row_blocks is not None:
                        entry = PrefixEntry(region_len, blocks=row_blocks[r])
                    else:
                        entry = PrefixEntry(region_len, caches=jax.tree.map(
                            lambda l, r=r: l if l.ndim == 2 else l[:, r:r + 1],
                            caches))
                    self._prefix_lru[key] = entry
                    refs[key] = entry
                    pin(entry)
                while len(self._prefix_lru) > self.prefix_cache_size:
                    self._evict_one_prefix()
        return refs, pins

    def _pool_rows(self, rows: int, length: int) -> Optional[list]:
        """Allocate a block run per row (evicting cold prefix entries if
        needed); None when the pool is absent or cannot host the rows — the
        caller falls back to dense storage."""
        if self.pool is None:
            return None
        nb = self.pool.blocks_for(length)
        need = rows * nb
        while self.pool.free_blocks < need and self._prefix_lru:
            self._evict_one_prefix()
        if self.pool.free_blocks < need:
            return None
        # ownership transfers to the probe-submission caller, which releases
        # every run in its round-scoped finally (_release_lease path)
        return [self.pool.alloc(nb) for _ in range(rows)]  # lint: disable=kv-pairing

    def _evict_one_prefix(self) -> None:
        _, entry = self._prefix_lru.popitem(last=False)
        if entry.blocks is not None:
            self.pool.decref(entry.blocks)

    def _release_pins(self, pins: list) -> None:
        for blocks in pins:
            self.pool.decref(blocks)

    def clear_prefix_cache(self) -> None:
        """Drop every cached prefix region (freeing its pool blocks)."""
        while self._prefix_lru:
            self._evict_one_prefix()

    def _entry_caches(self, entry: PrefixEntry):
        """Materialize an entry as the dense per-stack cache pytree the
        suffix-only prefill consumes (a gather is a copy of the stored
        bits, so both storage schemes execute identically)."""
        if entry.caches is not None:
            return entry.caches
        return self.pool.gather_stacked(entry.blocks, entry.length)

    def _run_window(self, cls: int, lw: int, full_ids: list,
                    keys: list, dense: dict) -> np.ndarray:
        """One suffix-window submission: every row attends over its own
        cached-KV slice [0, cls - lw) (selected per row from the window
        job's ``dense`` materialized entries) plus the recomputed window
        tokens [cls - lw, cls).  Bit-identical to a monolithic padded
        prefill of the full rows."""
        r_star = cls - lw
        uniq: list = []
        uniq_of: dict[tuple, int] = {}
        for key in keys:
            if key not in uniq_of:
                uniq_of[key] = len(uniq)
                uniq.append(dense[key])
        rows = len(full_ids)
        rows_p = _next_pow2(rows) if self.bucket_shapes else rows
        arr = np.full((rows_p, lw), PAD, np.int32)
        for r, ids in enumerate(full_ids):
            row = [PAD] * (cls - len(ids)) + list(ids)  # left-padded full row
            arr[r] = row[r_star:]
        eidx = np.zeros((rows_p,), np.int32)
        eidx[:rows] = [uniq_of[k] for k in keys]   # dummy rows reuse entry 0

        def cat(*leaves):
            if leaves[0].ndim == 2:                # stacked pos: arange(R)
                return leaves[0][:, :r_star]
            return jnp.concatenate([l[:, :, :r_star] for l in leaves], axis=1)

        assembled = jax.tree.map(cat, *uniq)
        idx = jnp.asarray(eidx)
        # mesh serving: the per-row cache gather is committed to the same
        # row split as the token batch (_put_rows axis=1 — caches carry the
        # row dim second), so a sliced submission's shards hold only their
        # rows' prefix KV; shared pos leaves (ndim 2) stay replicated
        assembled = jax.tree.map(
            lambda l: l if l.ndim == 2 else self._put_rows(
                jnp.take(l, idx, axis=1), axis=1),
            assembled)
        logits, _ = self._prefill_cont(self.params, assembled,
                                       self._make_batch(arr))
        self.stats.prefill_tokens += int(arr.size)
        self.stats.calls += 1
        self.stats.probe_rows += rows
        self.stats.probe_row_slots += rows_p
        # monolithic baseline: cls tokens per padded row of this submission
        self.stats.prefix_tokens_saved += rows_p * cls - int(arr.size)
        return np.asarray(logits.astype(jnp.float32))[:rows]

    def last_logits(self, prompts: Sequence[Prompt]) -> np.ndarray:
        return self.submit_probes(prompts)

    def score_parts(self, text: str, criteria: str) -> tuple[str, str]:
        """Structured score probe prompt: the criteria block is shared by
        every row of a scoring round (one prefix-KV entry per round)."""
        return (f"Criteria: {criteria}\nItem:", f" {text}\nRating:")

    def score(self, texts: Sequence[str], criteria: str) -> list[float]:
        logits = self.submit_probes(
            [self.score_parts(t, criteria) for t in texts])
        return [read_score(l) for l in logits]

    def _compare_parts(self, a: str, b: str, criteria: str) -> tuple[str, str]:
        # the shared block (criteria + Passage B — quicksort's pivot) leads,
        # so every row of a partition round reuses one prefix-KV entry
        return (f"Criteria: {criteria}\nPassage B: {b}\n",
                f"Passage A: {a}\nWhich ranks higher? Answer:")

    def _compare_prompt(self, a: str, b: str, criteria: str) -> str:
        prefix, suffix = self._compare_parts(a, b, criteria)
        return prefix + suffix

    def compare(self, a: str, b: str, criteria: str) -> int:
        return self.compare_many([(a, b)], criteria)[0]

    def compare_many(self, pairs: Sequence[tuple[str, str]],
                     criteria: str) -> list[int]:
        """A round of independent comparisons in one probe submission."""
        logits = self.submit_probes(
            [self._compare_parts(a, b, criteria) for a, b in pairs])
        return [read_compare(l) for l in logits]

    def yes_no(self, prompt: Prompt) -> bool:
        return self.yes_no_many([prompt])[0]

    def yes_no_many(self, prompts: Sequence[Prompt]) -> list[bool]:
        """A round of independent Y/N probes in one probe submission."""
        logits = self.submit_probes(prompts)
        return [read_yes_no(l) for l in logits]

    def rank_window(self, texts: Sequence[str], criteria: str) -> list[int]:
        """Permutation (ascending by score) from one shared-prefix batch."""
        scores = self.score(texts, criteria)
        return list(np.argsort(np.asarray(scores), kind="stable"))

    # ------------------------------------------------------------- generate
    def _encode_prompt(self, prompt: Prompt) -> list[int]:
        prefix, suffix = self._parts(prompt)
        return self.tok.encode(suffix if prefix is None else prefix + suffix)

    def generate(self, prompts: Sequence[Prompt],
                 max_new: Optional[int] = None,
                 max_new_per: Optional[Sequence[int]] = None) -> list[str]:
        """Batched greedy decode.  On paged-pool-capable archs this drives
        the continuous-batching step loop (admission waves into free
        pool/row capacity, per-row retirement); each row's output is
        token-identical to a solo ``generate_lockstep([prompt])`` run.
        Other archs fall back to the padded lockstep loop."""
        if not self.paged_enabled:
            return self.generate_lockstep(prompts, max_new, max_new_per)
        n = len(prompts)
        # scalar max_new: 0/None means "engine default" (lockstep's
        # ``max_new or self.max_new``); a PER-ROW entry of 0 is a genuine
        # zero budget, exactly as lockstep's max_new_per clamp treats it
        base = min(max_new or self.max_new, self.max_new)
        if max_new_per is None:
            limits = [base] * n
        else:
            assert len(max_new_per) == n
            limits = [min(int(l), self.max_new) for l in max_new_per]
        needs: dict[int, int] = {}

        def get_req(i):
            if i not in needs:            # tokenize once per request
                needs[i] = self.paged_block_need(prompts[i], limits[i])
            return prompts[i], limits[i], needs[i]

        backlog = list(range(n))          # FIFO over prompt indices
        rid_of: dict[int, int] = {}
        pending: set[int] = set()
        outs: dict[int, str] = {}
        while backlog or pending:
            for i, rid in self._paged_admit_wave(backlog, get_req):
                rid_of[i] = rid
                pending.add(rid)
            for rid, text in self.paged_step().items():
                if rid in pending:        # ours
                    outs[rid] = text
                    pending.discard(rid)
                else:                     # a concurrent driver's row (e.g.
                    self._paged_finished[rid] = text   # a scheduler drain)
        return [outs[rid_of[i]] for i in range(n)]

    def generate_lockstep(self, prompts: Sequence[Prompt],
                          max_new: Optional[int] = None,
                          max_new_per: Optional[Sequence[int]] = None
                          ) -> list[str]:
        """The padded lockstep baseline: one prefill batch, then all rows
        decode in lockstep until the LAST row finishes.  ``max_new_per``
        gives each row its own decode budget; rows that hit their budget
        are masked done and emit EOS while the rest keep decoding (and keep
        occupying a decode-row slot — the head-of-line blocking the paged
        loop eliminates)."""
        max_new = min(max_new or self.max_new, self.max_new)
        n = len(prompts)
        tokens = self._pad_ids([self._encode_prompt(p) for p in prompts])
        b, s = tokens.shape                       # b >= n with bucket_shapes
        if max_new_per is None:
            limits = np.full((n,), max_new, np.int64)
        else:
            assert len(max_new_per) == n
            limits = np.minimum(np.asarray(max_new_per, np.int64), self.max_new)
        limits = np.concatenate([limits, np.zeros((b - n,), np.int64)])
        horizon = int(limits.max(initial=0))
        logits, caches = self._prefill(self.params, self._make_batch(tokens))
        self.stats.prefill_tokens += int(tokens.size)
        self.stats.calls += 1
        out = np.full((b, horizon), EOS, np.int64)  # unwritten tail decodes empty
        cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        done = limits <= 0
        for t in range(horizon):
            out[:, t] = np.where(done, EOS, np.asarray(cur[:, 0]))
            done |= np.asarray(cur[:, 0]) == EOS
            done |= (t + 1) >= limits
            if done.all():
                break
            logits, caches = self._decode(self.params, caches, cur,
                                          jnp.int32(s + t))
            self.stats.decode_tokens += int((~done).sum())
            self.stats.decode_row_steps += b
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return [self.tok.decode(row) for row in out[:n]]

    # ------------------------------------- paged continuous-batching decode
    @property
    def paged_active(self) -> int:
        return len(self._paged_rows)

    def _row_limit(self, max_new: Optional[int]) -> int:
        return min(max_new if max_new is not None else self.max_new,
                   self.max_new)

    def paged_block_need(self, prompt: Prompt,
                         max_new: Optional[int] = None) -> int:
        """Worst-case (no prefix sharing) block count to admit ``prompt``."""
        cls = self._pad_class(len(self._encode_prompt(prompt)))
        return self.pool.blocks_for(cls + self._row_limit(max_new))

    def paged_room(self, need_blocks: int, rows_pending: int = 0,
                   blocks_pending: int = 0) -> bool:
        """Can a request needing ``need_blocks`` be admitted now, on top of
        ``rows_pending``/``blocks_pending`` already earmarked this wave?"""
        return (self.paged_active + rows_pending < self.max_decode_rows
                and blocks_pending + need_blocks <= self.pool.free_blocks)

    def _paged_admit_wave(self, queue: list, get_req,
                          max_wave: Optional[int] = None) -> list[tuple]:
        """Pop and admit the FIFO prefix of ``queue`` that fits free
        capacity right now (the shared admission driver behind
        :meth:`generate` and the scheduler's continuous drain).
        ``get_req(item) -> (prompt, max_new, need_blocks)`` — the caller
        memoizes ``need_blocks`` so the head-of-queue prompt is not
        re-tokenized every step it waits.  Returns [(item, rid)].  When the
        head request cannot fit an EMPTY loop, cold prefix runs are evicted
        to make room; a request bigger than the whole pool raises
        ``PoolExhausted``."""
        while True:
            wave, pend = [], 0
            while queue and (max_wave is None or len(wave) < max_wave):
                _, _, need = get_req(queue[0])
                if not self.paged_room(need, rows_pending=len(wave),
                                       blocks_pending=pend):
                    break
                wave.append(queue.pop(0))
                pend += need
            if wave:
                rids = self.paged_admit(
                    [get_req(it)[:2] for it in wave])
                return list(zip(wave, rids))
            # stuck iff nothing IN FLIGHT can still free blocks: finished
            # rows already freed theirs at retirement, so pending outputs
            # (possibly a concurrent driver's, endlessly re-stashed) must
            # NOT defer the eviction/raise — that would livelock a nested
            # generate() whose request needs the LRU's blocks
            if queue and not self._paged_rows:
                if self._prefix_lru:      # cold prefix runs yield to decode
                    self.clear_prefix_cache()
                    continue
                raise PoolExhausted(
                    f"request needs {get_req(queue[0])[2]} blocks but an "
                    f"empty pool frees only {self.pool.free_blocks}")
            return []

    def paged_admit(self, requests: Sequence[tuple]) -> list[int]:
        """Admit a wave of ``(prompt, max_new_or_None)`` requests into the
        continuous decode loop: allocate each row's block run, prefill at
        the row's OWN padded-length class (grouped per class, like probes),
        and scatter the prompt KV into the run.  Structured prompts whose
        (prefix, start) region is cached — or shared by a wave-mate — ride
        the prefix path: the row increfs the entry's full blocks and
        suffix-prefills only the remainder into private blocks appended
        after them.  Returns row ids; outputs arrive via :meth:`paged_step`.
        The caller checks :meth:`paged_room` first; admission beyond
        capacity raises ``PoolExhausted``."""
        reqs = []
        rids_out = []                     # one rid per request, IN ORDER
        for prompt, max_new in requests:
            prefix, suffix = self._parts(prompt)
            rid = next(self._paged_ids)
            rids_out.append(rid)
            limit = self._row_limit(max_new)
            if prefix is not None and self.prefix_cache_enabled:
                pids = tuple(self.tok.encode(prefix))
                sids = self.tok.encode(suffix, bos=False)
                enc = list(pids) + sids
            else:
                pids = sids = None
                enc = self._encode_prompt(prompt)
            cls = self._pad_class(len(enc))
            if limit <= 0:                         # degenerate: no decode
                self._paged_finished[rid] = ""
                continue
            reqs.append((rid, enc, cls, limit, pids, sids))
        # routing: a row rides the prefix path only when its entry is cached
        # or a wave-mate shares it (same policy as submit_probes)
        counts: dict[tuple, int] = {}
        for rid, enc, cls, limit, pids, sids in reqs:
            if pids is not None:
                key = self._region_key(pids, sids, cls)
                counts[(cls, key)] = counts.get((cls, key), 0) + 1
        plain: dict[int, list] = {}
        shared: dict[tuple, list] = {}
        for req in reqs:
            rid, enc, cls, limit, pids, sids = req
            if pids is not None:
                key = self._region_key(pids, sids, cls)
                if key in self._prefix_lru or counts[(cls, key)] >= 2:
                    shared.setdefault((cls, key), []).append(req)
                    continue
            plain.setdefault(cls, []).append(req)
        for cls in sorted(plain):
            for group in _chunks(plain[cls], self.max_probe_batch):
                self._admit_plain(cls, group)
        for (cls, key), group in sorted(shared.items(),
                                        key=lambda kv: kv[0][0]):
            entries, pins = self._fill_prefix_entries(cls, {key})
            try:
                entry = entries[key]
                n_shared = (0 if entry.blocks is None
                            else entry.length // self.pool.block_size)
                if n_shared == 0:
                    # region shorter than a block (or dense fallback):
                    # nothing to append onto — admit monolithically.  Unpin
                    # FIRST: the fill's blocks were not in paged_room's
                    # worst-case budget, so _alloc_rows must be free to
                    # evict the entry
                    self._release_pins(pins)
                    pins = []
                    for group_c in _chunks(group, self.max_probe_batch):
                        self._admit_plain(cls, group_c)
                else:
                    for group_c in _chunks(group, self.max_probe_batch):
                        self._admit_shared(cls, entry, n_shared, group_c)
            finally:                      # a PoolExhausted must not leak
                self._release_pins(pins)  # the round's entry references
        return rids_out

    def _admit_plain(self, cls: int, group: list) -> None:
        """Monolithic prefill of same-class rows into their block runs."""
        tokens = self._pad_ids([enc for _, enc, *_ in group], maxlen=cls)
        logits, caches = self._prefill_exact(self.params,
                                             self._make_batch(tokens))
        self.stats.prefill_tokens += int(tokens.size)
        self.stats.calls += 1
        row_blocks = self._alloc_rows(
            [self.pool.blocks_for(cls + limit)
             for _, _, _, limit, _, _ in group])
        # rows have differing decode headroom (per-request limits); only the
        # prompt span is written now — decode fills the tail block by block
        nb_w = self.pool.blocks_for(cls)
        self.pool.write(caches, [rb[:nb_w] for rb in row_blocks])
        self._start_rows(group, row_blocks, 0, logits)

    def _alloc_rows(self, counts: Sequence[int],
                    incref_run: Optional[list] = None) -> list[list]:
        """Allocate one block run per row, evicting cold prefix entries when
        the free list runs short (region fills are not part of
        ``paged_room``'s worst-case budget, so admission must be able to
        reclaim them); on a genuine shortfall, roll back the group's
        allocations (and ``incref_run`` references) before re-raising so a
        failed admission leaks nothing."""
        runs: list[list] = []
        try:
            for nb in counts:
                if incref_run is not None:
                    # released by the except-PoolExhausted rollback below;
                    # on success ownership lives in the returned row runs
                    self.pool.incref(incref_run)  # lint: disable=kv-pairing
                while (self.pool.free_blocks < nb and self._prefix_lru):
                    self._evict_one_prefix()
                # released by the except-PoolExhausted rollback below; on
                # success ownership lives in the returned row runs
                runs.append(self.pool.alloc(nb))  # lint: disable=kv-pairing
        except PoolExhausted:
            for rb in runs:
                self.pool.decref(rb)
            if incref_run is not None:    # one incref per loop entry
                for _ in range(len(runs) + 1):
                    self.pool.decref(incref_run)
            raise
        return runs

    def _admit_shared(self, cls: int, entry: PrefixEntry, n_shared: int,
                      group: list) -> None:
        """Suffix-only prefill of rows sharing one prefix entry: rows attend
        over the entry's gathered block run (positions [0, start)), compute
        the window [start, cls) themselves, and scatter it into private
        blocks appended after the increfed shared run — bit-identical to the
        monolithic prefill of :meth:`_admit_plain` (causal KV slicing is
        exact at any split; PR 2 contract)."""
        bs = self.pool.block_size
        start = n_shared * bs
        w = cls - start
        assert 0 < w, "shared region must leave a non-empty suffix window"
        rows = len(group)
        rows_p = _next_pow2(rows) if self.bucket_shapes else rows
        arr = np.full((rows_p, w), PAD, np.int32)
        for r, (_, enc, *_rest) in enumerate(group):
            row = [PAD] * (cls - len(enc)) + list(enc)
            arr[r] = row[start:]
        assembled = jax.tree.map(
            lambda l: l[:, :start] if l.ndim == 2 else l[:, :, :start],
            self._entry_caches(entry))
        logits, caches = self._prefill_cont(self.params, assembled,
                                            self._make_batch(arr))
        self.stats.prefill_tokens += int(arr.size)
        self.stats.calls += 1
        self.stats.prefix_tokens_saved += rows_p * cls - int(arr.size)
        shared_run = list(entry.blocks[:n_shared])
        row_blocks = self._alloc_rows(
            [self.pool.blocks_for(cls + limit) - n_shared
             for _, _, _, limit, _, _ in group], incref_run=shared_run)
        nb_w = self.pool.blocks_for(w)           # prompt span only (see plain)
        self.pool.write(caches, [rb[:nb_w] for rb in row_blocks], start=start)
        full = [shared_run + rb for rb in row_blocks]
        self._start_rows(group, full, n_shared, logits)

    def _start_rows(self, group: list, row_blocks: list, n_shared: int,
                    logits) -> None:
        first = np.asarray(jnp.argmax(logits, axis=-1))
        for r, (rid, _enc, cls, limit, _p, _s) in enumerate(group):
            self._paged_rows[rid] = _PagedRow(
                rid=rid, cls=cls, limit=limit, blocks=row_blocks[r],
                n_shared=n_shared, cur=int(first[r]))

    def paged_step(self) -> dict[int, str]:
        """One continuous-batching decode step: record each active row's
        pending token, retire rows that just finished (freeing their blocks
        IMMEDIATELY, before the decode runs, so the freed capacity is
        admittable this very step), then decode all remaining rows — each at
        its own position, gathered through its block table.  Returns
        {rid: output} for rows finished since the last call."""
        finished, self._paged_finished = self._paged_finished, {}
        active: list[_PagedRow] = []
        for rid, row in list(self._paged_rows.items()):
            row.emitted.append(row.cur)
            if row.cur == EOS or len(row.emitted) >= row.limit:
                finished[rid] = self.tok.decode(row.emitted)
                self.pool.decref(row.blocks)
                del self._paged_rows[rid]
            else:
                active.append(row)
        if not active:
            return finished
        b = len(active)
        b_p = _next_pow2(b) if self.bucket_shapes else b
        maxb = max(len(r.blocks) for r in active)
        maxb_p = _next_pow2(maxb) if self.bucket_shapes else maxb
        tables = np.zeros((b_p, maxb_p), np.int32)   # 0 = dummy block
        toks = np.full((b_p, 1), PAD, np.int32)
        pos = np.zeros((b_p,), np.int32)
        for i, row in enumerate(active):
            tables[i, :len(row.blocks)] = row.blocks
            toks[i, 0] = row.cur
            pos[i] = row.cls + row.t
        # mesh serving: decode rows ride the same data-parallel row split as
        # probe submissions (arena stays feature-sharded/block-replicated,
        # so every shard scatters its rows' new KV into the shared layout)
        args = (self.params, self.pool.arenas, self._put_rows(toks),
                self._put_rows(pos), self._put_rows(tables))
        if self.paged_kernel == "check":
            # validation mode: kernel first (arena NOT donated), dense as
            # the source of truth; per-step logits must agree to the
            # documented tolerances
            logits_k, _ = self._decode_paged_kernel(*args)
            logits, arenas = self._decode_paged(*args)
            np.testing.assert_allclose(
                np.asarray(logits_k.astype(jnp.float32))[:b],
                np.asarray(logits.astype(jnp.float32))[:b],
                rtol=PAGED_KERNEL_RTOL, atol=PAGED_KERNEL_ATOL)
        elif self.paged_kernel:
            logits, arenas = self._decode_paged_kernel(*args)
        else:
            logits, arenas = self._decode_paged(*args)
        self.pool.arenas = arenas
        self.stats.decode_tokens += b
        self.stats.decode_row_steps += b_p
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i, row in enumerate(active):
            row.cur = int(nxt[i])
            row.t += 1
        return finished

    # -------------------------------------- preemption: suspend and resume
    def paged_suspend(self, rid: int) -> SuspendedRow:
        """Evict an active decode row to a host-side stash, freeing its pool
        references (shared prefix blocks merely lose this row's ref — the
        LRU or wave-mates keep them alive).  Ordering makes this rollback-
        clean: the stash copy happens FIRST, so an exception mid-suspend
        leaves the row active and the pool untouched."""
        row = self._paged_rows[rid]
        stash = self.pool.stash_blocks(row.blocks)
        s = SuspendedRow(rid=row.rid, cls=row.cls, limit=row.limit,
                         cur=row.cur, t=row.t, emitted=list(row.emitted),
                         n_blocks=len(row.blocks), stash=stash)
        del self._paged_rows[rid]
        self.pool.decref(row.blocks)
        self.stats.preempt_suspends += 1
        self.stats.preempt_blocks_stashed += len(row.blocks)
        return s

    def paged_resume(self, s: SuspendedRow) -> int:
        """Re-admit a suspended row under its original rid: allocate a fresh
        private run, scatter the stash back, and rebuild the row mid-decode
        (``n_shared`` 0 — the resumed run is wholly private).  Continuation
        is byte-identical to never suspending: the stash round trip copies
        stored bits, and ``cur``/``t``/``emitted`` restore the exact decode
        state.  May raise ``PoolExhausted``; the finally rolls the
        allocation back, the stash stays intact, and the caller retries a
        later step."""
        blocks = self.pool.alloc(s.n_blocks)
        try:
            self.pool.unstash_blocks(s.stash, blocks)
            self._paged_rows[s.rid] = _PagedRow(
                rid=s.rid, cls=s.cls, limit=s.limit, blocks=blocks,
                n_shared=0, cur=s.cur, t=s.t, emitted=list(s.emitted))
            self.stats.preempt_resumes += 1
            blocks = None             # ownership transferred to the row
        finally:
            if blocks is not None:
                self.pool.decref(blocks)
        return s.rid


def _chunks(seq: list, step: Optional[int]):
    step = step or len(seq) or 1          # None = one unbounded chunk
    return (seq[i:i + step] for i in range(0, len(seq), step))
