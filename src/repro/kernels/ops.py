"""Jit'd public wrappers for the Pallas kernels.

Dispatch policy: compiled Pallas on TPU, interpret-mode (Python-executed
kernel body) elsewhere — so the SAME kernel code is validated on CPU CI and
deployed on pods.  ``force_interpret`` / ``force_ref`` env knobs support
A/B-ing kernels against their pure-jnp oracles in benchmarks.
"""
from __future__ import annotations

import os
from functools import partial

import jax

from . import ref
from .borda_count import borda_count as _borda
from .decode_attention import decode_attention as _decode
from .flash_attention import flash_attention as _flash
from .mlstm_scan import mlstm_scan as _mlstm
from .moe_gating import moe_gating as _moe_gate
from .paged_attention import paged_attention as _paged
from .ssm_scan import ssm_scan as _ssm
from .topk_scores import topk_scores as _topk


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def use_interpret() -> bool:
    if os.environ.get("REPRO_FORCE_INTERPRET"):
        return True
    return not on_tpu()


def use_ref() -> bool:
    return bool(os.environ.get("REPRO_FORCE_REF"))


@partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                   "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, block_q: int = 128, block_k: int = 128):
    """``q_offset`` > 0 runs suffix-only (chunked) prefill over prepended
    KV — the kernel-level counterpart of the serving prefix-KV cache."""
    if use_ref():
        return ref.attention_ref(q, k, v, causal=causal, window=window,
                                 q_offset=q_offset)
    return _flash(q, k, v, causal=causal, window=window, q_offset=q_offset,
                  block_q=block_q, block_k=block_k, interpret=use_interpret())


@partial(jax.jit, static_argnames=("block_k",))
def decode_attention(q, k_cache, v_cache, pos, *, block_k: int = 256):
    if use_ref():
        return ref.decode_attention_ref(q, k_cache, v_cache, pos)
    return _decode(q, k_cache, v_cache, pos, block_k=block_k,
                   interpret=use_interpret())


@jax.jit
def paged_decode_attention(q, k_pool, v_pool, tables, ctx_len):
    """Flash-decode over the block-paged KV pool: per-sequence block tables
    are scalar-prefetched so the kernel DMAs exactly the blocks a sequence
    owns.  TPU-deployment counterpart of the engine's decode step — like
    every kernel here, the model stack itself runs the XLA-level equivalent
    (layers.paged_decode_attention_dense, which the bit-identity contract
    needs); this is the pod-serving variant validated against the same
    ref oracle."""
    if use_ref():
        return ref.paged_decode_attention_ref(q, k_pool, v_pool, tables,
                                              ctx_len)
    return _paged(q, k_pool, v_pool, tables, ctx_len,
                  interpret=use_interpret())


@partial(jax.jit, static_argnames=("k", "block_n"))
def topk_scores(scores, k: int, *, block_n: int = 1024):
    """Two-stage top-k: blocked Pallas candidates + final jnp reduce."""
    if use_ref():
        return ref.topk_ref(scores, k)
    bv, bi = _topk(scores, k, block_n=block_n, interpret=use_interpret())
    cand_v, cand_i = bv.reshape(-1), bi.reshape(-1)
    vals, sel = jax.lax.top_k(cand_v, k)
    return vals, cand_i[sel]


@partial(jax.jit, static_argnames=("n_items", "block_items", "block_ballots"))
def borda_count(ballots, n_items: int, *, block_items: int = 128,
                block_ballots: int = 8):
    if use_ref():
        return ref.borda_ref(ballots, n_items)
    return _borda(ballots, n_items, block_items=block_items,
                  block_ballots=block_ballots, interpret=use_interpret())


@partial(jax.jit, static_argnames=("block_d", "chunk"))
def ssm_scan(x, dt, b_t, c_t, a, *, block_d: int = 256, chunk: int = 64):
    if use_ref():
        return ref.ssm_scan_ref(x, dt, b_t, c_t, a)[0]
    return _ssm(x, dt, b_t, c_t, a, block_d=block_d, chunk=chunk,
                interpret=use_interpret())


@partial(jax.jit, static_argnames=("chunk",))
def mlstm_scan(q, k, v, i_g, f_g, *, chunk: int = 64):
    if use_ref():
        return ref.mlstm_ref(q, k, v, i_g, f_g)
    return _mlstm(q, k, v, i_g, f_g, chunk=chunk, interpret=use_interpret())


@partial(jax.jit, static_argnames=("k", "block_t"))
def moe_gating(logits, k: int, *, block_t: int = 256):
    if use_ref():
        idx, gates, pos, _ = ref.moe_gating_ref(logits, k, capacity=1 << 30)
        return idx, gates, pos
    return _moe_gate(logits, k, block_t=block_t, interpret=use_interpret())
