"""Pallas TPU kernels for the perf-critical compute layers.

kernel              layer it accelerates (paper anchor)
------------------  ----------------------------------------------------
flash_attention     prefill/train attention, GQA + sliding window + chunked
                    prefill over prepended prefix KV (serving lever of the
                    external paths' shared-prefix batching, Sec. 3)
decode_attention    serve decode over dense ring KV caches (flash-decode)
paged_attention     serve decode over the block-paged KV pool (continuous
                    batching for Sec. 5.4 judge generations)
topk_scores         value-based ORDER BY ... LIMIT K selection (Sec. 3.1
                    pointwise scores -> Table 1 LIMIT-K pushdown)
borda_count         consensus aggregation of candidate rankings for the
                    budget-aware optimizer's pessimistic strategy (Sec. 5)
ssm_scan            Hymba Mamba heads (chunked selective scan)
mlstm_scan          xLSTM matrix-memory blocks (chunkwise-parallel)
moe_gating          Mixtral router top-k + dispatch ranks

Each kernel: ``<name>.py`` (pl.pallas_call + explicit BlockSpec VMEM
tiling), a jit'd wrapper in ``ops.py`` (interpret-mode on CPU, compiled on
TPU), and a pure-jnp oracle in ``ref.py`` asserted against in tests.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
