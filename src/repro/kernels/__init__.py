"""Pallas TPU kernels for the perf-critical compute layers.

kernel              layer it accelerates
------------------  ----------------------------------------------------
flash_attention     prefill/train attention (GQA + sliding window)
decode_attention    serve decode over ring KV caches (flash-decode)
topk_scores         value-based ORDER BY ... LIMIT K selection
borda_count         pessimistic-optimizer consensus aggregation
ssm_scan            Hymba Mamba heads (chunked selective scan)
mlstm_scan          xLSTM matrix-memory blocks (chunkwise-parallel)
moe_gating          Mixtral router top-k + dispatch ranks

Each kernel: ``<name>.py`` (pl.pallas_call + explicit BlockSpec VMEM
tiling), a jit'd wrapper in ``ops.py`` (interpret-mode on CPU, compiled on
TPU), and a pure-jnp oracle in ``ref.py`` asserted against in tests.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
