"""Flash-decode Pallas TPU kernel: one query token against a (possibly ring)
DENSE KV cache — the lockstep decode path (paged_attention.py is the
block-paged counterpart used by the continuous-batching loop).

Grid (batch, kv_head, kv_blocks): the whole GQA query-head *group* for one
KV head rides in a single (G, hd) VMEM tile (G = H/KV), so the MXU sees a
(G, hd) x (hd, Bk) matmul per block instead of H vector-dot passes.  Online
softmax over kv blocks with fp32 scratch; slot validity comes from the ring
cache's absolute-position array (pos >= 0), which makes full and sliding-
window caches the same kernel.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, pos_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, bk: int, n_blocks: int, cache_len: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale              # (G, hd)
    k = k_ref[0].astype(jnp.float32)                      # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    slot = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    valid = (pos_ref[...] >= 0) & (slot < cache_len)      # (1, bk)
    k = jnp.where(valid.T, k, 0.0)
    v = jnp.where(valid.T, v, 0.0)
    s = q @ k.T                                           # (G, bk)
    s = jnp.where(valid, s, NEG_INF)
    m_prev = m_scr[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_cur)
    alpha = jnp.exp(m_prev - m_cur)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + p @ v
    m_scr[...] = m_cur

    @pl.when(ki == n_blocks - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, pos, *, block_k: int = 256,
                     interpret: bool = False):
    """q: (B, H, hd); k_cache/v_cache: (B, S, KV, hd); pos: (S,) int32.
    Returns (B, H, hd)."""
    b, h, hd = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    bk = min(block_k, s)
    n_blocks = pl.cdiv(s, bk)
    scale = 1.0 / math.sqrt(hd)

    # (B, KV, G, hd) query groups; caches to (B*KV, S, hd)
    qg = q.reshape(b, kv, g, hd).reshape(b * kv, g, hd)
    kf = k_cache.transpose(0, 2, 1, 3).reshape(b * kv, s, hd)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(b * kv, s, hd)
    posf = pos.reshape(1, s)

    kernel = functools.partial(_kernel, scale=scale, bk=bk,
                               n_blocks=n_blocks, cache_len=s)
    out = pl.pallas_call(
        kernel,
        grid=(b, kv, n_blocks),
        in_specs=[
            pl.BlockSpec((1, g, hd), lambda bi, ci, ki: (bi * pl.num_programs(1) + ci, 0, 0)),
            pl.BlockSpec((1, bk, hd), lambda bi, ci, ki: (bi * pl.num_programs(1) + ci, ki, 0)),
            pl.BlockSpec((1, bk, hd), lambda bi, ci, ki: (bi * pl.num_programs(1) + ci, ki, 0)),
            pl.BlockSpec((1, bk), lambda bi, ci, ki: (0, ki)),
        ],
        out_specs=pl.BlockSpec((1, g, hd),
                               lambda bi, ci, ki: (bi * pl.num_programs(1) + ci, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kv, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kf, vf, posf)
    return out.reshape(b, h, hd)
