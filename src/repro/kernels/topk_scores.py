"""Blocked top-k selection Pallas TPU kernel — the value-based
``ORDER BY ... LIMIT K`` hot path (Sec. 3.1 pointwise scoring + the
Table 1 LIMIT-K pushdown: sort N pointwise scores, keep K).

TPU adaptation of GPU warp-bitonic selection: the score vector is tiled into
VPU-aligned blocks; each grid step extracts its block's local top-k by k
iterations of (max, mask) over an (8, bn/8) VMEM tile — a vectorized
reduction the VPU executes natively — writing (k values, k global indices)
per block.  The ops.py wrapper reduces the (n_blocks, k) candidates with one
final jnp.top_k (n_blocks*k << N).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -3.0e38


def _kernel(s_ref, v_ref, i_ref, *, k: int, bn: int, n: int):
    bi = pl.program_id(0)
    base = bi * bn
    s = s_ref[...].astype(jnp.float32)                    # (1, bn)
    idx = base + jax.lax.broadcasted_iota(jnp.int32, (1, bn), 1)
    s = jnp.where(idx < n, s, NEG_INF)

    def body(j, carry):
        s_cur, vals, idxs = carry
        m = jnp.max(s_cur, axis=-1)                       # (1,)
        am = jnp.argmax(s_cur, axis=-1)                   # (1,)
        vals = vals.at[:, j].set(m)
        idxs = idxs.at[:, j].set(base + am.astype(jnp.int32))
        hit = jax.lax.broadcasted_iota(jnp.int32, (1, bn), 1) == am[:, None]
        return jnp.where(hit, NEG_INF, s_cur), vals, idxs

    vals0 = jnp.full((1, k), NEG_INF, jnp.float32)
    idxs0 = jnp.zeros((1, k), jnp.int32)
    _, vals, idxs = jax.lax.fori_loop(0, k, body, (s, vals0, idxs0))
    v_ref[...] = vals
    i_ref[...] = idxs


def topk_scores(scores, k: int, *, block_n: int = 1024,
                interpret: bool = False):
    """scores (N,) -> (block-candidate values (n_blocks, k), indices).
    Compose with a final jnp top_k over the flattened candidates (ops.py)."""
    n = scores.shape[0]
    bn = min(block_n, max(k, pl.next_power_of_2(min(n, block_n))))
    n_blocks = pl.cdiv(n, bn)
    kernel = functools.partial(_kernel, k=k, bn=bn, n=n)
    vals, idxs = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((1, bn), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((1, k), lambda i: (i, 0)),
                   pl.BlockSpec((1, k), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n_blocks, k), jnp.float32),
                   jax.ShapeDtypeStruct((n_blocks, k), jnp.int32)],
        interpret=interpret,
    )(scores.reshape(1, n))
    return vals, idxs
