"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function is the mathematically transparent implementation that the
kernels/tests assert_allclose against across shape/dtype sweeps.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

f32 = jnp.float32
NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  q_offset: int = 0):
    """q: (B, H, Sq, hd); k, v: (B, KV, Sk, hd).  ``q_offset`` places the
    queries at absolute positions [q_offset, q_offset + Sq) of the key
    sequence — the chunked-prefill-over-prepended-KV case (Sk > Sq)."""
    b, h, sq, hd = q.shape
    kv, sk = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, kv, g, sq, hd).astype(f32) / math.sqrt(hd)
    scores = jnp.einsum("bkgqd,bksd->bkgqs", qg, k.astype(f32))
    rows = jnp.arange(sq)[:, None] + q_offset
    cols = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= cols <= rows
    if window:
        mask &= cols > rows - window
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", w, v.astype(f32))
    return out.reshape(b, h, sq, hd).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, pos):
    """q: (B, H, hd); caches (B, S, KV, hd); pos (S,) int32 (-1 = empty)."""
    b, h, hd = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    qg = q.reshape(b, kv, g, hd).astype(f32) / math.sqrt(hd)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache.astype(f32))
    valid = (pos >= 0)[None, None, None, :]
    scores = jnp.where(valid, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v_cache.astype(f32))
    return out.reshape(b, h, hd).astype(q.dtype)


def paged_decode_attention_ref(q, k_pool, v_pool, tables, ctx_len):
    """Paged decode oracle: gather each sequence's block run into a dense
    view, then masked attention.  q: (B, H, hd); k_pool/v_pool
    (NB, block_size, KV, hd); tables (B, MAXB) int32 block runs (0-padded —
    block 0 is the pool's dummy); ctx_len (B,) int32 valid lengths."""
    b, h, hd = q.shape
    bs, kv = k_pool.shape[1], k_pool.shape[2]
    maxb = tables.shape[1]
    g = h // kv
    flat = tables.reshape(-1)
    kg = jnp.take(k_pool, flat, axis=0).reshape(b, maxb * bs, kv, hd)
    vg = jnp.take(v_pool, flat, axis=0).reshape(b, maxb * bs, kv, hd)
    qg = q.reshape(b, kv, g, hd).astype(f32) / math.sqrt(hd)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, kg.astype(f32))
    valid = jnp.arange(maxb * bs)[None, :] < ctx_len[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, vg.astype(f32))
    return out.reshape(b, h, hd).astype(q.dtype)


def topk_ref(scores, k: int):
    """scores (N,) -> (values desc (k,), indices (k,))."""
    v, i = jax.lax.top_k(scores.astype(f32), k)
    return v, i


def borda_ref(ballots, n_items: int):
    """ballots (R, S) int32 item indices (-1 pads) -> points (n_items,)."""
    r, s = ballots.shape
    pts = jnp.arange(s, 0, -1, dtype=f32)                 # position points
    onehot = jax.nn.one_hot(jnp.where(ballots < 0, n_items, ballots),
                            n_items + 1, dtype=f32)[..., :n_items]
    return jnp.einsum("rsn,s->n", onehot, pts)


def ssm_scan_ref(x, dt, b_t, c_t, a, h0=None):
    """Sequential selective-scan oracle.
    x, dt: (B, S, D); b_t, c_t: (B, S, N); a: (D, N).
    Returns (y (B, S, D), h_final (B, D, N))."""
    bsz, s, d = x.shape
    n = a.shape[1]
    h = jnp.zeros((bsz, d, n), f32) if h0 is None else h0

    def step(h, inp):
        xt, dtt, bt, ct = inp
        da = jnp.exp(dtt[..., None] * a)                  # (B, D, N)
        h = da * h + (dtt * xt)[..., None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    xs = (x.transpose(1, 0, 2).astype(f32), dt.transpose(1, 0, 2).astype(f32),
          b_t.transpose(1, 0, 2).astype(f32), c_t.transpose(1, 0, 2).astype(f32))
    h_f, ys = jax.lax.scan(step, h, xs)
    return ys.transpose(1, 0, 2), h_f


def mlstm_ref(q, k, v, i_g, f_g):
    """Per-step mLSTM oracle.  q,k: (B,H,S,dqk); v: (B,H,S,dv);
    i_g,f_g: (B,H,S).  Returns h (B,H,S,dv)."""
    bsz, hh, s, dqk = q.shape
    dv = v.shape[-1]
    qs = q.astype(f32) / math.sqrt(dqk)

    def step(carry, t):
        c, n, m = carry
        lf = jax.nn.log_sigmoid(f_g[:, :, t])
        m2 = jnp.maximum(lf + m, i_g[:, :, t])
        decay = jnp.exp(lf + m - m2)
        inj = jnp.exp(i_g[:, :, t] - m2)
        c = decay[..., None, None] * c + inj[..., None, None] * (
            k[:, :, t, :, None].astype(f32) * v[:, :, t, None, :].astype(f32))
        n = decay[..., None] * n + inj[..., None] * k[:, :, t].astype(f32)
        num = jnp.einsum("bhkv,bhk->bhv", c, qs[:, :, t])
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qs[:, :, t])),
                          jnp.exp(-m2))
        return (c, n, m2), num / den[..., None]

    c0 = jnp.zeros((bsz, hh, dqk, dv), f32)
    n0 = jnp.zeros((bsz, hh, dqk), f32)
    m0 = jnp.zeros((bsz, hh), f32)
    _, hs = jax.lax.scan(step, (c0, n0, m0), jnp.arange(s))
    return hs.transpose(1, 2, 0, 3)                        # (B,H,S,dv)


def moe_gating_ref(logits, k: int, capacity: int):
    """logits (T, E) -> (idx (T,k), gates (T,k), pos (T,k), keep (T,k)).
    Position = arrival rank within each expert (row-major over (T, k))."""
    t, e = logits.shape
    top_vals, top_idx = jax.lax.top_k(logits.astype(f32), k)
    gates = jax.nn.softmax(top_vals, axis=-1)
    flat = top_idx.reshape(-1)
    onehot = jax.nn.one_hot(flat, e, dtype=jnp.int32)
    pos_flat = jnp.cumsum(onehot, axis=0) - 1
    pos = pos_flat[jnp.arange(t * k), flat].reshape(t, k)
    keep = pos < capacity
    return top_idx, gates, pos, keep
