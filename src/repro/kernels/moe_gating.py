"""Fused MoE top-k gating Pallas TPU kernel (Mixtral router hot path).

One pass over router logits produces, per token: the top-k expert ids, the
softmax-over-top-k gate weights, and the token's *arrival rank* within each
chosen expert (the dispatch slot).  The rank needs a running per-expert
counter across token blocks — the TPU grid is sequential, so the counter is
an (1, E) VMEM scratch accumulator (GPU versions need global atomics here;
the sequential grid is the TPU-native substitute).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -3.0e38


def _kernel(l_ref, idx_ref, gate_ref, pos_ref, cnt_scr, *, k: int, bt: int,
            e: int, n_tokens: int):
    ti = pl.program_id(0)

    @pl.when(ti == 0)
    def _init():
        cnt_scr[...] = jnp.zeros_like(cnt_scr)

    logits = l_ref[...].astype(jnp.float32)               # (bt, E)
    rows = ti * bt + jax.lax.broadcasted_iota(jnp.int32, (bt, 1), 0)
    live = rows < n_tokens                                # (bt, 1)
    logits = jnp.where(live, logits, NEG)

    # iterative top-k (k small): max + mask
    vals = jnp.zeros((bt, k), jnp.float32)
    idxs = jnp.zeros((bt, k), jnp.int32)
    cur = logits
    for j in range(k):
        m = jnp.max(cur, axis=-1)
        am = jnp.argmax(cur, axis=-1).astype(jnp.int32)
        vals = vals.at[:, j].set(m)
        idxs = idxs.at[:, j].set(am)
        hit = jax.lax.broadcasted_iota(jnp.int32, (bt, e), 1) == am[:, None]
        cur = jnp.where(hit, NEG, cur)

    gates = jax.nn.softmax(vals, axis=-1)

    # arrival ranks: one-hot cumsum within the block + running counters
    flat = idxs.reshape(bt * k)                           # row-major (t, j)
    oh = (jax.lax.broadcasted_iota(jnp.int32, (bt * k, e), 1)
          == flat[:, None]).astype(jnp.int32)
    live_flat = jnp.repeat(live[:, 0], k)[:, None].astype(jnp.int32)
    oh = oh * live_flat
    within = jnp.cumsum(oh, axis=0) - oh                  # exclusive
    base = cnt_scr[...]                                   # (1, E)
    pos_flat = jnp.sum((within + base) * oh, axis=-1)     # (bt*k,)
    cnt_scr[...] = base + jnp.sum(oh, axis=0, keepdims=True)

    idx_ref[...] = idxs
    gate_ref[...] = gates
    pos_ref[...] = pos_flat.reshape(bt, k)


def moe_gating(logits, k: int, *, block_t: int = 256,
               interpret: bool = False):
    """logits (T, E) -> (idx (T,k) int32, gates (T,k) fp32, pos (T,k) int32).
    ``pos`` is the row-major arrival rank within each expert (capacity
    filtering `pos < C` is the caller's one-liner)."""
    t, e = logits.shape
    bt = min(block_t, t)
    n_tb = pl.cdiv(t, bt)
    kernel = functools.partial(_kernel, k=k, bt=bt, e=e, n_tokens=t)
    idx, gates, pos = pl.pallas_call(
        kernel,
        grid=(n_tb,),
        in_specs=[pl.BlockSpec((bt, e), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bt, k), lambda i: (i, 0)),
                   pl.BlockSpec((bt, k), lambda i: (i, 0)),
                   pl.BlockSpec((bt, k), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n_tb * bt, k), jnp.int32),
                   jax.ShapeDtypeStruct((n_tb * bt, k), jnp.float32),
                   jax.ShapeDtypeStruct((n_tb * bt, k), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((1, e), jnp.int32)],
        interpret=interpret,
    )(logits)
    return idx[:t], gates[:t], pos[:t]
