"""Flash attention (prefill/train) Pallas TPU kernel.

Streaming-softmax attention with GQA and optional sliding-window masking.
The grid is (batch*q_heads, q_blocks, kv_blocks) with the kv dimension
innermost — on TPU the grid executes sequentially per core, so the fp32
online-softmax state (m, l, acc) lives in VMEM scratch and persists across
kv steps.  BlockSpecs keep one (Bq, hd) query tile and one (Bk, hd) KV tile
resident in VMEM; GQA maps each query head onto its shared KV head inside
the index_map (no KV duplication in HBM).  Causal/window masking is computed
from program ids; fully-dead KV blocks are skipped with pl.when.

Chunked prefill over prepended KV (the serving engine's prefix-KV cache):
``q_offset`` places the Sq query rows at absolute positions
``[q_offset, q_offset + Sq)`` of an Sk-long key sequence (Sk >= Sq — the
leading ``q_offset`` keys come from a cached prefix), so causal masking
compares absolute positions and a suffix-only prefill attends over
``[cached KV; own KV]`` exactly as a monolithic prefill would.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, q_offset: int, bq: int,
            bk: int, n_kv_blocks: int, kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq + q_offset                          # absolute position
    k_start = ki * bk
    live = jnp.bool_(True)
    if causal:
        live = k_start <= q_start + bq - 1
    if window:
        live = jnp.logical_and(live, k_start + bk - 1 > q_start - window)

    @pl.when(live)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
        v = v_ref[0].astype(jnp.float32)                  # (bk, hd)
        # zero the padded tail of the last kv block: 0-weight x garbage
        # (possibly-NaN OOB reads) would otherwise poison the accumulator
        col_valid = (k_start + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)
                     ) < kv_len
        k = jnp.where(col_valid, k, 0.0)
        v = jnp.where(col_valid, v, 0.0)
        s = q @ k.T                                       # (bq, bk)
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = cols < kv_len
        if causal:
            mask &= cols <= rows
        if window:
            mask &= cols > rows - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]                               # (bq, 1)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_cur)
        alpha = jnp.exp(m_prev - m_cur)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + p @ v
        m_scr[...] = m_cur

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (B, H, Sq, hd); k, v: (B, KV, Sk, hd).  Returns (B, H, Sq, hd).

    ``Sk`` may exceed ``Sq`` when the leading keys are a prepended
    (cached-prefix) KV; ``q_offset`` is then the absolute position of query
    row 0 — normally ``Sk - Sq`` — and causal masking compares absolute
    positions.  ``q_offset=0`` with ``Sq == Sk`` is ordinary self-attention.
    """
    b, h, sq, hd = q.shape
    kv, sk = k.shape[1], k.shape[2]
    assert h % kv == 0
    group = h // kv
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    n_q = pl.cdiv(sq, bq)
    n_k = pl.cdiv(sk, bk)
    scale = 1.0 / math.sqrt(hd)

    qf = q.reshape(b * h, sq, hd)
    kf = k.reshape(b * kv, sk, hd)
    vf = v.reshape(b * kv, sk, hd)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        return ((bh // h) * kv + (bh % h) // group, ki, 0)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, bq=bq, bk=bk, n_kv_blocks=n_k, kv_len=sk)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, hd), q_map),
            pl.BlockSpec((1, bk, hd), kv_map),
            pl.BlockSpec((1, bk, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, hd)
