"""Chunkwise-parallel mLSTM Pallas TPU kernel (xLSTM matrix memory).

Grid (batch*heads, seq_chunks), chunks innermost-sequential: the fp32 carry
(C (dqk, dv), n (dqk, 1), m (1, 1)) persists in VMEM scratch.  Within a
chunk everything is matmul-shaped for the MXU: the intra-chunk term is a
gate-decayed (T, T) attention-like product, the inter-chunk term is
q @ C_prev, both stabilized by a per-row running max (TFLA-style).
Correctness oracle: the per-step recurrence in ref.mlstm_ref.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, i_ref, f_ref, o_ref, c_scr, n_scr, m_scr, *,
            t: int, dqk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        c_scr[...] = jnp.zeros_like(c_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        m_scr[...] = jnp.zeros_like(m_scr)

    q = q_ref[0].astype(jnp.float32) / math.sqrt(dqk)     # (t, dqk)
    k = k_ref[0].astype(jnp.float32)                      # (t, dqk)
    v = v_ref[0].astype(jnp.float32)                      # (t, dv)
    ig = i_ref[...].astype(jnp.float32)[0]                # (t,)
    fg = f_ref[...].astype(jnp.float32)[0]                # (t,)

    c_prev = c_scr[...]
    n_prev = n_scr[...]                                   # (dqk, 1)
    m_prev = m_scr[0, 0]

    lf = jax.nn.log_sigmoid(fg)
    bcum = jnp.cumsum(lf)                                 # (t,)
    g_tot = bcum[t - 1]
    dmat = bcum[:, None] - bcum[None, :] + ig[None, :]    # (t, t)
    tri = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    dmat = jnp.where(tri, dmat, NEG)
    inter_log = bcum + m_prev                             # (t,)
    m_row = jnp.maximum(jnp.max(dmat, axis=-1), inter_log)
    m_row = jnp.maximum(m_row, -50.0)
    w_intra = jnp.exp(dmat - m_row[:, None])              # (t, t)
    w_inter = jnp.exp(inter_log - m_row)                  # (t,)

    scores = q @ k.T                                      # (t, t)
    h_intra = (w_intra * scores) @ v                      # (t, dv)
    h_inter = (q @ c_prev) * w_inter[:, None]             # (t, dv)
    n_comb = w_intra @ k + n_prev[:, 0][None, :] * w_inter[:, None]  # (t, dqk)
    denom = jnp.maximum(jnp.abs(jnp.sum(n_comb * q, axis=-1)),
                        jnp.exp(-m_row))
    o_ref[0] = ((h_intra + h_inter) / denom[:, None]).astype(o_ref.dtype)

    # carry update
    m_new = jnp.maximum(g_tot + m_prev, jnp.max(g_tot - bcum + ig))
    src = jnp.exp(g_tot - bcum + ig - m_new)              # (t,)
    decay = jnp.exp(g_tot + m_prev - m_new)
    c_scr[...] = decay * c_prev + k.T @ (src[:, None] * v)
    n_scr[...] = decay * n_prev + (k.T @ src[:, None])
    m_scr[0, 0] = m_new


def mlstm_scan(q, k, v, i_g, f_g, *, chunk: int = 64,
               interpret: bool = False):
    """q, k: (B, H, S, dqk); v: (B, H, S, dv); i_g, f_g: (B, H, S).
    Returns h (B, H, S, dv).  S must be a multiple of ``chunk``."""
    b, h, s, dqk = q.shape
    dv = v.shape[-1]
    assert s % chunk == 0
    bh = b * h
    qf = q.reshape(bh, s, dqk)
    kf = k.reshape(bh, s, dqk)
    vf = v.reshape(bh, s, dv)
    i_f = i_g.reshape(bh, s)
    f_f = f_g.reshape(bh, s)

    kernel = functools.partial(_kernel, t=chunk, dqk=dqk)
    out = pl.pallas_call(
        kernel,
        grid=(bh, s // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, dqk), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, dqk), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, dv), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk), lambda bi, ci: (bi, ci)),
            pl.BlockSpec((1, chunk), lambda bi, ci: (bi, ci)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dv), lambda bi, ci: (bi, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((dqk, dv), jnp.float32),
            pltpu.VMEM((dqk, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf, i_f, f_f)
    return out.reshape(b, h, s, dv)
