"""Chunked selective-scan (Mamba) Pallas TPU kernel — Hymba's SSM heads.

Grid (batch, d_inner_blocks, seq_chunks) with chunks innermost: the fp32 SSM
state h (Bd, N) lives in VMEM scratch and persists across the sequential
chunk dimension, so the recurrence never round-trips HBM.  Inputs stay in
their compact forms (x, dt, B_t, C_t) — the (S, D, N) outer products exist
only chunk-at-a-time in VMEM, which is the whole point of the blocking (the
GPU version materializes them in shared memory; VMEM plays that role here).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, o_ref, h_scr, *,
            chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[...].astype(jnp.float32)                    # (bd, N)

    def step(t, h):
        xt = x_ref[0, t, :].astype(jnp.float32)           # (bd,)
        dtt = dt_ref[0, t, :].astype(jnp.float32)         # (bd,)
        bt = b_ref[0, t, :].astype(jnp.float32)           # (N,)
        ct = c_ref[0, t, :].astype(jnp.float32)           # (N,)
        da = jnp.exp(dtt[:, None] * a)                    # (bd, N)
        h = da * h + (dtt * xt)[:, None] * bt[None, :]
        o_ref[0, t, :] = (h @ ct).astype(o_ref.dtype)     # (bd,)
        return h

    h_scr[...] = jax.lax.fori_loop(0, chunk, step, h_scr[...])


def ssm_scan(x, dt, b_t, c_t, a, *, block_d: int = 256, chunk: int = 64,
             interpret: bool = False):
    """x, dt: (B, S, D); b_t, c_t: (B, S, N); a: (D, N) -> y (B, S, D).
    S must be a multiple of ``chunk`` and D of ``block_d`` (callers pad)."""
    bsz, s, d = x.shape
    n = a.shape[1]
    bd = min(block_d, d)
    n_db = pl.cdiv(d, bd)
    n_ch = s // chunk
    assert s % chunk == 0 and d % bd == 0

    kernel = functools.partial(_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(bsz, n_db, n_ch),
        in_specs=[
            pl.BlockSpec((1, chunk, bd), lambda bi, di, ci: (bi, ci, di)),
            pl.BlockSpec((1, chunk, bd), lambda bi, di, ci: (bi, ci, di)),
            pl.BlockSpec((1, chunk, n), lambda bi, di, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, di, ci: (bi, ci, 0)),
            pl.BlockSpec((bd, n), lambda bi, di, ci: (di, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, bd), lambda bi, di, ci: (bi, ci, di)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, b_t, c_t, a)
    return out
