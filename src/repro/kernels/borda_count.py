"""Borda-count aggregation Pallas TPU kernel — consensus aggregation of
candidate rankings for the budget-aware optimizer's pessimistic strategy
(Sec. 5; hot at fleet scale: thousands of queries x R candidate ballots
each).

TPU adaptation: GPU implementations scatter-add with atomics; TPUs have no
scatter-atomics, so the positional-points accumulation is recast as a
one-hot-matmul that feeds the MXU: for an item-block of width Bn,
``points[n] = sum_{r,s} [ballot[r,s] == n] * pts[s]`` =
``einsum('rs n, s -> n')`` over the comparison one-hot.  Grid
(item_blocks, ballot_blocks) with ballots innermost, accumulating in VMEM
scratch.  Padded ballot slots carry index -1 and never match an item.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ballot_ref, pts_ref, o_ref, acc_scr, *, bn: int, br: int,
            n_ballot_blocks: int):
    ni = pl.program_id(0)
    ri = pl.program_id(1)

    @pl.when(ri == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    ballots = ballot_ref[...]                              # (br, S) int32
    pts = pts_ref[...].astype(jnp.float32)                 # (1, S)
    base = ni * bn
    items = base + jax.lax.broadcasted_iota(jnp.int32, (1, bn), 1)  # (1, bn)
    # one-hot contraction: (br*S, 1) ballots vs (1, bn) items on the VPU,
    # reduced with a (1, br*S) x (br*S, bn) MXU matmul against the points.
    flat = ballots.reshape(-1, 1)                          # (br*S, 1)
    onehot = (flat == items).astype(jnp.float32)           # (br*S, bn)
    w = jnp.broadcast_to(pts, (br, pts.shape[1])).reshape(1, -1)  # (1, br*S)
    acc_scr[...] += w @ onehot                             # (1, bn)

    @pl.when(ri == n_ballot_blocks - 1)
    def _finish():
        o_ref[...] = acc_scr[...]


def borda_count(ballots, n_items: int, *, block_items: int = 128,
                block_ballots: int = 8, interpret: bool = False):
    """ballots (R, S) int32 (-1 pads) -> points (n_items,) fp32.
    Points: position p contributes S - p (matches optimizer/borda.py)."""
    r, s = ballots.shape
    bn = min(block_items, pl.next_power_of_2(n_items))
    br = min(block_ballots, r)
    n_nb = pl.cdiv(n_items, bn)
    n_rb = pl.cdiv(r, br)
    pts = jnp.arange(s, 0, -1, dtype=jnp.float32).reshape(1, s)
    # pad ballot rows to a multiple of br with -1 (never matches an item)
    pad_r = n_rb * br - r
    if pad_r:
        ballots = jnp.concatenate(
            [ballots, jnp.full((pad_r, s), -1, ballots.dtype)])

    kernel = functools.partial(_kernel, bn=bn, br=br, n_ballot_blocks=n_rb)
    out = pl.pallas_call(
        kernel,
        grid=(n_nb, n_rb),
        in_specs=[pl.BlockSpec((br, s), lambda ni, ri: (ri, 0)),
                  pl.BlockSpec((1, s), lambda ni, ri: (0, 0))],
        out_specs=pl.BlockSpec((1, bn), lambda ni, ri: (0, ni)),
        out_shape=jax.ShapeDtypeStruct((1, n_nb * bn), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, bn), jnp.float32)],
        interpret=interpret,
    )(ballots, pts)
    return out[0, :n_items]
