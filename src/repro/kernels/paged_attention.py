"""Paged flash-decode Pallas TPU kernel: one query token per sequence
against the sequence's block run in the shared paged KV pool — the
TPU-deployment counterpart of the continuous-batching decode step's
gather+attend XLA path (layers.paged_decode_attention_dense; DESIGN.md
"Paged KV pool").

Grid (batch, kv_head, table_slots) with the block dimension innermost.  The
per-sequence block table rides in scalar-prefetch memory
(``pltpu.PrefetchScalarGridSpec``), so each step's BlockSpec index_map
resolves ``tables[b, i]`` BEFORE the kernel body runs and the DMA engine
fetches exactly the (block_size, hd) KV tile that block id names — the pool
itself never needs to be contiguous per sequence, which is the whole point
of paging: no copy on admission, no compaction on retirement.  As in
decode_attention, the GQA query-head group for one KV head rides in a
single (G, hd) VMEM tile and accumulates online-softmax state (m, l, acc)
in fp32 scratch across table slots.  Slot validity is positional:
``i * block_size + slot < ctx_len[b]`` — padded table slots point at dummy
block 0 and mask to zero weight, so arbitrary table padding cannot perturb
the result.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(tables_ref, ctx_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale: float, bs: int, n_slots: int):
    bi = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale           # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)                   # (bs, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    pos = ki * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    valid = pos < ctx_ref[bi]                             # (1, bs)
    k = jnp.where(valid.T, k, 0.0)
    v = jnp.where(valid.T, v, 0.0)
    s = q @ k.T                                           # (G, bs)
    s = jnp.where(valid, s, NEG_INF)
    m_prev = m_scr[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_cur)
    alpha = jnp.exp(m_prev - m_cur)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + p @ v
    m_scr[...] = m_cur

    @pl.when(ki == n_slots - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                       ).astype(o_ref.dtype)


def paged_attention(q, k_pool, v_pool, tables, ctx_len, *,
                    interpret: bool = False):
    """q: (B, H, hd); k_pool/v_pool: (NB, block_size, KV, hd) paged arenas;
    tables: (B, MAXB) int32 per-sequence block runs (0-padded);
    ctx_len: (B,) int32 valid KV length per sequence.  Returns (B, H, hd)."""
    b, h, hd = q.shape
    bs, kv = k_pool.shape[1], k_pool.shape[2]
    g = h // kv
    maxb = tables.shape[1]
    scale = 1.0 / math.sqrt(hd)

    # (B, KV, G, hd) query groups; pool flattened per KV head: (NB, KV, bs, hd)
    qg = q.reshape(b, kv, g, hd)
    kf = k_pool.transpose(0, 2, 1, 3)
    vf = v_pool.transpose(0, 2, 1, 3)

    kernel = functools.partial(_kernel, scale=scale, bs=bs, n_slots=maxb)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # tables, ctx_len
        grid=(b, kv, maxb),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd),
                         lambda bi, ci, ki, tables, ctx: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd),
                         lambda bi, ci, ki, tables, ctx:
                         (tables[bi, ki], ci, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd),
                         lambda bi, ci, ki, tables, ctx:
                         (tables[bi, ki], ci, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda bi, ci, ki, tables, ctx: (bi, ci, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        interpret=interpret,
    )(tables, ctx_len, qg, kf, vf)
    return out.reshape(b, h, hd)
