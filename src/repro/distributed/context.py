"""Activation-sharding context: lets launchers impose a residual-stream
PartitionSpec (e.g. Megatron-style sequence parallelism over the ``model``
axis) without the model code knowing about meshes.

Models call :func:`constrain` on the (B, S, D) residual between blocks; by
default it is the identity.  Launchers wrap tracing in :func:`activation_spec`
inside a mesh context, so ``with_sharding_constraint`` picks up the ambient
mesh.
"""
from __future__ import annotations

import contextlib
from contextvars import ContextVar
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

_ACT_SPEC: ContextVar[Optional[P]] = ContextVar("act_spec", default=None)
# (mesh, dp_axes tuple, model axis name) for shard_map-based layers
_SHARD_CTX: ContextVar[Optional[tuple]] = ContextVar("shard_ctx", default=None)


@contextlib.contextmanager
def activation_spec(spec: Optional[P]):
    token = _ACT_SPEC.set(spec)
    try:
        yield
    finally:
        _ACT_SPEC.reset(token)


@contextlib.contextmanager
def shard_context(mesh, dp_axes: tuple, model_axis: str = "model"):
    token = _SHARD_CTX.set((mesh, tuple(dp_axes), model_axis))
    try:
        yield
    finally:
        _SHARD_CTX.reset(token)


def get_shard_context() -> Optional[tuple]:
    return _SHARD_CTX.get()


def constrain(x):
    spec = _ACT_SPEC.get()
    if spec is None or x.ndim != 3:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def pin_rows(x, axis: int = 0):
    """Under a :func:`shard_context`, constrain ``x``'s row dim to the
    context's data axes — the serving engine's data-parallel row split —
    when the dim divides them (trace-time shapes, so the check is static);
    identity otherwise and outside any context.  An engine that wants rows
    replicated (``dp_probe_slices=False``) enters the context with empty
    ``dp_axes`` and this never fires."""
    ctx = _SHARD_CTX.get()
    if ctx is None:
        return x
    mesh, daxes, _ = ctx
    if not daxes:
        return x
    total = 1
    for a in daxes:
        total *= mesh.shape[a]
    if total <= 1 or x.shape[axis] % total != 0:
        return x
    entries: list = [None] * x.ndim
    entries[axis] = daxes
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, P(*entries)))


def sequence_parallel_spec(batch_axes=("data",), seq_axis: str = "model") -> P:
    """Residual stream (B, S, D): batch over data axes, seq over model."""
    return P(batch_axes, seq_axis, None)
