from .context import activation_spec, constrain, sequence_parallel_spec
from .sharding import (ShardingPlan, batch_specs, cache_specs, data_axes,
                       named, param_specs, zero1_specs)

__all__ = ["ShardingPlan", "batch_specs", "cache_specs", "data_axes",
           "named", "param_specs", "zero1_specs", "activation_spec",
           "constrain", "sequence_parallel_spec"]
