"""Sharding rules: parameter PartitionSpecs + batch specs for the production
mesh (axes ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod; batch always shards over all data-like axes).

Rules are name-based over the param pytree (tree_map_with_path) and
*divisibility-checked*: an axis assignment that does not divide the dim is
dropped rather than letting GSPMD pad (keeps the memory/FLOP accounting in
the roofline honest).  Head projections are sharded on their flattened
(H*head_dim) output dim — always divisible by 16 for the assigned archs even
when the head *count* (36, 25, 28...) is not.

Plan knobs (the hillclimbing levers):
  fsdp     shard weight matrices' non-TP dim over the data axes (XLA inserts
           per-stack all-gathers; memory <-> collective trade)
  zero1    shard optimizer moments over the data axes even when params are
           replicated there (all-gather of updates only at apply time)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class ShardingPlan:
    fsdp: bool = False
    zero1: bool = True
    # decode-time long-context: shard the KV/seq dim of caches over data axes
    seq_shard_cache: bool = True
    # decode cache layout: "feature" shards kv-heads/head_dim over `model`
    # (baseline); "seq" shards the cache sequence dim over `model` instead —
    # flash-decode-style context parallelism that avoids the per-step
    # full-cache all-gather GSPMD emits for the feature layout (§Perf D).
    cache_layout: str = "feature"


def data_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


MODEL = "model"

# leaf-name -> (model_dim, fsdp_dim); dims index into leaf.shape AFTER the
# leading stacked-layer dim(s) are skipped.  None = replicated on that front.
_RULES: dict[str, tuple[Optional[int], Optional[int]]] = {
    # attention / generic projections (d_in, d_out)
    "wq": (1, 0), "wk": (1, 0), "wv": (1, 0), "wo": (0, 1),
    "x_wq": (1, 0), "x_wk": (1, 0), "x_wv": (1, 0), "x_wo": (0, 1),
    # FFN
    "w_gate": (1, 0), "w_up": (1, 0), "w_down": (0, 1),
    # MoE (E, d, f) leaves handled by ndim offset below; router (d, E)
    "router": (None, 0),
    # SSM
    "w_in": (1, 0), "conv_w": (1, None), "conv_b": (0, None),
    "w_dt_in": (0, None), "w_dt_out": (1, 0), "dt_bias": (0, None),
    "w_B": (0, None), "w_C": (0, None), "A_log": (0, None),
    "D_skip": (0, None), "w_out": (0, 1),
    # xLSTM
    "w_q": (1, 0), "w_k": (1, 0), "w_v": (1, 0), "w_og": (1, 0),
    "w_i": (None, 0), "w_f": (None, 0), "gn_scale": (0, None),
    "w_z": (1, 0), "r_z": (None, None), "b_z": (0, None),
    "r_i": (None, None), "b_i": (0, None),
    "r_f": (None, None), "b_f": (0, None),
    "w_o": (1, 0), "r_o": (None, None), "b_o": (0, None),
    # norms
    "norm1": (None, None), "norm2": (None, None), "norm_x": (None, None),
    "fuse_a": (None, None), "fuse_s": (None, None),
}

_TOP_LEVEL = {
    "embed": (0, None),       # vocab-parallel embedding (Megatron style)
    "lm_head": (1, 0),        # (D, V): V over model, D over data when fsdp
    "final_norm": (None, None),
    "enc_norm": (None, None),
}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
    return ""


def _n_leading_stack_dims(path) -> int:
    """Stack params carry a leading layer dim; MoE experts add one more."""
    names = [str(e.key) for e in path if isinstance(e, jax.tree_util.DictKey)]
    lead = 0
    if "stacks" in names or "enc_stacks" in names:
        lead += 1
    if "moe" in names and names[-1] != "router":
        lead += 1  # (E, d, f)
    return lead


def _fit(dim_size: int, axes, mesh: Mesh):
    """Return the axis (or axis tuple) only if it divides dim_size."""
    if axes is None:
        return None
    axs = axes if isinstance(axes, tuple) else (axes,)
    total = int(np.prod([mesh.shape[a] for a in axs]))
    return axes if dim_size % total == 0 else None


def param_specs(params_shape, mesh: Mesh, plan: ShardingPlan = ShardingPlan()):
    """PartitionSpec pytree matching an eval_shape'd params pytree."""
    daxes = data_axes(mesh)

    def spec_for(path, leaf) -> P:
        name = _leaf_name(path)
        shape = leaf.shape
        nd = len(shape)
        if name in _TOP_LEVEL:
            m_dim, f_dim = _TOP_LEVEL[name]
            lead = 0
        elif name in _RULES:
            m_dim, f_dim = _RULES[name]
            lead = _n_leading_stack_dims(path)
        else:
            return P()
        entries: list = [None] * nd
        if m_dim is not None and lead + m_dim < nd:
            i = lead + m_dim
            entries[i] = _fit(shape[i], MODEL, mesh)
            if entries[i] is None and name in ("embed", "lm_head"):
                # odd vocab (122753, 256206, 32001...): fall back to
                # model-sharding the d_model dim instead of replicating
                # half a billion embedding params
                j = lead + (1 - m_dim) if nd >= lead + 2 else None
                if j is not None and entries[j] is None:
                    entries[j] = _fit(shape[j], MODEL, mesh)
        if plan.fsdp and f_dim is not None and lead + f_dim < nd:
            j = lead + f_dim
            if entries[j] is None:
                entries[j] = _fit(shape[j], daxes, mesh)
        return P(*entries)

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def zero1_specs(params_shape, pspecs, mesh: Mesh, plan: ShardingPlan):
    """Optimizer-moment specs: params' specs, plus (if zero1 and not fsdp)
    the first free divisible dim sharded over the data axes."""
    daxes = data_axes(mesh)

    def extend(leaf, spec: P):
        if not plan.zero1 or plan.fsdp:
            return spec
        entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for i, (dim, e) in enumerate(zip(leaf.shape, entries)):
            if e is None and _fit(dim, daxes, mesh) is not None and dim > 1024:
                entries[i] = daxes
                break
        return P(*entries)

    return jax.tree.map(extend, params_shape, pspecs)


def batch_specs(batch_shape, mesh: Mesh):
    """Shard every batch leaf's batch dim over the data axes.  Leaves whose
    leading dim is 3 (M-RoPE position triplets) shard dim 1 instead."""
    daxes = data_axes(mesh)

    def spec_for(leaf) -> P:
        if leaf.ndim >= 2 and leaf.shape[0] == 3:       # (3, B, S) positions
            return P(None, _fit(leaf.shape[1], daxes, mesh))
        if leaf.ndim == 0:
            return P()
        return P(_fit(leaf.shape[0], daxes, mesh))

    return jax.tree.map(spec_for, batch_shape)


def cache_specs(cache_shape, mesh: Mesh, plan: ShardingPlan = ShardingPlan()):
    """Decode caches: layer-stacked leaves (n, B, S, KV, hd) etc.
    Shard batch over data axes when divisible; otherwise (long_500k, B=1)
    shard the seq/state dim over data axes (context parallelism); shard the
    KV-head / feature dim over model when divisible."""
    daxes = data_axes(mesh)

    def spec_for(leaf) -> P:
        shape = leaf.shape
        nd = len(shape)
        if nd <= 1:
            return P()
        entries: list = [None] * nd
        # leading dim is the stacked-layer dim; dim1 = batch
        if nd >= 2:
            b_ax = _fit(shape[1], daxes, mesh)
            entries[1] = b_ax
            if b_ax is None and plan.seq_shard_cache and nd >= 3:
                entries[2] = _fit(shape[2], daxes, mesh)
        if plan.cache_layout == "seq" and nd >= 3 and entries[2] is None:
            # context parallelism: cache seq over `model`; attention psums
            # the softmax stats instead of regathering the cache
            entries[2] = _fit(shape[2], MODEL, mesh)
        if not any(e == MODEL or e == (MODEL,) for e in entries):
            # feature layout: model axis on the last divisible big dim
            for i in range(nd - 1, 1, -1):
                if entries[i] is None and _fit(shape[i], MODEL, mesh) \
                        and shape[i] >= 16:
                    entries[i] = MODEL
                    break
        return P(*entries)

    return jax.tree.map(spec_for, cache_shape)


def arena_specs(arenas, mesh: Mesh, plan: ShardingPlan = ShardingPlan()):
    """Serve-time paged-arena layout (ServeEngine(mesh=...)): PagedKV leaves
    are (n_layers, num_blocks, block_size, KV, hd).  Feature layout only:
    kv-heads over ``model`` when divisible (head_dim as the fallback for odd
    kv counts), and every OTHER dim — crucially the block dim — replicated,
    so the pool's free-list allocator, refcounts, and stash/unstash stay
    host-side and mesh-oblivious: a block id means the same arena slice on
    every device.  The ``seq`` cache_layout is a per-step-gather trade that
    only pays off for long dense caches; arenas always use feature layout."""

    def spec_for(leaf) -> P:
        shape = leaf.shape
        nd = len(shape)
        if nd != 5:
            return P()
        entries: list = [None] * nd
        entries[3] = _fit(shape[3], MODEL, mesh)
        if entries[3] is None:
            entries[4] = _fit(shape[4], MODEL, mesh)
        return P(*entries)

    return jax.tree.map(spec_for, arenas)


def rows_spec(n_rows: int, ndim: int, mesh: Mesh, axis: int = 0) -> P:
    """Probe/decode submission batches on a serving mesh: shard the row dim
    (``axis``; 0 for token batches, 1 for stacked caches) over the data axes
    — THE data-parallel row split.  Each data shard executes a contiguous
    row slice of the padded submission; rows that do not divide (tiny
    submissions below the shard count) stay replicated rather than letting
    GSPMD pad unevenly."""
    entries: list = [None] * ndim
    entries[axis] = _fit(n_rows, data_axes(mesh), mesh) if n_rows > 0 else None
    return P(*entries)


def named(mesh: Mesh, specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
