"""Core datatypes for the LLM ORDER BY operator.

A *key* is the unit being ordered (a row, passage, review, ...).  Access paths
only ever look at ``uid`` and ``text``; ``latent`` is the hidden ground-truth
ordering value used by the simulated oracle and by evaluation metrics — real
deployments simply leave it as ``nan``.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Sequence


@dataclass(frozen=True)
class Key:
    """One sortable item."""

    uid: int
    text: str
    latent: float = math.nan  # hidden ground truth (simulation / eval only)

    def tokens(self) -> int:
        """Crude token estimate (~4 chars/token), matching API billing."""
        return max(1, len(self.text) // 4)


@dataclass(frozen=True)
class SortSpec:
    """The logical ORDER BY clause: criteria text, direction, optional LIMIT."""

    criteria: str
    descending: bool = False
    limit: Optional[int] = None

    def effective_limit(self, n: int) -> int:
        return n if self.limit is None else min(self.limit, n)


class InvalidOutputError(RuntimeError):
    """Raised when the (simulated or real) LLM output fails structural checks.

    Mirrors the paper's JSON-decode / wrong-item-count failure mode observed
    for large listwise batches (Sec. 4.2).
    """


@dataclass
class SortResult:
    """Output of one access-path execution."""

    order: list[Key]                       # output order; [:limit] already applied
    path: str                              # access path name
    params: dict = field(default_factory=dict)
    n_calls: int = 0
    input_tokens: int = 0
    output_tokens: int = 0
    cost: float = 0.0

    def uids(self) -> list[int]:
        return [k.uid for k in self.order]


def as_keys(texts: Sequence[str], latents: Optional[Sequence[float]] = None) -> list[Key]:
    """Convenience constructor used by examples and tests."""
    if latents is None:
        latents = [math.nan] * len(texts)
    return [Key(uid=i, text=t, latent=float(z)) for i, (t, z) in enumerate(zip(texts, latents))]


def replace(key: Key, **kw) -> Key:
    return dataclasses.replace(key, **kw)
