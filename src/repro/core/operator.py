"""The LLM ORDER BY logical operator — public entry point.

``llm_order_by(keys, criteria, oracle, ...)`` mirrors the paper's SQL surface:

    SELECT id, text FROM reviews
    LLM_ORDER_BY(text, 'degree of positivity') DESC LIMIT 10;

``path="auto"`` routes through the budget-aware optimizer; any registry name
("pointwise", "ext_merge", ...) forces a static access path.
"""
from __future__ import annotations

from typing import Optional, Sequence

from .access_paths.base import PathParams, make_path
from .optimizer.cost_model import CandidateSpec
from .optimizer.optimizer import AccessPathOptimizer, OptimizerConfig, OptimizerReport
from .types import Key, SortResult, SortSpec
from .oracles.base import Oracle


def llm_order_by(keys: Sequence[Key], criteria: str, oracle: Oracle, *,
                 descending: bool = False, limit: Optional[int] = None,
                 path: str = "auto", params: Optional[PathParams] = None,
                 budget: Optional[float] = None, strategy: str = "borda",
                 sample_size: int = 20,
                 judge_oracle: Optional[Oracle] = None,
                 candidates: Optional[list[CandidateSpec]] = None,
                 ) -> tuple[SortResult, Optional[OptimizerReport]]:
    """Execute LLM ORDER BY; returns (result, optimizer_report_or_None)."""
    spec = SortSpec(criteria=criteria, descending=descending, limit=limit)
    if path != "auto":
        ap = make_path(path, params or PathParams())
        return ap.execute(keys, oracle, spec), None
    opt = AccessPathOptimizer(
        OptimizerConfig(sample_size=sample_size, budget=budget, strategy=strategy),
        candidates=candidates,
    )
    result, report = opt.choose_and_execute(keys, oracle, spec, judge_oracle=judge_oracle)
    return result, report


class Table:
    """Minimal rows-of-dicts relation so examples read like the paper's SQL."""

    def __init__(self, rows: Sequence[dict]):
        self.rows = list(rows)

    def llm_order_by(self, column: str, criteria: str, oracle: Oracle,
                     latent_column: Optional[str] = None, **kw
                     ) -> tuple[list[dict], SortResult, Optional[OptimizerReport]]:
        keys = [
            Key(uid=i, text=str(r[column]),
                latent=float(r[latent_column]) if latent_column else float("nan"))
            for i, r in enumerate(self.rows)
        ]
        result, report = llm_order_by(keys, criteria, oracle, **kw)
        ordered_rows = [self.rows[k.uid] for k in result.order]
        return ordered_rows, result, report
