"""The LLM ORDER BY logical operator — public entry point.

``llm_order_by(keys, criteria, oracle, ...)`` mirrors the paper's SQL surface:

    SELECT id, text FROM reviews
    LLM_ORDER_BY(text, 'degree of positivity') DESC LIMIT 10;

``path="auto"`` routes through the budget-aware optimizer; any registry name
("pointwise", "ext_merge", ...) forces a static access path.

``llm_order_by_many(queries)`` executes several ORDER BY queries
*concurrently* over one serving stack: each query's access path runs as a
resumable probe plan, and every scheduling tick merges the ready probes of
all queries into shared serving submissions (with cross-query dedup of
identical prompts).  Per-query results and ledgers are byte-identical to
running each query solo.  ``path="auto"`` queries ride the same tick
stream: their optimizer pipeline runs as an incremental driver on the
shared executor, under per-query admission control.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

from .access_paths.base import PathParams, make_path
from .executor import (ProbePlanExecutor, attach_memo, attach_scheduler,
                       auto_scheduler, detach_memo, detach_scheduler,
                       plan_sort_result)
from .optimizer.cost_model import CandidateSpec
from .optimizer.optimizer import (AccessPathOptimizer, OptimizerConfig,
                                  OptimizerDriver, OptimizerReport)
from .types import Key, SortResult, SortSpec
from .oracles.base import Oracle


def llm_order_by(keys: Sequence[Key], criteria: str, oracle: Oracle, *,
                 descending: bool = False, limit: Optional[int] = None,
                 path: str = "auto", params: Optional[PathParams] = None,
                 budget: Optional[float] = None, strategy: str = "borda",
                 sample_size: int = 20,
                 judge_oracle: Optional[Oracle] = None,
                 candidates: Optional[list[CandidateSpec]] = None,
                 ladder_thresholds: Optional[Sequence[float]] = None,
                 ) -> tuple[SortResult, Optional[OptimizerReport]]:
    """Execute LLM ORDER BY; returns (result, optimizer_report_or_None).

    ``ladder_thresholds``: cascade escalation thresholds for a
    :class:`~repro.core.oracles.cascade.CascadeOracle`-style backend —
    ``path="auto"`` then also explores draft-first cascade variants of
    every candidate path (ignored for oracles without ``at_threshold``)."""
    spec = SortSpec(criteria=criteria, descending=descending, limit=limit)
    if path != "auto":
        ap = make_path(path, params or PathParams())
        return ap.execute(keys, oracle, spec), None
    opt = AccessPathOptimizer(
        OptimizerConfig(sample_size=sample_size, budget=budget, strategy=strategy,
                        ladder_thresholds=ladder_thresholds),
        candidates=candidates,
    )
    result, report = opt.choose_and_execute(keys, oracle, spec, judge_oracle=judge_oracle)
    return result, report


@dataclass
class OrderQuery:
    """One concurrent LLM ORDER BY query for :func:`llm_order_by_many`.

    Each query carries its OWN oracle so per-query billing stays exact;
    oracles may (and for serving-level coalescing should) share one
    engine — e.g. one ``ModelOracle(engine)`` per query.

    ``path="auto"`` runs the full optimizer pipeline for this query on the
    SHARED executor (see :class:`~repro.core.optimizer.optimizer.OptimizerDriver`);
    ``budget``/``strategy``/``sample_size``/``judge_oracle``/``candidates``
    mirror :func:`llm_order_by`'s optimizer knobs and are ignored for
    static paths.  After :func:`llm_order_by_many` returns, an auto
    query's ``report`` field holds its :class:`OptimizerReport`.

    ``tenant`` names the priority class every serving-level submission of
    this query is billed to (see
    :class:`~repro.serving.scheduler.TenantSpec`)."""

    keys: Sequence[Key]
    criteria: str
    oracle: Oracle
    descending: bool = False
    limit: Optional[int] = None
    path: str = "quick"
    params: Optional[PathParams] = None
    budget: Optional[float] = None
    strategy: str = "borda"
    sample_size: int = 20
    judge_oracle: Optional[Oracle] = None
    candidates: Optional[list[CandidateSpec]] = None
    ladder_thresholds: Optional[Sequence[float]] = None
    tenant: str = "default"
    report: Optional[OptimizerReport] = None


def llm_order_by_many(queries: Sequence[OrderQuery], *,
                      scheduler=None, semantic_memo=None,
                      prefetch: Optional[bool] = None) -> list[SortResult]:
    """Execute several LLM ORDER BY queries concurrently over one engine.

    All queries' access-path plans advance together through a
    :class:`~repro.core.executor.ProbePlanExecutor`: each scheduling tick
    gathers the ready probe sets of every suspended plan and — on a
    ModelOracle backend sharing one engine — merges them into shared
    length-bucketed serving submissions, deduplicating identical prompts
    across queries.  Results are aligned with ``queries``; each
    ``SortResult``'s order AND accounting are ``==``-identical to running
    that query alone (the executor tracks per-plan ledger records).

    ``semantic_memo``: a shared
    :class:`~repro.core.oracles.cache.SemanticMemo` (or ``True`` for a
    fresh one) consulted by every deferred-capable oracle before emitting
    per-item probes — comparisons, pointwise scores, inquiries already
    answered for ANOTHER query (or an earlier call reusing the memo) are
    served from the memo instead of the backend.  Billing becomes
    first-requester-pays: a hit query's ``SortResult`` accounting shows
    only what it was billed, and ``oracle.reconciled_records()`` rebuilds
    its solo ledger byte-identically.  Orderings are unchanged either way
    (memo values are the raw probe results the query's own probes would
    have produced).  Default ``None``: no memo, per-query ledgers stay
    solo-identical.

    ``prefetch``: forwards to
    :class:`~repro.core.executor.ProbePlanExecutor` — ``None`` (default)
    enables prefix-region prefetch pipelining whenever a scheduler is in
    play; ``False`` pins the reactive fill-on-demand behavior (the
    benchmarks' baseline).

    ``path="auto"`` queries run their WHOLE optimizer pipeline — the
    membership gate, every pilot, selection, and the winner's full
    execution — as plans on this same shared executor via one
    :class:`~repro.core.optimizer.optimizer.OptimizerDriver` per query, so
    optimizer probe rounds co-schedule with every other query's.  Each
    driver's budget arithmetic reads only its own oracle's ledger, so
    per-query admission control (and the final report) matches a solo
    :func:`llm_order_by` run byte-for-byte."""
    from .oracles.cache import SemanticMemo
    oracles = [q.oracle for q in queries]
    judges = [q.judge_oracle for q in queries if q.judge_oracle is not None]
    if scheduler is None:
        scheduler = auto_scheduler(oracles + judges)
    if semantic_memo is True:
        semantic_memo = SemanticMemo()
    # every query's oracle becomes a client of the SAME live loop FOR THIS
    # CALL: deferred probe rounds ride its step gaps, and any generation
    # the oracle runs (judge rationales) decodes through it — so probes
    # and rationale tokens co-schedule instead of alternating whole
    # drains.  The attachment is scoped (restored on exit) so a later call
    # with a fresh scheduler re-attaches instead of pumping a stale loop;
    # the memo attachment is scoped the same way (the memo itself is the
    # caller's and outlives the call — cross-CALL reuse is the point).
    # Tenant tags are scoped identically: each query's oracle bills its
    # serving-level rounds to the query's priority class for this call.
    attached = attach_scheduler(oracles + judges, scheduler)
    attached_memo = attach_memo(oracles, semantic_memo)
    _MISSING = object()
    tenant_saved = []
    for q in queries:
        for o in (q.oracle, q.judge_oracle):
            if o is not None and q.tenant != "default":
                tenant_saved.append((o, getattr(o, "tenant", _MISSING)))
                o.tenant = q.tenant
    try:
        ex = ProbePlanExecutor(scheduler=scheduler, prefetch=prefetch)
        runs = []
        for i, q in enumerate(queries):
            spec = SortSpec(q.criteria, q.descending, q.limit)
            if q.path == "auto":
                opt = AccessPathOptimizer(
                    OptimizerConfig(sample_size=q.sample_size,
                                    budget=q.budget, strategy=q.strategy,
                                    ladder_thresholds=q.ladder_thresholds),
                    candidates=q.candidates)
                runs.append((q, spec, OptimizerDriver(
                    opt, list(q.keys), q.oracle, spec,
                    judge_oracle=q.judge_oracle, executor=ex,
                    tenant=q.tenant, name=f"q{i}:auto")))
            else:
                ap = make_path(q.path, q.params or PathParams())
                runs.append((q, spec, ex.submit_path(
                    ap, q.keys, q.oracle, spec, name=f"q{i}:{q.path}",
                    tenant=q.tenant)))
        drivers = [r for _q, _s, r in runs if isinstance(r, OptimizerDriver)]
        if drivers:
            def on_tick(_ex) -> None:
                for d in drivers:
                    d.on_tick(_ex)
            ex.run(on_tick=on_tick)
        else:
            ex.run()
        out = []
        for q, spec, r in runs:
            if isinstance(r, OptimizerDriver):
                q.report = r.report
                out.append(r.result)
            else:
                out.append(plan_sort_result(r, spec, len(q.keys),
                                            q.oracle.prices))
        return out
    finally:
        for o, prev in reversed(tenant_saved):
            if prev is _MISSING:
                del o.tenant
            else:
                o.tenant = prev
        detach_scheduler(attached)
        detach_memo(attached_memo)


class Table:
    """Minimal rows-of-dicts relation so examples read like the paper's SQL."""

    def __init__(self, rows: Sequence[dict]):
        self.rows = list(rows)

    def llm_order_by(self, column: str, criteria: str, oracle: Oracle,
                     latent_column: Optional[str] = None, **kw
                     ) -> tuple[list[dict], SortResult, Optional[OptimizerReport]]:
        keys = [
            Key(uid=i, text=str(r[column]),
                latent=float(r[latent_column]) if latent_column else float("nan"))
            for i, r in enumerate(self.rows)
        ]
        result, report = llm_order_by(keys, criteria, oracle, **kw)
        ordered_rows = [self.rows[k.uid] for k in result.order]
        return ordered_rows, result, report
