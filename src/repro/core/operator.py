"""The LLM ORDER BY logical operator — public entry point.

``llm_order_by(keys, criteria, oracle, ...)`` mirrors the paper's SQL surface:

    SELECT id, text FROM reviews
    LLM_ORDER_BY(text, 'degree of positivity') DESC LIMIT 10;

``path="auto"`` routes through the budget-aware optimizer; any registry name
("pointwise", "ext_merge", ...) forces a static access path.

``llm_order_by_many(queries)`` executes several ORDER BY queries
*concurrently* over one serving stack: each query's access path runs as a
resumable probe plan, and every scheduling tick merges the ready probes of
all queries into shared serving submissions (with cross-query dedup of
identical prompts).  Per-query results and ledgers are byte-identical to
running each query solo.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

from .access_paths.base import PathParams, make_path
from .executor import (ProbePlanExecutor, attach_memo, attach_scheduler,
                       auto_scheduler, detach_memo, detach_scheduler,
                       plan_sort_result)
from .optimizer.cost_model import CandidateSpec
from .optimizer.optimizer import AccessPathOptimizer, OptimizerConfig, OptimizerReport
from .types import Key, SortResult, SortSpec
from .oracles.base import Oracle


def llm_order_by(keys: Sequence[Key], criteria: str, oracle: Oracle, *,
                 descending: bool = False, limit: Optional[int] = None,
                 path: str = "auto", params: Optional[PathParams] = None,
                 budget: Optional[float] = None, strategy: str = "borda",
                 sample_size: int = 20,
                 judge_oracle: Optional[Oracle] = None,
                 candidates: Optional[list[CandidateSpec]] = None,
                 ) -> tuple[SortResult, Optional[OptimizerReport]]:
    """Execute LLM ORDER BY; returns (result, optimizer_report_or_None)."""
    spec = SortSpec(criteria=criteria, descending=descending, limit=limit)
    if path != "auto":
        ap = make_path(path, params or PathParams())
        return ap.execute(keys, oracle, spec), None
    opt = AccessPathOptimizer(
        OptimizerConfig(sample_size=sample_size, budget=budget, strategy=strategy),
        candidates=candidates,
    )
    result, report = opt.choose_and_execute(keys, oracle, spec, judge_oracle=judge_oracle)
    return result, report


@dataclass
class OrderQuery:
    """One concurrent LLM ORDER BY query for :func:`llm_order_by_many`.

    Each query carries its OWN oracle so per-query billing stays exact;
    oracles may (and for serving-level coalescing should) share one
    engine — e.g. one ``ModelOracle(engine)`` per query."""

    keys: Sequence[Key]
    criteria: str
    oracle: Oracle
    descending: bool = False
    limit: Optional[int] = None
    path: str = "quick"
    params: Optional[PathParams] = None


def llm_order_by_many(queries: Sequence[OrderQuery], *,
                      scheduler=None, semantic_memo=None,
                      prefetch: Optional[bool] = None) -> list[SortResult]:
    """Execute several LLM ORDER BY queries concurrently over one engine.

    All queries' access-path plans advance together through a
    :class:`~repro.core.executor.ProbePlanExecutor`: each scheduling tick
    gathers the ready probe sets of every suspended plan and — on a
    ModelOracle backend sharing one engine — merges them into shared
    length-bucketed serving submissions, deduplicating identical prompts
    across queries.  Results are aligned with ``queries``; each
    ``SortResult``'s order AND accounting are ``==``-identical to running
    that query alone (the executor tracks per-plan ledger records).

    ``semantic_memo``: a shared
    :class:`~repro.core.oracles.cache.SemanticMemo` (or ``True`` for a
    fresh one) consulted by every deferred-capable oracle before emitting
    per-item probes — comparisons, pointwise scores, inquiries already
    answered for ANOTHER query (or an earlier call reusing the memo) are
    served from the memo instead of the backend.  Billing becomes
    first-requester-pays: a hit query's ``SortResult`` accounting shows
    only what it was billed, and ``oracle.reconciled_records()`` rebuilds
    its solo ledger byte-identically.  Orderings are unchanged either way
    (memo values are the raw probe results the query's own probes would
    have produced).  Default ``None``: no memo, per-query ledgers stay
    solo-identical.

    ``prefetch``: forwards to
    :class:`~repro.core.executor.ProbePlanExecutor` — ``None`` (default)
    enables prefix-region prefetch pipelining whenever a scheduler is in
    play; ``False`` pins the reactive fill-on-demand behavior (the
    benchmarks' baseline).

    Static paths only — ``path="auto"`` (the optimizer) manages its own
    concurrent pilot executor and cannot be nested here."""
    from .oracles.cache import SemanticMemo
    for q in queries:
        if q.path == "auto":
            raise ValueError(
                "llm_order_by_many supports static access paths only; run "
                "path='auto' queries through llm_order_by")
    if scheduler is None:
        scheduler = auto_scheduler([q.oracle for q in queries])
    if semantic_memo is True:
        semantic_memo = SemanticMemo()
    # every query's oracle becomes a client of the SAME live loop FOR THIS
    # CALL: deferred probe rounds ride its step gaps, and any generation
    # the oracle runs (judge rationales) decodes through it — so probes
    # and rationale tokens co-schedule instead of alternating whole
    # drains.  The attachment is scoped (restored on exit) so a later call
    # with a fresh scheduler re-attaches instead of pumping a stale loop;
    # the memo attachment is scoped the same way (the memo itself is the
    # caller's and outlives the call — cross-CALL reuse is the point).
    attached = attach_scheduler([q.oracle for q in queries], scheduler)
    attached_memo = attach_memo([q.oracle for q in queries], semantic_memo)
    try:
        ex = ProbePlanExecutor(scheduler=scheduler, prefetch=prefetch)
        runs = []
        for i, q in enumerate(queries):
            spec = SortSpec(q.criteria, q.descending, q.limit)
            ap = make_path(q.path, q.params or PathParams())
            runs.append((q, spec, ex.submit_path(ap, q.keys, q.oracle, spec,
                                                 name=f"q{i}:{q.path}")))
        ex.run()
        return [plan_sort_result(run, spec, len(q.keys), q.oracle.prices)
                for q, spec, run in runs]
    finally:
        detach_scheduler(attached)
        detach_memo(attached_memo)


class Table:
    """Minimal rows-of-dicts relation so examples read like the paper's SQL."""

    def __init__(self, rows: Sequence[dict]):
        self.rows = list(rows)

    def llm_order_by(self, column: str, criteria: str, oracle: Oracle,
                     latent_column: Optional[str] = None, **kw
                     ) -> tuple[list[dict], SortResult, Optional[OptimizerReport]]:
        keys = [
            Key(uid=i, text=str(r[column]),
                latent=float(r[latent_column]) if latent_column else float("nan"))
            for i, r in enumerate(self.rows)
        ]
        result, report = llm_order_by(keys, criteria, oracle, **kw)
        ordered_rows = [self.rows[k.uid] for k in result.order]
        return ordered_rows, result, report
