"""Synthetic benchmark families mirroring the paper's datasets.

Each generator returns a :class:`RankingTask` = (keys with hidden latents,
criteria text, oracle profile, metric kind).  The latent is what the paper's
benchmarks hide (masked player height, masked population, qrel relevance):

 * ``nba_heights`` / ``world_population`` — factual keys, fully memorized
   (membership 100%) => pointwise excels (paper Sec. 4.2 / 6.2),
 * ``passages`` — DL19/DL20-like: long texts, low membership, comparisons
   reliable but scores uncalibrated => comparison-based excels,
 * ``tweets`` — TweetEval-like short sentiment texts, mixed membership,
 * ``movie_reviews`` — SembenchMovie-like medium reviews.

Text lengths matter: they drive token billing and judge context degradation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from .oracles.simulated import (FACTUAL, REASONING, SENTIMENT, OracleProfile)
from .types import Key


@dataclass
class RankingTask:
    name: str
    keys: list[Key]
    criteria: str
    profile: OracleProfile
    descending: bool = True
    limit: Optional[int] = None
    metric: str = "kendall"     # "kendall" | "ndcg"
    queries: int = 1            # number of sub-queries this family represents


def _mk_keys(rng: np.random.Generator, n: int, latents: np.ndarray,
             words_lo: int, words_hi: int, stem: str) -> list[Key]:
    keys = []
    for i in range(n):
        n_words = int(rng.integers(words_lo, words_hi + 1))
        words = rng.integers(0, 50_000, size=n_words)
        text = f"{stem}-{i} " + " ".join(f"w{w}" for w in words)
        keys.append(Key(uid=i, text=text, latent=float(latents[i])))
    return keys


def nba_heights(n: int = 200, seed: int = 0) -> RankingTask:
    rng = np.random.default_rng(seed)
    z = rng.standard_normal(n)  # standardized heights
    keys = _mk_keys(rng, n, z, 2, 4, "player")
    return RankingTask("nba", keys, "player height", FACTUAL,
                       descending=True, limit=None, metric="kendall")


def world_population(n: int = 200, seed: int = 1) -> RankingTask:
    rng = np.random.default_rng(seed)
    z = np.sort(rng.standard_normal(n) * 1.4)[::-1].copy()
    rng.shuffle(z)
    keys = _mk_keys(rng, n, z, 1, 3, "region")
    return RankingTask("population", keys, "population of the region", FACTUAL,
                       descending=True, limit=None, metric="kendall")


def passages(n: int = 100, seed: int = 2, query: str = "define bmt medical") -> RankingTask:
    rng = np.random.default_rng(seed)
    # BM25-retrieved top-100: a few highly relevant, long tail of marginal
    z = rng.gamma(shape=1.3, scale=0.8, size=n)
    keys = _mk_keys(rng, n, z, 120, 400, "passage")
    return RankingTask(f"dl-{query}", keys, f"relevance to query: {query}",
                       REASONING, descending=True, limit=10, metric="ndcg")


def tweets(n: int = 120, seed: int = 3, sentiment: str = "positivity") -> RankingTask:
    rng = np.random.default_rng(seed)
    z = rng.standard_normal(n)
    keys = _mk_keys(rng, n, z, 8, 40, "tweet")
    return RankingTask(f"tweets-{sentiment}", keys, f"intensity of {sentiment}",
                       SENTIMENT, descending=True, limit=10, metric="ndcg")


def movie_reviews(n: int = 150, seed: int = 4) -> RankingTask:
    rng = np.random.default_rng(seed)
    z = rng.standard_normal(n)
    profile = OracleProfile(
        name="movie", memorization=0.25, score_noise=0.6, score_squash=0.4,
        compare_temp=0.2, listwise_noise=0.25, membership_rate=0.25,
    )
    keys = _mk_keys(rng, n, z, 60, 180, "review")
    return RankingTask("movie-q9", keys, "degree of positivity", profile,
                       descending=True, limit=10, metric="ndcg")


def benchmark_suite(seed: int = 0) -> list[RankingTask]:
    """The Fig. 3 benchmark families (one task per family; the multi-query
    DL/Tweet families are expanded by benchmarks that need per-query spread)."""
    return [
        world_population(seed=seed + 1),
        tweets(seed=seed + 3),
        movie_reviews(seed=seed + 4),
        passages(seed=seed + 2),
    ]


def dl_queries(n_queries: int = 8, n: int = 100, seed: int = 10) -> list[RankingTask]:
    """A DL20-like multi-query family.

    Queries are heterogeneous (paper Fig. 2: the per-query optimal algorithm
    varies wildly within one benchmark): each query draws its own oracle
    calibration — some are score-friendly (well-calibrated pointwise), some
    comparison-friendly, some listwise-hostile.
    """
    rng = np.random.default_rng(seed)
    out = []
    for q in range(n_queries):
        t = passages(n=n, seed=seed + q, query=f"query-{q}")
        prof = OracleProfile(
            name=f"dl-q{q}",
            memorization=float(rng.uniform(0.0, 0.3)),
            score_noise=float(rng.uniform(0.3, 1.2)),
            score_squash=float(rng.uniform(0.2, 0.8)),
            compare_temp=float(rng.uniform(0.1, 0.6)),
            listwise_noise=float(rng.uniform(0.1, 0.6)),
            membership_rate=float(rng.uniform(0.0, 0.25)),
            judge_noise_per_ktok=0.09,
            seed=seed + q,
        )
        out.append(RankingTask(t.name, t.keys, t.criteria, prof,
                               descending=True, limit=t.limit, metric="ndcg"))
    return out
