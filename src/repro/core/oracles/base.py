"""Oracle interface + token/dollar accounting.

Every access path is written against :class:`Oracle`; the paper's hosted-API
assumption becomes an interface with three backends:

 * :class:`~repro.core.oracles.simulated.SimulatedOracle` — calibrated noise,
   used by benchmarks to reproduce the paper's empirical regime,
 * :class:`~repro.core.oracles.simulated.ExactOracle` — noise-free, used by
   property tests (a perfect comparator must yield a perfectly sorted list),
 * :class:`~repro.core.oracles.model_oracle.ModelOracle` — real JAX forward
   passes through the serving engine on the production mesh.

All billing flows through :class:`TokenLedger`, so Table-1 / Fig-1 style
call-count and dollar accounting is exact and identical across backends.
"""
from __future__ import annotations

import abc
from dataclasses import dataclass
from typing import NamedTuple, Optional, Sequence

from ..types import InvalidOutputError, Key


class PromptParts(NamedTuple):
    """Structured probe prompt: ``prefix`` is the block shared by every call
    of a round (instructions + criteria, plus the pivot in comparison
    rounds); ``suffix`` carries the per-key payload.  The logical prompt is
    the concatenation — backends that don't exploit structure just join the
    parts — but the pair form lets the serving layer prefill the shared
    prefix once per round and reuse its KV (ServeEngine's prefix-KV cache).
    Billing is a function of the logical prompt only, so structuring never
    changes the ledger."""

    prefix: str
    suffix: str

    @property
    def text(self) -> str:
        return self.prefix + self.suffix


@dataclass(frozen=True)
class PriceSheet:
    """$ per million tokens, mirroring per-token API billing."""

    input_per_mtok: float = 0.90
    output_per_mtok: float = 0.90
    name: str = "llama3.1-70b"

    def cost(self, input_tokens: int, output_tokens: int) -> float:
        return (input_tokens * self.input_per_mtok + output_tokens * self.output_per_mtok) / 1e6


LLAMA70B = PriceSheet(0.90, 0.90, "llama3.1-70b")
LLAMA405B = PriceSheet(8.00, 8.00, "llama3.1-405b")
GPT41 = PriceSheet(2.00, 8.00, "gpt-4.1")
STABLELM2 = PriceSheet(0.07, 0.07, "stablelm2-1.6b")


@dataclass(frozen=True)
class TieredPrices:
    """Per-tier price book for model-cascade execution: records tagged with a
    ``CallRecord.tier`` are priced by that tier's sheet, untiered records
    (``tier == ""``) by ``default``.  A LedgerView prices tier-aware books
    record-by-record, so one shared ledger yields exact per-tier dollars."""

    sheets: tuple[tuple[str, PriceSheet], ...] = ()
    default: PriceSheet = LLAMA70B

    @property
    def name(self) -> str:
        return self.default.name

    def sheet(self, tier: str) -> PriceSheet:
        for t, s in self.sheets:
            if t == tier:
                return s
        return self.default

    def record_cost(self, r: "CallRecord") -> float:
        return self.sheet(r.tier).cost(r.input_tokens, r.output_tokens)

    def cost(self, input_tokens: int, output_tokens: int) -> float:
        """Aggregate fallback (prices untiered token totals at ``default``)."""
        return self.default.cost(input_tokens, output_tokens)


CASCADE_70B = TieredPrices((("draft", STABLELM2), ("large", LLAMA70B)), LLAMA70B)


@dataclass(frozen=True)
class CallRecord:
    kind: str            # "score" | "compare" | "rank" | "inquire" | "judge"
    n_keys: int
    input_tokens: int
    output_tokens: int
    tag: str = ""
    tier: str = ""       # "" (single-model) | "draft" | "large" (cascade)


@dataclass
class LedgerView:
    records: list[CallRecord]

    @property
    def n_calls(self) -> int:
        return len(self.records)

    @property
    def input_tokens(self) -> int:
        return sum(r.input_tokens for r in self.records)

    @property
    def output_tokens(self) -> int:
        return sum(r.output_tokens for r in self.records)

    def cost(self, prices: PriceSheet) -> float:
        record_cost = getattr(prices, "record_cost", None)
        if record_cost is not None:  # tier-aware book: price record-by-record
            return sum(record_cost(r) for r in self.records)
        return prices.cost(self.input_tokens, self.output_tokens)

    def by_kind(self, kind: str) -> "LedgerView":
        return LedgerView([r for r in self.records if r.kind == kind])

    def by_tier(self, tier: str) -> "LedgerView":
        return LedgerView([r for r in self.records if r.tier == tier])


class TokenLedger(LedgerView):
    """Append-only call log with snapshot slicing for per-phase accounting."""

    def __init__(self) -> None:
        super().__init__(records=[])

    def charge(self, kind: str, input_tokens: int, output_tokens: int,
               n_keys: int = 1, tag: str = "", tier: str = "") -> None:
        self.records.append(CallRecord(kind, n_keys, int(input_tokens),
                                       int(output_tokens), tag, tier))

    def snapshot(self) -> int:
        return len(self.records)

    def since(self, snap: int) -> LedgerView:
        return LedgerView(self.records[snap:])

    def reset(self) -> None:
        self.records.clear()


@dataclass
class PromptCosts:
    """Token overheads of the prompt templates (Prompt Blocks 1-5).

    ``*_out`` entries model structured CoT outputs (the paper enables
    chain-of-thought fields in the response JSON schema).
    """

    score_prefix: int = 60       # Prompt Block 1 instructions + criteria
    score_out_per_key: int = 24  # rating + short CoT per key
    compare_prefix: int = 55     # Prompt Block 2
    compare_out: int = 30        # verdict + CoT
    rank_prefix: int = 60        # Prompt Block 3
    rank_out_per_key: int = 10   # permutation entry + brief CoT share
    inquire_prefix: int = 45     # Prompt Block 4
    inquire_out: int = 25
    judge_prefix: int = 90       # Prompt Block 5
    judge_out: int = 120


class Oracle(abc.ABC):
    """Semantic black box exposed through standard generation-API verbs."""

    def __init__(self, prices: PriceSheet = LLAMA70B, costs: Optional[PromptCosts] = None):
        self.ledger = TokenLedger()
        self.prices = prices
        self.costs = costs or PromptCosts()
        # Tier stamped on every record this oracle bills ("" = single-model).
        # Cascade oracles flip this per wave; see core/oracles/cascade.py.
        self.bill_tier = ""

    # ---- verbs -----------------------------------------------------------
    @abc.abstractmethod
    def score_batch(self, keys: Sequence[Key], criteria: str) -> list[float]:
        """Value-based: one float per key (higher = larger under criteria).

        ``len(keys) == 1`` is the pointwise path; larger batches are the
        external-pointwise path.  May raise InvalidOutputError.
        """

    @abc.abstractmethod
    def compare(self, a: Key, b: Key, criteria: str) -> int:
        """Comparison-based: +1 if ``a`` ranks above ``b`` under criteria
        (i.e. a's criteria value is larger), else -1."""

    @abc.abstractmethod
    def rank_batch(self, keys: Sequence[Key], criteria: str) -> list[Key]:
        """Listwise: permutation of ``keys`` in ascending criteria order
        (worst-to-best, following Prompt Block 3).  May raise
        InvalidOutputError."""

    @abc.abstractmethod
    def inquire(self, key: Key, criteria: str) -> bool:
        """Membership-inference Inquiry Prompt (Prompt Block 4)."""

    @abc.abstractmethod
    def judge(self, keys: Sequence[Key], criteria: str,
              candidates: Sequence[Sequence[Key]]) -> int:
        """LLM-as-Judge (Prompt Block 5): index of the best candidate ranking."""

    # ---- round (batch) verbs --------------------------------------------
    # Access paths are written against *rounds of independent calls*: at each
    # step they hand the oracle every call whose inputs are already known and
    # that no other call in the set depends on.  The defaults below execute
    # a round as a sequential loop over the point verbs, so results and
    # ledger records are identical call-for-call; ModelOracle overrides them
    # to execute one round as ONE padded serving submission (shared-prefix
    # prefill amortization — the batching economics of Sec. 4) while still
    # billing N logical calls, matching the ``rank_batches`` convention.

    def rank_batches(self, batches: Sequence[Sequence[Key]],
                     criteria: str) -> list[list[Key]]:
        """Batched listwise ranking — the paper's parallel run generation
        (Alg. 4 Phase 1).  Default: sequential loop; the ModelOracle
        overrides this with ONE padded serving batch for all windows."""
        return [self.rank_batch(list(b), criteria) for b in batches]

    def compare_batch(self, pairs: Sequence[tuple[Key, Key]],
                      criteria: str) -> list[int]:
        """One round of independent pairwise comparisons: ``+1``/``-1`` per
        pair, aligned with ``pairs`` (same semantics as :meth:`compare`)."""
        return [self.compare(a, b, criteria) for a, b in pairs]

    def inquire_batch(self, keys: Sequence[Key], criteria: str) -> list[bool]:
        """One round of independent membership inquiries (Prompt Block 4)."""
        return [self.inquire(k, criteria) for k in keys]

    def score_each(self, keys: Sequence[Key], criteria: str) -> list[float]:
        """One round of independent POINTWISE scores: each key is a logical
        single-key ``score_batch`` call (pointwise noise regime, pointwise
        billing) — unlike ``score_batch(keys)``, which is one m-key call."""
        return [self.score_batch([k], criteria)[0] for k in keys]

    def score_batches(self, batches: Sequence[Sequence[Key]],
                      criteria: str) -> list[list[float]]:
        """One round of independent m-key scoring calls (external pointwise):
        each batch is billed/noised as its own ``score_batch`` call."""
        return [self.score_batch(list(b), criteria) for b in batches]

    # ---- failure-isolating round execution ------------------------------
    # A round's calls are independent by definition, so ONE structurally
    # invalid element must not poison its round-mates: the ``try_`` variants
    # return ``None`` in place of each failing element (the failed attempt
    # is still billed, as production billing would).  Defaults catch per
    # element around the point verbs; backends whose batched implementation
    # cannot fail per element (ModelOracle logit probes) delegate straight
    # to the batched verb.  ``Ordering`` uses these so its retry/split
    # fallback re-runs ONLY the failing elements, keeping ledger accounting
    # identical to sequential execution even under failures.

    def try_rank_batches(self, batches: Sequence[Sequence[Key]],
                         criteria: str) -> list:
        out = []
        for b in batches:
            try:
                out.append(self.rank_batch(list(b), criteria))
            except InvalidOutputError:
                out.append(None)
        return out

    def try_score_batches(self, batches: Sequence[Sequence[Key]],
                          criteria: str) -> list:
        out = []
        for b in batches:
            try:
                out.append(self.score_batch(list(b), criteria))
            except InvalidOutputError:
                out.append(None)
        return out

    def try_score_each(self, keys: Sequence[Key], criteria: str) -> list:
        out = []
        for k in keys:
            try:
                out.append(self.score_batch([k], criteria)[0])
            except InvalidOutputError:
                out.append(None)
        return out

    # ---- billing helpers -------------------------------------------------
    # ``tier=None`` bills at the oracle's ambient ``bill_tier``; cascade
    # oracles pass an explicit tier per wave.
    def _charge_score(self, keys: Sequence[Key], tag: str = "",
                      tier: Optional[str] = None) -> None:
        c = self.costs
        inp = c.score_prefix + sum(k.tokens() for k in keys)
        out = c.score_out_per_key * len(keys)
        self.ledger.charge("score", inp, out, n_keys=len(keys), tag=tag,
                           tier=self.bill_tier if tier is None else tier)

    def _charge_compare(self, a: Key, b: Key, tag: str = "",
                        tier: Optional[str] = None) -> None:
        c = self.costs
        self.ledger.charge("compare", c.compare_prefix + a.tokens() + b.tokens(),
                           c.compare_out, n_keys=2, tag=tag,
                           tier=self.bill_tier if tier is None else tier)

    def _charge_rank(self, keys: Sequence[Key], tag: str = "",
                     tier: Optional[str] = None) -> None:
        c = self.costs
        inp = c.rank_prefix + sum(k.tokens() for k in keys)
        out = c.rank_out_per_key * len(keys)
        self.ledger.charge("rank", inp, out, n_keys=len(keys), tag=tag,
                           tier=self.bill_tier if tier is None else tier)

    def _charge_inquire(self, key: Key, tag: str = "",
                        tier: Optional[str] = None) -> None:
        c = self.costs
        self.ledger.charge("inquire", c.inquire_prefix + key.tokens(),
                           c.inquire_out, tag=tag,
                           tier=self.bill_tier if tier is None else tier)

    def _charge_judge(self, keys: Sequence[Key], candidates: Sequence[Sequence[Key]],
                      tag: str = "") -> int:
        """Returns the judge input token count (used for context-degradation)."""
        c = self.costs
        inp = (c.judge_prefix + sum(k.tokens() for k in keys)
               + sum(3 * len(cand) for cand in candidates))  # id lists
        self.ledger.charge("judge", inp, c.judge_out, n_keys=len(keys), tag=tag,
                           tier=self.bill_tier)
        return inp

    # ---- reporting -------------------------------------------------------
    def spend(self) -> float:
        return self.ledger.cost(self.prices)
