"""Simulated semantic oracles.

:class:`SimulatedOracle` reproduces the paper's empirical regime with
*temperature-0 semantics*: every response is a deterministic function of the
prompt (key uids + criteria + call kind), drawn from calibrated noise models:

 * pointwise scores   — latent value + miscalibration + Gaussian noise whose σ
   shrinks with the dataset's *memorization* level (factual keys are recalled,
   Sec. 5.2) and grows with listwise batch size (batch degradation, Alg. 1),
 * pairwise compares  — Bradley–Terry: P(correct) = σ((Δlatent)/τ),
 * listwise rankings  — noisy-score sort with batch-size-dependent σ and a
   primacy bias, plus a structural-failure probability that grows with batch
   size (the JSON-error mode the paper observed on Llama),
 * membership inquiry — per-key Bernoulli(membership_rate),
 * LLM-as-Judge       — true sample quality + noise ∝ prompt length
   (the "lost-in-the-middle" long-context degradation of Sec. 6.2).

:class:`ExactOracle` is the noise-free limit used by property tests.
"""
from __future__ import annotations

import hashlib
import math
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from ..metrics import kendall_tau
from ..types import InvalidOutputError, Key
from .base import LLAMA70B, Oracle, PriceSheet, PromptCosts


@dataclass(frozen=True)
class OracleProfile:
    """Calibration of one (model × dataset-family) pair."""

    name: str = "default"
    # --- pointwise / value-based ---
    memorization: float = 0.0      # 0..1; 1 => key values memorized verbatim
    score_noise: float = 0.35      # σ of pointwise score noise (latents ~ N(0,1))
    score_squash: float = 0.0      # 0..1 miscalibration: squashes score range
    batch_degradation: float = 0.20  # extra σ per log2(batch)
    # --- pairwise ---
    compare_temp: float = 0.25     # Bradley-Terry τ (lower = more reliable)
    # --- listwise ---
    listwise_noise: float = 0.30
    listwise_primacy: float = 0.05  # bias toward presented order
    invalid_rate: float = 0.02      # structural failure slope vs log2(m)
    # --- membership / judge ---
    membership_rate: float = 0.1
    judge_noise_per_ktok: float = 0.05
    seed: int = 0


# Calibrations for the two qualitative regimes in the paper.
FACTUAL = OracleProfile(
    name="factual", memorization=0.95, score_noise=0.08, compare_temp=0.55,
    listwise_noise=0.45, membership_rate=1.0, invalid_rate=0.03,
)
REASONING = OracleProfile(
    name="reasoning", memorization=0.05, score_noise=0.85, score_squash=0.55,
    compare_temp=0.16, listwise_noise=0.22, membership_rate=0.10,
    judge_noise_per_ktok=0.09,
)
SENTIMENT = OracleProfile(
    name="sentiment", memorization=0.30, score_noise=0.30, score_squash=0.2,
    compare_temp=0.22, listwise_noise=0.25, membership_rate=0.25,
)


def _hash_seed(*parts) -> int:
    h = hashlib.blake2b(repr(parts).encode(), digest_size=8)
    return int.from_bytes(h.digest(), "little")


class SimulatedOracle(Oracle):
    def __init__(self, profile: OracleProfile = REASONING,
                 prices: PriceSheet = LLAMA70B,
                 costs: Optional[PromptCosts] = None):
        super().__init__(prices=prices, costs=costs)
        self.profile = profile

    # -- deterministic noise (temperature-0 semantics) ----------------------
    def _rng(self, *parts) -> np.random.Generator:
        return np.random.default_rng(_hash_seed(self.profile.seed, *parts))

    def _point_sigma(self, m: int) -> float:
        p = self.profile
        base = p.score_noise * (1.0 - 0.9 * p.memorization)
        return base * (1.0 + p.batch_degradation * math.log2(max(m, 1)))

    def _squash(self, z: float) -> float:
        # miscalibration: compress dynamic range through tanh
        s = self.profile.score_squash
        return (1 - s) * z + s * math.tanh(z)

    # -- unbilled response values -------------------------------------------
    # Each verb = one _charge_* + one _*_value.  The value methods carry the
    # whole noise model and draw from the same rng streams, so a different
    # biller (the cascade oracle's escalation wave) reproduces this oracle's
    # answers byte-for-byte without double-billing.
    def _score_value(self, k: Key, criteria: str, m: int) -> float:
        rng = self._rng("score", k.uid, criteria, m)
        return self._squash(k.latent) + self._point_sigma(m) * rng.standard_normal()

    def _compare_value(self, a: Key, b: Key, criteria: str) -> int:
        # antisymmetric by canonical pair ordering
        lo, hi = (a, b) if a.uid <= b.uid else (b, a)
        rng = self._rng("compare", lo.uid, hi.uid, criteria)
        p_hi_wins = 1.0 / (1.0 + math.exp(-(hi.latent - lo.latent) / self.profile.compare_temp))
        hi_wins = rng.random() < p_hi_wins
        if hi_wins:
            return 1 if a is hi or a.uid == hi.uid else -1
        return 1 if a.uid == lo.uid else -1

    def _rank_values(self, keys: Sequence[Key], criteria: str) -> list[float]:
        p = self.profile
        m = len(keys)
        sigma = p.listwise_noise * (1.0 + p.batch_degradation * math.log2(max(m, 1)))
        uids = tuple(k.uid for k in keys)
        noisy = []
        for i, k in enumerate(keys):
            rng = self._rng("rank", uids, k.uid, criteria)
            val = k.latent + sigma * rng.standard_normal()
            val += p.listwise_primacy * (i / max(m - 1, 1))  # primacy bias
            noisy.append(val)
        return noisy

    def _inquire_value(self, key: Key, criteria: str) -> bool:
        rng = self._rng("inquire", key.uid, criteria)
        return bool(rng.random() < self.profile.membership_rate)

    # -- verbs ---------------------------------------------------------------
    def score_batch(self, keys: Sequence[Key], criteria: str) -> list[float]:
        self._charge_score(keys)
        m = len(keys)
        self._maybe_invalid("score", keys, criteria, m)
        return [self._score_value(k, criteria, m) for k in keys]

    def compare(self, a: Key, b: Key, criteria: str) -> int:
        self._charge_compare(a, b)
        return self._compare_value(a, b, criteria)

    def rank_batch(self, keys: Sequence[Key], criteria: str) -> list[Key]:
        self._charge_rank(keys)
        m = len(keys)
        self._maybe_invalid("rank", keys, criteria, m)
        order = np.argsort(np.asarray(self._rank_values(keys, criteria)),
                           kind="stable")
        return [keys[i] for i in order]  # ascending criteria (worst -> best)

    def inquire(self, key: Key, criteria: str) -> bool:
        self._charge_inquire(key)
        return self._inquire_value(key, criteria)

    def judge(self, keys: Sequence[Key], criteria: str,
              candidates: Sequence[Sequence[Key]]) -> int:
        inp_tokens = self._charge_judge(keys, candidates)
        p = self.profile
        sigma = p.judge_noise_per_ktok * (inp_tokens / 1000.0)
        best_i, best_v = 0, -math.inf
        for i, cand in enumerate(candidates):
            true_quality = kendall_tau(list(cand))  # vs latent ground truth
            rng = self._rng("judge", tuple(k.uid for k in cand), criteria, i)
            v = true_quality + sigma * rng.standard_normal()
            if v > best_v:
                best_i, best_v = i, v
        return best_i

    # -- structural failures ---------------------------------------------------
    def _maybe_invalid(self, kind: str, keys: Sequence[Key], criteria: str, m: int) -> None:
        if m < 4:
            return
        p_bad = min(0.9, self.profile.invalid_rate * max(0.0, math.log2(m) - 1.0))
        rng = self._rng("invalid", kind, tuple(k.uid for k in keys), criteria)
        if rng.random() < p_bad:
            raise InvalidOutputError(f"simulated malformed {kind} output (m={m})")


class ExactOracle(Oracle):
    """Noise-free oracle: property tests demand perfectly sorted output."""

    def score_batch(self, keys: Sequence[Key], criteria: str) -> list[float]:
        self._charge_score(keys)
        return [k.latent for k in keys]

    def compare(self, a: Key, b: Key, criteria: str) -> int:
        self._charge_compare(a, b)
        if a.latent == b.latent:
            return 1 if a.uid > b.uid else -1  # deterministic tie-break
        return 1 if a.latent > b.latent else -1

    def rank_batch(self, keys: Sequence[Key], criteria: str) -> list[Key]:
        self._charge_rank(keys)
        return sorted(keys, key=lambda k: (k.latent, k.uid))

    def inquire(self, key: Key, criteria: str) -> bool:
        self._charge_inquire(key)
        return True

    def judge(self, keys: Sequence[Key], criteria: str,
              candidates: Sequence[Sequence[Key]]) -> int:
        self._charge_judge(keys, candidates)
        scores = [kendall_tau(list(c)) for c in candidates]
        return int(np.argmax(scores))


class FlakyOracle(ExactOracle):
    """Exact oracle whose listwise calls fail deterministically above a batch
    size threshold — used to test Alg. 1's fallback and batch-split retry."""

    def __init__(self, fail_above: int = 8, **kw):
        super().__init__(**kw)
        self.fail_above = fail_above

    def score_batch(self, keys, criteria):
        if len(keys) > self.fail_above:
            self._charge_score(keys)
            raise InvalidOutputError(f"batch {len(keys)} > {self.fail_above}")
        return super().score_batch(keys, criteria)

    def rank_batch(self, keys, criteria):
        if len(keys) > self.fail_above:
            self._charge_rank(keys)
            raise InvalidOutputError(f"batch {len(keys)} > {self.fail_above}")
        return super().rank_batch(keys, criteria)
