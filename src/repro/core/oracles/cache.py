"""Client-side LLM output cache (Sec. 3.1).

"Repeated prompts with identical inputs are served directly from the cache,
reducing redundant LLM function calls" — this is what turns Alg. 1's batch-size
search into O(log2 m) *billed* calls.  The cache key is the full logical
prompt: (verb, uid tuple, criteria), matching temperature-0 determinism.
"""
from __future__ import annotations

from typing import Sequence

from ..types import Key
from .base import Oracle


class CachingOracle(Oracle):
    """Transparent memoizing wrapper around any Oracle.

    Billing: cache hits are free (no ledger charge); misses delegate and are
    billed by the inner oracle.  Both ledgers stay visible — ``self.ledger``
    aliases the inner ledger so access paths keep exact accounting.
    """

    def __init__(self, inner: Oracle):
        # Note: deliberately NOT calling super().__init__ — we alias the inner
        # oracle's ledger/prices so all accounting lands in one place.
        self.inner = inner
        self.ledger = inner.ledger
        self.prices = inner.prices
        self.costs = inner.costs
        self._cache: dict = {}
        self.hits = 0
        self.misses = 0

    def _memo(self, cache_key, thunk):
        if cache_key in self._cache:
            self.hits += 1
            return self._cache[cache_key]
        self.misses += 1
        val = thunk()
        self._cache[cache_key] = val
        return val

    def score_batch(self, keys: Sequence[Key], criteria: str) -> list[float]:
        ck = ("score", tuple(k.uid for k in keys), criteria)
        return list(self._memo(ck, lambda: self.inner.score_batch(keys, criteria)))

    def compare(self, a: Key, b: Key, criteria: str) -> int:
        ck = ("compare", a.uid, b.uid, criteria)
        return self._memo(ck, lambda: self.inner.compare(a, b, criteria))

    def rank_batch(self, keys: Sequence[Key], criteria: str) -> list[Key]:
        ck = ("rank", tuple(k.uid for k in keys), criteria)
        return list(self._memo(ck, lambda: self.inner.rank_batch(keys, criteria)))

    def inquire(self, key: Key, criteria: str) -> bool:
        ck = ("inquire", key.uid, criteria)
        return self._memo(ck, lambda: self.inner.inquire(key, criteria))

    def judge(self, keys, criteria, candidates):
        ck = ("judge", tuple(k.uid for k in keys), criteria,
              tuple(tuple(k.uid for k in c) for c in candidates))
        return self._memo(ck, lambda: self.inner.judge(keys, criteria, candidates))
