"""Client-side LLM output cache (Sec. 3.1) and the cross-query semantic
memo.

"Repeated prompts with identical inputs are served directly from the cache,
reducing redundant LLM function calls" — this is what turns Alg. 1's batch-size
search into O(log2 m) *billed* calls.  The cache key is the full logical
prompt — (verb, uid tuple, criteria) — NORMALIZED: the criteria string is
whitespace-canonicalized and the whole key is hashed stably (blake2b), so
logically identical comparisons issued by different queries (or different
spellings of one criteria) actually share entries, and keys are identical
across processes (unlike ``hash()``), which is what a persisted or shared
cache needs.

:class:`SemanticMemo` extends the idea ACROSS queries: a shared store of
raw probe results — comparisons, pointwise scores, membership inquiries —
keyed on the same normalized (kind, uids, criteria) identity, consulted by
``ModelOracle.begin_probe_round`` before emitting probes (see
``llm_order_by_many(..., semantic_memo=...)``).  Raw compare probes are
direction-free (the A-vs-B logit readout; direction is folded client-side
by ``Ordering.fold_compares``), so ASC and DESC queries over one criteria
share entries by construction.  Billing is first-requester-pays: the miss
that populates an entry is billed normally and its :class:`CallRecord` is
stored beside the value; a later hit is free but logs a (ledger position,
record) shadow pair on its oracle, so ``reconciled_records()`` can rebuild
the exact solo ledger — sum of per-query billed ledgers + hit shadows ==
the records of every query run alone.  See DESIGN.md "Locality scheduling
& cross-query cache".
"""
from __future__ import annotations

import hashlib
from typing import Sequence

from ..types import Key
from .base import CallRecord, Oracle


def canon_criteria(criteria: str) -> str:
    """Criteria normalization for cache/memo keys: strip the ends and
    collapse internal whitespace runs, so cosmetic spellings of one
    criteria ("relevance", " relevance\\n") share entries.  Key identity
    only — the prompt sent to the backend keeps the caller's exact
    string."""
    return " ".join(criteria.split())


def stable_key(*parts) -> str:
    """Order-sensitive stable hash of a cache key: blake2b over the repr
    of the parts.  Identical across processes and runs (``hash()`` is
    salted per process), compact, and collision-safe at 128 bits."""
    return hashlib.blake2b(repr(parts).encode(), digest_size=16).hexdigest()


class SemanticMemo:
    """Cross-query semantic probe cache (ModelOracle deferred rounds).

    Stores ``key -> (raw value, billed CallRecord)`` for the per-item
    probe kinds — ``compare`` / ``score_each`` / ``inquire`` — under
    first-requester-pays billing (module docstring).  Values are RAW probe
    results (direction-free compares, unfolded scores), so every query
    direction/limit folds them independently and per-query orderings stay
    byte-identical to solo execution.  Attach with
    ``llm_order_by_many(..., semantic_memo=SemanticMemo())`` or by setting
    ``oracle.memo`` directly; one instance may serve any number of
    sequential ``llm_order_by_many`` calls (that is the point)."""

    #: deferred round kind -> billing/record kind of one item
    KINDS = {"compare": "compare", "score_each": "score",
             "inquire": "inquire"}

    def __init__(self) -> None:
        self._store: dict[str, tuple] = {}
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._store)

    def key(self, kind: str, item, criteria: str) -> str:
        """The normalized identity of one probe: (item kind, uid tuple,
        canonical criteria), stably hashed.  ``item`` matches the deferred
        round payload: a (Key, Key) pair for ``compare``, a Key
        otherwise."""
        uids = ((item[0].uid, item[1].uid) if kind == "compare"
                else (item.uid,))
        return stable_key(self.KINDS[kind], uids, canon_criteria(criteria))

    def get(self, key: str):
        """(value, record) or None."""
        return self._store.get(key)

    def put(self, key: str, value, record: CallRecord) -> None:
        # setdefault: when two oracles miss the same key in one tick (both
        # already billed — first-REQUESTERS-pay), the first finisher's
        # value wins and the store never flips under a reader
        self._store.setdefault(key, (value, record))


class CachingOracle(Oracle):
    """Transparent memoizing wrapper around any Oracle.

    Billing: cache hits are free (no ledger charge); misses delegate and are
    billed by the inner oracle.  Both ledgers stay visible — ``self.ledger``
    aliases the inner ledger so access paths keep exact accounting.
    """

    def __init__(self, inner: Oracle):
        # Note: deliberately NOT calling super().__init__ — we alias the inner
        # oracle's ledger/prices so all accounting lands in one place.
        self.inner = inner
        self.ledger = inner.ledger
        self.prices = inner.prices
        self.costs = inner.costs
        self._cache: dict = {}
        self.hits = 0
        self.misses = 0

    @staticmethod
    def _ck(kind: str, uids, criteria: str) -> str:
        """Normalized cache key: whitespace-canonical criteria + stable
        hashing (module docstring), so logically identical calls from
        different queries hit regardless of criteria spelling."""
        return stable_key(kind, tuple(uids), canon_criteria(criteria))

    def _memo(self, cache_key, thunk):
        if cache_key in self._cache:
            self.hits += 1
            return self._cache[cache_key]
        self.misses += 1
        val = thunk()
        self._cache[cache_key] = val
        return val

    def score_batch(self, keys: Sequence[Key], criteria: str) -> list[float]:
        ck = self._ck("score", (k.uid for k in keys), criteria)
        return list(self._memo(ck, lambda: self.inner.score_batch(keys, criteria)))

    def compare(self, a: Key, b: Key, criteria: str) -> int:
        ck = self._ck("compare", (a.uid, b.uid), criteria)
        return self._memo(ck, lambda: self.inner.compare(a, b, criteria))

    def rank_batch(self, keys: Sequence[Key], criteria: str) -> list[Key]:
        ck = self._ck("rank", (k.uid for k in keys), criteria)
        return list(self._memo(ck, lambda: self.inner.rank_batch(keys, criteria)))

    def inquire(self, key: Key, criteria: str) -> bool:
        ck = self._ck("inquire", (key.uid,), criteria)
        return self._memo(ck, lambda: self.inner.inquire(key, criteria))

    # ---- round (batch) verbs: per-element memoization ---------------------
    # Each element of a round shares its cache entry with the equivalent
    # point call; only the misses are forwarded, still as one round (one
    # serving submission on a ModelOracle inner).

    def _memo_round(self, cache_keys, items, forward):
        # forward must not return None elements (the batch verbs never do);
        # the try_ variant handles the general case
        return self._memo_try_round(cache_keys, items, forward)

    def compare_batch(self, pairs, criteria: str) -> list[int]:
        cks = [self._ck("compare", (a.uid, b.uid), criteria) for a, b in pairs]
        return self._memo_round(
            cks, list(pairs), lambda ps: self.inner.compare_batch(ps, criteria))

    def inquire_batch(self, keys: Sequence[Key], criteria: str) -> list[bool]:
        cks = [self._ck("inquire", (k.uid,), criteria) for k in keys]
        return self._memo_round(
            cks, list(keys), lambda ks: self.inner.inquire_batch(ks, criteria))

    def score_each(self, keys: Sequence[Key], criteria: str) -> list[float]:
        # same cache keys (and list-valued entries) as score_batch([k])
        cks = [self._ck("score", (k.uid,), criteria) for k in keys]
        out = self._memo_round(
            cks, list(keys),
            lambda ks: [[v] for v in self.inner.score_each(ks, criteria)])
        return [float(v[0]) for v in out]

    def score_batches(self, batches, criteria: str) -> list[list[float]]:
        cks = [self._ck("score", (k.uid for k in b), criteria) for b in batches]
        return [list(v) for v in self._memo_round(
            cks, [list(b) for b in batches],
            lambda bs: self.inner.score_batches(bs, criteria))]

    # failure-isolating rounds: misses forward as one round; a None result
    # (structural failure) is returned but never cached, so a later retry
    # reaches the backend again.
    def _memo_try_round(self, cache_keys, items, forward):
        # dedup within the round: a repeat of a key whose first occurrence
        # SUCCEEDS is a hit (a sequential loop would serve it from cache);
        # a repeat of a key whose first occurrence failed structurally must
        # re-reach — and re-bill — the backend, exactly like the sequential
        # loop's cache miss (None is never cached).  Unique misses forward
        # as one round; repeats of failed keys forward as a follow-up round.
        missing: list[int] = []
        dup_later: list[int] = []
        seen: set = set()
        for i, ck in enumerate(cache_keys):
            if ck in self._cache:
                self.hits += 1
            elif ck in seen:
                dup_later.append(i)                # outcome not known yet
            else:
                self.misses += 1
                seen.add(ck)
                missing.append(i)
        out: dict[int, object] = {}

        def run(idx):
            for i, val in zip(idx, forward([items[i] for i in idx])):
                out[i] = val
                if val is not None:
                    self._cache[cache_keys[i]] = val

        if missing:
            run(missing)
        retry = [i for i in dup_later if cache_keys[i] not in self._cache]
        self.hits += len(dup_later) - len(retry)
        if retry:
            self.misses += len(retry)
            run(retry)
        # per-occurrence results: an occurrence that reached the backend
        # keeps its own value (even if a later retry of the same key
        # succeeded); the rest read the cache.
        return [out[i] if i in out else self._cache.get(ck)
                for i, ck in enumerate(cache_keys)]

    def try_rank_batches(self, batches, criteria: str) -> list:
        cks = [self._ck("rank", (k.uid for k in b), criteria) for b in batches]
        return self._memo_try_round(
            cks, [list(b) for b in batches],
            lambda bs: self.inner.try_rank_batches(bs, criteria))

    def try_score_batches(self, batches, criteria: str) -> list:
        cks = [self._ck("score", (k.uid for k in b), criteria) for b in batches]
        return self._memo_try_round(
            cks, [list(b) for b in batches],
            lambda bs: self.inner.try_score_batches(bs, criteria))

    def try_score_each(self, keys: Sequence[Key], criteria: str) -> list:
        cks = [self._ck("score", (k.uid,), criteria) for k in keys]
        out = self._memo_try_round(
            cks, list(keys),
            lambda ks: [None if v is None else [v]
                        for v in self.inner.try_score_each(ks, criteria)])
        return [None if v is None else float(v[0]) for v in out]

    def judge(self, keys, criteria, candidates):
        ck = self._ck("judge", (tuple(k.uid for k in keys),
                        tuple(tuple(k.uid for k in c) for c in candidates)),
                   criteria)
        return self._memo(ck, lambda: self.inner.judge(keys, criteria, candidates))
