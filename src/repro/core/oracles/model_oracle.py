"""ModelOracle: the Oracle interface backed by REAL JAX forward passes
through the serving engine — the production path of the LLM ORDER BY
operator.  Token billing uses actual tokenizer counts (not estimates), so the
optimizer's cost model calibrates against genuine serving costs.

Every access path and both optimizer strategies run unchanged against this
backend (tests/test_model_oracle.py), which is the point of the paper's
"semantic black box" framing: the physical sorting algorithms are oblivious
to whether the comparator is an API or a pod-hosted model.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from ..types import Key
from .base import LLAMA70B, Oracle, PriceSheet, PromptCosts, PromptParts


class ModelOracle(Oracle):
    def __init__(self, engine, prices: PriceSheet = LLAMA70B,
                 costs: Optional[PromptCosts] = None,
                 judge_rationale_tokens: int = 0,
                 scheduler=None):
        super().__init__(prices=prices, costs=costs)
        self.engine = engine
        # > 0: the judge free-decodes a rationale per candidate before the
        # quality probe (Sec. 5.4's CoT judging) — a mixed-length generation
        # workload served by the engine's continuous-batching loop; the
        # candidates share one prefix-KV block run (criteria + sample)
        self.judge_rationale_tokens = judge_rationale_tokens
        # optional BatchScheduler: when attached (llm_order_by_many and the
        # optimizer attach their shared scheduler automatically), rationale
        # generations run THROUGH the unified step loop — probe rounds from
        # concurrent plans are answered in this oracle's decode step gaps
        # instead of waiting for the whole generation to drain
        self.scheduler = scheduler
        # optional cross-query SemanticMemo (core/oracles/cache.py),
        # consulted by begin_probe_round for the per-item kinds: memo hits
        # skip both billing AND the backend probe (first-requester-pays),
        # and are logged as (ledger position, stored CallRecord) shadow
        # pairs so reconciled_records() rebuilds the exact solo ledger.
        # None (the default) keeps deferred rounds byte-identical to the
        # synchronous verbs — attach via llm_order_by_many(semantic_memo=)
        self.memo = None
        self.memo_hit_log: list[tuple[int, object]] = []
        # serving tenant class this oracle's probe rounds and rationale
        # generations ride under (scheduler.TenantSpec); "default" keeps
        # every sink call signature-compatible with non-tenant schedulers.
        # llm_order_by_many scopes this per query (operator.attach_tenants)
        self.tenant = "default"

    # -- billing helpers using real token counts -----------------------------
    def _real_tokens(self, text: str) -> int:
        return len(self.engine.tok.encode(text))

    def score_batch(self, keys: Sequence[Key], criteria: str) -> list[float]:
        inp = self.costs.score_prefix + sum(self._real_tokens(k.text) for k in keys)
        self.ledger.charge("score", inp, self.costs.score_out_per_key * len(keys),
                           n_keys=len(keys), tier=self.bill_tier)
        return self.engine.score([k.text for k in keys], criteria)

    def compare(self, a: Key, b: Key, criteria: str) -> int:
        inp = (self.costs.compare_prefix + self._real_tokens(a.text)
               + self._real_tokens(b.text))
        self.ledger.charge("compare", inp, self.costs.compare_out, n_keys=2,
                           tier=self.bill_tier)
        return self.engine.compare(a.text, b.text, criteria)

    def compare_batch(self, pairs, criteria: str) -> list[int]:
        """One round of independent comparisons in ONE padded serving
        submission; billed as len(pairs) logical compare calls (same records
        as the sequential default, same convention as rank_batches)."""
        if not pairs:
            return []
        for a, b in pairs:
            inp = (self.costs.compare_prefix + self._real_tokens(a.text)
                   + self._real_tokens(b.text))
            self.ledger.charge("compare", inp, self.costs.compare_out, n_keys=2,
                               tier=self.bill_tier)
        return self.engine.compare_many(
            [(a.text, b.text) for a, b in pairs], criteria)

    def rank_batch(self, keys: Sequence[Key], criteria: str) -> list[Key]:
        inp = self.costs.rank_prefix + sum(self._real_tokens(k.text) for k in keys)
        self.ledger.charge("rank", inp, self.costs.rank_out_per_key * len(keys),
                           n_keys=len(keys), tier=self.bill_tier)
        perm = self.engine.rank_window([k.text for k in keys], criteria)
        return [keys[i] for i in perm]

    @staticmethod
    def _split_rounds(scores, batches, rank: bool):
        """Split a flat per-key score list back into per-batch results:
        stable-argsorted key permutations (``rank``) or raw score lists.
        Shared by the synchronous round verbs AND finish_probe_round, so
        deferred and solo interpretation cannot drift apart."""
        out, i = [], 0
        for b in batches:
            s = scores[i:i + len(b)]
            i += len(b)
            if rank:
                order = np.argsort(np.asarray(s), kind="stable")
                out.append([b[j] for j in order])
            else:
                out.append(list(s))
        return out

    def rank_batches(self, batches, criteria: str):
        """Parallel run generation: score every window's keys in ONE padded
        serving batch (shared criteria prefix), then split and argsort."""
        flat = [k.text for b in batches for k in b]
        if not flat:
            return []
        # billed as len(batches) logical calls, executed as one submission
        for b in batches:
            self.ledger.charge(
                "rank",
                self.costs.rank_prefix + sum(self._real_tokens(k.text) for k in b),
                self.costs.rank_out_per_key * len(b), n_keys=len(b),
                tier=self.bill_tier)
        return self._split_rounds(self.engine.score(flat, criteria),
                                  batches, rank=True)

    def score_each(self, keys: Sequence[Key], criteria: str) -> list[float]:
        """N logical single-key score calls, ONE serving submission."""
        if not keys:
            return []
        for k in keys:
            self.ledger.charge("score",
                               self.costs.score_prefix + self._real_tokens(k.text),
                               self.costs.score_out_per_key, n_keys=1,
                               tier=self.bill_tier)
        return self.engine.score([k.text for k in keys], criteria)

    def score_batches(self, batches, criteria: str) -> list[list[float]]:
        """N logical m-key score calls, ONE serving submission."""
        flat = [k.text for b in batches for k in b]
        if not flat:
            return [[] for _ in batches]
        for b in batches:
            inp = self.costs.score_prefix + sum(self._real_tokens(k.text) for k in b)
            self.ledger.charge("score", inp, self.costs.score_out_per_key * len(b),
                               n_keys=len(b), tier=self.bill_tier)
        return self._split_rounds(self.engine.score(flat, criteria),
                                  batches, rank=False)

    # logit probes cannot fail structurally: the failure-isolating round
    # variants are exactly the batched submissions
    def try_rank_batches(self, batches, criteria: str) -> list:
        return self.rank_batches(batches, criteria)

    def try_score_batches(self, batches, criteria: str) -> list:
        return self.score_batches(batches, criteria)

    def try_score_each(self, keys: Sequence[Key], criteria: str) -> list:
        return self.score_each(keys, criteria)

    # ---- deferred round verbs (probe-plan executor) -----------------------
    # A round can be split into BEGIN (bill the ledger — identical records
    # to the synchronous verb — and enqueue the round's prompts into a
    # BatchScheduler as ONE probe-round work item behind a RoundFuture) and
    # FINISH (read the future's logits and interpret them).  The executor
    # begins every suspended plan's round and pumps the unified step loop
    # once — all plans' probes of the tick ride that step's gap in shared
    # length-bucketed submissions with cross-plan dedup, while any in-flight
    # decode rows advance alongside; a round begun mid-generation therefore
    # resolves between decode steps instead of after the drain.  Deferral is
    # sound here because logit probes cannot fail structurally, so the
    # Ordering-level retry/split fallback has nothing to catch; the raw
    # results only need the direction fold applied
    # (``Ordering.fold_compares`` / ``fold_scores`` / ``fold_window_result``).

    def _probe_prompt(self, kind: str, item, criteria: str):
        """The serving prompt of ONE per-item probe (``compare`` /
        ``score_each`` / ``inquire``) — shared by begin_probe_round and
        :meth:`preview_round_prompts` so prefetch warms exactly the
        regions the round will touch."""
        if kind == "compare":
            a, b = item
            return self.engine._compare_parts(a.text, b.text, criteria)
        if kind == "score_each":
            return self.engine.score_parts(item.text, criteria)
        return self._inquire_prompt(item, criteria)

    def _charge_probe(self, kind: str, item, tier: Optional[str] = None) -> None:
        """Bill ONE per-item probe — identical record to the synchronous
        batch verbs.  ``tier=None`` bills at the ambient ``bill_tier``;
        the cascade oracle passes explicit "draft"/"large" per wave."""
        tier = self.bill_tier if tier is None else tier
        if kind == "compare":
            a, b = item
            inp = (self.costs.compare_prefix + self._real_tokens(a.text)
                   + self._real_tokens(b.text))
            self.ledger.charge("compare", inp, self.costs.compare_out,
                               n_keys=2, tier=tier)
        elif kind == "score_each":
            self.ledger.charge(
                "score",
                self.costs.score_prefix + self._real_tokens(item.text),
                self.costs.score_out_per_key, n_keys=1, tier=tier)
        else:
            self.ledger.charge(
                "inquire",
                self.costs.inquire_prefix + self._real_tokens(item.text),
                self.costs.inquire_out, tier=tier)

    def preview_round_prompts(self, kind: str, payload, criteria: str) -> list:
        """The prompts the NEXT ``begin_probe_round(kind, payload, ...)``
        call will submit, built WITHOUT billing or side effects — the
        executor's prefetch pipeline warms their prefix regions in an
        earlier step gap.  Memo-resident items are excluded: they will
        never reach the backend, so warming their regions is waste."""
        if kind in ("score_batches", "rank_windows"):
            return [self.engine.score_parts(k.text, criteria)
                    for b in payload for k in b]
        if kind not in ("compare", "score_each", "inquire"):
            return []
        items = payload
        if self.memo is not None:
            items = [it for it in payload
                     if self.memo.get(self.memo.key(kind, it, criteria))
                     is None]
        return [self._probe_prompt(kind, it, criteria) for it in items]

    def begin_probe_round(self, kind: str, payload, criteria: str, sink):
        """Bill one round now and enqueue its prompts into ``sink`` (a
        BatchScheduler); returns an opaque token for
        :meth:`finish_probe_round`.  ``kind`` is one of ``compare`` /
        ``score_each`` / ``score_batches`` / ``rank_windows`` /
        ``inquire``; ``payload`` matches the corresponding batch verb.

        With a :class:`~repro.core.oracles.cache.SemanticMemo` attached
        (``self.memo``), the per-item kinds consult it first: a hit skips
        billing and the probe (logging a reconciliation shadow — see
        :meth:`reconciled_records`); misses are billed normally and their
        values land in the memo at finish time, CallRecord attached."""
        eng = self.engine
        prompts: list = []
        meta = None
        plan = None                    # memo plan: (hits, keys, records)
        if kind in ("compare", "score_each", "inquire"):
            hits: dict[int, object] = {}
            miss_keys: list = []
            miss_records: list = []
            for i, item in enumerate(payload):
                mkey = None
                if self.memo is not None:
                    mkey = self.memo.key(kind, item, criteria)
                    ent = self.memo.get(mkey)
                    if ent is not None:
                        value, record = ent
                        hits[i] = value
                        self.memo.hits += 1
                        self.memo_hit_log.append(
                            (len(self.ledger.records), record))
                        continue
                    self.memo.misses += 1
                self._charge_probe(kind, item)
                miss_keys.append(mkey)
                miss_records.append(self.ledger.records[-1])
                prompts.append(self._probe_prompt(kind, item, criteria))
            if self.memo is not None:
                plan = (hits, miss_keys, miss_records)
        elif kind in ("score_batches", "rank_windows"):
            bill_kind = "score" if kind == "score_batches" else "rank"
            prefix = (self.costs.score_prefix if kind == "score_batches"
                      else self.costs.rank_prefix)
            per_key = (self.costs.score_out_per_key if kind == "score_batches"
                       else self.costs.rank_out_per_key)
            for b in payload:
                inp = prefix + sum(self._real_tokens(k.text) for k in b)
                self.ledger.charge(bill_kind, inp, per_key * len(b),
                                   n_keys=len(b), tier=self.bill_tier)
                prompts.extend(eng.score_parts(k.text, criteria) for k in b)
            meta = [list(b) for b in payload]
        else:
            raise ValueError(f"unknown deferred round kind {kind!r}")
        if hasattr(sink, "submit_probe_round"):
            if self.tenant != "default":   # default stays signature-neutral
                return (kind, sink.submit_probe_round(
                    prompts, tenant=self.tenant), meta, plan)
            return (kind, sink.submit_probe_round(prompts), meta, plan)
        # legacy sink: per-probe rids read back from sink.probe_results
        return (kind, [sink.submit_probe(p) for p in prompts], meta, plan)

    def finish_probe_round(self, token, sink):
        """Interpret one begun round's logits.  Future-based rounds resolve
        through the sink's step loop (``sink.resolve`` pumps until the
        round's step gap has serviced it — at most one step away); legacy
        rid rounds read ``sink.probe_results``.  Returns the same raw
        values the synchronous batch verb would have.  Rounds begun with a
        memo plan fan hits and fresh results back into payload order and
        publish the fresh values (with their billed CallRecords) to the
        memo."""
        from ...serving.engine import read_compare, read_score, read_yes_no
        kind, handle, meta, plan = token
        if hasattr(handle, "result"):            # RoundFuture
            if not handle.done:
                sink.resolve(handle)
            logits = handle.result()
        else:
            logits = [sink.probe_results.pop(rid) for rid in handle]
        if kind in ("score_batches", "rank_windows"):
            return self._split_rounds([read_score(l) for l in logits], meta,
                                      rank=(kind == "rank_windows"))
        read = {"compare": read_compare, "score_each": read_score,
                "inquire": read_yes_no}[kind]
        fresh = [read(l) for l in logits]
        if plan is None:
            return fresh
        hits, miss_keys, miss_records = plan
        for mkey, value, record in zip(miss_keys, fresh, miss_records):
            self.memo.put(mkey, value, record)
        out: list = [None] * (len(hits) + len(fresh))
        it = iter(fresh)
        for i in range(len(out)):
            out[i] = hits[i] if i in hits else next(it)
        return out

    def reconciled_records(self) -> list:
        """This oracle's ledger with the memo hits' shadow
        :class:`CallRecord`\\ s re-inserted at the positions solo execution
        would have billed them — byte-identical (``==``) to the solo run's
        ``ledger.records`` when the memo'd values came from identical
        probes (the first-requester-pays reconciliation contract: sum of
        per-query billed ledgers + hit shadows == solo ledgers)."""
        out: list = []
        li = 0
        log = self.memo_hit_log
        for pos in range(len(self.ledger.records) + 1):
            while li < len(log) and log[li][0] == pos:
                out.append(log[li][1])
                li += 1
            if pos < len(self.ledger.records):
                out.append(self.ledger.records[pos])
        return out

    def _inquire_prompt(self, key: Key, criteria: str) -> PromptParts:
        # structured (shared_prefix, per_key_suffix): a whole membership
        # round shares one prefix-KV entry in the serving engine
        return PromptParts(
            f"You have seen the following {criteria}: \"",
            f"{key.text}\" in your training data? Answer Y or N:")

    def inquire(self, key: Key, criteria: str) -> bool:
        self.ledger.charge("inquire",
                           self.costs.inquire_prefix + self._real_tokens(key.text),
                           self.costs.inquire_out, tier=self.bill_tier)
        return self.engine.yes_no(self._inquire_prompt(key, criteria))

    def inquire_batch(self, keys: Sequence[Key], criteria: str) -> list[bool]:
        """One round of membership inquiries in ONE serving submission."""
        if not keys:
            return []
        for k in keys:
            self.ledger.charge("inquire",
                               self.costs.inquire_prefix + self._real_tokens(k.text),
                               self.costs.inquire_out, tier=self.bill_tier)
        return self.engine.yes_no_many(
            [self._inquire_prompt(k, criteria) for k in keys])

    def judge(self, keys: Sequence[Key], criteria: str,
              candidates: Sequence[Sequence[Key]]) -> int:
        self._charge_judge(keys, candidates)
        listings = [" > ".join(k.text[:40] for k in cand[:10])
                    for cand in candidates]
        prefix = f"Criteria: {criteria}\nRanking:"
        rationales = [""] * len(candidates)
        if self.judge_rationale_tokens > 0 and candidates:
            # free-decode a rationale per candidate ranking: candidate
            # rationales are independent mixed-length generations, so they
            # ride the continuous-batching loop (short verdicts retire
            # early; the shared criteria prefix is one pinned block run).
            # With a scheduler attached they run THROUGH the unified step
            # loop, so concurrent plans' probe rounds are answered in this
            # generation's step gaps instead of behind the whole drain.
            rationale_prompts = [
                PromptParts(prefix, f" {lst}\nJudge rationale:")
                for lst in listings]
            if self.scheduler is not None and self.scheduler.paged \
                    and self.scheduler.engine is self.engine:
                kw = ({} if self.tenant == "default"
                      else {"tenant": self.tenant})
                rationales = self.scheduler.generate(
                    rationale_prompts, max_new=self.judge_rationale_tokens,
                    **kw)
            else:
                rationales = self.engine.generate(
                    rationale_prompts, max_new=self.judge_rationale_tokens)
            for r in rationales:
                self.ledger.charge("judge", 0,
                                   self._real_tokens(r) if r else 1,
                                   n_keys=0, tag="rationale",
                                   tier=self.bill_tier)
        # score each candidate ranking as a whole via a quality probe prompt
        prompts = []
        for lst, rat in zip(listings, rationales):
            suffix = (f" {lst}\nQuality rating:" if not rat else
                      f" {lst}\nRationale: {rat}\nQuality rating:")
            prompts.append(PromptParts(prefix, suffix))
        logits = self.engine.last_logits(prompts)
        from ...serving.engine import read_score
        scores = [read_score(l) for l in logits]
        return int(np.argmax(scores))
