from .base import Oracle, PriceSheet, TokenLedger, LLAMA70B, LLAMA405B, GPT41
from .simulated import ExactOracle, FlakyOracle, OracleProfile, SimulatedOracle
from .cache import CachingOracle

__all__ = ["Oracle", "PriceSheet", "TokenLedger", "LLAMA70B", "LLAMA405B",
           "GPT41", "ExactOracle", "FlakyOracle", "OracleProfile",
           "SimulatedOracle", "CachingOracle"]
