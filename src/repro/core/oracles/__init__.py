from .base import Oracle, PriceSheet, TokenLedger, LLAMA70B, LLAMA405B, GPT41
from .simulated import ExactOracle, FlakyOracle, OracleProfile, SimulatedOracle
from .cache import CachingOracle, SemanticMemo, canon_criteria, stable_key

__all__ = ["Oracle", "PriceSheet", "TokenLedger", "LLAMA70B", "LLAMA405B",
           "GPT41", "ExactOracle", "FlakyOracle", "OracleProfile",
           "SimulatedOracle", "CachingOracle", "SemanticMemo",
           "canon_criteria", "stable_key"]
