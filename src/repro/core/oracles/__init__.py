from .base import (Oracle, PriceSheet, TieredPrices, TokenLedger, LLAMA70B,
                   LLAMA405B, GPT41, STABLELM2, CASCADE_70B)
from .simulated import ExactOracle, FlakyOracle, OracleProfile, SimulatedOracle
from .cascade import (CascadeOracle, DRAFT_1p6B, SimulatedCascadeOracle,
                      probe_margin)
from .cache import CachingOracle, SemanticMemo, canon_criteria, stable_key

__all__ = ["Oracle", "PriceSheet", "TieredPrices", "TokenLedger", "LLAMA70B",
           "LLAMA405B", "GPT41", "STABLELM2", "CASCADE_70B", "ExactOracle",
           "FlakyOracle", "OracleProfile", "SimulatedOracle", "CascadeOracle",
           "SimulatedCascadeOracle", "DRAFT_1p6B", "probe_margin",
           "CachingOracle", "SemanticMemo", "canon_criteria", "stable_key"]
