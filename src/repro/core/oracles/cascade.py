"""Model-cascade oracle: draft-first probe rounds with uncertainty escalation.

Every probe round runs as a TWO-WAVE submission.  Wave 1 answers the whole
round on a small draft engine (a reduced config from ``configs/registry``,
e.g. stablelm-1.6b); each row's confidence is its logit margin —
``|logit_A − logit_B|`` for compares, the rating gap for scores, the Y/N gap
for inquiries.  Only rows whose margin falls below ``threshold`` escalate to
the large engine in wave 2.  Both waves live inside the SAME round future,
so executor ticks, fairness bounds, and per-plan attribution are unchanged;
the scheduler routes the waves onto per-tier engine lanes
(:meth:`~repro.serving.scheduler.BatchScheduler.submit_cascade_round`).

Billing: the draft wave bills one draft-tier record per logical call at
round begin (payload order); the escalation wave bills large-tier records at
escalation time (slot order) and attributes them back to the owning plan via
the round token's ``extra_records``.  A :class:`~.base.TieredPrices` book
then prices the shared ledger exactly per tier.

Identity anchor: ``threshold=inf`` (or ``draft_engine=None``) collapses to
pure large-model execution — no draft wave, untiered records — and is
byte-identical in BOTH output and ledger to :class:`ModelOracle` on the
large engine.  ``threshold=0`` never escalates (margins are nonnegative), so
zero large-tier probe records are billed.

:class:`SimulatedCascadeOracle` is the calibrated-noise twin (draft answers
from a noisier profile with an explicit Bradley–Terry margin; escalations
answered by the large profile's exact rng streams), giving the fast tier-1
identity tests and the table11 sweep the same contract without a model.
"""
from __future__ import annotations

import copy
import math
from typing import Optional, Sequence

import numpy as np

from ..types import Key
from .base import CASCADE_70B, Oracle, PromptCosts, TieredPrices
from .model_oracle import ModelOracle
from .simulated import OracleProfile, REASONING, SimulatedOracle

# Calibration for a small draft judge: noisier scores, flatter compare
# logits (higher Bradley–Terry temperature), no memorization.  The compare
# temperature is the load-bearing number: at 0.45 the draft's CONFIDENT
# answers (high |logit margin|) are usually right even though its overall
# accuracy trails the large profile — which is exactly the regime where
# margin-gated escalation pays (benchmarks/table11_cascade.py).
DRAFT_1p6B = OracleProfile(
    name="draft-1.6b", memorization=0.0, score_noise=0.95, score_squash=0.40,
    compare_temp=0.45, listwise_noise=0.60, membership_rate=0.10,
    invalid_rate=0.0,
)


def probe_margin(kind: str, logits) -> float:
    """Uncertainty of ONE answered probe from its last-position logits:
    the gap between the two tokens the read-out would compare."""
    from ...serving.engine import (TOK_A, TOK_B, TOK_HI, TOK_LO, TOK_NO,
                                   TOK_YES)
    l = np.asarray(logits)
    if kind == "compare":
        return float(abs(l[TOK_A] - l[TOK_B]))
    if kind == "inquire":
        return float(abs(l[TOK_YES] - l[TOK_NO]))
    return float(abs(l[TOK_HI] - l[TOK_LO]))  # score / rank rating gap


class _CascadeToken:
    """Deferred-round token wrapping the inner (kind, future, meta, plan)
    token with the escalation wave's large-tier records, which the executor
    folds into the owning plan's attribution after finish."""

    __slots__ = ("inner", "extra_records")

    def __init__(self, inner, extra_records: list):
        self.inner = inner
        self.extra_records = extra_records


class CascadeOracle(ModelOracle):
    """ModelOracle over a (draft, large) engine pair.

    ``engine`` is the LARGE engine (the quality anchor); ``draft_engine``
    the small one.  ``threshold`` is the escalation margin — calibrate with
    :meth:`calibrate_threshold` or sweep it via the optimizer's ladder
    (:meth:`at_threshold` views share this oracle's ledger and engines).

    A SemanticMemo is NOT consulted in cascade mode (memo'd values are
    large-model answers; replaying them for a draft-priced round would
    corrupt tier attribution) — attach one only at ``threshold=inf``.
    """

    def __init__(self, engine, draft_engine=None, threshold: float = math.inf,
                 prices: TieredPrices = CASCADE_70B,
                 costs: Optional[PromptCosts] = None,
                 judge_rationale_tokens: int = 0, scheduler=None):
        super().__init__(engine, prices=prices, costs=costs,
                         judge_rationale_tokens=judge_rationale_tokens,
                         scheduler=scheduler)
        self.draft_engine = draft_engine
        self.threshold = float(threshold)

    @property
    def _cascading(self) -> bool:
        return self.draft_engine is not None and self.threshold != math.inf

    def at_threshold(self, threshold: float) -> "CascadeOracle":
        """A rung view at a different escalation threshold sharing THIS
        oracle's ledger, engines, scheduler, and tenant — the optimizer
        pilots (path × threshold) candidates through these, so one budget
        governs the whole ladder."""
        clone = copy.copy(self)
        clone.threshold = float(threshold)
        return clone

    # ---- two-wave round core --------------------------------------------
    def _bill_draft_round(self, kind: str, payload, criteria: str) -> list:
        """Bill the draft wave (one draft-tier record per logical call,
        payload order) and return the round's prompts — the SAME prompts
        both engines answer (pure string templates over key text)."""
        prompts: list = []
        if kind in ("compare", "score_each", "inquire"):
            for item in payload:
                self._charge_probe(kind, item, tier="draft")
                prompts.append(self._probe_prompt(kind, item, criteria))
        else:  # score_batches / rank_windows: one record per batch
            bill_kind = "score" if kind == "score_batches" else "rank"
            prefix = (self.costs.score_prefix if kind == "score_batches"
                      else self.costs.rank_prefix)
            per_key = (self.costs.score_out_per_key if kind == "score_batches"
                       else self.costs.rank_out_per_key)
            for b in payload:
                inp = prefix + sum(self._real_tokens(k.text) for k in b)
                self.ledger.charge(bill_kind, inp, per_key * len(b),
                                   n_keys=len(b), tier="draft")
                prompts.extend(self.engine.score_parts(k.text, criteria)
                               for k in b)
        return prompts

    def _bill_escalations(self, kind: str, payload, esc: Sequence[int]) -> None:
        """Bill the escalation wave: large-tier records in slot order.  For
        the batch kinds, one record per batch that escalated ≥1 key (n_keys
        and token counts cover ONLY the escalated keys)."""
        if kind in ("compare", "score_each", "inquire"):
            for i in esc:
                self._charge_probe(kind, payload[i], tier="large")
            return
        bill_kind = "score" if kind == "score_batches" else "rank"
        prefix = (self.costs.score_prefix if kind == "score_batches"
                  else self.costs.rank_prefix)
        per_key = (self.costs.score_out_per_key if kind == "score_batches"
                   else self.costs.rank_out_per_key)
        esc_set = set(esc)
        flat = 0
        for b in payload:
            keys = [k for j, k in enumerate(b, start=flat) if j in esc_set]
            flat += len(b)
            if keys:
                inp = prefix + sum(self._real_tokens(k.text) for k in keys)
                self.ledger.charge(bill_kind, inp, per_key * len(keys),
                                   n_keys=len(keys), tier="large")

    def _cascade_round(self, kind: str, payload, criteria: str) -> list:
        """Synchronous two-wave execution; returns final per-slot logits
        (ledger order: all draft records, then escalations in slot order —
        identical to the deferred path through submit_cascade_round)."""
        prompts = self._bill_draft_round(kind, payload, criteria)
        final = list(self.draft_engine.submit_probes(prompts))
        esc = [i for i, l in enumerate(final)
               if probe_margin(kind, l) < self.threshold]
        self._bill_escalations(kind, payload, esc)
        if esc:
            large = self.engine.submit_probes([prompts[i] for i in esc])
            for j, i in enumerate(esc):
                final[i] = large[j]
        return final

    def calibrate_threshold(self, keys: Sequence[Key], criteria: str,
                            quantile: float = 0.5, kind: str = "compare",
                            max_probes: int = 32) -> float:
        """Set ``threshold`` at a quantile of the draft margins observed on
        a sample: ``quantile=0.5`` escalates roughly half the probes.  The
        calibration probes run (and are billed) as a draft-tier round."""
        if self.draft_engine is None:
            raise ValueError("calibration needs a draft engine")
        if kind == "compare":
            payload = [(keys[i], keys[i + 1])
                       for i in range(len(keys) - 1)][:max_probes]
        else:
            payload = list(keys)[:max_probes]
        prompts = self._bill_draft_round(kind, payload, criteria)
        logits = self.draft_engine.submit_probes(prompts)
        margins = [probe_margin(kind, l) for l in logits]
        self.threshold = float(np.quantile(np.asarray(margins), quantile))
        return self.threshold

    # ---- synchronous round verbs ----------------------------------------
    def compare_batch(self, pairs, criteria: str) -> list[int]:
        if not self._cascading or not pairs:
            return super().compare_batch(pairs, criteria)
        from ...serving.engine import read_compare
        return [read_compare(l)
                for l in self._cascade_round("compare", pairs, criteria)]

    def compare(self, a: Key, b: Key, criteria: str) -> int:
        if not self._cascading:
            return super().compare(a, b, criteria)
        return self.compare_batch([(a, b)], criteria)[0]

    def score_each(self, keys: Sequence[Key], criteria: str) -> list[float]:
        if not self._cascading or not keys:
            return super().score_each(keys, criteria)
        from ...serving.engine import read_score
        return [read_score(l)
                for l in self._cascade_round("score_each", keys, criteria)]

    def score_batches(self, batches, criteria: str) -> list[list[float]]:
        if not self._cascading or not any(len(b) for b in batches):
            return super().score_batches(batches, criteria)
        from ...serving.engine import read_score
        logits = self._cascade_round("score_batches", batches, criteria)
        return self._split_rounds([read_score(l) for l in logits],
                                  [list(b) for b in batches], rank=False)

    def score_batch(self, keys: Sequence[Key], criteria: str) -> list[float]:
        if not self._cascading:
            return super().score_batch(keys, criteria)
        return self.score_batches([list(keys)], criteria)[0]

    def rank_batches(self, batches, criteria: str):
        if not self._cascading or not any(len(b) for b in batches):
            return super().rank_batches(batches, criteria)
        from ...serving.engine import read_score
        logits = self._cascade_round("rank_windows", batches, criteria)
        return self._split_rounds([read_score(l) for l in logits],
                                  [list(b) for b in batches], rank=True)

    def rank_batch(self, keys: Sequence[Key], criteria: str) -> list[Key]:
        if not self._cascading:
            return super().rank_batch(keys, criteria)
        return self.rank_batches([list(keys)], criteria)[0]

    def inquire_batch(self, keys: Sequence[Key], criteria: str) -> list[bool]:
        if not self._cascading or not keys:
            return super().inquire_batch(keys, criteria)
        from ...serving.engine import read_yes_no
        return [read_yes_no(l)
                for l in self._cascade_round("inquire", keys, criteria)]

    def inquire(self, key: Key, criteria: str) -> bool:
        if not self._cascading:
            return super().inquire(key, criteria)
        return self.inquire_batch([key], criteria)[0]

    # ---- deferred rounds (probe-plan executor) ---------------------------
    def preview_round_prompts(self, kind: str, payload, criteria: str) -> list:
        if not self._cascading:
            return super().preview_round_prompts(kind, payload, criteria)
        # wave 1 runs on the draft engine: warming the LARGE engine's
        # prefix regions for prompts that may never escalate is waste
        return []

    def begin_probe_round(self, kind: str, payload, criteria: str, sink):
        if not self._cascading:
            return super().begin_probe_round(kind, payload, criteria, sink)
        if not hasattr(sink, "submit_cascade_round"):
            raise TypeError("cascade rounds need a BatchScheduler sink with "
                            "submit_cascade_round (two-lane step loop)")
        payload = list(payload)
        prompts = self._bill_draft_round(kind, payload, criteria)
        extra: list = []

        def escalate(draft_logits: dict) -> set:
            """Scheduler callback at the end of wave 1: pick + bill the
            escalations; records land in ``extra`` for plan attribution."""
            esc = [i for i in sorted(draft_logits)
                   if probe_margin(kind, draft_logits[i]) < self.threshold]
            snap = len(self.ledger.records)
            self._bill_escalations(kind, payload, esc)
            extra.extend(self.ledger.records[snap:])
            return set(esc)

        kw = {} if self.tenant == "default" else {"tenant": self.tenant}
        fut = sink.submit_cascade_round(prompts, escalate, **kw)
        meta = ([list(b) for b in payload]
                if kind in ("score_batches", "rank_windows") else None)
        return _CascadeToken((kind, fut, meta, None), extra)

    def finish_probe_round(self, token, sink):
        if isinstance(token, _CascadeToken):
            token = token.inner
        return super().finish_probe_round(token, sink)


class SimulatedCascadeOracle(Oracle):
    """Calibrated-noise twin of :class:`CascadeOracle`: a noisy draft
    profile answers wave 1 with an explicit margin (the Bradley–Terry
    logistic delta for compares, |rating| for scores), and escalations are
    answered by the large profile's exact rng streams — so at
    ``threshold=inf`` every verb delegates to a plain
    :class:`SimulatedOracle` on the large profile, byte-identical in
    answers AND ledger records.  Cascade-mode draft waves never fail
    structurally (logit-probe semantics); passthrough keeps the large
    profile's failure model."""

    def __init__(self, draft: OracleProfile = DRAFT_1p6B,
                 large: OracleProfile = REASONING,
                 threshold: float = math.inf,
                 prices: TieredPrices = CASCADE_70B,
                 costs: Optional[PromptCosts] = None):
        super().__init__(prices=prices, costs=costs)
        self._draft = SimulatedOracle(draft, prices=prices, costs=costs)
        self._large = SimulatedOracle(large, prices=prices, costs=costs)
        # one shared ledger: passthrough delegation bills through _large
        self._draft.ledger = self.ledger
        self._large.ledger = self.ledger
        self.threshold = float(threshold)

    @property
    def _cascading(self) -> bool:
        return self.threshold != math.inf

    def at_threshold(self, threshold: float) -> "SimulatedCascadeOracle":
        clone = copy.copy(self)
        clone.threshold = float(threshold)
        return clone

    # ---- draft-wave answers with explicit margins ------------------------
    def _draft_compare_delta(self, a: Key, b: Key, criteria: str) -> float:
        """Signed Bradley–Terry delta w.r.t. ``a``: Δlatent/τ_draft plus
        standard-logistic noise, so P(delta>0) = σ(Δ/τ) exactly; |delta|
        is the draft's confidence margin."""
        lo, hi = (a, b) if a.uid <= b.uid else (b, a)
        rng = self._draft._rng("compare", lo.uid, hi.uid, criteria)
        u = min(max(rng.random(), 1e-12), 1.0 - 1e-12)
        noise = math.log(u) - math.log1p(-u)
        delta = ((hi.latent - lo.latent) / self._draft.profile.compare_temp
                 + noise)
        return delta if (a is hi or a.uid == hi.uid) else -delta

    def _cascade_score_batches(self, batches, criteria: str,
                               bill: str) -> list[list[float]]:
        """Two-wave scoring over batches (draft values + |rating| margins,
        then per-batch escalation of low-margin keys to the large profile);
        billing order matches CascadeOracle: all draft records, then
        escalations in batch order."""
        charge = self._charge_score if bill == "score" else self._charge_rank
        batches = [list(b) for b in batches]
        vals_all = []
        for b in batches:
            charge(b, tier="draft")
            vals_all.append([self._draft._score_value(k, criteria, len(b))
                             for k in b])
        for b, vals in zip(batches, vals_all):
            esc = [i for i, v in enumerate(vals) if abs(v) < self.threshold]
            if esc:
                charge([b[i] for i in esc], tier="large")
                for i in esc:
                    vals[i] = self._large._score_value(b[i], criteria, len(b))
        return vals_all

    # ---- verbs -----------------------------------------------------------
    def compare(self, a: Key, b: Key, criteria: str) -> int:
        if not self._cascading:
            return self._large.compare(a, b, criteria)
        return self.compare_batch([(a, b)], criteria)[0]

    def compare_batch(self, pairs, criteria: str) -> list[int]:
        if not self._cascading:
            return self._large.compare_batch(pairs, criteria)
        deltas = []
        for a, b in pairs:
            self._charge_compare(a, b, tier="draft")
            deltas.append(self._draft_compare_delta(a, b, criteria))
        out = []
        for (a, b), d in zip(pairs, deltas):
            if abs(d) < self.threshold:
                self._charge_compare(a, b, tier="large")
                out.append(self._large._compare_value(a, b, criteria))
            else:
                out.append(1 if d > 0 else -1)
        return out

    def score_batch(self, keys: Sequence[Key], criteria: str) -> list[float]:
        if not self._cascading:
            return self._large.score_batch(keys, criteria)
        return self._cascade_score_batches([list(keys)], criteria, "score")[0]

    def score_each(self, keys: Sequence[Key], criteria: str) -> list[float]:
        if not self._cascading:
            return self._large.score_each(keys, criteria)
        out = self._cascade_score_batches([[k] for k in keys], criteria,
                                          "score")
        return [v[0] for v in out]

    def score_batches(self, batches, criteria: str) -> list[list[float]]:
        if not self._cascading:
            return self._large.score_batches(batches, criteria)
        return self._cascade_score_batches(batches, criteria, "score")

    def rank_batch(self, keys: Sequence[Key], criteria: str) -> list[Key]:
        if not self._cascading:
            return self._large.rank_batch(keys, criteria)
        return self.rank_batches([list(keys)], criteria)[0]

    def rank_batches(self, batches, criteria: str):
        if not self._cascading:
            return self._large.rank_batches(batches, criteria)
        batches = [list(b) for b in batches]
        vals = self._cascade_score_batches(batches, criteria, "rank")
        out = []
        for b, v in zip(batches, vals):
            order = np.argsort(np.asarray(v), kind="stable")
            out.append([b[i] for i in order])
        return out

    def inquire(self, key: Key, criteria: str) -> bool:
        if not self._cascading:
            return self._large.inquire(key, criteria)
        return self.inquire_batch([key], criteria)[0]

    def inquire_batch(self, keys: Sequence[Key], criteria: str) -> list[bool]:
        if not self._cascading:
            return self._large.inquire_batch(keys, criteria)
        rate = self._draft.profile.membership_rate
        drafts = []
        for k in keys:
            self._charge_inquire(k, tier="draft")
            u = self._draft._rng("inquire", k.uid, criteria).random()
            drafts.append((bool(u < rate), abs(u - rate)))
        out = []
        for k, (ans, margin) in zip(keys, drafts):
            if margin < self.threshold:
                self._charge_inquire(k, tier="large")
                out.append(self._large._inquire_value(k, criteria))
            else:
                out.append(ans)
        return out

    def judge(self, keys: Sequence[Key], criteria: str,
              candidates: Sequence[Sequence[Key]]) -> int:
        # judging stays on the large profile in both modes (selection-time
        # quality probe, untiered like single-model execution)
        return self._large.judge(keys, criteria, candidates)

    def try_rank_batches(self, batches, criteria: str) -> list:
        if not self._cascading:
            return self._large.try_rank_batches(batches, criteria)
        return super().try_rank_batches(batches, criteria)

    def try_score_batches(self, batches, criteria: str) -> list:
        if not self._cascading:
            return self._large.try_score_batches(batches, criteria)
        return super().try_score_batches(batches, criteria)

    def try_score_each(self, keys: Sequence[Key], criteria: str) -> list:
        if not self._cascading:
            return self._large.try_score_each(keys, criteria)
        return super().try_score_each(keys, criteria)
