"""External bubble sort — the RankGPT sliding-window strategy (Sec. 3.2).

A window of ``m`` keys is ranked listwise, then the window slides by
``h = m/2`` toward the front of the output, so the best remaining ``h`` keys
"bubble up" per pass.  Pass ``p`` fixes output positions ``[0, p*h)``; with
LIMIT K only ``ceil(K/h)`` passes are needed — O(K*N/m^2) calls vs
O(N^2/m^2) for the full sort (Table 1).

Probe plan: windows within one pass form a strict dependency chain (each
overlaps its predecessor by ``m - h``), but windows of *successive passes*
are independent once the region they read has been fully written by the
previous pass.  The plan therefore software-pipelines the passes: the full
schedule of window ops is known statically, and each round greedily takes
every op whose earlier overlapping ops have all completed — a
dependency-preserving reorder, so every window call sees exactly the input
it would see sequentially and output order is byte-identical for any
deterministic-per-prompt oracle.  In steady state a round carries one window
from each in-flight pass (a wavefront), and each round suspends the plan as
ONE ``RankWindows`` probe set — cutting serving submissions from
``passes * windows_per_pass`` to ``~windows_per_pass + 2 * passes``.
"""
from __future__ import annotations

import bisect
import math
from typing import Optional, Sequence

from ..executor import RankWindows
from ..types import Key, SortSpec
from .base import AccessPath, PathParams, register


def _pass_starts(n: int, m: int, h: int, fixed: int) -> list[int]:
    starts = []
    i = n - m
    while i > fixed:
        starts.append(i)
        i -= h
    starts.append(fixed)
    return starts


@register("ext_bubble")
class ExternalBubbleSort(AccessPath):
    def _plan(self, keys: Sequence[Key], spec: SortSpec):
        keys = list(keys)
        n = len(keys)
        m = max(2, self.params.batch_size)
        h = max(m // 2, 1)
        if n <= m:
            ranked = yield RankWindows([keys])
            return ranked[0]
        want = spec.effective_limit(n)
        n_passes = math.ceil(want / h)

        # static schedule: every window op in sequential order
        ops: list[int] = []  # window start positions
        for p in range(n_passes):
            fixed = p * h
            if fixed >= n - 1:
                break
            ops.extend(_pass_starts(n, m, h, fixed))

        # Wavefront rounds by dependency level: op k conflicts with every
        # earlier op whose start lies within (s-m, s+m) (overlapping [s, s+m)
        # regions), and ops sharing a start conflict pairwise, so their
        # levels are strictly increasing — the LAST earlier op at each
        # conflicting start carries the max level.  level[k] = 1 + max over
        # those predecessors; ops of one level have pairwise-disjoint
        # regions (conflicting ops always differ in level), so each level is
        # one RankWindows probe set applied in place.  This is a
        # dependency-preserving reorder computed in O(ops * m/h * log).
        at: dict[int, list[int]] = {}
        for k, s in enumerate(ops):
            at.setdefault(s, []).append(k)
        starts_sorted = sorted(at)
        levels = [0] * len(ops)
        n_levels = 0
        for k, s in enumerate(ops):
            lvl = 0
            lo = bisect.bisect_right(starts_sorted, s - m)
            hi = bisect.bisect_left(starts_sorted, s + m)
            for s2 in starts_sorted[lo:hi]:
                lst = at[s2]
                pos = bisect.bisect_left(lst, k) - 1
                if pos >= 0:  # last earlier op at a conflicting start
                    lvl = max(lvl, levels[lst[pos]] + 1)
            levels[k] = lvl
            n_levels = max(n_levels, lvl + 1)
        by_level: list[list[int]] = [[] for _ in range(n_levels)]
        for k, lvl in enumerate(levels):
            by_level[lvl].append(k)  # index order within a level
        for round_ids in by_level:
            ranked = yield RankWindows([keys[ops[k]:ops[k] + m]
                                        for k in round_ids])
            for k, r in zip(round_ids, ranked):
                keys[ops[k]:ops[k] + m] = r
        return keys

    @classmethod
    def est_calls(cls, n: int, k: Optional[int], params: PathParams) -> float:
        m = max(2, params.batch_size)
        h = max(m // 2, 1)
        if n <= m:
            return 1.0
        want = n if k is None else min(k, n)
        passes = math.ceil(want / h)
        per_pass = max(1, math.ceil((n - m) / h) + 1)
        return float(passes * per_pass)
