"""External bubble sort — the RankGPT sliding-window strategy (Sec. 3.2).

A window of ``m`` keys is ranked listwise, then the window slides by
``h = m/2`` toward the front of the output, so the best remaining ``h`` keys
"bubble up" per pass.  Pass ``p`` fixes output positions ``[0, p*h)``; with
LIMIT K only ``ceil(K/h)`` passes are needed — O(K*N/m^2) calls vs
O(N^2/m^2) for the full sort (Table 1).
"""
from __future__ import annotations

import math
from typing import Optional

from ..types import Key, SortSpec
from .base import AccessPath, Ordering, PathParams, register


@register("ext_bubble")
class ExternalBubbleSort(AccessPath):
    def _order(self, keys, ordering: Ordering, spec: SortSpec) -> list[Key]:
        keys = list(keys)
        n = len(keys)
        m = max(2, self.params.batch_size)
        h = max(m // 2, 1)
        if n <= m:
            return ordering.window(keys)
        want = spec.effective_limit(n)
        n_passes = math.ceil(want / h)
        for p in range(n_passes):
            fixed = p * h
            if fixed >= n - 1:
                break
            starts = []
            i = n - m
            while i > fixed:
                starts.append(i)
                i -= h
            starts.append(fixed)
            for s in starts:
                keys[s:s + m] = ordering.window(keys[s:s + m])
        return keys

    @classmethod
    def est_calls(cls, n: int, k: Optional[int], params: PathParams) -> float:
        m = max(2, params.batch_size)
        h = max(m // 2, 1)
        if n <= m:
            return 1.0
        want = n if k is None else min(k, n)
        passes = math.ceil(want / h)
        per_pass = max(1, math.ceil((n - m) / h) + 1)
        return float(passes * per_pass)
