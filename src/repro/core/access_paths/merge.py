"""LLM external merge sort — the paper's new algorithm (Sec. 3.2, Alg. 4/5).

Phase 1 (run generation): chunks of ``m`` keys are each sorted with one
listwise call.  Phase 2 (iterative merging): sorted runs are merged two at a
time.  The two-way merge (Alg. 5) keeps a sliding buffer of up to ``h = m/2``
keys from each run, asks the LLM for a partial order of the buffer, and emits
ranked items until one side's buffered portion is exhausted — at which point
the buffer must be refilled, because the unseen next element of the exhausted
run may precede the survivors.

LIMIT-K pushdown: merged runs are truncated to K, so run sizes stop growing at
K and each subsequent round halves the number of runs — a geometric series
bounded by O(N/m), giving O(N/m * (2 + log K/m)) total calls (Table 1).

Probe plan: Phase 1 is one ``RankWindows`` probe set (the paper's "in
parallel" run generation).  In Phase 2 every merge of a round advances in
lockstep — each step gathers the current window buffer of every unfinished
merge cursor and suspends as ONE ``RankWindows`` probe set, so a round costs
max-refills submissions instead of sum-of-refills, and the executor can
interleave these steps with other plans' rounds.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

from ..executor import RankWindows
from ..types import Key, SortSpec
from .base import AccessPath, PathParams, _log2, register


class _MergeCursor:
    """State of one in-flight two-way merge (Alg. 5): run pointers, emitted
    output, and the current window buffer awaiting an LLM ranking.  Encodes
    the emission/consistency-repair logic of the sequential Alg. 5 loop —
    including the LIMIT-K early stop at ``cap`` — so lockstep execution is
    call-for-call identical to merging the pairs one at a time."""

    def __init__(self, l1: list[Key], l2: list[Key], h: int,
                 cap: Optional[int] = None):
        self.l1, self.l2, self.h, self.cap = l1, l2, h, cap
        self.i = self.j = 0
        self.out: list[Key] = []
        self.done = False
        self._fast_forward()

    def _fast_forward(self) -> None:
        """Emit the tail without an oracle call once one run is exhausted;
        stop — issuing no further windows — once ``cap`` items are emitted
        (ranking positions past K can never reach the output)."""
        if self.done:
            return
        if self.cap is not None and len(self.out) >= self.cap:
            self.out = self.out[:self.cap]; self.done = True
        elif self.i >= len(self.l1):
            self.out.extend(self.l2[self.j:]); self.done = True
        elif self.j >= len(self.l2):
            self.out.extend(self.l1[self.i:]); self.done = True
        if self.done and self.cap is not None:
            self.out = self.out[:self.cap]

    def buffer(self) -> list[Key]:
        """The next window to rank (only valid while not done)."""
        t1 = min(self.h, len(self.l1) - self.i)
        t2 = min(self.h, len(self.l2) - self.j)
        return self.l1[self.i:self.i + t1] + self.l2[self.j:self.j + t2]

    def consume(self, ranked: list[Key]) -> None:
        """Apply one ranked buffer: emit until one side's buffered portion
        is exhausted, then advance the pointers.

        Consistency repair: the paper's emission loop advances each run's
        pointer by the COUNT of items emitted from that run, which implicitly
        assumes the LLM's buffer ranking preserves each run's internal order.
        A noisy ranking can invert two same-run items, double-emitting one
        and dropping another.  We therefore *project* the ranked order onto
        the runs: when the ranking says "next emit from run r", we emit run
        r's next unconsumed item (runs are already sorted, so for a faithful
        oracle this is the identity; under noise it guarantees the output is
        a permutation)."""
        t1 = min(self.h, len(self.l1) - self.i)
        t2 = min(self.h, len(self.l2) - self.j)
        in_l1 = {k.uid for k in self.l1[self.i:self.i + t1]}
        e1 = e2 = 0
        for x in ranked:
            if x.uid in in_l1:
                self.out.append(self.l1[self.i + e1])  # next unconsumed, run 1
                e1 += 1
            else:
                self.out.append(self.l2[self.j + e2])  # next unconsumed, run 2
                e2 += 1
            if e1 == t1 or e2 == t2:
                break  # one side exhausted within this window -> refill
        self.i += e1
        self.j += e2
        self._fast_forward()


@register("ext_merge")
class ExternalMergeSort(AccessPath):
    def _plan(self, keys: Sequence[Key], spec: SortSpec):
        keys = list(keys)
        m = max(2, self.params.batch_size)
        h = max(m // 2, 1)
        cap = spec.limit  # truncate merged runs at K (Sec. 3.3)
        if not keys:
            return []

        # Phase 1: run generation — independent listwise calls, one round.
        chunks = [keys[i:i + m] for i in range(0, len(keys), m)]
        runs: list[list[Key]] = yield RankWindows(chunks)
        if cap is not None:
            # LIMIT-K pushdown starts at the runs themselves: a run's item
            # at position >= K trails K earlier run-mates in every merge
            runs = [r[:cap] for r in runs]

        # Phase 2: iterative two-way merging in lockstep — each step gathers
        # the current buffer of every unfinished merge into one round.
        while len(runs) > 1:
            nxt: list[list[Key]] = []
            slots: list = []  # per output slot: cursor | carried run
            for i in range(0, len(runs), 2):
                if i + 1 < len(runs):
                    slots.append(_MergeCursor(runs[i], runs[i + 1], h, cap))
                else:
                    slots.append(runs[i])  # odd run carried forward
            while True:
                active = [c for c in slots
                          if isinstance(c, _MergeCursor) and not c.done]
                if not active:
                    break
                ranked = yield RankWindows([c.buffer() for c in active])
                for c, r in zip(active, ranked):
                    c.consume(r)
            for s in slots:
                merged = s.out if isinstance(s, _MergeCursor) else s
                if cap is not None:
                    merged = merged[:cap]  # incl. carried odd runs, so run
                    # sizes actually stop growing at K
                nxt.append(merged)
            runs = nxt
        return runs[0] if runs else []

    # ---- Table 1 --------------------------------------------------------------
    @classmethod
    def est_calls(cls, n: int, k: Optional[int], params: PathParams) -> float:
        m = max(2, params.batch_size)
        runs = math.ceil(n / m)
        if runs <= 1:
            return 1.0
        if k is None or k >= n:
            # run generation + log2(runs) merge rounds, each ~2N/m windows
            return runs * (1 + _log2(runs))
        return (n / m) * (2 + _log2(max(k, m) / m))
