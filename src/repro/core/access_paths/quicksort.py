"""Comparison-based quick sort (Sec. 3.2, Algorithms 2 & 3).

* ``votes = 1``  — vanilla LLM quick sort (Lotus-style).
* ``votes = v>1`` — quick sort with majority voting: each item is compared to
  the pivot *and* to ``v-1`` peers sampled from the opposite initial
  partition.  Unanimous items are placed immediately; conflicted items wait
  until their peers are firmly classified, then Algorithm 2's weighted vote
  decides (initial pivot comparison carries weight 1.5).  A deadlock (no
  waiting item has fully classified peers) is broken by voting with the
  current partial partitions.

LIMIT-K pushdown = partial quick sort (Martinez '04): only the prefix-covering
partitions are recursed into, giving O(v(N + K log K)) calls.
"""
from __future__ import annotations

import hashlib
import math
from typing import Optional, Sequence

import numpy as np

from ..types import Key, SortSpec
from .base import AccessPath, Ordering, PathParams, _log2, register


def _det_sample(pool: list[Key], k: int, seed_parts) -> list[Key]:
    if k <= 0 or not pool:
        return []
    # stable digest, NOT builtin hash(): str hashing is randomized per
    # process (PYTHONHASHSEED), which made peer sampling — and therefore
    # quick-sort outputs — vary run to run
    h = hashlib.blake2b(repr(seed_parts).encode(), digest_size=8)
    rng = np.random.default_rng(int.from_bytes(h.digest(), "little"))
    idx = rng.choice(len(pool), size=min(k, len(pool)), replace=False)
    return [pool[i] for i in idx]


@register("quick")
class QuickSort(AccessPath):
    """Set ``params.votes`` to 1 for vanilla, 3 for the paper's ``quick_3``."""

    def _order(self, keys, ordering: Ordering, spec: SortSpec) -> list[Key]:
        return self._sort(list(keys), ordering, spec.limit)

    # ---- recursive partial quick sort -------------------------------------
    def _sort(self, keys: list[Key], ordering: Ordering, limit: Optional[int]) -> list[Key]:
        if len(keys) <= 1:
            return keys
        if len(keys) == 2:
            a, b = keys
            return [a, b] if ordering.before(a, b) else [b, a]
        pivot, rest = keys[0], keys[1:]
        front, back = self._partition(pivot, rest, ordering)
        out = self._sort(front, ordering, limit)
        if limit is not None and len(out) >= limit:
            return out[:limit]
        out = out + [pivot]
        rem = None if limit is None else limit - len(out)
        if rem is None or rem > 0:
            out = out + self._sort(back, ordering, rem)
        return out

    # ---- Algorithm 3 partition ---------------------------------------------
    # Round structure: every comparison in the partition is independent once
    # its inputs are known, so the whole partition is at most TWO rounds —
    # round 1: all |rest| pivot comparisons; round 2: all peer votes (peers
    # are sampled from the round-1 split).  With ``coalesce`` each round is
    # one backend submission; otherwise the seed's sequential point calls.
    def _partition(self, pivot: Key, rest: list[Key], ordering: Ordering):
        v = self.params.votes
        coalesce = self.params.coalesce
        if coalesce:  # round 1: all pivot comparisons in one submission
            flags = ordering.before_many([(x, pivot) for x in rest])
            initial = {x.uid: f for x, f in zip(rest, flags)}
        else:
            initial = {x.uid: ordering.before(x, pivot) for x in rest}
        if v <= 1:
            front = [x for x in rest if initial[x.uid]]
            back = [x for x in rest if not initial[x.uid]]
            return front, back

        init_front = [x for x in rest if initial[x.uid]]
        init_back = [x for x in rest if not initial[x.uid]]

        # round 2: every item's peer votes (sampled from the opposite
        # round-1 partition) — all independent, one submission.
        peers_of: dict[int, list[Key]] = {}
        for x in rest:
            pool = init_back if initial[x.uid] else init_front
            peers_of[x.uid] = _det_sample(
                [y for y in pool if y.uid != x.uid], v - 1,
                ("qs-peers", x.uid, pivot.uid))
        if coalesce:
            flat = [(x, y) for x in rest for y in peers_of[x.uid]]
            flat_res = iter(ordering.before_many(flat))
            results_of = {x.uid: [next(flat_res) for _ in peers_of[x.uid]]
                          for x in rest}
        else:
            results_of = {x.uid: [ordering.before(x, y) for y in peers_of[x.uid]]
                          for x in rest}

        front: list[Key] = []
        back: list[Key] = []
        placed: dict[int, bool] = {}  # uid -> placed-in-front?
        deferred: list[tuple[Key, bool, list[Key], list[bool]]] = []

        for x in rest:
            r_init = initial[x.uid]
            peers = peers_of[x.uid]
            peer_results = results_of[x.uid]
            allres = [r_init] + peer_results
            if all(allres):
                front.append(x); placed[x.uid] = True
            elif not any(allres):
                back.append(x); placed[x.uid] = False
            else:
                deferred.append((x, r_init, peers, peer_results))

        # iterative resolution; Algorithm 2 vote once peers are classified
        while deferred:
            progressed = False
            still: list[tuple[Key, bool, list[Key], list[bool]]] = []
            for item in deferred:
                x, r_init, peers, peer_results = item
                if all(y.uid in placed for y in peers):
                    self._vote_place(item, placed, front, back)
                    progressed = True
                else:
                    still.append(item)
            deferred = still
            if deferred and not progressed:
                # deadlock: resolve the head with current partial partitions
                self._vote_place(deferred.pop(0), placed, front, back)

        return front, back

    @staticmethod
    def _vote_place(item, placed: dict[int, bool], front: list[Key], back: list[Key]):
        """Algorithm 2: weighted vote.  'front' plays the paper's L role."""
        x, r_init, peers, peer_results = item
        f_vote = 1.5 if r_init else 0.0
        b_vote = 0.0 if r_init else 1.5
        for y, r_y in zip(peers, peer_results):
            side = placed.get(y.uid)          # True=front, False=back, None=unplaced
            if side is True and r_y:          # y in L and x before y => x in L
                f_vote += 1.0
            elif side is False and not r_y:   # y in G and x after y => x in G
                b_vote += 1.0
        if f_vote > b_vote:
            front.append(x); placed[x.uid] = True
        else:
            back.append(x); placed[x.uid] = False

    # ---- Table 1 --------------------------------------------------------------
    @classmethod
    def est_calls(cls, n: int, k: Optional[int], params: PathParams) -> float:
        v = max(params.votes, 1)
        if k is None or k >= n:
            return v * n * _log2(n)
        return v * (n + k * _log2(k))
