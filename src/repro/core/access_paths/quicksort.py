"""Comparison-based quick sort (Sec. 3.2, Algorithms 2 & 3).

* ``votes = 1``  — vanilla LLM quick sort (Lotus-style).
* ``votes = v>1`` — quick sort with majority voting: each item is compared to
  the pivot *and* to ``v-1`` peers sampled from the opposite initial
  partition.  Unanimous items are placed immediately; conflicted items wait
  until their peers are firmly classified, then Algorithm 2's weighted vote
  decides (initial pivot comparison carries weight 1.5).  A deadlock (no
  waiting item has fully classified peers) is broken by voting with the
  current partial partitions.

LIMIT-K pushdown = partial quick sort (Martinez '04): only the prefix-covering
partitions are recursed into, giving O(v(N + K log K)) calls.

Probe plan: the recursion is flattened into a **wavefront over partitions**.
Every live subproblem (a segment awaiting partitioning, at its pivot or
peer-vote stage, or a 2-element segment awaiting its single comparison)
contributes its ready comparisons to ONE ``ComparePairs`` round per
scheduling step, so sibling partitions — which the old recursive form
serialized — advance together and the plan suspends ~2·depth times instead
of ~2·(#partitions).  Pruning is decided the moment a partition's split is
known: a child whose output offset falls at or past LIMIT K is never
expanded, exactly the calls the sequential recursion would skip.  The
comparison set (and therefore the ledger multiset) is identical to the
recursive form; only the round grouping changes.
"""
from __future__ import annotations

import hashlib
from typing import Optional, Sequence

import numpy as np

from ..executor import ComparePairs
from ..types import Key, SortSpec
from .base import AccessPath, PathParams, _log2, register


def _det_sample(pool: list[Key], k: int, seed_parts) -> list[Key]:
    if k <= 0 or not pool:
        return []
    # stable digest, NOT builtin hash(): str hashing is randomized per
    # process (PYTHONHASHSEED), which made peer sampling — and therefore
    # quick-sort outputs — vary run to run
    h = hashlib.blake2b(repr(seed_parts).encode(), digest_size=8)
    rng = np.random.default_rng(int.from_bytes(h.digest(), "little"))
    idx = rng.choice(len(pool), size=min(k, len(pool)), replace=False)
    return [pool[i] for i in idx]


def _flatten(piece, out: list) -> None:
    """In-order traversal of the nested slot tree built by the plan."""
    for item in piece:
        if isinstance(item, Key):
            out.append(item)
        else:
            _flatten(item, out)


@register("quick")
class QuickSort(AccessPath):
    """Set ``params.votes`` to 1 for vanilla, 3 for the paper's ``quick_3``."""

    # ---- wavefront probe plan ---------------------------------------------
    def _plan(self, keys: Sequence[Key], spec: SortSpec):
        keys = list(keys)
        if len(keys) <= 1:
            return keys
        out_root: list = []
        active: list[dict] = []

        def spawn(seg: list[Key], limit: Optional[int], slot: list) -> None:
            # a child whose local LIMIT budget is exhausted would only rank
            # positions >= K: never expanded (partial quick sort pruning)
            if limit is not None and limit <= 0:
                return
            if len(seg) <= 1:
                slot.append(list(seg))
                return
            stage = "pair" if len(seg) == 2 else "pivot"
            active.append({"keys": list(seg), "limit": limit, "slot": slot,
                           "stage": stage})

        spawn(keys, spec.limit, out_root)
        while active:
            current, active = active, []
            pairs: list = []
            spans: list[tuple[int, int]] = []
            for node in current:
                prs = self._node_pairs(node)
                spans.append((len(pairs), len(pairs) + len(prs)))
                pairs.extend(prs)
            flags = yield ComparePairs(pairs)
            for node, (i, j) in zip(current, spans):
                self._node_advance(node, flags[i:j], spawn, active)
        out: list[Key] = []
        _flatten(out_root, out)
        return out

    def _node_pairs(self, node: dict) -> list:
        """The comparisons this subproblem needs at its current stage."""
        if node["stage"] == "pair":
            a, b = node["keys"]
            return [(a, b)]
        if node["stage"] == "pivot":
            pivot, rest = node["keys"][0], node["keys"][1:]
            return [(x, pivot) for x in rest]
        return node["flat_peers"]          # stage == "peers"

    def _node_advance(self, node: dict, res: list, spawn, active: list) -> None:
        """Consume one round's results, then finalize or re-arm the node."""
        if node["stage"] == "pair":
            a, b = node["keys"]
            node["slot"].append([a, b] if res[0] else [b, a])
            return
        pivot, rest = node["keys"][0], node["keys"][1:]
        if node["stage"] == "pivot":
            initial = {x.uid: f for x, f in zip(rest, res)}
            if self.params.votes <= 1:
                front = [x for x in rest if initial[x.uid]]
                back = [x for x in rest if not initial[x.uid]]
                self._finalize(node, pivot, front, back, spawn)
                return
            # arm the peer-vote round: peers sampled from the opposite
            # initial partition (Algorithm 3)
            init_front = [x for x in rest if initial[x.uid]]
            init_back = [x for x in rest if not initial[x.uid]]
            peers_of: dict[int, list[Key]] = {}
            for x in rest:
                pool = init_back if initial[x.uid] else init_front
                peers_of[x.uid] = _det_sample(
                    [y for y in pool if y.uid != x.uid],
                    self.params.votes - 1, ("qs-peers", x.uid, pivot.uid))
            node["initial"] = initial
            node["peers_of"] = peers_of
            node["flat_peers"] = [(x, y) for x in rest
                                  for y in peers_of[x.uid]]
            node["stage"] = "peers"
            active.append(node)
            return
        # stage == "peers": Algorithm 2's deferred weighted-vote resolution
        flat_res = iter(res)
        results_of = {x.uid: [next(flat_res) for _ in node["peers_of"][x.uid]]
                      for x in rest}
        front, back = self._resolve_partition(
            rest, node["initial"], node["peers_of"], results_of)
        self._finalize(node, pivot, front, back, spawn)

    def _finalize(self, node: dict, pivot: Key, front: list[Key],
                  back: list[Key], spawn) -> None:
        """Split known: schedule both children (they run concurrently from
        the next round on) and prune everything past the LIMIT budget."""
        slot, limit = node["slot"], node["limit"]
        front_slot: list = []
        slot.append(front_slot)
        spawn(front, limit, front_slot)
        if limit is not None and len(front) >= limit:
            return                          # pivot and back land past K
        slot.append([pivot])
        rem = None if limit is None else limit - len(front) - 1
        back_slot: list = []
        slot.append(back_slot)
        spawn(back, rem, back_slot)

    # ---- Algorithm 2 vote resolution ---------------------------------------
    def _resolve_partition(self, rest: list[Key], initial: dict,
                           peers_of: dict, results_of: dict):
        front: list[Key] = []
        back: list[Key] = []
        placed: dict[int, bool] = {}  # uid -> placed-in-front?
        deferred: list[tuple[Key, bool, list[Key], list[bool]]] = []

        for x in rest:
            r_init = initial[x.uid]
            peers = peers_of[x.uid]
            peer_results = results_of[x.uid]
            allres = [r_init] + peer_results
            if all(allres):
                front.append(x); placed[x.uid] = True
            elif not any(allres):
                back.append(x); placed[x.uid] = False
            else:
                deferred.append((x, r_init, peers, peer_results))

        # iterative resolution; Algorithm 2 vote once peers are classified
        while deferred:
            progressed = False
            still: list[tuple[Key, bool, list[Key], list[bool]]] = []
            for item in deferred:
                x, r_init, peers, peer_results = item
                if all(y.uid in placed for y in peers):
                    self._vote_place(item, placed, front, back)
                    progressed = True
                else:
                    still.append(item)
            deferred = still
            if deferred and not progressed:
                # deadlock: resolve the head with current partial partitions
                self._vote_place(deferred.pop(0), placed, front, back)

        return front, back

    @staticmethod
    def _vote_place(item, placed: dict[int, bool], front: list[Key], back: list[Key]):
        """Algorithm 2: weighted vote.  'front' plays the paper's L role."""
        x, r_init, peers, peer_results = item
        f_vote = 1.5 if r_init else 0.0
        b_vote = 0.0 if r_init else 1.5
        for y, r_y in zip(peers, peer_results):
            side = placed.get(y.uid)          # True=front, False=back, None=unplaced
            if side is True and r_y:          # y in L and x before y => x in L
                f_vote += 1.0
            elif side is False and not r_y:   # y in G and x after y => x in G
                b_vote += 1.0
        if f_vote > b_vote:
            front.append(x); placed[x.uid] = True
        else:
            back.append(x); placed[x.uid] = False

    # ---- Table 1 --------------------------------------------------------------
    @classmethod
    def est_calls(cls, n: int, k: Optional[int], params: PathParams) -> float:
        v = max(params.votes, 1)
        if k is None or k >= n:
            return v * n * _log2(n)
        return v * (n + k * _log2(k))
