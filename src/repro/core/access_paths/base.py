"""Access-path base machinery.

Direction handling: every algorithm produces *output order* directly —
``result.order[0]`` is the first row the query returns; ``LIMIT K`` is always
``order[:K]``.  The :class:`Ordering` adapter folds ASC/DESC into the oracle
verbs so the algorithms themselves are direction-free:

 * ``sort_key(score)``   — lower sorts earlier in the output,
 * ``before(a, b)``      — True iff ``a`` must precede ``b`` in the output,
 * ``window(keys)``      — listwise window ranking in output order.

Probe plans: algorithms are *resumable* — each path's ``_plan`` generator
yields typed probe sets (``executor.ComparePairs`` / ``ScoreEach`` /
``ScoreBatches`` / ``RankWindows``) describing every call whose inputs are
already known, and suspends until the results come back at the yield point.
Solo execution drives one plan through ``executor.drive_plan``, resolving
each probe set with the matching :class:`Ordering` round verb
(``before_many``, ``scores_each``, ``scores_many``, ``windows``) — so the
retry/binary-split fallback and billing are the familiar synchronous
semantics, and the oracle still executes a round as one backend submission
where it can (ModelOracle: one padded prefill) and as a sequential loop
otherwise.  ``executor.ProbePlanExecutor`` drives many suspended plans at
once — concurrent queries, optimizer pilot candidates — merging same-kind
probes from different plans into shared serving submissions.  See DESIGN.md
"Probe-plan executor".

Cost models: Table 1 of the paper, used both for optimizer cost extrapolation
(Sec. 5.1) and for the Table-1 benchmark that checks our empirical call counts
against the asymptotics.
"""
from __future__ import annotations

import abc
import math
from dataclasses import dataclass
from typing import Callable, Optional, Sequence

from ..executor import drive_plan
from ..types import InvalidOutputError, Key, SortResult, SortSpec
from ..oracles.base import Oracle


class Ordering:
    """Direction-folding adapter over an Oracle, with retry/split fallback for
    structurally invalid listwise outputs (production behavior: one salted
    retry, then binary split).  Point verbs (``before``/``scores``/``window``)
    have round counterparts (``before_many``/``scores_each``/``scores_many``/
    ``windows``) that submit a whole set of independent calls at once,
    preserving the fallback per sub-batch."""

    def __init__(self, oracle: Oracle, spec: SortSpec):
        self.oracle = oracle
        self.spec = spec
        self.sign = -1.0 if spec.descending else 1.0

    # -- direction folding --------------------------------------------------
    # Shared by the synchronous round verbs below and by the executor's
    # deferred-round path (which reads raw oracle results back from the
    # scheduler drain and must apply the exact same fold).
    def fold_scores(self, raw: Sequence[float]) -> list[float]:
        return [self.sign * s for s in raw]

    def fold_compares(self, cmps: Sequence[int]) -> list[bool]:
        if self.spec.descending:
            return [c > 0 for c in cmps]
        return [c < 0 for c in cmps]

    def fold_window_result(self, ranked: Sequence[Key]) -> list[Key]:
        return list(reversed(ranked)) if self.spec.descending else list(ranked)

    # -- value-based ---------------------------------------------------------
    def scores(self, keys: Sequence[Key]) -> list[float]:
        """Sort keys ascending by these values to get output order."""
        raw = self._score_with_fallback(list(keys))
        return [self.sign * s for s in raw]

    def _score_with_fallback(self, keys: list[Key]) -> list[float]:
        try:
            return self.oracle.score_batch(keys, self.spec.criteria)
        except InvalidOutputError:
            if len(keys) == 1:
                raise
            return self._score_split(keys)

    def _score_split(self, keys: list[Key]) -> list[float]:
        """Binary-split re-derivation after a (billed) structural failure;
        only valid for len(keys) >= 2."""
        mid = len(keys) // 2
        return (self._score_with_fallback(keys[:mid])
                + self._score_with_fallback(keys[mid:]))

    def scores_each(self, keys: Sequence[Key]) -> list[float]:
        """One round of independent single-key scores (pointwise billing),
        executed as one backend submission where the oracle supports it.
        A single-key structural failure is unrecoverable (nothing to split),
        so it propagates as InvalidOutputError — matching the sequential
        pointwise loop — except that the whole round has already been
        attempted and billed by then, not just the keys before the failure."""
        keys = list(keys)
        if not keys:
            return []
        try:
            raw = self.oracle.try_score_each(keys, self.spec.criteria)
        except InvalidOutputError:  # wholesale backend failure: split round
            if len(keys) == 1:
                return self.scores(keys)  # point-call path (may re-raise)
            mid = len(keys) // 2
            return self.scores_each(keys[:mid]) + self.scores_each(keys[mid:])
        out = []
        for k, v in zip(keys, raw):
            if v is None:  # billed failure; nothing to split at size 1
                raise InvalidOutputError(
                    f"single-key score failed for uid={k.uid}")
            out.append(self.sign * v)
        return out

    def scores_many(self, chunks: Sequence[Sequence[Key]]) -> list[list[float]]:
        """One round of independent m-key scoring calls (external pointwise),
        one backend submission where supported.  Per-chunk failure isolation:
        only a structurally failing chunk takes the (already billed) binary
        split path, exactly as it would when executed sequentially."""
        chunks = [list(c) for c in chunks]
        if not chunks:
            return []
        try:
            raw = self.oracle.try_score_batches(chunks, self.spec.criteria)
        except InvalidOutputError:  # wholesale backend failure: split round
            if len(chunks) == 1:
                return [self.scores(chunks[0])]
            mid = len(chunks) // 2
            return self.scores_many(chunks[:mid]) + self.scores_many(chunks[mid:])
        out: list[list[float]] = []
        for c, vals in zip(chunks, raw):
            if vals is None:  # billed failure: split (or give up at size 1)
                if len(c) == 1:
                    raise InvalidOutputError(
                        f"single-key score failed for uid={c[0].uid}")
                vals = self._score_split(c)
            out.append([self.sign * s for s in vals])
        return out

    # -- pairwise --------------------------------------------------------------
    def before(self, a: Key, b: Key) -> bool:
        """True iff a precedes b in the output order."""
        cmp = self.oracle.compare(a, b, self.spec.criteria)  # +1: a larger
        return (cmp > 0) if self.spec.descending else (cmp < 0)

    def before_many(self, pairs: Sequence[tuple[Key, Key]]) -> list[bool]:
        """One round of independent comparisons — ``[a precedes b in output]``
        per pair — executed as one backend submission where the oracle
        supports it, with binary-split retry per sub-batch on failure."""
        pairs = list(pairs)
        if not pairs:
            return []
        try:
            cmps = self.oracle.compare_batch(pairs, self.spec.criteria)
        except InvalidOutputError:
            if len(pairs) == 1:
                return [self.before(*pairs[0])]
            mid = len(pairs) // 2
            return self.before_many(pairs[:mid]) + self.before_many(pairs[mid:])
        return self.fold_compares(cmps)

    # -- listwise ----------------------------------------------------------------
    def window(self, keys: Sequence[Key]) -> list[Key]:
        """Permutation of keys in output order (first = returned first)."""
        keys = list(keys)
        ranked = self._rank_with_fallback(keys)
        return list(reversed(ranked)) if self.spec.descending else ranked

    def windows(self, batches: Sequence[Sequence[Key]]) -> list[list[Key]]:
        """Batched windows (parallel run generation): one backend submission
        where the oracle supports it.  Per-window failure isolation
        (``try_rank_batches``): a structurally failing window takes its own
        (already billed) split path; its round-mates are not re-billed."""
        batches = [list(b) for b in batches]
        if not batches:
            return []
        try:
            ranked = self.oracle.try_rank_batches(batches, self.spec.criteria)
        except InvalidOutputError:  # wholesale backend failure: split round
            if len(batches) == 1:
                return [self.window(batches[0])]
            mid = len(batches) // 2
            return self.windows(batches[:mid]) + self.windows(batches[mid:])
        out: list[list[Key]] = []
        for b, r in zip(batches, ranked):
            if r is None:
                r = self._rank_split(b)
            out.append(self.fold_window_result(r))
        return out

    def _rank_with_fallback(self, keys: list[Key]) -> list[Key]:
        try:
            return self.oracle.rank_batch(keys, self.spec.criteria)
        except InvalidOutputError:
            return self._rank_split(keys)

    def _rank_split(self, keys: list[Key]) -> list[Key]:
        """Split re-ranking after a (billed) structural failure."""
        if len(keys) <= 2:
            # degrade to a pairwise comparison
            if len(keys) < 2:
                return keys
            a, b = keys
            return [a, b] if self.oracle.compare(a, b, self.spec.criteria) < 0 else [b, a]
        mid = len(keys) // 2
        lo = self._rank_with_fallback(keys[:mid])
        hi = self._rank_with_fallback(keys[mid:])
        # cheap interleave by a final attempt on the halves' concatenation:
        # merge by latent-free round-robin is meaningless, so re-rank halves
        # pairwise-merged via compare of run heads (bounded extra calls).
        out: list[Key] = []
        i = j = 0
        while i < len(lo) and j < len(hi):
            if self.oracle.compare(lo[i], hi[j], self.spec.criteria) < 0:
                out.append(lo[i]); i += 1
            else:
                out.append(hi[j]); j += 1
        out.extend(lo[i:]); out.extend(hi[j:])
        return out


@dataclass(frozen=True)
class PathParams:
    batch_size: int = 4      # m, for external paths
    votes: int = 1           # v, for quick sort
    max_batch: int = 32      # M cap in Alg. 1
    agreement: float = 0.9   # θ in Alg. 1
    agreement_atol: float = 0.35  # |Δscore| tolerance counted as agreement
    # Round batching: emit each level's independent oracle calls as one
    # backend submission (ModelOracle -> one padded prefill).  False restores
    # the seed's sequential point-call structure — same outputs under any
    # deterministic-per-prompt oracle, more serving submissions; kept as a
    # diagnostic baseline for benchmarks/table4_submissions.py.
    coalesce: bool = True


class AccessPath(abc.ABC):
    """One physical implementation of LLM ORDER BY."""

    name: str = "base"

    def __init__(self, params: PathParams = PathParams()):
        self.params = params

    @abc.abstractmethod
    def _plan(self, keys: Sequence[Key], spec: SortSpec):
        """Resumable probe plan: a generator that yields typed probe sets
        (``executor.ComparePairs`` / ``ScoreEach`` / ``ScoreBatches`` /
        ``RankWindows`` / ``SerialProbe``) and receives their
        direction-folded results at the yield point; returns keys in output
        order (may exceed ``spec.effective_limit``; ``execute`` truncates).
        The plan never touches the oracle itself, so its driver decides
        whether a round runs as one submission (solo), element-wise
        (``coalesce=False`` diagnostic baseline), or merged with other
        plans' rounds (``executor.ProbePlanExecutor``)."""

    def execute(self, keys: Sequence[Key], oracle: Oracle, spec: SortSpec) -> SortResult:
        """Solo synchronous execution: drive this path's plan to completion,
        resolving each probe set through :class:`Ordering`'s round verbs."""
        snap = oracle.ledger.snapshot()
        ordering = Ordering(oracle, spec)
        out = drive_plan(self._plan(list(keys), spec), ordering,
                         coalesce=self.params.coalesce)
        k = spec.effective_limit(len(keys))
        out = out[:k]
        view = oracle.ledger.since(snap)
        return SortResult(
            order=out, path=self.name, params=self.describe_params(),
            n_calls=view.n_calls, input_tokens=view.input_tokens,
            output_tokens=view.output_tokens, cost=view.cost(oracle.prices),
        )

    def describe_params(self) -> dict:
        return {"batch_size": self.params.batch_size, "votes": self.params.votes}

    # ---- Table 1 cost model ------------------------------------------------
    @classmethod
    @abc.abstractmethod
    def est_calls(cls, n: int, k: Optional[int], params: PathParams) -> float:
        """Expected number of LLM calls (Table 1)."""

    @classmethod
    def scale_factor(cls, n_full: int, n_sample: int, k: Optional[int],
                     params: PathParams) -> float:
        """Cost-extrapolation ratio used by the optimizer (Sec. 5.1,
        Examples 5.1/5.2): estimated_full = sampled_cost x this."""
        lo = cls.est_calls(n_sample, k, params)
        hi = cls.est_calls(n_full, k, params)
        return hi / max(lo, 1e-9)


_REGISTRY: dict[str, Callable[..., AccessPath]] = {}


def register(name: str):
    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def make_path(name: str, params: PathParams = PathParams()) -> AccessPath:
    return _REGISTRY[name](params)


def available_paths() -> list[str]:
    return sorted(_REGISTRY)


def _log2(x: float) -> float:
    return math.log2(max(x, 1.0))
