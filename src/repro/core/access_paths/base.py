"""Access-path base machinery.

Direction handling: every algorithm produces *output order* directly —
``result.order[0]`` is the first row the query returns; ``LIMIT K`` is always
``order[:K]``.  The :class:`Ordering` adapter folds ASC/DESC into the oracle
verbs so the algorithms themselves are direction-free:

 * ``sort_key(score)``   — lower sorts earlier in the output,
 * ``before(a, b)``      — True iff ``a`` must precede ``b`` in the output,
 * ``window(keys)``      — listwise window ranking in output order.

Cost models: Table 1 of the paper, used both for optimizer cost extrapolation
(Sec. 5.1) and for the Table-1 benchmark that checks our empirical call counts
against the asymptotics.
"""
from __future__ import annotations

import abc
import math
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

from ..types import InvalidOutputError, Key, SortResult, SortSpec
from ..oracles.base import Oracle


class Ordering:
    """Direction-folding adapter over an Oracle, with retry/split fallback for
    structurally invalid listwise outputs (production behavior: one salted
    retry, then binary split)."""

    def __init__(self, oracle: Oracle, spec: SortSpec):
        self.oracle = oracle
        self.spec = spec
        self.sign = -1.0 if spec.descending else 1.0

    # -- value-based ---------------------------------------------------------
    def scores(self, keys: Sequence[Key]) -> list[float]:
        """Sort keys ascending by these values to get output order."""
        raw = self._score_with_fallback(list(keys))
        return [self.sign * s for s in raw]

    def _score_with_fallback(self, keys: list[Key]) -> list[float]:
        try:
            return self.oracle.score_batch(keys, self.spec.criteria)
        except InvalidOutputError:
            if len(keys) == 1:
                raise
            mid = len(keys) // 2
            return (self._score_with_fallback(keys[:mid])
                    + self._score_with_fallback(keys[mid:]))

    # -- pairwise --------------------------------------------------------------
    def before(self, a: Key, b: Key) -> bool:
        """True iff a precedes b in the output order."""
        cmp = self.oracle.compare(a, b, self.spec.criteria)  # +1: a larger
        return (cmp > 0) if self.spec.descending else (cmp < 0)

    # -- listwise ----------------------------------------------------------------
    def window(self, keys: Sequence[Key]) -> list[Key]:
        """Permutation of keys in output order (first = returned first)."""
        keys = list(keys)
        ranked = self._rank_with_fallback(keys)
        return list(reversed(ranked)) if self.spec.descending else ranked

    def windows(self, batches: Sequence[Sequence[Key]]) -> list[list[Key]]:
        """Batched windows (parallel run generation): one backend submission
        where the oracle supports it, with per-window fallback on failure."""
        try:
            ranked = self.oracle.rank_batches([list(b) for b in batches],
                                              self.spec.criteria)
        except InvalidOutputError:
            return [self.window(b) for b in batches]
        if self.spec.descending:
            ranked = [list(reversed(r)) for r in ranked]
        return ranked

    def _rank_with_fallback(self, keys: list[Key]) -> list[Key]:
        try:
            return self.oracle.rank_batch(keys, self.spec.criteria)
        except InvalidOutputError:
            if len(keys) <= 2:
                # degrade to a pairwise comparison
                if len(keys) < 2:
                    return keys
                a, b = keys
                return [a, b] if self.oracle.compare(a, b, self.spec.criteria) < 0 else [b, a]
            mid = len(keys) // 2
            lo = self._rank_with_fallback(keys[:mid])
            hi = self._rank_with_fallback(keys[mid:])
            # cheap interleave by a final attempt on the halves' concatenation:
            # merge by latent-free round-robin is meaningless, so re-rank halves
            # pairwise-merged via compare of run heads (bounded extra calls).
            out: list[Key] = []
            i = j = 0
            while i < len(lo) and j < len(hi):
                if self.oracle.compare(lo[i], hi[j], self.spec.criteria) < 0:
                    out.append(lo[i]); i += 1
                else:
                    out.append(hi[j]); j += 1
            out.extend(lo[i:]); out.extend(hi[j:])
            return out


@dataclass(frozen=True)
class PathParams:
    batch_size: int = 4      # m, for external paths
    votes: int = 1           # v, for quick sort
    max_batch: int = 32      # M cap in Alg. 1
    agreement: float = 0.9   # θ in Alg. 1
    agreement_atol: float = 0.35  # |Δscore| tolerance counted as agreement


class AccessPath(abc.ABC):
    """One physical implementation of LLM ORDER BY."""

    name: str = "base"

    def __init__(self, params: PathParams = PathParams()):
        self.params = params

    @abc.abstractmethod
    def _order(self, keys: Sequence[Key], ordering: Ordering, spec: SortSpec) -> list[Key]:
        """Return keys in output order; may return only the first
        ``spec.effective_limit`` items when a limit pushdown applies."""

    def execute(self, keys: Sequence[Key], oracle: Oracle, spec: SortSpec) -> SortResult:
        snap = oracle.ledger.snapshot()
        ordering = Ordering(oracle, spec)
        out = self._order(list(keys), ordering, spec)
        k = spec.effective_limit(len(keys))
        out = out[:k]
        view = oracle.ledger.since(snap)
        return SortResult(
            order=out, path=self.name, params=self.describe_params(),
            n_calls=view.n_calls, input_tokens=view.input_tokens,
            output_tokens=view.output_tokens, cost=view.cost(oracle.prices),
        )

    def describe_params(self) -> dict:
        return {"batch_size": self.params.batch_size, "votes": self.params.votes}

    # ---- Table 1 cost model ------------------------------------------------
    @classmethod
    @abc.abstractmethod
    def est_calls(cls, n: int, k: Optional[int], params: PathParams) -> float:
        """Expected number of LLM calls (Table 1)."""

    @classmethod
    def scale_factor(cls, n_full: int, n_sample: int, k: Optional[int],
                     params: PathParams) -> float:
        """Cost-extrapolation ratio used by the optimizer (Sec. 5.1,
        Examples 5.1/5.2): estimated_full = sampled_cost x this."""
        lo = cls.est_calls(n_sample, k, params)
        hi = cls.est_calls(n_full, k, params)
        return hi / max(lo, 1e-9)


_REGISTRY: dict[str, Callable[..., AccessPath]] = {}


def register(name: str):
    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def make_path(name: str, params: PathParams = PathParams()) -> AccessPath:
    return _REGISTRY[name](params)


def available_paths() -> list[str]:
    return sorted(_REGISTRY)


def _log2(x: float) -> float:
    return math.log2(max(x, 1.0))
