"""Value-based access paths (Sec. 3.1).

* :class:`Pointwise` — one LLM call per key, O(N).
* :class:`ExternalPointwise` — m keys per call, O(N/m), with the
  agreement-based adaptive batch-size search of Algorithm 1 (O(log2 m) billed
  calls thanks to the client-side cache).

Both plans are single-round: every scoring call is independent, so the whole
derivation is ONE ``ScoreEach`` / ``ScoreBatches`` probe set.  Algorithm 1's
batch-size search is the one inherently *sequential* subroutine in the
access-path layer (each doubling decision depends on the previous round's
scores), so it is emitted as a ``SerialProbe`` — resolved immediately by its
driver, never merged across plans.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import numpy as np

from ..executor import ScoreBatches, ScoreEach, SerialProbe
from ..types import InvalidOutputError, Key, SortSpec
from ..oracles.cache import CachingOracle
from .base import AccessPath, Ordering, PathParams, register


def _stable_sort_by(keys: Sequence[Key], values: Sequence[float]) -> list[Key]:
    order = np.argsort(np.asarray(values, dtype=np.float64), kind="stable")
    return [keys[i] for i in order]


@register("pointwise")
class Pointwise(AccessPath):
    def _plan(self, keys: Sequence[Key], spec: SortSpec):
        keys = list(keys)
        vals = yield ScoreEach(keys)   # all N single-key calls: one round
        return _stable_sort_by(keys, vals)

    @classmethod
    def est_calls(cls, n: int, k: Optional[int], params: PathParams) -> float:
        return float(n)


@register("ext_pointwise")
class ExternalPointwise(AccessPath):
    """Batched value derivation with adaptive batch sizing (Algorithm 1)."""

    def choose_batch_size(self, keys: Sequence[Key], ordering: Ordering) -> int:
        """Algorithm 1: double m while merged per-batch scores agree with the
        combined 2m-batch scores.  Caching makes re-used prompts free."""
        p = self.params
        oracle = ordering.oracle
        cached = oracle if isinstance(oracle, CachingOracle) else CachingOracle(oracle)
        crit = ordering.spec.criteria
        m = 2
        while 2 * m < len(keys) and m < p.max_batch:
            b1 = list(keys[:m])
            b2 = list(keys[m:2 * m])
            b3 = b1 + b2
            try:
                # raw calls (no split-retry fallback): Alg. 1 must observe
                # structural failures and stop doubling
                v1 = cached.score_batch(b1, crit)
                v2 = cached.score_batch(b2, crit)
                v3 = cached.score_batch(b3, crit)
            except InvalidOutputError:
                break
            v12 = v1 + v2
            agree = sum(1 for a, b in zip(v12, v3) if abs(a - b) <= p.agreement_atol)
            alpha = agree / (2 * m)
            if alpha >= p.agreement:
                m *= 2
            else:
                return m
        return m

    def _plan(self, keys: Sequence[Key], spec: SortSpec):
        keys = list(keys)
        if self.params.batch_size == 0:
            m = yield SerialProbe(lambda o: self.choose_batch_size(keys, o))
        else:
            m = self.params.batch_size
        self._chosen_m = m
        chunks = [keys[i:i + m] for i in range(0, len(keys), m)]
        # all N/m m-key calls are independent: one round
        nested = yield ScoreBatches(chunks)
        vals = [v for vs in nested for v in vs]
        return _stable_sort_by(keys, vals)

    def describe_params(self) -> dict:
        d = super().describe_params()
        if getattr(self, "_chosen_m", None) is not None:
            d["chosen_batch_size"] = self._chosen_m
        return d

    @classmethod
    def est_calls(cls, n: int, k: Optional[int], params: PathParams) -> float:
        m = max(params.batch_size, 2)
        return math.ceil(n / m) + math.log2(m)  # scoring + Alg.1 probes
