"""Physical implementations (access paths) of LLM ORDER BY.

path             paper anchor
---------------  ------------------------------------------------------
pointwise        Sec. 3.1 — one scoring call per key
ext_pointwise    Sec. 3.1, Alg. 1 — m keys/call, adaptive batch size
quick            Sec. 3.2, Alg. 2 & 3 — pivot comparisons + peer voting
ext_bubble       Sec. 3.2 — RankGPT sliding-window passes
ext_merge        Sec. 3.2, Alg. 4 & 5 — semantic-aware external merge

Every path executes against the same Oracle verbs (semantic black box) and
emits *rounds* of independent calls for batched serving (DESIGN.md).
"""
from .base import (AccessPath, Ordering, PathParams, available_paths,
                   make_path, register)
from .pointwise import ExternalPointwise, Pointwise
from .quicksort import QuickSort
from .bubble import ExternalBubbleSort
from .merge import ExternalMergeSort

__all__ = [
    "AccessPath", "Ordering", "PathParams", "available_paths", "make_path",
    "register", "Pointwise", "ExternalPointwise", "QuickSort",
    "ExternalBubbleSort", "ExternalMergeSort",
]
