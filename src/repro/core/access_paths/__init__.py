"""Physical implementations (access paths) of LLM ORDER BY."""
from .base import (AccessPath, Ordering, PathParams, available_paths,
                   make_path, register)
from .pointwise import ExternalPointwise, Pointwise
from .quicksort import QuickSort
from .bubble import ExternalBubbleSort
from .merge import ExternalMergeSort

__all__ = [
    "AccessPath", "Ordering", "PathParams", "available_paths", "make_path",
    "register", "Pointwise", "ExternalPointwise", "QuickSort",
    "ExternalBubbleSort", "ExternalMergeSort",
]
