"""The paper's primary contribution: the LLM ORDER BY semantic operator,
its physical access paths, and the budget-aware access-path optimizer."""
from .types import InvalidOutputError, Key, SortResult, SortSpec, as_keys
from .operator import OrderQuery, Table, llm_order_by, llm_order_by_many
from .access_paths import (AccessPath, PathParams, available_paths, make_path)
from .executor import (ComparePairs, InquireEach, PlanCancelled,
                       ProbePlanExecutor, RankWindows, ScoreBatches,
                       ScoreEach, SerialProbe, drive_plan)
from .optimizer.optimizer import (AccessPathOptimizer, OptimizerConfig,
                                  OptimizerReport)
from .optimizer.cost_model import CandidateSpec, default_candidates
from .oracles.base import (CASCADE_70B, GPT41, LLAMA70B, LLAMA405B, Oracle,
                           PriceSheet, TieredPrices, TokenLedger)
from .oracles.simulated import (FACTUAL, REASONING, SENTIMENT, ExactOracle,
                                FlakyOracle, OracleProfile, SimulatedOracle)
from .oracles.cascade import (CascadeOracle, DRAFT_1p6B,
                              SimulatedCascadeOracle)
from .oracles.cache import CachingOracle
from . import datasets, metrics

__all__ = [
    "InvalidOutputError", "Key", "SortResult", "SortSpec", "as_keys",
    "OrderQuery", "Table", "llm_order_by", "llm_order_by_many",
    "AccessPath", "PathParams", "available_paths",
    "make_path", "ComparePairs", "InquireEach", "PlanCancelled",
    "ProbePlanExecutor", "RankWindows", "ScoreBatches", "ScoreEach",
    "SerialProbe", "drive_plan",
    "AccessPathOptimizer", "OptimizerConfig", "OptimizerReport",
    "CandidateSpec", "default_candidates", "Oracle", "PriceSheet",
    "TieredPrices", "TokenLedger", "GPT41", "LLAMA70B", "LLAMA405B",
    "CASCADE_70B", "FACTUAL", "REASONING", "SENTIMENT", "ExactOracle",
    "FlakyOracle", "OracleProfile", "SimulatedOracle", "CascadeOracle",
    "SimulatedCascadeOracle", "DRAFT_1p6B", "CachingOracle", "datasets",
    "metrics",
]
