"""Probe-plan executor: resumable access paths over a shared probe stream.

An access path no longer *calls* the oracle mid-algorithm; it *describes*
its next round of independent probes by yielding a typed probe set and
suspends until the results arrive at the yield point:

 * :class:`ComparePairs`  — pairwise comparisons; results are
   ``[a precedes b in the output]`` booleans (direction already folded),
 * :class:`ScoreEach`     — single-key pointwise scores (ascending sort of
   the returned values gives output order),
 * :class:`ScoreBatches`  — independent m-key scoring calls,
 * :class:`RankWindows`   — independent listwise windows, returned in
   output order,
 * :class:`InquireEach`   — membership inquiries (Prompt Block 4),
 * :class:`SerialProbe`   — escape hatch for inherently sequential,
   data-dependent subroutines (Alg. 1 adaptive batch sizing): resolved by
   calling ``fn(ordering)`` immediately and never merged across plans.

Solo execution (:meth:`AccessPath.execute`) drives a single plan through
:func:`drive_plan`, resolving each probe set with the matching
:class:`~repro.core.access_paths.base.Ordering` round verb — so the
retry/binary-split fallback, the billing convention, and the output are
exactly the PR-1 synchronous semantics (``Ordering``'s round verbs are the
thin synchronous adapter over single-plan execution).

Concurrent execution (:class:`ProbePlanExecutor`) drives any number of
plans in **ticks**: every tick, each suspended plan's ready probe set is
resolved once (fairness: no plan waits more than one tick behind its
round-mates), and on a deferred-capable backend (ModelOracle + a
``BatchScheduler``) all plans' rounds are begun as future-backed probe
work and the tick pumps ONE step of the unified serving loop — the rounds
ride that step's gap merged into shared length-bucketed submissions with
cross-plan dedup of identical prompts, while any in-flight decode rows
(judge rationales, another driver's generates) advance one token in the
same step instead of the tick waiting behind their drain.  Per-plan ledger
records are tracked even on a shared oracle, so a plan's accounting under
the executor is record-for-record identical to its solo run.  See
DESIGN.md "Probe-plan executor" and "Unified step loop".
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Sequence

from .oracles.base import CallRecord, LedgerView
from .types import InvalidOutputError, SortResult, SortSpec


# --------------------------------------------------------------- probe sets
@dataclass
class ComparePairs:
    """Result: ``[a precedes b in the output]`` per pair."""
    pairs: list  # [(Key, Key)]


@dataclass
class ScoreEach:
    """Result: one direction-folded score per key (pointwise billing)."""
    keys: list


@dataclass
class ScoreBatches:
    """Result: one direction-folded score list per chunk (one m-key call
    each — the external-pointwise billing regime)."""
    chunks: list  # [[Key]]


@dataclass
class RankWindows:
    """Result: each window's keys permuted into output order."""
    batches: list  # [[Key]]


@dataclass
class InquireEach:
    """Result: one membership boolean per key (no direction to fold)."""
    keys: list


@dataclass
class SerialProbe:
    """Sequential, data-dependent subroutine: resolved as ``fn(ordering)``
    the moment its plan is serviced; opaque to cross-plan merging."""
    fn: Callable


class PlanCancelled(RuntimeError):
    """A plan was cancelled by its driver (budget cut, short-circuit)."""


# ---------------------------------------------------------- sync resolution
def resolve_probes(ordering, ps, coalesce: bool = True):
    """Resolve one probe set against an :class:`Ordering` synchronously.

    ``coalesce=True`` uses the round verbs (one backend submission where the
    oracle supports it, retry/split fallback per sub-batch); ``coalesce=False``
    replays the seed's sequential point-call structure — same results under
    any deterministic-per-prompt oracle, same ledger multiset."""
    if isinstance(ps, ComparePairs):
        if coalesce:
            return ordering.before_many(ps.pairs)
        return [ordering.before(a, b) for a, b in ps.pairs]
    if isinstance(ps, ScoreEach):
        if coalesce:
            return ordering.scores_each(ps.keys)
        out = []
        for k in ps.keys:
            out.extend(ordering.scores([k]))
        return out
    if isinstance(ps, ScoreBatches):
        if coalesce:
            return ordering.scores_many(ps.chunks)
        return [ordering.scores(list(c)) for c in ps.chunks]
    if isinstance(ps, RankWindows):
        if coalesce:
            return ordering.windows(ps.batches)
        return [ordering.window(list(b)) for b in ps.batches]
    if isinstance(ps, InquireEach):
        crit = ordering.spec.criteria
        if coalesce:
            return ordering.oracle.inquire_batch(list(ps.keys), crit)
        return [ordering.oracle.inquire(k, crit) for k in ps.keys]
    if isinstance(ps, SerialProbe):
        return ps.fn(ordering)
    raise TypeError(f"unknown probe set {type(ps).__name__}")


def drive_plan(gen, ordering, coalesce: bool = True):
    """Drive one plan to completion synchronously (the solo adapter used by
    :meth:`AccessPath.execute`); returns the plan's return value."""
    try:
        ps = next(gen)
        while True:
            ps = gen.send(resolve_probes(ordering, ps, coalesce))
    except StopIteration as stop:
        return stop.value


# ----------------------------------------------------- deferred round glue
_DEFERRED_KIND = {
    ComparePairs: "compare",
    ScoreEach: "score_each",
    ScoreBatches: "score_batches",
    RankWindows: "rank_windows",
    InquireEach: "inquire",
}


def _deferred_payload(ps):
    if isinstance(ps, ComparePairs):
        return list(ps.pairs)
    if isinstance(ps, (ScoreEach, InquireEach)):
        return list(ps.keys)
    if isinstance(ps, ScoreBatches):
        return [list(c) for c in ps.chunks]
    if isinstance(ps, RankWindows):
        return [list(b) for b in ps.batches]
    return None


def _fold_raw(ordering, ps, raw):
    """Apply the Ordering direction fold to a deferred round's raw results —
    the same post-processing the synchronous round verbs perform."""
    if isinstance(ps, ComparePairs):
        return ordering.fold_compares(raw)
    if isinstance(ps, ScoreEach):
        return ordering.fold_scores(raw)
    if isinstance(ps, ScoreBatches):
        return [ordering.fold_scores(v) for v in raw]
    if isinstance(ps, RankWindows):
        return [ordering.fold_window_result(r) for r in raw]
    return raw


# ------------------------------------------------------------------- plans
class PlanRun:
    """One plan's execution state under the executor."""

    def __init__(self, name: str, gen, ordering, coalesce: bool = True,
                 path=None, tenant: str = "default"):
        self.name = name
        self.gen = gen
        self.ordering = ordering
        self.coalesce = coalesce
        self.path = path               # AccessPath instance (describe_params)
        self.tenant = tenant           # serving tenant class (TenantSpec)
        self.pending = None            # probe set awaiting resolution
        self.primed = False
        self.done = False
        self.result = None
        self.error: Optional[BaseException] = None
        self.records: list[CallRecord] = []   # this plan's ledger slice
        self.ticks = 0

    def cancel(self, reason: str = "cancelled") -> None:
        if self.done:
            return
        self.gen.close()
        self.done = True
        self.error = PlanCancelled(reason)

    # internal: advance the generator one step
    def _advance(self, value) -> None:
        try:
            self.pending = self.gen.send(value) if self.primed else next(self.gen)
            self.primed = True
        except StopIteration as stop:
            self.done = True
            self.result = stop.value
        except InvalidOutputError as e:
            # unrecoverable structural failure escaping the retry/split
            # fallback — exactly what a solo run would raise
            self.done = True
            self.error = e

    def _fail(self, e: BaseException) -> None:
        self.gen.close()
        self.done = True
        self.error = e


class ProbePlanExecutor:
    """Dataflow executor over any number of probe plans.

    Tick semantics: every tick, each live plan's pending probe set is
    resolved exactly once and the plan resumes with the results.  With a
    ``scheduler`` (a :class:`~repro.serving.scheduler.BatchScheduler`) and
    deferred-capable oracles (``begin_probe_round``/``finish_probe_round``
    — ModelOracle's logit probes, which cannot fail structurally), all
    plans' rounds of a tick are enqueued as future-backed probe work and
    ONE ``pump`` of the unified step loop services them: merged
    length-bucketed submissions, identical prompts deduplicated across
    plans, and any in-flight decode rows advancing alongside.  Oracles
    without deferred support (Simulated/Exact/Caching wrappers) resolve
    synchronously inside the tick — same interleaving, no serving-level
    merge.

    Billing: each plan's ledger records are captured per resolution, so
    ``run.records`` is record-for-record what a solo run of the same plan
    would have billed, even when plans share one oracle instance.

    Prefetch pipelining (``prefetch``, default on whenever a scheduler is
    attached): at the end of every tick — after plans advance and expose
    their NEXT pending probe sets — each deferrable plan's upcoming round
    is previewed (``ModelOracle.preview_round_prompts``, no billing) and
    the shared prefix regions worth warming
    (:func:`repro.serving.locality.prefetch_candidates`) are enqueued as
    ``PrefixFill`` work on the scheduler.  The fills ride the NEXT step
    gap of the unified loop — overlapping any in-flight decode — so when
    the round's probes arrive a tick later, their regions are already
    LRU-resident.  Pure serving-side warm-up: routing, results, and
    ledgers are untouched (only candidate regions the routing policy
    would cache anyway are filled).
    """

    def __init__(self, scheduler=None, prefetch: Optional[bool] = None,
                 tenant_budgets: Optional[dict] = None):
        self.scheduler = scheduler
        self.prefetch = (scheduler is not None if prefetch is None
                         else prefetch and scheduler is not None)
        self.prefetches = 0            # PrefixFill work items enqueued
        self.runs: list[PlanRun] = []
        self.ticks = 0
        # per-tenant LEDGER budgets (billed input+output tokens): a tenant
        # whose plans' combined ledger slices cross its budget has every
        # remaining plan cancelled before the next round begins.  Merged
        # with the scheduler's TenantSpec.ledger_budget entries; an
        # explicit mapping here wins per name.
        self.tenant_budgets = dict(tenant_budgets or {})
        self.budget_cancelled = 0      # plans cancelled by a ledger budget

    # ------------------------------------------------------------- submit
    def submit_plan(self, gen, ordering, name: str = "",
                    coalesce: bool = True, path=None,
                    tenant: str = "default") -> PlanRun:
        run = PlanRun(name or f"plan-{len(self.runs)}", gen, ordering,
                      coalesce=coalesce, path=path, tenant=tenant)
        self.runs.append(run)
        return run

    def submit_path(self, path, keys, oracle, spec: SortSpec,
                    name: str = "", tenant: str = "default") -> PlanRun:
        """Convenience: submit one access path's plan on ``keys``."""
        from .access_paths.base import Ordering
        ordering = Ordering(oracle, spec)
        return self.submit_plan(path._plan(list(keys), spec), ordering,
                                name=name or path.name,
                                coalesce=path.params.coalesce, path=path,
                                tenant=tenant)

    # ---------------------------------------------------- ledger budgets
    def _ledger_budget(self, tenant: str) -> Optional[int]:
        if tenant in self.tenant_budgets:
            return self.tenant_budgets[tenant]
        specs = getattr(self.scheduler, "tenants", None)
        if specs and tenant in specs:
            return specs[tenant].ledger_budget
        return None

    def _tenant_billed(self, tenant: str) -> int:
        """Billed tokens (input + output) across this executor's runs of
        one tenant — the per-plan ledger slices, so a shared oracle bills
        each tenant only for its own plans' records."""
        return sum(r.input_tokens + r.output_tokens
                   for run in self.runs if run.tenant == tenant
                   for r in run.records)

    def _enforce_ledger_budgets(self, live: list) -> list:
        out = []
        for run in live:
            budget = self._ledger_budget(run.tenant)
            if budget is not None and self._tenant_billed(run.tenant) >= budget:
                run.cancel(f"tenant {run.tenant!r} ledger budget "
                           f"({budget} tokens) exhausted")
                self.budget_cancelled += 1
                continue
            out.append(run)
        return out

    # --------------------------------------------------------------- ticks
    def _can_defer(self, run: PlanRun, ps) -> bool:
        return (self.scheduler is not None and run.coalesce
                and type(ps) in _DEFERRED_KIND
                and hasattr(run.ordering.oracle, "begin_probe_round"))

    def tick(self) -> bool:
        """One scheduling tick; returns True while any plan remains live."""
        live = []
        for run in self.runs:
            if run.done:
                continue
            if not run.primed:
                run._advance(None)
            if not run.done:
                live.append(run)
        live = self._enforce_ledger_budgets(live)
        if not live:
            return False
        self.ticks += 1
        deferred: list[tuple[PlanRun, object, object]] = []
        ready: list[tuple[PlanRun, object]] = []
        for run in live:
            run.ticks += 1
            ps = run.pending
            ledger = run.ordering.oracle.ledger
            snap = ledger.snapshot()
            if self._can_defer(run, ps):
                payload = _deferred_payload(ps)
                token = run.ordering.oracle.begin_probe_round(
                    _DEFERRED_KIND[type(ps)], payload,
                    run.ordering.spec.criteria, self.scheduler)
                run.records.extend(ledger.records[snap:])
                deferred.append((run, ps, token))
                continue
            try:
                value = resolve_probes(run.ordering, ps, run.coalesce)
            except InvalidOutputError as e:
                run.records.extend(ledger.records[snap:])
                run._fail(e)
                continue
            run.records.extend(ledger.records[snap:])
            ready.append((run, value))
        if deferred:
            # ONE pump of the live loop for the whole tick: every deferred
            # plan's probes ride the next step gap in shared length-bucketed
            # submissions (identical prompts deduped across plans), and any
            # in-flight decode rows — a judge rationale generation, another
            # driver's rows — advance one token in the same step instead of
            # the tick waiting behind their drain.  begin_probe_round has
            # already billed and enqueued every round, so each token MUST be
            # finished even when the pump or an earlier fold raises: the
            # finally drain collects abandoned rounds so no billed probes
            # stay queued in the scheduler behind a propagating error
            pending = list(deferred)
            try:
                self.scheduler.pump()
                while pending:
                    run, ps, token = pending.pop(0)
                    raw = run.ordering.oracle.finish_probe_round(
                        token, self.scheduler)
                    # cascade rounds bill their escalation wave mid-pump;
                    # the token carries those records for exact per-plan
                    # attribution (drafts landed at begin time above)
                    run.records.extend(getattr(token, "extra_records", ()))
                    ready.append((run, _fold_raw(run.ordering, ps, raw)))
            finally:
                for run, _ps, token in pending:
                    try:
                        run.ordering.oracle.finish_probe_round(
                            token, self.scheduler)
                    except Exception:
                        pass  # best-effort drain on the error path
                    run.records.extend(getattr(token, "extra_records", ()))
        for run, value in ready:
            run._advance(value)
        if self.prefetch:
            self._prefetch_next_rounds()
        return any(not r.done for r in self.runs)

    def _prefetch_next_rounds(self) -> None:
        """Peek every live plan's NEXT pending probe set and enqueue
        prefix fills for the regions it will share, so the warm-ups ride
        the step gap(s) between this tick and the round's own service
        step (class docstring)."""
        prompts: list = []
        for run in self.runs:
            ps = run.pending
            if run.done or ps is None or not self._can_defer(run, ps):
                continue
            oracle = run.ordering.oracle
            if not hasattr(oracle, "preview_round_prompts"):
                continue
            prompts.extend(oracle.preview_round_prompts(
                _DEFERRED_KIND[type(ps)], _deferred_payload(ps),
                run.ordering.spec.criteria))
        if not prompts:
            return
        from ..serving.locality import prefetch_candidates
        fills = prefetch_candidates(self.scheduler.engine, prompts)
        if fills:
            self.scheduler.submit_prefix_fill(fills)
            self.prefetches += 1

    def run(self, on_tick: Optional[Callable] = None) -> list[PlanRun]:
        """Tick until every plan completes.  ``on_tick(self)`` runs after
        each tick and may submit new plans or cancel running ones."""
        while True:
            progressed = self.tick()
            if on_tick is not None:
                on_tick(self)
            if not progressed and all(r.done for r in self.runs):
                break
        return self.runs


def attach_scheduler(oracles: Sequence, scheduler) -> list:
    """Point each oracle that rides ``scheduler``'s engine (and has no
    scheduler of its own) at the shared live loop, so oracle-side
    generations (judge rationales) decode through it.  Returns the list of
    oracles actually attached — pass it to :func:`detach_scheduler` when
    the driving call ends, so a LATER call with a fresh scheduler
    re-attaches instead of pumping a stale loop."""
    attached = []
    if scheduler is None:
        return attached
    for o in oracles:
        if (o is not None and getattr(o, "scheduler", None) is None
                and getattr(o, "engine", None) is scheduler.engine):
            o.scheduler = scheduler
            attached.append(o)
    return attached


def detach_scheduler(attached: Sequence) -> None:
    for o in attached:
        o.scheduler = None


def attach_memo(oracles: Sequence, memo) -> list:
    """Point each deferred-capable oracle without a memo of its own at the
    shared :class:`~repro.core.oracles.cache.SemanticMemo`.  Returns the
    oracles actually attached — pass to :func:`detach_memo` when the
    driving call ends (the memo itself outlives the call; only the
    attachment is scoped)."""
    attached = []
    if memo is None:
        return attached
    for o in oracles:
        if (o is not None and hasattr(o, "begin_probe_round")
                and getattr(o, "memo", None) is None):
            o.memo = memo
            attached.append(o)
    return attached


def detach_memo(attached: Sequence) -> None:
    for o in attached:
        o.memo = None


def auto_scheduler(oracles: Sequence):
    """Build a shared probe queue (``BatchScheduler``) when every
    deferred-capable oracle in ``oracles`` rides one engine; None otherwise
    (plans still interleave tick-by-tick, rounds resolve synchronously
    per plan)."""
    engines = {}
    drafts = {}
    for o in oracles:
        if (hasattr(o, "begin_probe_round")
                and getattr(o, "engine", None) is not None):
            engines[id(o.engine)] = o.engine
            d = getattr(o, "draft_engine", None)
            if d is not None:
                drafts[id(d)] = d
    if len(engines) != 1 or len(drafts) > 1:
        return None
    from ..serving.scheduler import BatchScheduler
    (engine,) = engines.values()
    return BatchScheduler(engine,
                          draft_engine=next(iter(drafts.values()), None))


# ----------------------------------------------------------------- results
def plan_sort_result(run: PlanRun, spec: SortSpec, n_keys: int,
                     prices) -> SortResult:
    """Build the :class:`SortResult` a solo ``AccessPath.execute`` would
    have returned, from a finished plan's output and per-plan records."""
    if run.error is not None:
        raise run.error
    view = LedgerView(list(run.records))
    k = spec.effective_limit(n_keys)
    return SortResult(
        order=list(run.result)[:k],
        path=run.path.name if run.path is not None else run.name,
        params=run.path.describe_params() if run.path is not None else {},
        n_calls=view.n_calls, input_tokens=view.input_tokens,
        output_tokens=view.output_tokens, cost=view.cost(prices),
    )
