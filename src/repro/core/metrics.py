"""Ranking-quality metrics: Kendall's tau and nDCG@k.

Pure numpy (no scipy in this container).  Conventions follow the paper:
 * Kendall's tau for full-sort benchmarks (NBA heights, world population),
 * nDCG@10 for LIMIT-K / passage-ranking benchmarks (DL19/DL20, TweetEval).
"""
from __future__ import annotations

import math
from typing import Mapping, Sequence

import numpy as np

from .types import Key


def kendall_tau(order: Sequence[Key], descending: bool = False) -> float:
    """Kendall tau-a between a produced order and the latent ground truth.

    ``order`` is the output order of an access path.  For ascending sorts the
    ideal has latents non-decreasing along the list.  Returns in [-1, 1].
    """
    z = np.asarray([k.latent for k in order], dtype=np.float64)
    if descending:
        z = -z
    n = z.shape[0]
    if n < 2:
        return 1.0
    diff = z[None, :] - z[:, None]          # diff[i, j] = z_j - z_i
    upper = np.triu_indices(n, k=1)
    d = diff[upper]
    concordant = np.count_nonzero(d > 0)
    discordant = np.count_nonzero(d < 0)
    total = n * (n - 1) / 2
    return float((concordant - discordant) / total)


def kendall_tau_between(a_uids: Sequence[int], b_uids: Sequence[int]) -> float:
    """Kendall tau between two permutations of the same uid set."""
    pos_b = {u: i for i, u in enumerate(b_uids)}
    ranks = np.asarray([pos_b[u] for u in a_uids], dtype=np.float64)
    n = len(ranks)
    if n < 2:
        return 1.0
    diff = ranks[None, :] - ranks[:, None]
    upper = np.triu_indices(n, k=1)
    d = diff[upper]
    concordant = np.count_nonzero(d > 0)
    discordant = np.count_nonzero(d < 0)
    return float((concordant - discordant) / (n * (n - 1) / 2))


def graded_relevance(keys: Sequence[Key], n_grades: int = 4, descending: bool = True) -> dict[int, int]:
    """TREC-style graded relevance derived from latent values.

    The best ``~n/10`` items get the top grade and grades fall off
    geometrically, imitating DL19/DL20 qrel sparsity (most passages grade 0).
    """
    ordered = sorted(keys, key=lambda k: k.latent, reverse=descending)
    n = len(ordered)
    rel: dict[int, int] = {}
    # geometric buckets: top 5% -> n_grades-1, next 10% -> n_grades-2, ...
    bounds = []
    frac = 0.05
    for g in range(n_grades - 1, 0, -1):
        bounds.append((g, frac))
        frac *= 2
    idx = 0
    for g, f in bounds:
        hi = min(n, idx + max(1, int(round(f * n))))
        for k in ordered[idx:hi]:
            rel[k.uid] = g
        idx = hi
    for k in ordered[idx:]:
        rel[k.uid] = 0
    return rel


def dcg(rels: Sequence[float]) -> float:
    return float(sum(r / math.log2(i + 2) for i, r in enumerate(rels)))


def ndcg_at_k(order: Sequence[Key], relevance: Mapping[int, float], k: int = 10) -> float:
    """nDCG@k of a produced order against a graded relevance map."""
    got = [relevance.get(key.uid, 0.0) for key in order[:k]]
    ideal = sorted(relevance.values(), reverse=True)[:k]
    idcg = dcg(ideal)
    if idcg == 0.0:
        return 0.0
    return dcg(got) / idcg


def ndcg_between(order_uids: Sequence[int], gold_uids: Sequence[int], k: int = 10) -> float:
    """nDCG@k of one ranking against another ranking used as a proxy gold.

    Positions in ``gold_uids`` are converted to graded gains (first item
    highest).  Used by the pessimistic (Borda) optimizer to score candidates
    against the consensus gold list.
    """
    n = len(gold_uids)
    gains = {u: float(n - i) for i, u in enumerate(gold_uids)}
    got = [gains.get(u, 0.0) for u in order_uids[:k]]
    ideal = sorted(gains.values(), reverse=True)[:k]
    idcg = dcg(ideal)
    if idcg == 0.0:
        return 0.0
    return dcg(got) / idcg
