"""The budget-aware access-path optimizer (Sec. 5).

Pipeline (choose_and_execute):
  1. draw a deterministic sample of ``sample_size`` keys;
  2. **world-knowledge gate + pilot runs** — one probe-plan executor drives
     the Inquiry-Prompt round (Sec. 5.2) AND every candidate's sample run
     *concurrently*: the gate's inquiries ride the same scheduling tick as
     the candidates' first rounds, and on a ModelOracle backend all plans'
     probes merge into shared serving submissions instead of the pilots
     starving the engine between each candidate's rounds.  100% membership
     cancels the pilots and executes pointwise directly (the speculative
     first pilot rounds are the price of overlapping the gate with
     sampling); otherwise each surviving candidate's sampled cost and
     sample ranking come from its per-plan ledger slice
     (failed/structurally-invalid candidates are dropped);
  4. **cost extrapolation** — scale sampled cost by the Table-1 complexity
     ratio; filter candidates whose estimated full-run cost violates the
     user budget (Sec. 5.1/5.3, Fig. 5);
  5. **selection** — 'judge' (optimistic, Sec. 5.4; the judge's candidate
     probes ride one batched submission on the ModelOracle), 'borda'
     (pessimistic, Sec. 5.5), or 'oracle' (ground-truth upper-bound used in
     Table 3);
  6. execute the winner once over the full dataset.

Budget-capped sampling under concurrency: with no budget every candidate is
admitted at tick 1 (maximum merging).  With a budget, sampling must be
spend-observed — the FIRST candidate is still admitted cheapest-first and
run to completion so the cost model can calibrate.  From then on admission
is *predictive*: completed pilots yield a measured $/est_call rate
(``cost_model.dollars_per_est_call``), each remaining candidate's sample
spend is predicted as ``est_calls x rate`` (``predict_sample_cost``), and
additional pilots are co-admitted while observed spend plus every
in-flight candidate's FULL prediction stays under
``budget * sampling_fraction`` — overlapped pilots merge their probe
rounds into shared serving submissions, and cap overshoot is bounded by
prediction error instead of whole in-flight pilots (regression-pinned in
tests/test_optimizer.py).  ``pilot_overlap=False`` restores the strictly
serial wait-for-each-pilot semantics.  Once spend crosses the cap with at
least one successful sample, the rest are dropped ("sampling-budget").
The gate round always overlaps the first candidate.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from ..access_paths.base import Ordering
from ..executor import (PlanCancelled, ProbePlanExecutor, attach_scheduler,
                        auto_scheduler, detach_scheduler, plan_sort_result)
from ..metrics import kendall_tau, kendall_tau_between, ndcg_between, ndcg_at_k
from ..types import Key, SortResult, SortSpec
from ..oracles.base import LedgerView, Oracle
from .borda import borda_consensus
from .cost_model import (CandidateSpec, default_candidates,
                         dollars_per_est_call, est_sample_calls,
                         estimate_full_cost, ladder_candidates,
                         predict_sample_cost)
from .judge import judge_select
from .membership import membership_plan

COMPARISON_KINDS = ("quick", "ext_bubble", "ext_merge")


@dataclass
class OptimizerConfig:
    sample_size: int = 20
    budget: Optional[float] = None
    # "borda" | "judge" | "oracle" pick ONE path (the paper's optimizer);
    # "consensus" (beyond-paper) executes the top-``consensus_k`` affordable
    # candidates on the full dataset and Borda-merges their output rankings —
    # trading surplus budget for ensemble robustness at execution time.
    strategy: str = "borda"
    consensus_k: int = 2
    membership_threshold: float = 1.0
    # Budget-filter safety margins (beyond-paper hardening).  The paper notes
    # (Sec. 6.3) that an underestimated algorithm "can lead to a direct
    # violation of the user's budget constraint" — and quick-sort-family
    # estimates indeed run ~2x low under noisy comparators (deferred-vote
    # rounds + deeper recursion are invisible at sample scale).  Estimates
    # are reported raw; filtering multiplies them by these factors.
    safety_comparison: float = 2.0
    safety_value: float = 1.1
    # Sampling may consume at most this fraction of the budget (candidates
    # are sampled cheapest-first; the rest are dropped unsampled).  Without
    # this, a tight budget is blown during stage 2 before anything executes.
    sampling_fraction: float = 0.35
    # Predictive pilot overlap under a budget: once one pilot has completed
    # (calibrating a measured $/est_call rate), additional pilots are
    # co-admitted while observed spend + Σ in-flight predictions stays
    # under the sampling cap.  False restores strictly serial sampling
    # (admit one, wait for its full observed cost).
    pilot_overlap: bool = True
    # Model-cascade ladder (core/oracles/cascade.py): when the oracle
    # supports ``at_threshold`` and thresholds are given, the candidate
    # pool is expanded with a cascade variant of every path per threshold
    # — the optimizer then picks a (path, rung, threshold) tuple under
    # the same budget, with $/est_call calibrated per rung.  Ignored for
    # oracles without a cascade ladder.
    ladder_thresholds: Optional[Sequence[float]] = None
    seed: int = 0


@dataclass
class OptimizerReport:
    chosen: Optional[CandidateSpec] = None
    reason: str = ""
    membership_rate: float = 0.0
    sample_uids: list = field(default_factory=list)
    sample_results: dict = field(default_factory=dict)   # label -> SortResult
    est_costs: dict = field(default_factory=dict)        # label -> $ estimate
    sample_scores: dict = field(default_factory=dict)    # label -> selection score
    in_budget: list = field(default_factory=list)
    dropped: list = field(default_factory=list)          # (label, why)
    optimizer_cost: float = 0.0
    execution_cost: float = 0.0
    # peak number of pilot candidates in flight in one tick — > 1 under a
    # budget means predictive overlap engaged (no-budget runs admit all)
    max_concurrent_pilots: int = 0

    @property
    def total_cost(self) -> float:
        return self.optimizer_cost + self.execution_cost


class AccessPathOptimizer:
    def __init__(self, config: OptimizerConfig = OptimizerConfig(),
                 candidates: Optional[list[CandidateSpec]] = None):
        self.config = config
        self.candidates = candidates if candidates is not None else default_candidates()

    # ------------------------------------------------------------------ utils
    def _sample(self, keys: Sequence[Key]) -> list[Key]:
        s = min(self.config.sample_size, len(keys))
        rng = np.random.default_rng(self.config.seed)
        idx = rng.choice(len(keys), size=s, replace=False)
        return [keys[i] for i in sorted(idx)]

    @staticmethod
    def _rank_similarity(candidate: SortResult, gold_uids: list[int],
                         spec: SortSpec) -> float:
        """kendall tau for full sorts, nDCG@K for LIMIT-K queries — matching
        the benchmark's own objective (Sec. 6.1)."""
        uids = candidate.uids()
        if spec.limit is not None:
            return ndcg_between(uids, gold_uids, k=spec.limit)
        return kendall_tau_between(uids, gold_uids)

    # ------------------------------------------------------------- selection
    def _select(self, pool: list[CandidateSpec], sample: list[Key],
                spec: SortSpec, report: OptimizerReport,
                judge_oracle: Oracle) -> CandidateSpec:
        if len(pool) == 1:
            if not report.reason:
                report.reason = "single-candidate"
            return pool[0]
        strategy = self.config.strategy

        if strategy == "judge":
            orders = [report.sample_results[c.label].order for c in pool]
            win = judge_select(sample, spec.criteria, orders, judge_oracle)
            report.reason = "judge"
            return pool[int(win)]

        if strategy == "oracle":
            # ground-truth selection (Table 3 upper bound): best sample metric
            best, best_v = pool[0], -math.inf
            for c in pool:
                order = report.sample_results[c.label].order
                if spec.limit is not None:
                    from ..metrics import graded_relevance
                    rel = graded_relevance(sample, descending=spec.descending)
                    v = ndcg_at_k(order, rel, k=min(spec.limit, len(sample)))
                else:
                    v = kendall_tau(order, descending=spec.descending)
                report.sample_scores[c.label] = v
                if v > best_v:
                    best, best_v = c, v
            report.reason = "oracle"
            return best

        # default: pessimistic Borda consensus (Sec. 5.5)
        ballots = [report.sample_results[c.label].uids()
                   for c in pool if c.comparison_based]
        if not ballots:  # all-value-based pool (e.g. tight budget): best vs each other
            ballots = [report.sample_results[c.label].uids() for c in pool]
        universe = [k.uid for k in sample]
        gold = borda_consensus(ballots, universe)
        best, best_v = pool[0], -math.inf
        for c in pool:
            v = self._rank_similarity(report.sample_results[c.label], gold, spec)
            report.sample_scores[c.label] = v
            if v > best_v:
                best, best_v = c, v
        report.reason = "borda"
        return best

    # ------------------------------------------------------------- main entry
    def choose_and_execute(self, keys: Sequence[Key], oracle: Oracle,
                           spec: SortSpec,
                           judge_oracle: Optional[Oracle] = None,
                           scheduler=None
                           ) -> tuple[SortResult, OptimizerReport]:
        """Run the whole pipeline on a private executor.  This is a thin
        wrapper over :class:`OptimizerDriver` — the SAME incremental code
        path ``llm_order_by_many(path="auto")`` drives on its shared
        executor — so a solo auto query and one riding a many-query tick
        stream produce byte-identical ledgers by construction."""
        keys = list(keys)
        sched = scheduler if scheduler is not None else auto_scheduler([oracle])
        # the pilot phase drives the SAME live serving loop everything else
        # rides: deferred rounds resolve in its step gaps, and any
        # oracle-side generation (judge rationales) co-schedules with them.
        # Scoped to this call — detached in the finally below, so repeat
        # optimizations never pump a stale loop.
        attached = attach_scheduler([oracle, judge_oracle], sched)
        try:
            ex = ProbePlanExecutor(scheduler=sched)
            driver = OptimizerDriver(self, keys, oracle, spec,
                                     judge_oracle=judge_oracle, executor=ex)
            ex.run(on_tick=driver.on_tick)
            return driver.result, driver.report
        finally:
            detach_scheduler(attached)


class OptimizerDriver:
    """The optimizer pipeline as an incremental driver over an EXTERNAL
    :class:`~repro.core.executor.ProbePlanExecutor`.

    Every stage that used to block — waiting for the pilots, then
    executing the winner synchronously — is instead advanced from
    ``on_tick``: the membership gate and pilot plans are submitted up
    front, each tick runs the budget-capped admission policy (the
    docstring at the top of this module), and once the pilots settle the
    selection stages run inline and the winner is submitted as one more
    plan on the same executor.  ``llm_order_by_many`` gives each auto
    query its own driver on ONE shared executor, so N optimizer queries'
    pilot rounds (and full executions) merge into the same serving
    submissions as everything else — per-query admission control is just
    each driver's own cap arithmetic over its own oracle's ledger."""

    def __init__(self, opt: AccessPathOptimizer, keys: Sequence[Key],
                 oracle: Oracle, spec: SortSpec,
                 judge_oracle: Optional[Oracle] = None, executor=None,
                 tenant: str = "default", name: str = "auto"):
        cfg = opt.config
        self.opt = opt
        self.cfg = cfg
        self.keys = list(keys)
        self.oracle = oracle
        self.spec = spec
        self.judge_oracle = judge_oracle
        self.ex = executor
        self.tenant = tenant
        self.name = name
        self.report = OptimizerReport()
        self.snap = oracle.ledger.snapshot()
        self.sample = opt._sample(self.keys)
        self.report.sample_uids = [k.uid for k in self.sample]
        # stages 1+2: gate + pilot candidates on the shared executor — the
        # gate's inquiry round and every candidate's sample run advance
        # together, their ready probes merging into shared serving drains.
        self.sample_spec = SortSpec(spec.criteria, spec.descending,
                                    None if spec.limit is None
                                    else min(spec.limit, len(self.sample)))
        self.k_s = (None if spec.limit is None
                    else min(spec.limit, len(self.sample)))
        self.sample_cap = (None if cfg.budget is None
                           else cfg.budget * cfg.sampling_fraction)
        pool = opt.candidates
        if cfg.ladder_thresholds and hasattr(oracle, "at_threshold"):
            pool = ladder_candidates(pool, list(cfg.ladder_thresholds))
        self.backlog = sorted(
            pool,
            key=lambda c: est_sample_calls(c, len(self.sample), self.k_s))
        self.pilots: list[tuple[CandidateSpec, object]] = []
        # rate$ is the global $/est_call calibration; rung$ holds per-rung
        # rates (cascade rungs run cheaper per call than large-only)
        self.state: dict = {"member": False, "rate$": None, "rung$": {}}
        self.gate = self.ex.submit_plan(
            membership_plan(self.sample), Ordering(oracle, spec),
            name=f"{name}:membership", tenant=tenant)
        # no budget: every pilot rides the gate's tick; budget: cheapest
        # rides it, the rest are admitted predictively while under the cap
        self._admit(len(self.backlog) if self.sample_cap is None else 1)
        self.phase = "pilots"
        self.exec_runs: list = []
        self._consensus_take: list[CandidateSpec] = []
        self._consensus_queue: list[CandidateSpec] = []
        self.result: Optional[SortResult] = None
        self.done = False

    # ------------------------------------------------------------- helpers
    def _oracle_for(self, cand: CandidateSpec) -> Oracle:
        """The oracle a candidate's plans run on: a cascade rung view for
        ladder candidates (shared ledger/engines, so _spent() still sees
        every dollar), the base oracle otherwise."""
        if cand.threshold is None:
            return self.oracle
        return self.oracle.at_threshold(cand.threshold)

    def _admit(self, n: int) -> None:
        while self.backlog and n > 0:
            cand = self.backlog.pop(0)
            self.pilots.append((cand, self.ex.submit_path(
                cand.make(), self.sample, self._oracle_for(cand),
                self.sample_spec, name=cand.label, tenant=self.tenant)))
            n -= 1

    def _spent(self) -> float:
        return self.oracle.ledger.since(self.snap).cost(self.oracle.prices)

    def _sampled_cost(self, run) -> float:
        return LedgerView(list(run.records)).cost(self.oracle.prices)

    def _predicted(self, cand) -> float:
        # per-rung rate when that rung has a completed pilot, else the
        # global rate — a cascade rung's first pilot is predicted off the
        # pooled rate (conservative: large-only rates overestimate it)
        rate = self.state["rung$"].get(cand.rung, self.state["rate$"])
        return predict_sample_cost(cand, len(self.sample), self.k_s, rate)

    def _submit_exec(self, cand: CandidateSpec) -> None:
        self.exec_runs.append(self.ex.submit_path(
            cand.make(), self.keys, self._oracle_for(cand), self.spec,
            name=f"{self.name}:exec:{cand.label}", tenant=self.tenant))

    # ---------------------------------------------------------------- tick
    def on_tick(self, _ex=None) -> None:
        if self.done:
            return
        if self.phase == "pilots":
            self._pilot_tick()
            if (self.gate.done and not self.backlog
                    and all(r.done for _c, r in self.pilots)):
                self._transition()
        if self.phase == "execute" and all(r.done for r in self.exec_runs):
            if self._consensus_queue:     # serial consensus chain
                self._submit_exec(self._consensus_queue.pop(0))
            else:
                self._finish()

    def _pilot_tick(self) -> None:
        cfg, report, state = self.cfg, self.report, self.state
        report.max_concurrent_pilots = max(
            report.max_concurrent_pilots,
            sum(1 for _c, r in self.pilots if not r.done))
        if self.gate.done and "rate" not in state:
            if self.gate.error is not None:
                # a structurally failing gate propagated before the
                # executor refactor; keep that contract rather than
                # reading a silent 0.0 membership rate
                raise self.gate.error
            state["rate"] = self.gate.result
            report.membership_rate = state["rate"]
            if state["rate"] >= cfg.membership_threshold:
                state["member"] = True           # Sec. 5.2 short-circuit
                for _c, run in self.pilots:
                    run.cancel("membership short-circuit")
                self.backlog.clear()
                return
        if self.sample_cap is None or not self.backlog:
            return
        # Budget-capped sampling is spend-observed: the cap check sees
        # completed pilots' full sampled costs, and once spend crosses
        # the cap with one successful sample the rest are dropped.
        spent_now = self._spent()
        succeeded = any(r.done and r.error is None for _c, r in self.pilots)
        inflight = [(c, r) for c, r in self.pilots if not r.done]
        if spent_now >= self.sample_cap and succeeded:
            for cand in self.backlog:
                report.dropped.append((cand.label, "sampling-budget"))
            self.backlog.clear()
            return
        # serial floor (exactly the pre-overlap semantics): with
        # nothing in flight and headroom left, admit the next cheapest
        # regardless of prediction — prediction may only ADD overlap,
        # never starve a candidate the serial policy would have sampled
        if not inflight:
            self._admit(1)
            inflight = [self.pilots[-1]]
        if not cfg.pilot_overlap:
            return
        # predictive overlap: calibrate $/est_call on completed pilots,
        # then co-admit while observed spend + every in-flight
        # candidate's FULL predicted sample cost fits under the cap —
        # overshoot is bounded by prediction error, not by whole
        # in-flight pilots (ROADMAP "budgeted-pilot overlap")
        completed = [(c, self._sampled_cost(r)) for c, r in self.pilots
                     if r.done and r.error is None]
        state["rate$"] = dollars_per_est_call(
            completed, len(self.sample), self.k_s)
        rungs = {c.rung for c, _cost in completed}
        state["rung$"] = {
            rung: dollars_per_est_call(
                [(c, cost) for c, cost in completed if c.rung == rung],
                len(self.sample), self.k_s)
            for rung in rungs}
        if state["rate$"] is None:
            return                          # uncalibrated: stay serial
        committed = spent_now + sum(self._predicted(c) for c, _r in inflight)
        while (self.backlog
               and committed + self._predicted(self.backlog[0])
               <= self.sample_cap):
            committed += self._predicted(self.backlog[0])
            self._admit(1)

    # -------------------------------------------------- stages 3-5 inline
    def _transition(self) -> None:
        cfg, report = self.cfg, self.report
        self.phase = "execute"
        if self.state["member"]:
            report.chosen = CandidateSpec("pointwise")
            report.reason = "membership"
            report.optimizer_cost = self._spent()
            self._submit_exec(report.chosen)
            return
        alive: list[CandidateSpec] = []
        for cand, run in self.pilots:
            if run.error is not None:
                why = (str(run.error) if isinstance(run.error, PlanCancelled)
                       else f"invalid-output: {run.error}")
                report.dropped.append((cand.label, why))
                continue
            # the run's per-plan ledger slice IS its sampled cost — identical
            # records to a solo execute() of the same candidate
            res = plan_sort_result(run, self.sample_spec, len(self.sample),
                                   self.oracle.prices)
            report.sample_results[cand.label] = res
            est = estimate_full_cost(cand, res.cost, len(self.sample),
                                     len(self.keys), self.spec.limit)
            report.est_costs[cand.label] = est
            alive.append(cand)

        # -- stage 3: budget filter ---------------------------------------
        spent = self._spent()
        in_budget = []
        for cand in alive:
            est = report.est_costs[cand.label]
            margin = (cfg.safety_comparison if cand.comparison_based
                      else cfg.safety_value)
            if cfg.budget is not None and spent + est * margin > cfg.budget:
                report.dropped.append(
                    (cand.label, f"over-budget est=${est:.3f}x{margin:g}"))
            else:
                in_budget.append(cand)
        if not in_budget and alive:
            # nothing affordable: degrade to the cheapest estimate
            cheapest = min(alive, key=lambda c: report.est_costs[c.label])
            in_budget = [cheapest]
            report.reason = "budget-forced-cheapest"
        report.in_budget = [c.label for c in in_budget]
        if not in_budget:
            raise RuntimeError("no runnable candidate access path")

        # -- stage 4: selection ---------------------------------------------
        if cfg.strategy == "consensus":
            self._consensus_transition(in_budget, spent)
            return
        chosen = self.opt._select(
            in_budget, self.sample, self.spec, report,
            self.judge_oracle if self.judge_oracle is not None
            else self.oracle)
        report.chosen = chosen
        report.optimizer_cost = self._spent()
        # -- stage 5: full execution rides the shared executor --------------
        self._submit_exec(chosen)

    def _consensus_transition(self, pool: list, spent: float) -> None:
        """Beyond-paper consensus: rank the affordable pool by sample-level
        Borda agreement, then execute the top-k serially (each full run is
        one plan; the next is submitted when the previous finishes, so the
        shared ledger's record order matches the old synchronous loop) and
        Borda-merge their outputs in :meth:`_finish`."""
        cfg, report = self.cfg, self.report
        ranked_pool = list(pool)
        if len(pool) > 1:
            ballots = [report.sample_results[c.label].uids()
                       for c in pool if c.comparison_based] or \
                      [report.sample_results[c.label].uids() for c in pool]
            gold = borda_consensus(ballots, [k.uid for k in self.sample])
            scores = {c.label: self.opt._rank_similarity(
                report.sample_results[c.label], gold, self.spec)
                for c in pool}
            report.sample_scores.update(scores)
            ranked_pool.sort(key=lambda c: -scores[c.label])
        # greedily take candidates while the budget holds
        take: list[CandidateSpec] = []
        est_sum = 0.0
        for c in ranked_pool:
            est = report.est_costs[c.label]
            if len(take) < cfg.consensus_k and (
                    cfg.budget is None
                    or spent + est_sum + est <= cfg.budget):
                take.append(c)
                est_sum += est
        if not take:
            take = [ranked_pool[0]]
        report.chosen = take[0]
        report.reason = "consensus:" + "+".join(c.label for c in take)
        report.optimizer_cost = spent
        self._consensus_take = take
        self._consensus_queue = take[1:]
        self._submit_exec(take[0])

    def _finish(self) -> None:
        report = self.report
        results = [plan_sort_result(run, self.spec, len(self.keys),
                                    self.oracle.prices)
                   for run in self.exec_runs]
        report.execution_cost = sum(r.cost for r in results)
        if len(results) == 1:
            self.result = results[0]
        else:                             # consensus Borda merge
            universe = [k.uid for k in self.keys]
            merged_uids = borda_consensus([r.uids() for r in results],
                                          universe)
            by_uid = {k.uid: k for k in self.keys}
            k_eff = self.spec.effective_limit(len(self.keys))
            self.result = SortResult(
                order=[by_uid[u] for u in merged_uids[:k_eff]],
                path="consensus(" + "+".join(r.path for r in results) + ")",
                n_calls=sum(r.n_calls for r in results),
                input_tokens=sum(r.input_tokens for r in results),
                output_tokens=sum(r.output_tokens for r in results),
                cost=report.execution_cost,
            )
        self.done = True
