"""Cost estimation (Sec. 5.1).

The optimizer runs every candidate on a small sample, observes the *actual*
dollar cost, then extrapolates to the full dataset by scaling with the Table-1
call-complexity ratio (Examples 5.1 / 5.2: pointwise scales linearly, external
bubble quadratically, ...).  ``estimate_full_cost`` is that scaling; the
Table-2 benchmark validates it against true execution cost.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from ..access_paths.base import PathParams, _REGISTRY


@dataclass(frozen=True)
class CandidateSpec:
    """One entry of the optimizer's candidate pool.

    ``rung``/``threshold`` are the model-cascade ladder dimension: a
    candidate with ``threshold`` set is executed on
    ``oracle.at_threshold(threshold)`` (draft-first rounds, escalating
    low-margin probes), so the optimizer explores (path, rung, threshold)
    tuples under one budget.  ``threshold=None`` is the plain large-model
    candidate.  ``rung`` groups candidates that share a $/est_call rate —
    cascade rungs are cheaper per call than large-only, so the pilot
    phase calibrates each rung separately."""

    path: str                      # registry name ("pointwise", "ext_merge", ...)
    params: PathParams = PathParams()
    label: str = ""
    rung: str = ""                 # rate-calibration group ("" = large-only)
    threshold: Optional[float] = None  # cascade escalation threshold

    def __post_init__(self):
        if self.threshold is not None and not self.rung:
            object.__setattr__(self, "rung", f"t{self.threshold:g}")
        if not self.label:
            object.__setattr__(self, "label", self.default_label())

    def default_label(self) -> str:
        if self.path == "quick":
            base = ("quick" if self.params.votes <= 1
                    else f"quick_{self.params.votes}")
        elif self.path.startswith("ext_") and self.path != "ext_pointwise":
            base = f"{self.path}_{self.params.batch_size}"
        else:
            base = self.path
        if self.threshold is not None:
            base += f"@t{self.threshold:g}"
        return base

    @property
    def comparison_based(self) -> bool:
        return self.path in ("quick", "ext_bubble", "ext_merge")

    def make(self):
        return _REGISTRY[self.path](self.params)


def default_candidates(min_batch: int = 4) -> list[CandidateSpec]:
    """The paper's pool: both value-based paths plus all comparison-based
    paths at their *minimum viable batch size* (Sec. 5.3: the test-time
    scaling insight says bigger batches only trade quality for cost inside
    one path, so the pool explores paths, not batch sizes)."""
    return [
        CandidateSpec("pointwise"),
        CandidateSpec("ext_pointwise", PathParams(batch_size=min_batch)),
        CandidateSpec("quick", PathParams(votes=1)),
        CandidateSpec("quick", PathParams(votes=3)),
        CandidateSpec("ext_bubble", PathParams(batch_size=min_batch)),
        CandidateSpec("ext_merge", PathParams(batch_size=min_batch)),
    ]


def ladder_candidates(pool: "list[CandidateSpec]",
                      thresholds: "list[float]") -> "list[CandidateSpec]":
    """Expand a candidate pool along the cascade ladder: the original
    large-only candidates plus, for every escalation threshold, a cascade
    variant of each path.  Call complexity (Table 1) is threshold-invariant
    — a cascade round issues the same logical calls, only cheaper ones —
    so ``est_calls`` stays path-driven and the per-rung $/est_call rate
    carries the whole cost difference."""
    out = list(pool)
    for t in thresholds:
        out.extend(CandidateSpec(c.path, c.params, threshold=float(t))
                   for c in pool)
    return out


def estimate_full_cost(spec: CandidateSpec, sampled_cost: float,
                       n_sample: int, n_full: int, k: Optional[int]) -> float:
    """sampled_cost x complexity(N, K) / complexity(n_sample, K_sample)."""
    cls = _REGISTRY[spec.path]
    k_sample = None if k is None else min(k, n_sample)
    lo = cls.est_calls(n_sample, k_sample, spec.params)
    hi = cls.est_calls(n_full, k, spec.params)
    return sampled_cost * hi / max(lo, 1e-9)


def est_sample_calls(spec: CandidateSpec, n_sample: int,
                     k: Optional[int]) -> float:
    """Table-1 call-complexity of one candidate's SAMPLE run — the
    denominator of :func:`estimate_full_cost` and the per-candidate call
    predictor behind budget-capped pilot overlap."""
    k_sample = None if k is None else min(k, n_sample)
    return _REGISTRY[spec.path].est_calls(n_sample, k_sample, spec.params)


def dollars_per_est_call(observed: "list[tuple[CandidateSpec, float]]",
                         n_sample: int, k: Optional[int]) -> Optional[float]:
    """Measured $/est_call over completed pilot runs: total observed
    sampled cost divided by total Table-1 estimated calls.  ``observed``
    is [(candidate, actual sampled $)]; returns None until at least one
    pilot has completed (the predictor is uncalibrated)."""
    if not observed:
        return None
    total_cost = sum(cost for _spec, cost in observed)
    total_calls = sum(est_sample_calls(spec, n_sample, k)
                      for spec, _cost in observed)
    return total_cost / max(total_calls, 1e-9)


def predict_sample_cost(spec: CandidateSpec, n_sample: int, k: Optional[int],
                        rate: float) -> float:
    """Predicted sample-run spend of a not-yet-run candidate: its Table-1
    sample call complexity times the measured $/est_call ``rate``.  Used by
    the optimizer to admit OVERLAPPING pilots under a budget cap — a
    candidate is co-admitted only while observed spend plus every in-flight
    candidate's full prediction stays under the cap, so overshoot is
    bounded by prediction error rather than by whole in-flight pilots."""
    return est_sample_calls(spec, n_sample, k) * rate
