"""World-knowledge gate via membership inference (Sec. 5.2).

Runs the Inquiry Prompt (Prompt Block 4) on a sample; if 100% of sampled keys
are recognized as training-corpus members, the optimizer short-circuits to the
pointwise path — the model is acting as a reliable knowledge retriever and the
derived values probe parametric memory directly.
"""
from __future__ import annotations

from typing import Sequence

from ..executor import InquireEach
from ..types import Key
from ..oracles.base import Oracle


def membership_plan(sample: Sequence[Key]):
    """Probe-plan form of the gate: the whole sample's inquiries are ONE
    ``InquireEach`` round, so under the optimizer's pilot executor the gate
    rides the same scheduling tick (and, on a ModelOracle backend, the same
    merged serving drain) as the candidates' first rounds.  Returns the
    membership rate."""
    sample = list(sample)
    if not sample:
        return 0.0
    hits = yield InquireEach(sample)
    return sum(hits) / len(sample)


def membership_rate(sample: Sequence[Key], oracle: Oracle, criteria: str) -> float:
    if not sample:
        return 0.0
    # one round: all inquiries are independent, so the ModelOracle executes
    # them as a single padded serving submission (billed per key)
    hits = sum(oracle.inquire_batch(list(sample), criteria))
    return hits / len(sample)


def is_world_knowledge(sample: Sequence[Key], oracle: Oracle, criteria: str,
                       threshold: float = 1.0) -> tuple[bool, float]:
    """Strict threshold (default 100%): false negatives merely fall back to
    the Judge/Borda stages, false positives would mis-route reasoning queries
    to an uncalibrated pointwise scorer."""
    rate = membership_rate(sample, oracle, criteria)
    return rate >= threshold, rate
