"""Borda-count consensus aggregation (Sec. 5.5).

Each candidate ranking is a ballot: the item in output position ``p`` of a
ballot over ``s`` items receives ``s - p`` points (truncated ballots award 0
to unlisted items — standard partial-ballot Borda, Emerson '13).  Summing over
ballots yields the *gold ranking*: the collective preference used as a proxy
source of truth.

This numpy implementation is the semantic reference for the
``kernels/borda_count`` Pallas kernel (one-hot matmul formulation on TPU).
"""
from __future__ import annotations

from typing import Sequence

import numpy as np


def borda_scores(ballots: Sequence[Sequence[int]], universe: Sequence[int]) -> dict[int, float]:
    """Total Borda points per uid over all ballots."""
    scores = {u: 0.0 for u in universe}
    for ballot in ballots:
        s = len(ballot)
        for pos, uid in enumerate(ballot):
            if uid in scores:
                scores[uid] += float(s - pos)
    return scores


def borda_consensus(ballots: Sequence[Sequence[int]], universe: Sequence[int]) -> list[int]:
    """Gold ranking: uids by descending total points (uid tie-break)."""
    scores = borda_scores(ballots, universe)
    return sorted(universe, key=lambda u: (-scores[u], u))


def borda_matrix(ballots_idx: np.ndarray, n_items: int) -> np.ndarray:
    """Vectorized points-per-item from an (R, S) index matrix of ballots.

    ``ballots_idx[r, p]`` is the item index at position p of ballot r; -1 pads
    truncated ballots.  Mirrors the kernels/borda_count layout exactly.
    """
    r, s = ballots_idx.shape
    pts = np.zeros(n_items, dtype=np.float64)
    pos_pts = np.arange(s, 0, -1, dtype=np.float64)  # s - p
    for i in range(r):
        valid = ballots_idx[i] >= 0
        np.add.at(pts, ballots_idx[i][valid], pos_pts[valid])
    return pts
