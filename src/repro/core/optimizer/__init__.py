from .optimizer import (AccessPathOptimizer, OptimizerConfig,
                        OptimizerDriver, OptimizerReport)
from .cost_model import CandidateSpec, default_candidates, estimate_full_cost
from .borda import borda_consensus, borda_matrix, borda_scores
from .membership import is_world_knowledge, membership_rate
from .judge import judge_select

__all__ = ["AccessPathOptimizer", "OptimizerConfig", "OptimizerDriver",
           "OptimizerReport",
           "CandidateSpec", "default_candidates", "estimate_full_cost",
           "borda_consensus", "borda_matrix", "borda_scores",
           "is_world_knowledge", "membership_rate", "judge_select"]
