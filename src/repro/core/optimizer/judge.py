"""LLM-as-Judge candidate selection (Sec. 5.4, Prompt Block 5).

The judge oracle (paper: always the strongest model, Llama3.1-405b) sees the
sampled keys, the ranking criteria, and every candidate's output ranking, and
returns the identifier of the best-sorted candidate.  Long prompts degrade
judge reliability (Sec. 6.2) — the simulated oracle models that as noise
proportional to prompt length.
"""
from __future__ import annotations

from typing import Sequence

from ..types import Key
from ..oracles.base import Oracle


def judge_select(sample: Sequence[Key], criteria: str,
                 candidate_orders: Sequence[Sequence[Key]],
                 judge_oracle: Oracle) -> int:
    """Index of the winning candidate according to the judge."""
    return judge_oracle.judge(list(sample), criteria,
                              [list(c) for c in candidate_orders])
