"""Assigned-architecture configs (one module per arch) + registry.

``--arch <id>`` ids use dashes (as assigned); module names use underscores.
Each module exposes ``full()`` (the exact assigned hyper-parameters; only
instantiated abstractly via the dry-run) and ``reduced()`` (same family,
small dims; used by CPU smoke tests).
"""
from .registry import ARCH_IDS, get_config, get_reduced, list_archs

__all__ = ["ARCH_IDS", "get_config", "get_reduced", "list_archs"]
