"""xlstm-1.3b [ssm] — 48L d_model=2048 4H d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks.  [arXiv:2405.04517; unverified]

Layout: 6 groups of (7 mLSTM + 1 sLSTM) — the paper's ~7:1 interleave made
scan-homogeneous.  mLSTM uses matrix memory with v head_dim 512 and q/k
head_dim 256 (the paper's 0.5 qk projection factor); no FFN (d_ff=0), the
gated projections live inside the blocks."""
from repro.models.config import ModelConfig, grouped_pattern


def full() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b", family="ssm",
        n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=50304,
        pattern=grouped_pattern(6, ("mlstm", 7), ("slstm", 1)),
        head_dim=512, qk_dim=256,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke", family="ssm",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=512,
        pattern=grouped_pattern(1, ("mlstm", 2), ("slstm", 1)),
        head_dim=16, qk_dim=8,
        scan_chunk=8,
    )
