"""Arch id -> config registry (``--arch <id>`` everywhere)."""
from __future__ import annotations

from importlib import import_module

from repro.models.config import ModelConfig

_MODULES = {
    "minicpm-2b": "repro.configs.minicpm_2b",
    "phi4-mini-3.8b": "repro.configs.phi4_mini_3p8b",
    "stablelm-1.6b": "repro.configs.stablelm_1p6b",
    "llama3-8b": "repro.configs.llama3_8b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "qwen2-vl-7b": "repro.configs.qwen2_vl_7b",
    "hymba-1.5b": "repro.configs.hymba_1p5b",
    "xlstm-1.3b": "repro.configs.xlstm_1p3b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    return import_module(_MODULES[arch]).full()


def get_reduced(arch: str) -> ModelConfig:
    return import_module(_MODULES[arch]).reduced()


def list_archs() -> list[str]:
    return list(ARCH_IDS)


# Model-cascade rung order (core/oracles/cascade.py): draft-first probe
# execution runs wave 1 on an early rung's engine and escalates low-margin
# rows to a later rung.  Ordered smallest to largest.
_LADDER = ("stablelm-1.6b", "llama3-8b", "mixtral-8x22b")


def ladder() -> list[str]:
    """Arch ids of the draft→large cascade ladder, smallest first."""
    return list(_LADDER)
