"""minicpm-2b [dense] — 40L d_model=2304 36H (GQA kv=36) d_ff=5760
vocab=122753 — WSD schedule (wired in training/optimizer.py), MiniCPM
depth-scaled residuals + scaled/tied embeddings.  [arXiv:2404.06395; hf]"""
import math

from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="minicpm-2b", family="dense",
        n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36,
        d_ff=5760, vocab_size=122753,
        pattern=(("attn", 40),),
        rope_theta=10_000.0,
        tie_embeddings=True,
        embed_scale=12.0,
        residual_scale=1.4 / math.sqrt(40),
        logit_scale=256.0 / 2304.0,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="minicpm-2b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=160, vocab_size=512,
        pattern=(("attn", 2),),
        rope_theta=10_000.0,
        tie_embeddings=True,
        embed_scale=12.0,
        residual_scale=1.4 / math.sqrt(2),
        logit_scale=256.0 / 2304.0,
        scan_chunk=8,
    )
