"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""
from repro.models.config import ModelConfig, MoESpec


def full() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab_size=32000,
        pattern=(("moe_swa", 32),),
        moe=MoESpec(n_experts=8, top_k=2, capacity_factor=1.25),
        sliding_window=4096,
        rope_theta=1_000_000.0,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=112, vocab_size=512,
        pattern=(("moe_swa", 2),),
        moe=MoESpec(n_experts=4, top_k=2, capacity_factor=4.0),
        sliding_window=16,
        rope_theta=1_000_000.0,
        scan_chunk=8,
    )
