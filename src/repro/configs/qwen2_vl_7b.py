"""qwen2-vl-7b [vlm] — 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — M-RoPE (temporal/height/width sections 16/24/24), dynamic
resolution.  [arXiv:2409.12191; hf]

Vision frontend is a STUB per the assignment: ``input_specs()`` supplies
precomputed patch embeddings (B, S, D) plus 3D M-RoPE position ids (3, B, S)."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b", family="vlm",
        n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
        d_ff=18944, vocab_size=152064,
        pattern=(("attn", 28),),
        mrope_sections=(16, 24, 24),   # sums to head_dim//2 = 64
        input_mode="embeds",
        rope_theta=1_000_000.0,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=512,
        pattern=(("attn", 2),),
        mrope_sections=(2, 3, 3),      # sums to head_dim//2 = 8
        input_mode="embeds",
        rope_theta=1_000_000.0,
        scan_chunk=8,
    )
