"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attention + Mamba heads in every block.
[arXiv:2411.13676; hf]

Adaptation (DESIGN.md §5): Hymba's 3 global-attention layers + meta tokens
become 4 group-uniform global layers (1 global + 7 sliding-window per group
of 8) so every stack is scan-homogeneous; meta tokens are dropped.  The
long_500k cell runs with a linear-in-4-layers dense cache (global layers)
plus O(1) SSM/ring state everywhere else."""
from repro.models.config import ModelConfig, grouped_pattern


def full() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", family="hybrid",
        n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
        d_ff=5504, vocab_size=32001,
        pattern=grouped_pattern(4, ("hymba_g", 1), ("hymba_l", 7)),
        ssm_state=16,
        sliding_window=1024,
        rope_theta=10_000.0,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="hymba-smoke", family="hybrid",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=512,
        pattern=grouped_pattern(1, ("hymba_g", 1), ("hymba_l", 2)),
        ssm_state=4,
        sliding_window=16,
        rope_theta=10_000.0,
        scan_chunk=8,
    )
