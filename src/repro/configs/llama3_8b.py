"""llama3-8b [dense] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — GQA, 128k vocab, rope theta 500k.  [arXiv:2407.21783]"""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab_size=128256,
        pattern=(("attn", 32),),
        rope_theta=500_000.0,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama3-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=224, vocab_size=512,
        pattern=(("attn", 2),),
        rope_theta=500_000.0,
        scan_chunk=8,
    )
