"""mixtral-8x22b [moe] — 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""
from repro.models.config import ModelConfig, MoESpec


def full() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b", family="moe",
        n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab_size=32768,
        pattern=(("moe_swa", 56),),
        moe=MoESpec(n_experts=8, top_k=2, capacity_factor=1.25),
        sliding_window=4096,
        rope_theta=1_000_000.0,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=512,
        pattern=(("moe_swa", 2),),
        moe=MoESpec(n_experts=4, top_k=2, capacity_factor=4.0),
        sliding_window=16,
        rope_theta=1_000_000.0,
        scan_chunk=8,
    )
