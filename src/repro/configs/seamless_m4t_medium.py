"""seamless-m4t-medium [audio] — 12L d_model=1024 16H (GQA kv=16) d_ff=4096
vocab=256206 — encoder-decoder, multimodal.  [arXiv:2308.11596; hf]

Per the assignment, only the transformer BACKBONE is modeled: 12 encoder
layers (bidirectional) + 12 decoder layers (self + cross attention).  The
audio frontend is a STUB — ``input_specs()`` supplies precomputed frame
embeddings as the encoder input.  Train/serve shapes split seq_len equally
between encoder and decoder (documented in DESIGN.md)."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium", family="audio",
        n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=4096, vocab_size=256206,
        pattern=(("xdec", 12),),
        enc_pattern=(("enc", 12),),
        input_mode="encdec",
        rope_theta=10_000.0,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="seamless-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=512,
        pattern=(("xdec", 2),),
        enc_pattern=(("enc", 2),),
        input_mode="encdec",
        rope_theta=10_000.0,
        scan_chunk=8,
    )
