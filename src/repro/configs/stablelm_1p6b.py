"""stablelm-1.6b [dense] — 24L d_model=2048 32H (GQA kv=32) d_ff=5632
vocab=100352.  [hf:stabilityai/stablelm-2-1_6b; unverified]
Adaptation note: StableLM-2 uses partial-rotary (25%) + biased LayerNorm; we
use full-rotary RMSNorm blocks (shared block library), documented in
DESIGN.md."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b", family="dense",
        n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=5632, vocab_size=100352,
        pattern=(("attn", 24),),
        rope_theta=10_000.0,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="stablelm-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=176, vocab_size=512,
        pattern=(("attn", 2),),
        rope_theta=10_000.0,
        scan_chunk=8,
    )
