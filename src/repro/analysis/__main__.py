"""CLI for the invariant linter.

    PYTHONPATH=src python -m repro.analysis src tests benchmarks
    PYTHONPATH=src python -m repro.analysis src --json report.json
    PYTHONPATH=src python -m repro.analysis src --baseline accepted.json
    PYTHONPATH=src python -m repro.analysis --rules

Exit status: 0 when every finding is baselined (or there are none),
1 when new findings exist, 2 on usage errors.  ``--write-baseline`` accepts
the current findings into the baseline file and exits 0 — use it only for
documented exceptions (see DESIGN.md "Static analysis").
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .framework import load_baseline, run_paths, split_new, write_baseline
from .rules import ALL_RULES


def _print_catalog() -> None:
    width = max(len(r.id) for r in ALL_RULES)
    for rule in ALL_RULES:
        print(f"  {rule.id:<{width}}  {rule.summary}")


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST-based invariant linter for the repro codebase")
    parser.add_argument("paths", nargs="*",
                        help="files or directories to lint (e.g. src tests)")
    parser.add_argument("--json", metavar="FILE", default=None,
                        help="write the full findings report as JSON")
    parser.add_argument("--baseline", metavar="FILE", default=None,
                        help="JSON file of accepted findings; only NEW "
                             "findings fail the run")
    parser.add_argument("--write-baseline", action="store_true",
                        help="accept current findings into --baseline and "
                             "exit 0")
    parser.add_argument("--rules", action="store_true",
                        help="print the rule catalog and exit")
    args = parser.parse_args(argv)

    if args.rules:
        _print_catalog()
        return 0
    if not args.paths:
        parser.print_usage(sys.stderr)
        print("error: no paths given (try: src tests benchmarks)",
              file=sys.stderr)
        return 2
    if args.write_baseline and not args.baseline:
        print("error: --write-baseline requires --baseline FILE",
              file=sys.stderr)
        return 2

    report = run_paths(args.paths)
    findings = report.sorted()

    baseline = []
    if args.baseline and Path(args.baseline).exists():
        baseline = load_baseline(args.baseline)
    new, accepted = split_new(findings, baseline)

    if args.write_baseline:
        write_baseline(args.baseline, findings)
        print(f"wrote {len(findings)} finding(s) to {args.baseline}")
        return 0

    if args.json:
        payload = {
            "files": report.files,
            "suppressed": report.suppressed,
            "baselined": len(accepted),
            "new": [f.to_dict() for f in new],
            "findings": [f.to_dict() for f in findings],
            "rules": {r.id: r.summary for r in ALL_RULES},
        }
        Path(args.json).write_text(json.dumps(payload, indent=1) + "\n")

    for f in new:
        print(str(f))
    tail = (f"{report.files} file(s), {len(new)} new finding(s), "
            f"{len(accepted)} baselined, {report.suppressed} suppressed")
    print(tail)
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
