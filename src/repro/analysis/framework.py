"""Rule framework: findings, suppressions, baseline, file walking, reports.

A :class:`Rule` inspects one parsed module and yields :class:`Finding`\\ s.
The framework owns everything rules should not re-implement:

* per-line suppressions — ``# lint: disable=rule-a,rule-b`` (or ``all``) on
  the flagged line drops the finding; the framework counts what it dropped
  so suppressions stay visible in the report,
* an optional JSON baseline of accepted findings, matched by
  ``(rule, path, message)`` rather than line number so unrelated edits that
  shift lines don't resurrect baselined findings,
* deterministic ordering of files and findings (sorted paths, then
  line/rule), so output is byte-stable across runs and machines.

``check_source`` is the fixture entry point used by tests: it lints a
source string as if it lived at a given relative path.
"""
from __future__ import annotations

import ast
import json
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterable, Optional, Sequence

from .astutil import ancestors as _ancestors
from .astutil import build_parents

_DISABLE_RE = re.compile(r"#\s*lint:\s*disable=([A-Za-z0-9_\-, ]+)")

#: rule id attached to files the linter cannot parse.
PARSE_ERROR = "parse-error"


@dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at a specific location."""
    path: str
    line: int
    rule: str
    message: str

    def key(self) -> tuple:
        # line numbers churn with unrelated edits; baseline matching is
        # therefore (rule, path, message) only.
        return (self.rule, self.path, self.message)

    def to_dict(self) -> dict:
        return {"path": self.path, "line": self.line,
                "rule": self.rule, "message": self.message}

    @classmethod
    def from_dict(cls, d: dict) -> "Finding":
        return cls(path=str(d["path"]), line=int(d.get("line", 0)),
                   rule=str(d["rule"]), message=str(d["message"]))

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


class ModuleSource:
    """One parsed file: source lines, AST, parent links, suppressions."""

    def __init__(self, relpath: str, text: str):
        self.relpath = relpath
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text)
        self.parents = build_parents(self.tree)

    def ancestors(self, node: ast.AST):
        return _ancestors(node, self.parents)

    def suppressed_rules(self, line: int) -> frozenset:
        """Rule ids disabled on a given 1-based source line."""
        if not (1 <= line <= len(self.lines)):
            return frozenset()
        m = _DISABLE_RE.search(self.lines[line - 1])
        if not m:
            return frozenset()
        return frozenset(part.strip() for part in m.group(1).split(",")
                         if part.strip())

    def is_suppressed(self, finding: Finding) -> bool:
        rules = self.suppressed_rules(finding.line)
        return finding.rule in rules or "all" in rules


class Rule:
    """Base class: subclasses set ``id``/``summary`` and implement check()."""

    id: str = ""
    summary: str = ""

    def applies(self, relpath: str) -> bool:
        return True

    def check(self, mod: ModuleSource) -> Iterable[Finding]:
        raise NotImplementedError

    def finding(self, mod: ModuleSource, node, message: str) -> Finding:
        line = node if isinstance(node, int) else getattr(node, "lineno", 0)
        return Finding(path=mod.relpath, line=line, rule=self.id,
                       message=message)


def in_src(relpath: str) -> bool:
    """Scope helper: does this path live under the shipped package?

    Substring match so absolute paths (CLI invoked from outside the repo
    root) scope the same as repo-relative ones."""
    return "src/repro/" in relpath.replace("\\", "/")


@dataclass
class Report:
    """Aggregate result of a lint run."""
    findings: list = field(default_factory=list)   # surviving (not suppressed)
    suppressed: int = 0
    files: int = 0

    def sorted(self) -> list:
        return sorted(self.findings)


def check_module(mod: ModuleSource, rules: Sequence[Rule]) -> tuple[list, int]:
    """Run every applicable rule; returns (findings, n_suppressed)."""
    kept, dropped = [], 0
    for rule in rules:
        if not rule.applies(mod.relpath):
            continue
        for f in rule.check(mod):
            if mod.is_suppressed(f):
                dropped += 1
            else:
                kept.append(f)
    return kept, dropped


def check_source(text: str, relpath: str = "src/repro/fixture.py",
                 rules: Optional[Sequence[Rule]] = None) -> list:
    """Lint a source string as if it lived at ``relpath`` (test entry point)."""
    if rules is None:
        from .rules import ALL_RULES
        rules = ALL_RULES
    mod = ModuleSource(relpath, text)
    findings, _ = check_module(mod, rules)
    return sorted(findings)


def iter_py_files(paths: Sequence[str], root: Optional[Path] = None):
    """Yield (abs_path, relpath) for every .py under the given paths, sorted."""
    root = root or Path.cwd()
    seen = set()
    for raw in paths:
        p = Path(raw)
        if not p.is_absolute():
            p = root / p
        candidates = [p] if p.is_file() else sorted(p.rglob("*.py"))
        for f in candidates:
            if f.suffix != ".py" or "__pycache__" in f.parts:
                continue
            try:
                rel = f.relative_to(root).as_posix()
            except ValueError:
                rel = f.as_posix()
            if rel not in seen:
                seen.add(rel)
                yield f, rel


def run_paths(paths: Sequence[str], rules: Optional[Sequence[Rule]] = None,
              root: Optional[Path] = None) -> Report:
    """Lint every .py file under ``paths``; parse failures become findings."""
    if rules is None:
        from .rules import ALL_RULES
        rules = ALL_RULES
    report = Report()
    for abspath, rel in iter_py_files(paths, root=root):
        report.files += 1
        try:
            mod = ModuleSource(rel, abspath.read_text())
        except (SyntaxError, UnicodeDecodeError) as e:
            line = getattr(e, "lineno", 0) or 0
            report.findings.append(Finding(path=rel, line=line,
                                           rule=PARSE_ERROR, message=str(e)))
            continue
        found, dropped = check_module(mod, rules)
        report.findings.extend(found)
        report.suppressed += dropped
    report.findings.sort()
    return report


# ---------------------------------------------------------------- baseline

def load_baseline(path) -> list:
    data = json.loads(Path(path).read_text())
    return [Finding.from_dict(d) for d in data.get("findings", data)]


def write_baseline(path, findings: Sequence[Finding]) -> None:
    payload = {"findings": [f.to_dict() for f in sorted(findings)]}
    Path(path).write_text(json.dumps(payload, indent=1) + "\n")


def split_new(findings: Sequence[Finding],
              baseline: Sequence[Finding]) -> tuple[list, list]:
    """Partition into (new, baselined) by line-insensitive key."""
    accepted = {f.key() for f in baseline}
    new = [f for f in findings if f.key() not in accepted]
    old = [f for f in findings if f.key() in accepted]
    return new, old
