"""Rule registry: one instance per invariant, in catalog order.

Adding a rule = write the module, instantiate it here, document it in the
DESIGN.md "Static analysis" catalog, and add a good/bad fixture pair to
tests/test_analysis.py (the bad snippet must fail if the rule is removed).
"""
from .determinism import DeterminismRule
from .jit import JitPurityRule
from .kv import KVPairingRule
from .ledger import LedgerDisciplineRule
from .regionkey import RegionKeyRule
from .unused import UnusedNameRule

ALL_RULES = (
    KVPairingRule(),
    LedgerDisciplineRule(),
    JitPurityRule(),
    RegionKeyRule(),
    DeterminismRule(),
    UnusedNameRule(),
)

__all__ = ["ALL_RULES", "KVPairingRule", "LedgerDisciplineRule",
           "JitPurityRule", "RegionKeyRule", "DeterminismRule",
           "UnusedNameRule"]
