"""jit-purity: no host-side impurity or traced-value branching under trace.

Functions handed to ``jax.jit`` / ``pl.pallas_call`` / ``shard_map`` run
once at trace time; impure calls (``time.*``, stdlib ``random``,
``datetime.now``, ``print``, ``np.random``) execute at trace time only and
silently freeze into the compiled graph, while ``.item()`` forces a host
sync that defeats async dispatch.  A Python ``if`` on a name bound from a
``jnp`` op is a trace-time error (ConcretizationTypeError) at best and a
shape-dependent miscompile at worst — the rule flags it statically so the
mistake never reaches a device.

Resolution is same-module and syntactic: decorator forms ``@jax.jit``,
``@partial(jax.jit, ...)`` (including aliased ``@_partial(_shard_map, ...)``
as in models/moe.py), and call forms ``jit(f)`` / ``pl.pallas_call(k, ...)``
where ``f`` is a local ``def``/``lambda`` or ``partial`` thereof.  Callees
we cannot resolve (bound methods like ``lm.prefill``) are skipped — a
documented limitation, not a pass.
"""
from __future__ import annotations

import ast
from typing import Iterable, Optional

from ..astutil import dotted_name, last_segment
from ..framework import Finding, ModuleSource, Rule

WRAPPERS = frozenset({"jit", "pallas_call", "shard_map"})
BANNED_BARE = frozenset({"print", "input", "breakpoint"})
DATETIME_NOW = frozenset({"now", "utcnow", "today"})


class JitPurityRule(Rule):
    id = "jit-purity"
    summary = ("bodies traced by jax.jit/pallas_call/shard_map must not call "
               "time/random/datetime.now/print/.item(), nor branch with "
               "Python if on names bound from jnp ops")

    def check(self, mod: ModuleSource) -> Iterable[Finding]:
        defs = _local_defs(mod.tree)
        seen = set()
        targets = []

        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if any(_is_wrapper_decorator(d) for d in node.decorator_list):
                    targets.append(node)
            elif isinstance(node, ast.Call) and _is_wrapper(node.func) \
                    and node.args:
                fn = _resolve(node.args[0], defs)
                if fn is not None:
                    targets.append(fn)

        for fn in targets:
            if id(fn) in seen:
                continue
            seen.add(id(fn))
            yield from self._check_body(mod, fn)

    def _check_body(self, mod: ModuleSource, fn) -> Iterable[Finding]:
        label = getattr(fn, "name", "<lambda>")
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                msg = _impure_call(node)
                if msg:
                    yield self.finding(
                        mod, node,
                        f"{msg} inside traced body '{label}' — runs once at "
                        f"trace time / forces host sync")
        traced = _jnp_bound_names(fn)
        if not traced:
            return
        for node in ast.walk(fn):
            test = None
            if isinstance(node, (ast.If, ast.While, ast.IfExp)):
                test = node.test
            if test is None:
                continue
            hit = _traced_operand(test, traced)
            if hit:
                yield self.finding(
                    mod, node,
                    f"Python branch on '{hit}' (bound from a jnp op) inside "
                    f"traced body '{label}' — use jnp.where/lax.cond")


# --------------------------------------------------------------- matching

def _is_wrapper(expr: ast.expr) -> bool:
    name = dotted_name(expr)
    return name is not None and last_segment(name).lstrip("_") in WRAPPERS


def _is_wrapper_decorator(dec: ast.expr) -> bool:
    if _is_wrapper(dec):                      # @jax.jit
        return True
    if isinstance(dec, ast.Call):
        if _is_wrapper(dec.func):             # @jax.jit(...)
            return True
        name = dotted_name(dec.func)          # @partial(jax.jit, ...)
        if name and last_segment(name).lstrip("_") == "partial" \
                and dec.args and _is_wrapper(dec.args[0]):
            return True
    return False


def _local_defs(tree: ast.AST) -> dict:
    defs = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs[node.name] = node
        elif isinstance(node, ast.Assign) and isinstance(node.value, ast.Lambda):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    defs[t.id] = node.value
    return defs


def _resolve(expr: ast.expr, defs: dict):
    if isinstance(expr, ast.Lambda):
        return expr
    if isinstance(expr, ast.Name):
        return defs.get(expr.id)
    if isinstance(expr, ast.Call):            # jit(partial(f, ...))
        name = dotted_name(expr.func)
        if name and last_segment(name).lstrip("_") == "partial" and expr.args:
            return _resolve(expr.args[0], defs)
    return None


def _impure_call(call: ast.Call) -> Optional[str]:
    if isinstance(call.func, ast.Name) and call.func.id in BANNED_BARE:
        return f"impure call {call.func.id}()"
    name = dotted_name(call.func)
    if name:
        parts = name.split(".")
        if parts[0] == "time" and len(parts) > 1:
            return f"impure call {name}()"
        if parts[0] == "datetime" and parts[-1] in DATETIME_NOW:
            return f"impure call {name}()"
        if parts[0] == "random" and len(parts) > 1:
            return f"nondeterministic call {name}()"
        if len(parts) >= 3 and parts[0] in ("np", "numpy") \
                and parts[1] == "random":
            return f"nondeterministic call {name}()"
    if isinstance(call.func, ast.Attribute) and call.func.attr == "item" \
            and not call.args:
        return "device sync .item()"
    return None


def _jnp_bound_names(fn) -> frozenset:
    traced = set()
    for node in ast.walk(fn):
        if not isinstance(node, ast.Assign):
            continue
        if not isinstance(node.value, ast.Call):
            continue
        name = dotted_name(node.value.func)
        if not name:
            continue
        if name.split(".")[0] == "jnp" or name.startswith("jax.numpy."):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    traced.add(t.id)
                elif isinstance(t, ast.Tuple):
                    traced.update(e.id for e in t.elts
                                  if isinstance(e, ast.Name))
    return frozenset(traced)


def _traced_operand(test: ast.expr, traced: frozenset) -> Optional[str]:
    """Direct traced-name operands only: x.ndim / len(x) are trace-static."""
    if isinstance(test, ast.Name):
        return test.id if test.id in traced else None
    if isinstance(test, ast.Compare):
        for operand in [test.left, *test.comparators]:
            if isinstance(operand, ast.Name) and operand.id in traced:
                return operand.id
        return None
    if isinstance(test, ast.BoolOp):
        for v in test.values:
            hit = _traced_operand(v, traced)
            if hit:
                return hit
        return None
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        return _traced_operand(test.operand, traced)
    return None
