"""jit-purity: no host-side impurity or traced-value branching under trace.

Functions handed to ``jax.jit`` / ``pl.pallas_call`` / ``shard_map`` run
once at trace time; impure calls (``time.*``, stdlib ``random``,
``datetime.now``, ``print``, ``np.random``) execute at trace time only and
silently freeze into the compiled graph, while ``.item()`` forces a host
sync that defeats async dispatch.  A Python ``if`` on a name bound from a
``jnp`` op is a trace-time error (ConcretizationTypeError) at best and a
shape-dependent miscompile at worst — the rule flags it statically so the
mistake never reaches a device.

Resolution is same-module and syntactic: decorator forms ``@jax.jit``,
``@partial(jax.jit, ...)`` (including aliased ``@_partial(_shard_map, ...)``
as in models/moe.py), and call forms ``jit(f)`` / ``pl.pallas_call(k, ...)``
where ``f`` is a local ``def``/``lambda``, a ``partial`` thereof, or a name
ASSIGNED from such a ``partial`` — which covers the serving engine's
mesh-jitted closures (``jax.jit(_decode_paged_sharded, ...)``: a local def
wrapping the model call in a ``shard_context``).  Callees we cannot resolve
(bound methods like ``lm.prefill``) are skipped — a documented limitation,
not a pass.

Donation pairing: every wrapper call with a ``donate_argnums`` keyword the
rule can resolve to literal indices (literal tuple/int, or a name assigned
one — the engine's ``donate = (1,)``) is checked against what it donates.
A resolvable local def must donate a parameter whose NAME reads as a
reusable device buffer (arena/cache/state — the serving arenas and the
train loop's optimizer state); donating ``params`` or a token batch
invalidates the caller's copy mid-flight.  Method references are checked by
name: ``decode_step_paged``/``decode_step`` may donate exactly their
arena/cache argument (argnum 1), while ``prefill``/``prefill_cont`` must
never donate — prefix-cache entries alias their output caches.  Computed
donate expressions (ternaries, ``**kw``) are skipped like unresolvable
callees.
"""
from __future__ import annotations

import ast
from typing import Iterable, Optional

from ..astutil import dotted_name, last_segment
from ..framework import Finding, ModuleSource, Rule

WRAPPERS = frozenset({"jit", "pallas_call", "shard_map"})
BANNED_BARE = frozenset({"print", "input", "breakpoint"})
DATETIME_NOW = frozenset({"now", "utcnow", "today"})

# donation pairing: method-name contracts for the serving/dryrun jits.  The
# VALUE is the set of argnums that hold the donatable arena/cache pytree.
DONATABLE_METHODS = {"decode_step_paged": frozenset({1}),
                     "decode_step": frozenset({1})}
# prefill outputs are aliased by prefix-cache entries (engine LRU holds
# direct references): donating their inputs/outputs is always a bug
NON_DONATABLE_METHODS = frozenset({"prefill", "prefill_cont"})
# a donated local-def parameter must read as a reusable device buffer
DONATABLE_PARAM_HINTS = ("arena", "cache", "state")


class JitPurityRule(Rule):
    id = "jit-purity"
    summary = ("bodies traced by jax.jit/pallas_call/shard_map must not call "
               "time/random/datetime.now/print/.item(), nor branch with "
               "Python if on names bound from jnp ops")

    def check(self, mod: ModuleSource) -> Iterable[Finding]:
        defs = _local_defs(mod.tree)
        consts = _const_assigns(mod.tree)
        seen = set()
        targets = []

        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if any(_is_wrapper_decorator(d) for d in node.decorator_list):
                    targets.append(node)
            elif isinstance(node, ast.Call) and _is_wrapper(node.func) \
                    and node.args:
                fn = _resolve(node.args[0], defs)
                if fn is not None:
                    targets.append(fn)
                yield from self._check_donation(mod, node, defs, consts)

        for fn in targets:
            if id(fn) in seen:
                continue
            seen.add(id(fn))
            yield from self._check_body(mod, fn)

    def _check_donation(self, mod: ModuleSource, call: ast.Call,
                        defs: dict, consts: dict) -> Iterable[Finding]:
        kw = next((k for k in call.keywords
                   if k.arg == "donate_argnums"), None)
        if kw is None:
            return
        idxs = _const_tuple(kw.value, consts)
        if idxs is None:       # ternary / computed — skipped, not a pass
            return
        fn = _resolve(call.args[0], defs)
        if fn is not None:
            params = [a.arg for a in fn.args.args]
            label = getattr(fn, "name", "<lambda>")
            for i in idxs:
                pname = params[i] if 0 <= i < len(params) else None
                if pname is None or not any(
                        h in pname.lower() for h in DONATABLE_PARAM_HINTS):
                    yield self.finding(
                        mod, call,
                        f"donate_argnums={tuple(idxs)} on '{label}' donates "
                        f"parameter {pname!r}, which does not look like a "
                        f"reusable arena/cache/state buffer — donation "
                        f"invalidates the caller's copy")
            return
        mname = _method_name(call.args[0])
        if mname is None:
            return
        if mname in NON_DONATABLE_METHODS and idxs:
            yield self.finding(
                mod, call,
                f"donating into '{mname}' — prefill caches are aliased by "
                f"prefix-cache entries and must never be donated")
        elif mname in DONATABLE_METHODS:
            bad = set(idxs) - DONATABLE_METHODS[mname]
            if bad:
                yield self.finding(
                    mod, call,
                    f"'{mname}' may only donate its arena argument (argnums "
                    f"{sorted(DONATABLE_METHODS[mname])}), got {tuple(idxs)}")

    def _check_body(self, mod: ModuleSource, fn) -> Iterable[Finding]:
        label = getattr(fn, "name", "<lambda>")
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                msg = _impure_call(node)
                if msg:
                    yield self.finding(
                        mod, node,
                        f"{msg} inside traced body '{label}' — runs once at "
                        f"trace time / forces host sync")
        traced = _jnp_bound_names(fn)
        if not traced:
            return
        for node in ast.walk(fn):
            test = None
            if isinstance(node, (ast.If, ast.While, ast.IfExp)):
                test = node.test
            if test is None:
                continue
            hit = _traced_operand(test, traced)
            if hit:
                yield self.finding(
                    mod, node,
                    f"Python branch on '{hit}' (bound from a jnp op) inside "
                    f"traced body '{label}' — use jnp.where/lax.cond")


# --------------------------------------------------------------- matching

def _is_wrapper(expr: ast.expr) -> bool:
    name = dotted_name(expr)
    return name is not None and last_segment(name).lstrip("_") in WRAPPERS


def _is_wrapper_decorator(dec: ast.expr) -> bool:
    if _is_wrapper(dec):                      # @jax.jit
        return True
    if isinstance(dec, ast.Call):
        if _is_wrapper(dec.func):             # @jax.jit(...)
            return True
        name = dotted_name(dec.func)          # @partial(jax.jit, ...)
        if name and last_segment(name).lstrip("_") == "partial" \
                and dec.args and _is_wrapper(dec.args[0]):
            return True
    return False


def _local_defs(tree: ast.AST) -> dict:
    defs = {}
    pending = []          # names assigned from partial(...): resolve after
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs[node.name] = node
        elif isinstance(node, ast.Assign) and isinstance(node.value, ast.Lambda):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    defs[t.id] = node.value
        elif isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            name = dotted_name(node.value.func)
            if name and last_segment(name).lstrip("_") == "partial":
                pending.append(node)
    # second pass: f2 = partial(f, ...) resolves through defs collected above
    for node in pending:
        fn = _resolve(node.value, defs)
        if fn is not None:
            for t in node.targets:
                if isinstance(t, ast.Name):
                    defs.setdefault(t.id, fn)
    return defs


def _resolve(expr: ast.expr, defs: dict):
    if isinstance(expr, ast.Lambda):
        return expr
    if isinstance(expr, ast.Name):
        return defs.get(expr.id)
    if isinstance(expr, ast.Call):            # jit(partial(f, ...))
        name = dotted_name(expr.func)
        if name and last_segment(name).lstrip("_") == "partial" and expr.args:
            return _resolve(expr.args[0], defs)
    return None


def _method_name(expr: ast.expr) -> Optional[str]:
    """Last segment of the callee a jit call wraps: ``lm.decode_step`` ->
    ``decode_step``, peeling one ``partial(...)`` layer if present."""
    if isinstance(expr, ast.Call):
        name = dotted_name(expr.func)
        if name and last_segment(name).lstrip("_") == "partial" and expr.args:
            return _method_name(expr.args[0])
        return None
    name = dotted_name(expr)
    return last_segment(name) if name else None


def _const_assigns(tree: ast.AST) -> dict:
    assigns = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            assigns[node.targets[0].id] = node.value
    return assigns


def _const_tuple(expr, consts: dict, depth: int = 0):
    """Resolve a donate_argnums expression to a tuple of ints, or None when
    it is computed (ternary, attribute, call) — those sites are skipped."""
    if expr is None or depth > 3:
        return None
    if isinstance(expr, ast.Constant):
        v = expr.value
        return (v,) if isinstance(v, int) and not isinstance(v, bool) else None
    if isinstance(expr, (ast.Tuple, ast.List)):
        out = []
        for e in expr.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, int) \
                    and not isinstance(e.value, bool):
                out.append(e.value)
            else:
                return None
        return tuple(out)
    if isinstance(expr, ast.Name):
        return _const_tuple(consts.get(expr.id), consts, depth + 1)
    return None


def _impure_call(call: ast.Call) -> Optional[str]:
    if isinstance(call.func, ast.Name) and call.func.id in BANNED_BARE:
        return f"impure call {call.func.id}()"
    name = dotted_name(call.func)
    if name:
        parts = name.split(".")
        if parts[0] == "time" and len(parts) > 1:
            return f"impure call {name}()"
        if parts[0] == "datetime" and parts[-1] in DATETIME_NOW:
            return f"impure call {name}()"
        if parts[0] == "random" and len(parts) > 1:
            return f"nondeterministic call {name}()"
        if len(parts) >= 3 and parts[0] in ("np", "numpy") \
                and parts[1] == "random":
            return f"nondeterministic call {name}()"
    if isinstance(call.func, ast.Attribute) and call.func.attr == "item" \
            and not call.args:
        return "device sync .item()"
    return None


def _jnp_bound_names(fn) -> frozenset:
    traced = set()
    for node in ast.walk(fn):
        if not isinstance(node, ast.Assign):
            continue
        if not isinstance(node.value, ast.Call):
            continue
        name = dotted_name(node.value.func)
        if not name:
            continue
        if name.split(".")[0] == "jnp" or name.startswith("jax.numpy."):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    traced.add(t.id)
                elif isinstance(t, ast.Tuple):
                    traced.update(e.id for e in t.elts
                                  if isinstance(e, ast.Name))
    return frozenset(traced)


def _traced_operand(test: ast.expr, traced: frozenset) -> Optional[str]:
    """Direct traced-name operands only: x.ndim / len(x) are trace-static."""
    if isinstance(test, ast.Name):
        return test.id if test.id in traced else None
    if isinstance(test, ast.Compare):
        for operand in [test.left, *test.comparators]:
            if isinstance(operand, ast.Name) and operand.id in traced:
                return operand.id
        return None
    if isinstance(test, ast.BoolOp):
        for v in test.values:
            hit = _traced_operand(v, traced)
            if hit:
                return hit
        return None
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        return _traced_operand(test.operand, traced)
    return None
