"""ledger-discipline: billing happens in core/oracles/, rounds always finish.

Two sub-invariants:

1. Ledger mutation (``*.ledger.charge(...)``) and billing-record /
   ledger construction (``CallRecord(...)``, ``TokenLedger(...)``) are only
   legal inside ``src/repro/core/oracles/``.  Everything above bills
   *through* an Oracle verb so per-query reconciliation
   (SemanticMemo.reconciled_records, interleaved==solo ledger identity)
   keeps holding — a direct charge from serving or an access path would be
   invisible to the memo and silently break byte-identical billing.

2. Any function that calls ``begin_probe_round`` must also call
   ``finish_probe_round`` with at least one of those finish calls inside a
   ``finally`` block.  ``begin`` bills and enqueues the round immediately;
   abandoning the token leaves billed-but-unserved probes in the scheduler
   (the executor.tick bug fixed in this PR).

Cascade extensions (core/oracles/cascade.py): the draft→large escalation
machinery moves billing decisions into a mid-pump callback, so two more
billing sites are confined to the oracle layer:

3. ``*.charge(..., tier=...)`` — tier-tagged CallRecord construction —
   is flagged outside ``core/oracles/`` regardless of the receiver's
   name.  A tier tag from serving or an access path would let a
   non-oracle layer decide which price sheet a record books against.

4. ``*.submit_cascade_round(...)`` is flagged outside ``core/oracles/``:
   its ``escalate`` callback bills the large wave, so a caller above the
   oracle layer would be a billing site in disguise.  (Deferred cascade
   rounds still flow through ``begin/finish_probe_round``, so invariant 2
   covers their pairing — escalation waves resolve inside the same
   token's finish, which must sit in a ``finally``.)
"""
from __future__ import annotations

import ast
from typing import Iterable

from ..astutil import callee_attr, calls_in, dotted_name
from ..framework import Finding, ModuleSource, Rule, in_src

ALLOWED_PREFIX = "src/repro/core/oracles/"
BILLING_CTORS = frozenset({"CallRecord", "TokenLedger"})


class LedgerDisciplineRule(Rule):
    id = "ledger-discipline"
    summary = ("ledger.charge()/CallRecord()/TokenLedger()/charge(tier=...)/"
               "submit_cascade_round() only inside core/oracles/; "
               "begin_probe_round paired with a finish_probe_round in a "
               "finally block")

    def applies(self, relpath: str) -> bool:
        return in_src(relpath)

    def check(self, mod: ModuleSource) -> Iterable[Finding]:
        allowed = ALLOWED_PREFIX in mod.relpath.replace("\\", "/")
        if not allowed:
            yield from self._check_billing_sites(mod)
        yield from self._check_round_pairing(mod)

    def _check_billing_sites(self, mod: ModuleSource) -> Iterable[Finding]:
        for call in calls_in(mod.tree):
            name = dotted_name(call.func)
            attr = callee_attr(call)
            if name:
                parts = name.split(".")
                if parts[-1] == "charge" and "ledger" in parts[:-1]:
                    yield self.finding(
                        mod, call,
                        "direct ledger.charge() outside core/oracles/ — "
                        "bill through an Oracle verb so memo reconciliation "
                        "sees the spend")
            if (attr == "charge" and isinstance(call.func, ast.Attribute)
                    and any(kw.arg == "tier" for kw in call.keywords)):
                yield self.finding(
                    mod, call,
                    "tier-tagged charge(tier=...) outside core/oracles/ — "
                    "which price sheet a record books against is an "
                    "oracle-layer decision")
            if attr == "submit_cascade_round" and isinstance(call.func,
                                                             ast.Attribute):
                yield self.finding(
                    mod, call,
                    "submit_cascade_round() outside core/oracles/ — its "
                    "escalate callback bills the large wave, making the "
                    "caller a billing site")
            if attr in BILLING_CTORS and isinstance(call.func, ast.Name):
                yield self.finding(
                    mod, call,
                    f"{attr}() constructed outside core/oracles/ — billing "
                    f"records and ledgers are owned by the oracle layer")

    def _check_round_pairing(self, mod: ModuleSource) -> Iterable[Finding]:
        for fn in ast.walk(mod.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if fn.name in ("begin_probe_round", "finish_probe_round"):
                continue  # the definitions themselves
            begins = [c for c in calls_in(list(fn.body))
                      if callee_attr(c) == "begin_probe_round"
                      and isinstance(c.func, ast.Attribute)]
            if not begins:
                continue
            finishes = [c for c in calls_in(list(fn.body))
                        if callee_attr(c) == "finish_probe_round"]
            if not finishes:
                yield self.finding(
                    mod, begins[0],
                    "begin_probe_round() with no finish_probe_round() in "
                    "this function — the billed round is never served")
                continue
            if not any(self._in_finally(mod, c) for c in finishes):
                yield self.finding(
                    mod, begins[0],
                    "begin_probe_round() but no finish_probe_round() call "
                    "is inside a finally block — an exception mid-tick "
                    "abandons billed rounds")

    @staticmethod
    def _in_finally(mod: ModuleSource, call: ast.Call) -> bool:
        prev: ast.AST = call
        for anc in mod.ancestors(call):
            if isinstance(anc, ast.Try) and prev in anc.finalbody:
                return True
            if isinstance(anc, ast.stmt):
                prev = anc
        return False
