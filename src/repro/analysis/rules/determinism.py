"""determinism: no process-salted hashing or unseeded RNG in src/repro/.

PR 1's worst bug: quicksort's peer sampling seeded from builtin ``hash()``,
which is salted by PYTHONHASHSEED — two runs of the same query produced
different probe orders (and therefore different ledgers) across processes.
The fix was a blake2b digest (``core.oracles.cache.stable_key``).  This
rule bans the whole class inside the shipped package:

* builtin ``hash(...)``,
* stdlib ``random.*`` except an explicitly seeded ``random.Random(seed)``
  (``jax.random`` is keyed and fine; it does not match the dotted root),
* ``np.random.*`` legacy global API, and ``np.random.default_rng()``
  without a seed argument (seeded ``default_rng(seed)`` / ``Generator`` /
  ``SeedSequence`` / ``PCG64`` / ``Philox`` constructions are fine).
"""
from __future__ import annotations

import ast
from typing import Iterable

from ..astutil import dotted_name
from ..framework import Finding, ModuleSource, Rule, in_src

_SEEDED_CTORS = frozenset({"Generator", "SeedSequence", "PCG64", "Philox",
                           "MT19937", "bit_generator"})


class DeterminismRule(Rule):
    id = "determinism"
    summary = ("no builtin hash(), stdlib random, or unseeded np.random in "
               "src/repro/ — use blake2b stable_key / seeded default_rng")

    def applies(self, relpath: str) -> bool:
        return in_src(relpath)

    def check(self, mod: ModuleSource) -> Iterable[Finding]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            if isinstance(node.func, ast.Name) and node.func.id == "hash":
                yield self.finding(
                    mod, node,
                    "builtin hash() is PYTHONHASHSEED-salted — use "
                    "core.oracles.cache.stable_key (blake2b) instead")
                continue
            name = dotted_name(node.func)
            if not name:
                continue
            parts = name.split(".")
            if parts[0] == "random" and len(parts) > 1:
                if parts[1] == "Random" and node.args:
                    continue  # explicitly seeded
                yield self.finding(
                    mod, node,
                    f"{name}() draws from process-global stdlib RNG — seed "
                    f"an np.random.default_rng(seed) instead")
            elif len(parts) >= 3 and parts[0] in ("np", "numpy") \
                    and parts[1] == "random":
                tail = parts[2]
                if tail in _SEEDED_CTORS:
                    continue
                if tail == "default_rng" and node.args:
                    continue
                yield self.finding(
                    mod, node,
                    f"{name}() is unseeded/legacy np.random — pass an "
                    f"explicit seed to np.random.default_rng")
