"""unused-name: imports that nothing in the module references.

Dead imports are how dead code starts (PR 2 removed the orphaned ``cum``
helper; its import lingered).  The rule is intentionally narrow — imports
only, matched against every ``Name`` load in the module plus ``__all__``
strings — so it has no false positives on attribute-only usage
(``import os`` + ``os.environ`` counts as used via the ``os`` Name node).

Exempt: ``from __future__ import ...`` (semantic, not a binding in the
usual sense), ``import *``, and ``__init__.py`` files entirely (re-export
modules bind names precisely so other modules can import them).
"""
from __future__ import annotations

import ast
from typing import Iterable

from ..framework import Finding, ModuleSource, Rule


class UnusedNameRule(Rule):
    id = "unused-name"
    summary = "imported names never referenced in the module (re-exports exempt)"

    def applies(self, relpath: str) -> bool:
        return not relpath.replace("\\", "/").endswith("__init__.py")

    def check(self, mod: ModuleSource) -> Iterable[Finding]:
        imported = []  # (bound_name, display_name, lineno)
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    bound = alias.asname or alias.name.split(".")[0]
                    imported.append((bound, alias.name, node.lineno))
            elif isinstance(node, ast.ImportFrom):
                if node.module == "__future__":
                    continue
                for alias in node.names:
                    if alias.name == "*":
                        continue
                    bound = alias.asname or alias.name
                    imported.append((bound, alias.name, node.lineno))
        if not imported:
            return

        used = {n.id for n in ast.walk(mod.tree) if isinstance(n, ast.Name)}
        used |= _dunder_all(mod.tree)

        for bound, display, lineno in imported:
            if bound not in used:
                yield self.finding(
                    mod, lineno,
                    f"'{display}' imported but unused")


def _dunder_all(tree: ast.AST) -> set:
    names = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) \
                and any(isinstance(t, ast.Name) and t.id == "__all__"
                        for t in node.targets):
            for sub in ast.walk(node.value):
                if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
                    names.add(sub.value)
    return names
