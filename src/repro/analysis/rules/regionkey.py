"""region-key-unification: prefix-region keys come from ServeEngine._region_key.

PR 5 fixed a drift bug where probe routing, paged admission and prefetch
each built the region tuple ``(prefix_ids, window - len(prefix) - len(sfx))``
by hand; one site computing the window differently made a warm region look
cold (wasted fills) or, worse, routed rows to a stale cached region.  All
construction now goes through ``ServeEngine._region_key`` — this rule keeps
it that way by flagging the tuple's distinctive shape anywhere else:
a 2-tuple whose second element is a subtraction involving ``len(...)``.
"""
from __future__ import annotations

import ast
from typing import Iterable

from ..framework import Finding, ModuleSource, Rule, in_src


class RegionKeyRule(Rule):
    id = "region-key-unification"
    summary = ("no ad-hoc (prefix_ids, window - len(...)) region-key tuples "
               "outside ServeEngine._region_key")

    def applies(self, relpath: str) -> bool:
        return in_src(relpath)

    def check(self, mod: ModuleSource) -> Iterable[Finding]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Tuple) or len(node.elts) != 2:
                continue
            if not _is_len_subtraction(node.elts[1]):
                continue
            if any(isinstance(a, (ast.FunctionDef, ast.AsyncFunctionDef))
                   and a.name == "_region_key" for a in mod.ancestors(node)):
                continue
            yield self.finding(
                mod, node,
                "ad-hoc region-key tuple (ids, window - len(...)) — route "
                "through ServeEngine._region_key so keys cannot drift")


def _is_len_subtraction(expr: ast.expr) -> bool:
    """A BinOp subtree using Sub that contains a len(...) call."""
    if not isinstance(expr, ast.BinOp):
        return False
    has_sub = any(isinstance(n, ast.BinOp) and isinstance(n.op, ast.Sub)
                  for n in ast.walk(expr))
    has_len = any(isinstance(n, ast.Call) and isinstance(n.func, ast.Name)
                  and n.func.id == "len" for n in ast.walk(expr))
    return has_sub and has_len
