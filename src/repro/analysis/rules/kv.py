"""kv-pairing: every KV refcount acquire must release on ALL paths.

The paged KV pool (serving/kv_pool.py) hands out per-block refcounts;
a raised exception between an ``incref``/``lease`` and its matching
``decref``/``release`` strands blocks forever — the pool never reclaims
them and long-running serving eventually hits PoolExhausted (the exact
leak class PR 3's round-pin try/finally and this PR's prefetch_prefixes /
paged_admit fixes closed).

The rule is lexical, not dataflow: an acquiring call is OK when it is
(a) inside a ``try`` body whose ``finally`` performs a release,
(b) the statement *immediately before* such a ``try`` (the standard
    acquire-then-guard idiom: nothing can raise in between), or
(c) inside a ``with`` block (context managers own their cleanup).
Call sites that intentionally transfer ownership to their caller (e.g.
``_fill_prefix_entries``'s pin closure) carry ``# lint: disable=kv-pairing``
with a comment naming the owner.
"""
from __future__ import annotations

import ast
from typing import Iterable

from ..astutil import callee_attr, calls_in, enclosing_statement, following_statement
from ..framework import Finding, ModuleSource, Rule, in_src

#: method names that take a refcount / pool lease.  ``alloc`` joined the
#: set with the preemption work: suspend/resume moves whole block runs in
#: and out of the pool, so a raw alloc whose blocks never reach a row (or
#: a rollback) is exactly the stranded-pin class this rule exists for.
ACQUIRES = frozenset({"alloc", "incref", "lease", "_lease_probe_blocks",
                      "_fill_prefix_entries"})
#: method names that give one back.
RELEASES = frozenset({"decref", "release", "_release_lease", "_release_pins",
                      "free"})


class KVPairingRule(Rule):
    id = "kv-pairing"
    summary = ("incref/lease call sites must reach a decref/release on all "
               "paths (finally block, adjacent try/finally, or with block)")

    def applies(self, relpath: str) -> bool:
        return in_src(relpath)

    def check(self, mod: ModuleSource) -> Iterable[Finding]:
        for call in calls_in(mod.tree):
            name = callee_attr(call)
            if name not in ACQUIRES:
                continue
            # the definition of an acquire method is not a call site
            if isinstance(call.func, ast.Name):
                continue
            if self._guarded(mod, call):
                continue
            yield self.finding(
                mod, call,
                f"{name}() without a finally-guarded release on this path "
                f"— wrap in try/finally with "
                f"{'/'.join(sorted(RELEASES))} or move the acquire "
                f"immediately before an existing try/finally")

    def _guarded(self, mod: ModuleSource, call: ast.Call) -> bool:
        # (a)/(c): enclosing try-with-releasing-finally, or a with block.
        prev = call
        for anc in mod.ancestors(call):
            if isinstance(anc, (ast.With, ast.AsyncWith)):
                return True
            if isinstance(anc, ast.Try) and prev in anc.body \
                    and _block_releases(anc.finalbody):
                return True
            if isinstance(anc, ast.stmt):
                prev = anc
        # (b): the next statement is a try whose finally releases.
        stmt = enclosing_statement(call, mod.parents)
        if stmt is not None:
            nxt = following_statement(stmt, mod.parents)
            if isinstance(nxt, ast.Try) and _block_releases(nxt.finalbody):
                return True
        return False


def _block_releases(block: list) -> bool:
    return any(callee_attr(c) in RELEASES for c in calls_in(block))
