"""Small shared AST helpers for the invariant rules.

Everything here is deliberately syntactic: rules match dotted-name shapes
(``self.pool.incref`` -> ``"self.pool.incref"``) rather than doing import
resolution, and compensate with narrow patterns + per-line suppressions.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional


def dotted_name(node: ast.expr) -> Optional[str]:
    """``a.b.c`` -> ``"a.b.c"``; None when any segment is not a Name/Attribute."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


def call_name(call: ast.Call) -> Optional[str]:
    """Dotted name of a call's callee, or None for computed callees."""
    return dotted_name(call.func)


def last_segment(name: str) -> str:
    return name.rsplit(".", 1)[-1]


def build_parents(tree: ast.AST) -> dict[ast.AST, ast.AST]:
    return {child: parent
            for parent in ast.walk(tree)
            for child in ast.iter_child_nodes(parent)}


def ancestors(node: ast.AST, parents: dict[ast.AST, ast.AST]) -> Iterator[ast.AST]:
    """Yield node's ancestors innermost-first (excluding node itself)."""
    cur = parents.get(node)
    while cur is not None:
        yield cur
        cur = parents.get(cur)


def enclosing_statement(node: ast.AST,
                        parents: dict[ast.AST, ast.AST]) -> Optional[ast.stmt]:
    """The outermost statement whose parent is a statement-list holder.

    I.e. the simple statement that contains ``node``, suitable for
    "what is the next statement after this one" questions.
    """
    cur: Optional[ast.AST] = node
    while cur is not None:
        parent = parents.get(cur)
        if isinstance(cur, ast.stmt) and _holds_stmt_list(parent, cur):
            return cur
        cur = parent
    return None


def _holds_stmt_list(parent: Optional[ast.AST], child: ast.stmt) -> bool:
    if parent is None:
        return False
    for field in ("body", "orelse", "finalbody"):
        block = getattr(parent, field, None)
        if isinstance(block, list) and child in block:
            return True
    if isinstance(parent, ast.Try) and child in parent.handlers:  # pragma: no cover
        return True
    return False


def following_statement(stmt: ast.stmt,
                        parents: dict[ast.AST, ast.AST]) -> Optional[ast.stmt]:
    """The statement immediately after ``stmt`` in its enclosing block."""
    parent = parents.get(stmt)
    if parent is None:
        return None
    for field in ("body", "orelse", "finalbody"):
        block = getattr(parent, field, None)
        if isinstance(block, list) and stmt in block:
            i = block.index(stmt)
            return block[i + 1] if i + 1 < len(block) else None
    return None


def calls_in(nodes) -> Iterator[ast.Call]:
    for n in nodes if isinstance(nodes, list) else [nodes]:
        for sub in ast.walk(n):
            if isinstance(sub, ast.Call):
                yield sub


def callee_attr(call: ast.Call) -> Optional[str]:
    """Final attribute/name of the callee: ``self.pool.incref(..)`` -> ``incref``."""
    if isinstance(call.func, ast.Attribute):
        return call.func.attr
    if isinstance(call.func, ast.Name):
        return call.func.id
    return None
