"""repro.analysis — AST-based invariant linter for this reproduction.

Every guarantee the serving stack makes — byte-identical orderings, exact
per-query ledger reconciliation (SemanticMemo first-requester-pays), zero
KV block leaks — rests on conventions that used to be enforced only by
runtime asserts inside specific tests.  This package locks them in
*statically*: a small rule framework (``framework.py``) walks every file's
AST and reports :class:`Finding`\\ s for code that violates one of the
repo's hard-won invariants (``rules/``).  Run it as

    PYTHONPATH=src python -m repro.analysis src tests benchmarks

Pure stdlib (``ast`` only — no jax import), so the CI ``analysis`` job
needs no dependency install.  Rule catalog, suppression and baseline
conventions: DESIGN.md "Static analysis".
"""
from .framework import (Finding, Report, check_source, load_baseline,
                        run_paths, split_new, write_baseline)
from .rules import ALL_RULES

__all__ = ["Finding", "Report", "ALL_RULES", "check_source", "run_paths",
           "load_baseline", "write_baseline", "split_new"]
