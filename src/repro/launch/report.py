"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dry-run JSONL.

    PYTHONPATH=src python -m repro.launch.report results/dryrun_singlepod.jsonl
"""
from __future__ import annotations

import json
import sys


def load(path: str) -> list[dict]:
    with open(path) as f:
        return [json.loads(l) for l in f if l.strip()]


def fmt_bytes(b) -> str:
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def dryrun_table(recs: list[dict]) -> str:
    out = ["| arch | shape | mesh | compile_s | args/dev | temp/dev | "
           "flops/dev | AR bytes/dev | AG | A2A | CP |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        mesh = "2x16x16" if r.get("multi_pod") else "16x16"
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | {mesh} | skip | "
                       f"{r['skipped'][:58]} |  |  |  |  |  |  |")
            continue
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | {mesh} | ERROR | "
                       f"{r['error'][:58]} |  |  |  |  |  |  |")
            continue
        ma = r.get("memory_analysis", {})
        ca = r.get("cost_analysis", {})
        cb = r.get("collectives", {}).get("bytes", {})
        out.append(
            f"| {r['arch']} | {r['shape']} | {mesh} | {r.get('compile_s', '-')} "
            f"| {fmt_bytes(ma.get('argument_size_in_bytes'))} "
            f"| {fmt_bytes(ma.get('temp_size_in_bytes'))} "
            f"| {ca.get('flops', 0):.3g} "
            f"| {fmt_bytes(cb.get('all-reduce'))} "
            f"| {fmt_bytes(cb.get('all-gather'))} "
            f"| {fmt_bytes(cb.get('all-to-all'))} "
            f"| {fmt_bytes(cb.get('collective-permute'))} |")
    return "\n".join(out)


def roofline_table(recs: list[dict]) -> str:
    out = ["| arch | shape | compute_s | memory_s | collective_s | dominant "
           "| MODEL_FLOPS | HLO_FLOPs | useful | bound_s |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("multi_pod"):
            continue
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"skip: {r['skipped'][:44]} |  |  |  |  |")
            continue
        if "error" in r:
            continue
        t = r.get("roofline", {})
        out.append(
            f"| {r['arch']} | {r['shape']} | {t.get('compute_s', 0):.4g} "
            f"| {t.get('memory_s', 0):.4g} | {t.get('collective_s', 0):.4g} "
            f"| **{t.get('dominant', '?').replace('_s','')}** "
            f"| {t.get('model_flops', 0):.3g} | {t.get('hlo_flops_global', 0):.3g} "
            f"| {t.get('useful_ratio', 0):.3g} "
            f"| {t.get('step_time_bound_s', 0):.4g} |")
    return "\n".join(out)


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_singlepod.jsonl"
    recs = load(path)
    print("## Dry-run records\n")
    print(dryrun_table(recs))
    print("\n## Roofline\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
