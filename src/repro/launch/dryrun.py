import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below runs with 512 placeholder host devices ---------------
import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402
from typing import Any, Optional  # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config, list_archs                    # noqa: E402
from repro.distributed.sharding import (ShardingPlan, batch_specs,  # noqa: E402
                                        cache_specs, named,
                                        param_specs, zero1_specs)
from repro.launch.mesh import make_production_mesh                  # noqa: E402
from repro.launch.roofline import (collective_bytes_by_kind,        # noqa: E402
                                   roofline_terms)
from repro.launch.specs import (batch_specs_for, cache_specs_for,   # noqa: E402
                                cell_applicable, decode_token_spec)
from repro.models.config import SHAPES                              # noqa: E402
from repro.models.model import LM                                   # noqa: E402
from repro.training.optimizer import OptimConfig, apply_updates     # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results")


def _abstract_params(lm: LM):
    return jax.eval_shape(lambda: lm.init(jax.random.PRNGKey(0)))


def _abstract_opt(params_shape):
    from repro.training.optimizer import init_opt_state
    return jax.eval_shape(lambda: init_opt_state(params_shape))


def _mem_analysis(compiled) -> dict:
    out: dict[str, Any] = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                out[attr] = int(v)
    except Exception as e:  # backend-dependent availability
        out["error"] = str(e)
    return out


def _cost_analysis(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float))}
    except Exception as e:
        return {"error": str(e)}


def dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
                plan: ShardingPlan = ShardingPlan(), verbose: bool = True,
                save_hlo: Optional[str] = None, unroll: bool = True,
                seq_parallel: bool = False,
                cfg_overrides: Optional[dict] = None) -> dict:
    """Lower + compile one (arch x shape x mesh) cell; returns the record.

    ``unroll=True`` unrolls layer-stack scans so cost_analysis counts every
    layer (needed for the single-pod roofline table).  The multi-pod sweep —
    which only proves shardability — uses ``unroll=False`` (10x faster
    compiles, identical partitioning decisions).
    """
    t0 = time.time()
    import dataclasses
    cfg = dataclasses.replace(get_config(arch), scan_unroll=unroll,
                              **(cfg_overrides or {}))
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    rec: dict[str, Any] = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "chips": int(n_chips), "kind": shape.kind,
        "plan": {"fsdp": plan.fsdp, "zero1": plan.zero1,
                 "seq_parallel": seq_parallel, "unroll": unroll,
                 **(cfg_overrides or {})},
    }
    ok, why = cell_applicable(cfg, shape_name)
    if not ok:
        rec["skipped"] = why
        if verbose:
            print(f"[skip] {arch} x {shape_name}: {why}")
        return rec

    lm = LM(cfg)
    params_shape = _abstract_params(lm)
    pspecs = param_specs(params_shape, mesh, plan)
    p_shard = named(mesh, pspecs)

    import contextlib
    from repro.distributed.context import (activation_spec, shard_context,
                                           sequence_parallel_spec)
    dp = tuple(a for a in mesh.axis_names if a != "model")
    act_ctx = (activation_spec(sequence_parallel_spec(dp))
               if seq_parallel else contextlib.nullcontext())
    sm_ctx = (shard_context(mesh, dp, "model")
              if cfg.moe_impl == "sharded" else contextlib.nullcontext())
    with mesh, act_ctx, sm_ctx:
        if shape.kind == "train":
            opt_shape = _abstract_opt(params_shape)
            ospecs = zero1_specs(opt_shape["m"], pspecs, mesh, plan)
            state_shape = {"params": params_shape,
                           "opt": {"m": opt_shape["m"], "v": opt_shape["v"],
                                   "step": opt_shape["step"]}}
            state_shard = {"params": p_shard,
                           "opt": {"m": named(mesh, ospecs),
                                   "v": named(mesh, ospecs),
                                   "step": None}}
            batch_shape = batch_specs_for(cfg, shape)
            b_shard = named(mesh, batch_specs(batch_shape, mesh))
            ocfg = OptimConfig()

            def train_step(state, batch):
                (loss, _), grads = jax.value_and_grad(
                    lm.loss, has_aux=True)(state["params"], batch)
                p2, o2, info = apply_updates(state["params"], grads,
                                             state["opt"], ocfg)
                return {"params": p2, "opt": o2}, (loss, info["grad_norm"])

            jitted = jax.jit(train_step,
                             in_shardings=(state_shard, b_shard),
                             out_shardings=(state_shard, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_shape, batch_shape)
        elif shape.kind == "prefill":
            batch_shape = batch_specs_for(cfg, shape)
            b_shard = named(mesh, batch_specs(batch_shape, mesh))
            # pin the emitted caches' layout (unconstrained out-shardings let
            # GSPMD pick gather-happy layouts — §Perf E)
            out_shape = jax.eval_shape(lm.prefill, params_shape, batch_shape)
            c_shard = named(mesh, cache_specs(out_shape[1], mesh, plan))
            jitted = jax.jit(lm.prefill, in_shardings=(p_shard, b_shard),
                             out_shardings=(None, c_shard))
            lowered = jitted.lower(params_shape, batch_shape)
        else:  # decode
            caches_shape = cache_specs_for(cfg, shape)
            c_shard = named(mesh, cache_specs(caches_shape, mesh, plan))
            tok_shape = decode_token_spec(cfg, shape)
            t_shard = named(mesh, batch_specs(tok_shape, mesh))
            jitted = jax.jit(lm.decode_step,
                             in_shardings=(p_shard, c_shard, t_shard, None),
                             out_shardings=(None, c_shard),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_shape, caches_shape, tok_shape,
                                   jax.ShapeDtypeStruct((), jnp.int32))

        t_lower = time.time()
        compiled = lowered.compile()
        t_compile = time.time()

    rec["lower_s"] = round(t_lower - t0, 1)
    rec["compile_s"] = round(t_compile - t_lower, 1)
    rec["memory_analysis"] = _mem_analysis(compiled)
    rec["cost_analysis"] = _cost_analysis(compiled)
    hlo = compiled.as_text()
    rec["collectives"] = collective_bytes_by_kind(hlo)
    rec["roofline"] = roofline_terms(rec, cfg, shape)
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    if verbose:
        ca = rec["cost_analysis"]
        print(f"[ok] {arch} x {shape_name} ({'2-pod 512' if multi_pod else '1-pod 256'}) "
              f"lower {rec['lower_s']}s compile {rec['compile_s']}s")
        print(f"     memory_analysis: {rec['memory_analysis']}")
        print(f"     cost_analysis: flops/device={ca.get('flops', float('nan')):.3e} "
              f"bytes/device={ca.get('bytes accessed', float('nan')):.3e}")
        print(f"     collectives (per-device bytes): {rec['collectives']}")
        print(f"     roofline: {rec['roofline']}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="train_4k|prefill_32k|decode_32k|long_500k|all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--no-unroll", action="store_true",
                    help="rolled layer scans: fast compiles, FLOP counts "
                         "undercount loop bodies (use for multi-pod pass)")
    ap.add_argument("--seq-parallel", action="store_true",
                    help="sequence-shard the residual stream over 'model'")
    ap.add_argument("--attn-impl", default=None,
                    choices=["einsum", "bf16", "qchunk"],
                    help="attention implementation override (perf iteration)")
    ap.add_argument("--attn-chunk", type=int, default=None)
    ap.add_argument("--remat", default=None, choices=["none", "dots", "full"])
    ap.add_argument("--moe-impl", default=None, choices=["global", "sharded"])
    ap.add_argument("--scan-chunk", type=int, default=None,
                    help="SSM/mLSTM chunkwise length override")
    ap.add_argument("--cache-layout", default=None,
                    choices=["feature", "seq"],
                    help="decode cache sharding layout (§Perf D)")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    plan = ShardingPlan(fsdp=args.fsdp, zero1=not args.no_zero1,
                        cache_layout=args.cache_layout or "feature")
    overrides = {}
    if args.attn_impl:
        overrides["attn_impl"] = args.attn_impl
    if args.attn_chunk:
        overrides["attn_chunk"] = args.attn_chunk
    if args.remat:
        overrides["remat"] = args.remat
    if args.moe_impl:
        overrides["moe_impl"] = args.moe_impl
    if args.scan_chunk:
        overrides["scan_chunk"] = args.scan_chunk

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    rec = dryrun_cell(arch, shape, multi_pod=mp, plan=plan,
                                      save_hlo=args.save_hlo,
                                      unroll=not args.no_unroll,
                                      seq_parallel=args.seq_parallel,
                                      cfg_overrides=overrides or None)
                except Exception as e:
                    n_fail += 1
                    rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                           "error": f"{type(e).__name__}: {e}"}
                    print(f"[FAIL] {arch} x {shape}: {e}")
                    traceback.print_exc()
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")


if __name__ == "__main__":
    main()
