"""Close the loop: derive the ORDER BY optimizer's PriceSheet from OUR OWN
serving roofline, instead of an external API's price list.

The paper bills oracle calls at an API's $/Mtoken.  When the oracle is a
model this framework serves, the honest price is

    $/token = (chips x $/chip-hour / 3600) / (tokens/s at the roofline bound)

with prefill tokens priced off the prefill_32k cell and decode tokens off
decode_32k.  ``price_sheet_from_roofline`` reads dry-run records and returns
a :class:`repro.core.oracles.base.PriceSheet` the optimizer consumes
unchanged — cost-based access-path selection end-to-end on our own pods.
"""
from __future__ import annotations

import json

from ..core.oracles.base import PriceSheet
from ..models.config import SHAPES


def _bound(rec: dict) -> float:
    return rec["roofline"]["step_time_bound_s"]


def price_sheet_from_records(recs: list[dict], arch: str,
                             chip_hour_usd: float = 1.20,
                             utilization: float = 0.6) -> PriceSheet:
    """PriceSheet for ``arch`` from its prefill/decode roofline bounds.

    ``utilization`` discounts ideal roofline throughput to a realistic
    serving duty cycle.
    """
    by = {(r["arch"], r["shape"]): r for r in recs
          if "roofline" in r and not r.get("multi_pod")}
    pre = by.get((arch, "prefill_32k"))
    dec = by.get((arch, "decode_32k"))
    if pre is None or dec is None:
        raise KeyError(f"no prefill/decode records for {arch}")
    chips = pre["chips"]
    pod_usd_per_s = chips * chip_hour_usd / 3600.0

    pre_tok_s = SHAPES["prefill_32k"].tokens_per_step / _bound(pre) * utilization
    dec_tok_s = SHAPES["decode_32k"].tokens_per_step / _bound(dec) * utilization
    return PriceSheet(
        input_per_mtok=pod_usd_per_s / pre_tok_s * 1e6,
        output_per_mtok=pod_usd_per_s / dec_tok_s * 1e6,
        name=f"{arch}@self-hosted",
    )


def price_sheet_from_file(path: str, arch: str, **kw) -> PriceSheet:
    with open(path) as f:
        recs = [json.loads(l) for l in f if l.strip()]
    return price_sheet_from_records(recs, arch, **kw)
