"""Production mesh definition (TPU v5e, 256 chips/pod).

Defined as a FUNCTION so importing this module never touches jax device
state — device count is locked on first jax init, and only dryrun.py (which
sets XLA_FLAGS before any import) may build the 256/512-device meshes.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


def parse_mesh(spec: str):
    """Build a ("data", "model") mesh from a ``DxM`` flag string (e.g.
    ``8x1``, ``4x2``) — the serving launcher's ``--mesh``.  The product
    must not exceed the visible device count (force extra CPU devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``)."""
    parts = spec.lower().split("x")
    if len(parts) != 2:
        raise ValueError(f"--mesh expects DxM (e.g. 8x1), got {spec!r}")
    data, model = (int(p) for p in parts)
    have = jax.device_count()
    if data * model > have:
        raise ValueError(
            f"mesh {data}x{model} needs {data * model} devices, "
            f"{have} visible (set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N "
            f"before jax initializes)")
    return make_local_mesh(data, model)


# TPU v5e hardware constants (per chip) — the roofline denominators.
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_LINK_BW = 50e9              # bytes/s per link
