"""input_specs(): ShapeDtypeStruct stand-ins for every model input of every
(arch x shape) cell — weak-type-correct, shardable, zero device allocation.

Cell semantics:
  train_4k     train_step(state, batch)            tokens (B, S)
  prefill_32k  prefill(params, batch)              context ingestion
  decode_32k   decode_step(params, caches, tok, pos)  one token, S-cache
  long_500k    decode_step with a 524288-token state  (sub-quadratic archs)

Modality stubs per the assignment: [vlm] gets precomputed patch embeddings
(B, S, D) + M-RoPE position ids (3, B, S); [audio] gets encoder frame
embeddings; enc-dec splits seq_len equally between encoder and decoder.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..models.config import SHAPES, InputShape, ModelConfig
from ..models.model import LM

i32 = jnp.int32
bf16 = jnp.bfloat16


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def batch_specs_for(cfg: ModelConfig, shape: InputShape) -> dict[str, Any]:
    """Abstract batch for train/prefill cells."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.input_mode == "embeds":
        out = {"embeds": sds((b, s, cfg.d_model), bf16)}
        if cfg.mrope_sections:
            out["positions"] = sds((3, b, s), i32)
        if shape.kind == "train":
            out["tokens"] = sds((b, s), i32)     # targets
        return out
    if cfg.input_mode == "encdec":
        se = s // 2
        return {"enc_embeds": sds((b, se, cfg.d_model), bf16),
                "tokens": sds((b, se), i32)}
    return {"tokens": sds((b, s), i32)}


def cache_specs_for(cfg: ModelConfig, shape: InputShape) -> Any:
    """Abstract decode caches (layer-stacked pytree) for decode cells."""
    lm = LM(cfg)
    b = shape.global_batch
    cache_len = shape.seq_len if cfg.input_mode != "encdec" else shape.seq_len // 2
    enc_len = shape.seq_len // 2 if cfg.input_mode == "encdec" else 0
    return jax.eval_shape(
        lambda: lm.init_caches(b, cache_len, enc_len=enc_len))


def decode_token_spec(cfg: ModelConfig, shape: InputShape) -> Any:
    b = shape.global_batch
    if cfg.input_mode == "embeds":
        return sds((b, 1, cfg.d_model), bf16)
    return sds((b, 1), i32)


def input_specs(cfg: ModelConfig, shape_name: str) -> dict[str, Any]:
    """All abstract inputs for one cell (params excluded — see dryrun)."""
    shape = SHAPES[shape_name]
    out: dict[str, Any] = {"batch": batch_specs_for(cfg, shape)}
    if shape.kind == "decode":
        out = {
            "caches": cache_specs_for(cfg, shape),
            "token": decode_token_spec(cfg, shape),
            "position": sds((), i32),
        }
    return out


def cell_applicable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """long_500k only for sub-quadratic-state archs (DESIGN.md skip table)."""
    if shape_name == "long_500k" and not cfg.long_context_ok:
        return False, ("pure full-attention arch: a 524288-token dense KV "
                       "cache is not sub-quadratic (documented skip)")
    return True, ""
