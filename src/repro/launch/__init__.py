"""Launchers: production mesh, multi-pod dry-run, roofline, train, serve.

NOTE: import ``repro.launch.dryrun`` only as a __main__ entry point — its
first two lines set XLA_FLAGS to 512 host devices, which locks the device
count for the whole process.
"""
from .mesh import make_local_mesh, make_production_mesh

__all__ = ["make_local_mesh", "make_production_mesh"]
