"""Roofline analysis from the compiled dry-run artifact.

Three terms per (arch x shape x mesh), all in seconds:

  compute    = HLO_FLOPs_global   / (chips * 197e12  bf16 FLOP/s)
  memory     = HLO_bytes_global   / (chips * 819e9   B/s HBM)
  collective = coll_bytes_global  / (chips * 50e9    B/s ICI link)

``compiled.cost_analysis()`` and the post-partitioning HLO text are
*per-device* (SPMD emits one program), so global = per-device x chips; the
two conventions cancel and each term equals per-device quantity / per-chip
bandwidth.  Collective bytes are the RESULT buffer sizes of every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute
op (for a ring all-reduce the wire traffic is ~2x the buffer; we report the
buffer convention and note it in EXPERIMENTS.md).

MODEL_FLOPS uses the 6*N*D (train) / 2*N*D (inference) convention with
N = active params (MoE: top-k experts only), D = tokens processed; the
ratio MODEL_FLOPS / HLO_FLOPs exposes remat recompute, attention FLOPs,
and padding/dispatch waste.
"""
from __future__ import annotations

import re
from typing import Any

from ..models.config import InputShape, ModelConfig
from .mesh import HBM_BW, ICI_LINK_BW, PEAK_FLOPS_BF16

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

# result type(s) of an HLO instruction: "f32[128,1024]{1,0}" or a tuple
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_by_kind(hlo_text: str) -> dict[str, Any]:
    """Per-device result bytes of every collective op, by kind + count.
    Ops inside while-loop bodies are counted once per body occurrence
    (trip-count weighting is applied by the caller via layer counts when
    needed; scan-over-layers collectives appear once in the body)."""
    out = {k: 0 for k in COLLECTIVE_OPS}
    counts = {k: 0 for k in COLLECTIVE_OPS}
    for m in _INSTR_RE.finditer(hlo_text):
        type_str, op = m.group(1), m.group(2)
        out[op] += _type_bytes(type_str)
        counts[op] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


def _while_trip_counts(hlo_text: str) -> list[int]:
    """Best-effort trip counts from XLA's while-loop analysis comments."""
    return [int(x) for x in re.findall(r"trip_count=(\d+)", hlo_text)]


def inner_scan_flop_correction(cfg: ModelConfig, shape: InputShape) -> float:
    """GLOBAL FLOPs that XLA's cost analysis misses because they sit inside
    rolled inner recurrence scans (counted once instead of trip_count times).

    Outer layer-stack and loss scans are fully unrolled for the dry-run
    (cfg.scan_unroll), so only the SSM / mLSTM chunk scans and the sLSTM
    per-step scan need correction.  Matmul terms only (elementwise undercount
    is <1% of these blocks); train cells get the standard fwd+bwd multiplier
    of 3x.
    """
    if shape.kind == "decode":
        return 0.0  # decode has no inner scans (single-step recurrences)
    toks = shape.global_batch * shape.seq_len
    s = shape.seq_len
    t = cfg.scan_chunk
    mult = 3.0 if shape.kind == "train" else 1.0
    missing = 0.0
    for kind, n_layers in cfg.pattern:
        if kind in ("hymba_g", "hymba_l"):
            di, ns = cfg.d_inner, cfg.ssm_state
            per_tok = 2 * di * ns * 3          # assoc-scan compose + y-einsum
            n_chunks = max(s // t, 1)
            missing += n_layers * per_tok * toks * (n_chunks - 1) / n_chunks
        elif kind == "mlstm":
            h, dqk, dv = cfg.n_heads, cfg.qk, cfg.hd
            n_chunks = max(s // t, 1)
            body = (2 * h * (3 * t * t * max(dqk, dv)          # scores/intra/n
                             + 3 * t * dqk * dv)               # inter + carry
                    * shape.global_batch)
            missing += n_layers * body * (n_chunks - 1)
        elif kind == "slstm":
            h, hd = cfg.n_heads, cfg.hd
            per_step = 8 * h * hd * hd * shape.global_batch   # 4 rec matmuls
            missing += n_layers * per_step * (s - 1)
    return missing * mult


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    n_active = cfg.active_param_count()
    toks = shape.tokens_per_step
    if shape.kind == "train":
        return 6.0 * n_active * toks
    return 2.0 * n_active * toks


def roofline_terms(rec: dict, cfg: ModelConfig, shape: InputShape) -> dict:
    chips = rec["chips"]
    ca = rec.get("cost_analysis", {})
    flops_dev = ca.get("flops", 0.0) or 0.0
    bytes_dev = ca.get("bytes accessed", 0.0) or 0.0
    coll_dev = rec.get("collectives", {}).get("total_bytes", 0) or 0

    correction = inner_scan_flop_correction(cfg, shape)
    hlo_global = flops_dev * chips + correction
    compute_s = hlo_global / (chips * PEAK_FLOPS_BF16)
    memory_s = (bytes_dev * chips) / (chips * HBM_BW)
    collective_s = (coll_dev * chips) / (chips * ICI_LINK_BW)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=lambda k: terms[k])
    mf = model_flops(cfg, shape)
    return {
        **{k: float(f"{v:.6g}") for k, v in terms.items()},
        "dominant": dominant,
        "model_flops": float(f"{mf:.6g}"),
        "hlo_flops_global": float(f"{hlo_global:.6g}"),
        "inner_scan_correction": float(f"{correction:.6g}"),
        "useful_ratio": float(f"{(mf / hlo_global if hlo_global else 0):.4g}"),
        "step_time_bound_s": float(f"{max(terms.values()):.6g}"),
    }
