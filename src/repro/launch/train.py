"""Production training launcher: ``python -m repro.launch.train --arch <id>``.

On real hardware this runs under multi-host JAX (jax.distributed.initialize
before anything else); in this container it runs reduced configs on the local
device — the full configs are exercised by dryrun.py.  Either way the code
path is identical: sharded state, auto-resume, straggler watchdog, the works.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, get_reduced, list_archs
from repro.data import DataConfig, DataPipeline
from repro.models import LM
from repro.training import OptimConfig, TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="reduced config (full configs need a pod)")
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", default=None,
                    help="cosine|wsd|const (default: wsd for minicpm)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    lm = LM(cfg)
    schedule = args.schedule or ("wsd" if args.arch == "minicpm-2b" else "cosine")
    tc = TrainConfig(
        steps=args.steps, ckpt_dir=args.ckpt_dir,
        grad_accum=args.grad_accum, compression=args.compress_grads,
        optim=OptimConfig(lr=args.lr, schedule=schedule,
                          warmup_steps=max(args.steps // 20, 5),
                          total_steps=args.steps),
    )
    pipe = DataPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                   seq_len=args.seq,
                                   global_batch=args.batch,
                                   seed=args.seed))
    trainer = Trainer(lm, tc)
    state = trainer.init_state(jax.random.PRNGKey(args.seed))
    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"arch={cfg.name} params={n_params:,} schedule={schedule} "
          f"steps={args.steps}")
    out = trainer.run(state, iter(pipe), resume=args.ckpt_dir is not None)
    h = out["history"]
    print(f"done: loss {h[0]['loss']:.4f} -> {h[-1]['loss']:.4f}; "
          f"median step {trainer.watchdog.median*1e3:.0f}ms; "
          f"stragglers flagged: {len(trainer.watchdog.flagged)}")


if __name__ == "__main__":
    main()
