"""Serving launcher: hosts a model behind the ORDER BY ModelOracle and runs
semantic ORDER BY queries against it.

``python -m repro.launch.serve --arch stablelm-1.6b --query "positivity" ...``
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, get_reduced, list_archs
from repro.core import as_keys, llm_order_by
from repro.core.oracles.model_oracle import ModelOracle
from repro.models import LM
from repro.serving import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--query", default="degree of positivity")
    ap.add_argument("--path", default="auto")
    ap.add_argument("--strategy", default="borda")
    ap.add_argument("--limit", type=int, default=5)
    ap.add_argument("--budget", type=float, default=None)
    ap.add_argument("--items", nargs="*", default=None)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    engine = ServeEngine(lm, params, max_new_tokens=16)
    oracle = ModelOracle(engine)

    items = args.items or [
        "absolutely loved it, best purchase ever",
        "terrible, broke after one day",
        "it is fine, nothing special",
        "pretty good overall, minor flaws",
        "worst experience of my life",
        "exceeded every expectation",
        "mediocre at best",
        "would recommend with reservations",
    ]
    keys = as_keys(items)
    result, report = llm_order_by(
        keys, args.query, oracle, path=args.path, descending=True,
        limit=args.limit, budget=args.budget, strategy=args.strategy,
        sample_size=min(8, len(keys)))
    print(f"arch={cfg.name} path={result.path} calls={result.n_calls} "
          f"cost=${result.cost:.5f}")
    if report is not None:
        print(f"optimizer: chose={report.chosen.label} reason={report.reason} "
              f"membership={report.membership_rate:.2f}")
    for i, k in enumerate(result.order):
        print(f"  {i+1}. {k.text}")
    print(f"engine stats: {engine.stats}")


if __name__ == "__main__":
    main()
