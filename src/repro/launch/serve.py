"""Serving launcher: hosts a model behind the ORDER BY ModelOracle and runs
semantic ORDER BY queries against it.

``python -m repro.launch.serve --arch stablelm-1.6b --query "positivity" ...``

Sharded serving: ``--mesh DxM`` (e.g. ``--mesh 8x1``) lowers the engine onto
a ("data", "model") mesh — probe rounds split into per-data-shard row
slices, decode runs tensor-parallel over the model axis — and ``--fsdp``
additionally shards the weights over the data axes.  On CPU, force devices
with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config, get_reduced, list_archs
from repro.core import as_keys, llm_order_by
from repro.core.oracles.model_oracle import ModelOracle
from repro.distributed.sharding import ShardingPlan
from repro.launch.mesh import parse_mesh
from repro.models import LM
from repro.serving import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--query", default="degree of positivity")
    ap.add_argument("--path", default="auto")
    ap.add_argument("--strategy", default="borda")
    ap.add_argument("--limit", type=int, default=5)
    ap.add_argument("--budget", type=float, default=None)
    ap.add_argument("--items", nargs="*", default=None)
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="serve on a data x model mesh (e.g. 8x1, 4x2)")
    ap.add_argument("--fsdp", action="store_true",
                    help="also shard weights over the data axes")
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    mesh = parse_mesh(args.mesh) if args.mesh else None
    if args.fsdp and mesh is None:
        raise SystemExit("--fsdp requires --mesh")
    engine = ServeEngine(lm, params, max_new_tokens=16, mesh=mesh,
                         plan=ShardingPlan(fsdp=args.fsdp) if mesh else None)
    oracle = ModelOracle(engine)

    items = args.items or [
        "absolutely loved it, best purchase ever",
        "terrible, broke after one day",
        "it is fine, nothing special",
        "pretty good overall, minor flaws",
        "worst experience of my life",
        "exceeded every expectation",
        "mediocre at best",
        "would recommend with reservations",
    ]
    keys = as_keys(items)
    t0 = time.perf_counter()
    result, report = llm_order_by(
        keys, args.query, oracle, path=args.path, descending=True,
        limit=args.limit, budget=args.budget, strategy=args.strategy,
        sample_size=min(8, len(keys)))
    print(f"arch={cfg.name} path={result.path} calls={result.n_calls} "
          f"cost=${result.cost:.5f}")
    if report is not None:
        print(f"optimizer: chose={report.chosen.label} reason={report.reason} "
              f"membership={report.membership_rate:.2f}")
    dt = time.perf_counter() - t0
    for i, k in enumerate(result.order):
        print(f"  {i+1}. {k.text}")
    tps = engine.stats.decode_tokens / dt if dt > 0 else 0.0
    mesh_note = f" mesh={args.mesh}" if args.mesh else ""
    print(f"engine stats: {engine.stats}")
    print(f"throughput:{mesh_note} decode_tokens={engine.stats.decode_tokens} "
          f"wall={dt:.3f}s decode_tokens_per_s={tps:.1f}")


if __name__ == "__main__":
    main()
