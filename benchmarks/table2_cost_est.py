"""Paper Table 2: sampled-cost extrapolation vs true execution cost per
algorithm (20-key samples, World-Population-like dataset)."""
from __future__ import annotations

from repro.core import SimulatedOracle
from repro.core.datasets import world_population
from repro.core.optimizer.cost_model import (default_candidates,
                                             estimate_full_cost)
from repro.core.types import SortSpec

from .common import emit


def main(n: int = 100, n_sample: int = 20) -> list[tuple]:
    task = world_population(n=n)
    spec = SortSpec(task.criteria, True, None)
    sample = task.keys[:n_sample]
    rows = [("table2", "algorithm", "est_usd", "true_usd", "diff_usd")]
    for cand in default_candidates():
        o_s = SimulatedOracle(task.profile)
        res_s = cand.make().execute(sample, o_s, spec)
        est = estimate_full_cost(cand, res_s.cost, n_sample, n, None)
        o_f = SimulatedOracle(task.profile)
        res_f = cand.make().execute(task.keys, o_f, spec)
        rows.append(("table2", cand.label, round(est, 4),
                     round(res_f.cost, 4), round(est - res_f.cost, 4)))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
