"""Table 5 (repo-specific): prefill tokens saved by the prefix-KV cache.

Runs access paths on the REAL ModelOracle backend twice — prefix cache OFF
vs ON (two engines sharing one set of weights) — and reports padded prefill
tokens, serving submissions, prefix-cache hit rate, and token savings.
Output order and the oracle ledger (logical calls + billed tokens) are
byte-identical in both modes: the cache is bit-exact by construction
(DESIGN.md "Prefix-KV cache"), so only serving-side prefill work drops.

The headline acceptance check: quicksort at N=64 must prefill >= 30% fewer
tokens with the cache on (the pivot block of each partition round is
prefilled once instead of once per row).

    PYTHONPATH=src python -m benchmarks.table5_prefix_cache [--json OUT] [N ...]
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

from repro.core import PathParams, as_keys, make_path
from repro.core.oracles.model_oracle import ModelOracle
from repro.core.types import SortSpec

PATHS = ("quick", "ext_merge", "pointwise")


def _engines(max_new: int = 8):
    import jax
    from repro.configs import get_reduced
    from repro.models import LM
    from repro.serving import ServeEngine
    cfg = get_reduced("llama3-8b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    return (ServeEngine(lm, params, max_new_tokens=max_new,
                        prefix_cache_size=0),
            ServeEngine(lm, params, max_new_tokens=max_new))


def run(sizes: list[int]) -> list[dict]:
    eng_off, eng_on = _engines()
    rng = np.random.default_rng(0)
    rows: list[dict] = []
    for n in sizes:
        keys = as_keys([f"doc {i:04d}" for i in range(n)],
                       list(rng.standard_normal(n)))
        spec = SortSpec("relevance", True, None)
        for path in PATHS:
            out = {}
            h0, m0, s0 = (eng_on.stats.prefix_hits, eng_on.stats.prefix_misses,
                          eng_on.stats.prefix_tokens_saved)
            f0 = eng_on.stats.prefix_fill_submissions
            for mode, eng in (("off", eng_off), ("on", eng_on)):
                oracle = ModelOracle(eng)
                t0_tok, t0_sub = eng.stats.prefill_tokens, eng.stats.calls
                t0 = time.perf_counter()
                res = make_path(path, PathParams(batch_size=4)).execute(
                    keys, oracle, spec)
                out[mode] = dict(
                    prefill_tokens=eng.stats.prefill_tokens - t0_tok,
                    submissions=eng.stats.calls - t0_sub,
                    seconds=round(time.perf_counter() - t0, 3),
                    ledger=(oracle.ledger.n_calls, oracle.ledger.input_tokens,
                            oracle.ledger.output_tokens),
                    uids=res.uids(),
                )
            reduction = 1.0 - out["on"]["prefill_tokens"] / max(
                out["off"]["prefill_tokens"], 1)
            hits = eng_on.stats.prefix_hits - h0
            misses = eng_on.stats.prefix_misses - m0
            row = dict(
                path=path, n=n,
                prefill_tokens_off=out["off"]["prefill_tokens"],
                prefill_tokens_on=out["on"]["prefill_tokens"],
                reduction=round(reduction, 4),
                submissions_off=out["off"]["submissions"],
                submissions_on=out["on"]["submissions"],
                # probe submissions stay near parity (<= one extra plain
                # submission per class when selected and demoted rows mix);
                # region fills are the extra (tiny) forward passes the
                # cache spends to save per-row tokens
                fill_submissions_on=(eng_on.stats.prefix_fill_submissions
                                     - f0),
                seconds_off=out["off"]["seconds"],
                seconds_on=out["on"]["seconds"],
                hit_rate=round(hits / max(hits + misses, 1), 4),
                tokens_saved=eng_on.stats.prefix_tokens_saved - s0,
                order_identical=out["off"]["uids"] == out["on"]["uids"],
                ledger_identical=out["off"]["ledger"] == out["on"]["ledger"],
            )
            rows.append(row)
            assert row["order_identical"] and row["ledger_identical"], row
            if path == "quick" and n >= 64:
                assert reduction >= 0.30, (
                    f"quick N={n}: prefix cache saved only {reduction:.1%} "
                    f"prefill tokens (acceptance floor: 30%)")
    return rows


def main() -> None:
    from benchmarks.common import parse_json_flag
    argv, json_path = parse_json_flag(sys.argv[1:])
    sizes = [int(a) for a in argv if a.isdigit()] or [64]
    rows = run(sizes)
    cols = ("path", "n", "prefill_tokens_off", "prefill_tokens_on",
            "reduction", "submissions_off", "submissions_on",
            "fill_submissions_on", "hit_rate", "order_identical",
            "ledger_identical")
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
