"""Table 6 (repo-specific): paged continuous-batching decode vs lockstep.

A judge-style mixed-length generation workload (many short verdicts, a few
long rationale stragglers — the LLM-as-Judge traffic of Sec. 5.4) runs
twice over one set of weights:

 * **lockstep** — the padded batch loop: every batch decodes until its
   longest row finishes, so short rows idle in their slots behind the
   straggler (head-of-line blocking);
 * **paged** — the continuous step loop over the block-paged KV pool:
   finished rows retire and free their blocks immediately, queued requests
   are admitted into the vacated slots between steps.

The headline metric is **straggler waste**: ``decode_row_steps``
(physical row-slots occupied across decode steps) minus ``decode_tokens``
(useful tokens produced).  Acceptance: the paged loop wastes FEWER
decode-row steps than lockstep, and its outputs are token-identical to the
solo lockstep baseline per request (the bit-identity contract of
DESIGN.md "Paged KV pool").

    PYTHONPATH=src python -m benchmarks.table6_paged_decode [--json OUT] [N ...]
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

MAX_NEW = 24


def _engines():
    import jax
    from repro.configs import get_reduced
    from repro.models import LM
    from repro.serving import ServeEngine
    cfg = get_reduced("llama3-8b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    return (ServeEngine(lm, params, max_new_tokens=MAX_NEW, pool_blocks=0),
            ServeEngine(lm, params, max_new_tokens=MAX_NEW,
                        max_decode_rows=8))


def workload(n: int, seed: int = 0):
    """n mixed-length judge requests: ~3/4 short verdicts (2-4 tokens),
    ~1/4 long rationale stragglers (the full budget)."""
    rng = np.random.default_rng(seed)
    prompts, limits = [], []
    for i in range(n):
        straggler = i % 4 == 3
        body = "criteria compliance of candidate ranking " + "x" * int(
            rng.integers(0, 40))
        prompts.append(f"Judge {i}: {body}\nVerdict:")
        limits.append(MAX_NEW if straggler else int(rng.integers(2, 5)))
    return prompts, limits


def run(sizes: list[int]) -> list[dict]:
    from repro.serving import BatchScheduler
    eng_lock, eng_paged = _engines()
    rows: list[dict] = []
    for n in sizes:
        prompts, limits = workload(n)
        out = {}
        for mode, eng in (("lockstep", eng_lock), ("paged", eng_paged)):
            sched = BatchScheduler(eng, max_batch=8,
                                   paged=(mode == "paged"))
            for p, l in zip(prompts, limits):
                sched.submit(p, max_new=l)
            s0 = (eng.stats.decode_row_steps, eng.stats.decode_tokens)
            t0 = time.perf_counter()
            drained = sched.run()
            dt = time.perf_counter() - t0
            row_steps = eng.stats.decode_row_steps - s0[0]
            useful = eng.stats.decode_tokens - s0[1]
            out[mode] = dict(
                outputs=[drained[r] for r in sorted(drained)],
                row_steps=row_steps, useful_tokens=useful,
                wasted_row_steps=row_steps - useful,
                seconds=round(dt, 3),
                tok_per_s=round(useful / max(dt, 1e-9), 1),
            )
        # token identity: paged == solo lockstep per request
        solo = [eng_lock.generate_lockstep([p], max_new_per=[l])[0]
                for p, l in zip(prompts, limits)]
        identical = out["paged"]["outputs"] == solo
        row = dict(
            n=n, max_new=MAX_NEW,
            useful_tokens=out["paged"]["useful_tokens"],
            lockstep_row_steps=out["lockstep"]["row_steps"],
            paged_row_steps=out["paged"]["row_steps"],
            lockstep_wasted=out["lockstep"]["wasted_row_steps"],
            paged_wasted=out["paged"]["wasted_row_steps"],
            lockstep_tok_per_s=out["lockstep"]["tok_per_s"],
            paged_tok_per_s=out["paged"]["tok_per_s"],
            token_identical=identical,
        )
        rows.append(row)
        assert identical, f"paged outputs diverged from solo lockstep (n={n})"
        assert row["paged_wasted"] < row["lockstep_wasted"], (
            f"paged wasted {row['paged_wasted']} decode-row steps vs "
            f"lockstep {row['lockstep_wasted']} (n={n}) — continuous "
            f"batching must waste fewer")
    return rows


def main() -> None:
    from benchmarks.common import parse_json_flag
    argv, json_path = parse_json_flag(sys.argv[1:])
    sizes = [int(a) for a in argv if a.isdigit()] or [24]
    rows = run(sizes)
    cols = ("n", "useful_tokens", "lockstep_row_steps", "paged_row_steps",
            "lockstep_wasted", "paged_wasted", "lockstep_tok_per_s",
            "paged_tok_per_s", "token_identical")
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
