"""Paper Table 3: sample-size sensitivity of Borda / Judge / Oracle
selection on the DL-like multi-query family (mean +/- std over 3 seeds)."""
from __future__ import annotations

import numpy as np

from repro.core import OptimizerConfig, AccessPathOptimizer, SimulatedOracle
from repro.core.datasets import dl_queries
from repro.core.types import SortSpec

from .common import emit, task_quality


def main(n_queries: int = 6, n: int = 60) -> list[tuple]:
    rows = [("table3", "samples", "strategy", "mean_ndcg", "std")]
    tasks = dl_queries(n_queries=n_queries, n=n)
    for s in (15, 20, 25):
        for strat in ("borda", "judge", "oracle"):
            means = []
            for seed in range(3):
                qs = []
                for t in tasks:
                    o = SimulatedOracle(t.profile)
                    opt = AccessPathOptimizer(OptimizerConfig(
                        sample_size=s, strategy=strat, seed=seed))
                    res, _ = opt.choose_and_execute(
                        t.keys, o, SortSpec(t.criteria, t.descending, t.limit))
                    qs.append(task_quality(t, res.order))
                means.append(float(np.mean(qs)))
            rows.append(("table3", s, strat, round(float(np.mean(means)), 4),
                         round(float(np.std(means)), 4)))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
