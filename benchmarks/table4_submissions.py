"""Table 4 (repo-specific): serving submissions saved by round batching.

Runs each access path on the REAL ModelOracle backend twice — once with the
seed's sequential point-call structure (``PathParams.coalesce=False``) and
once with round-based batched execution — and reports serving submissions
(``engine.stats.calls``), logical LLM calls (ledger), and wall-clock.  Output
order and ledger accounting are identical in both modes (uniform-length keys
keep padding identical); only the number of padded prefill submissions — and
therefore wall-clock — changes.

    PYTHONPATH=src python -m benchmarks.table4_submissions [--json OUT] [N ...]
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

from repro.core import PathParams, as_keys, make_path
from repro.core.oracles.model_oracle import ModelOracle
from repro.core.types import SortSpec

PATHS = ("quick", "ext_merge", "ext_bubble", "pointwise", "ext_pointwise")


def _engine(max_new: int = 8):
    import jax
    from repro.configs import get_reduced
    from repro.models import LM
    from repro.serving import ServeEngine
    cfg = get_reduced("llama3-8b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    return ServeEngine(lm, params, max_new_tokens=max_new)


def main() -> None:
    from benchmarks.common import parse_json_flag
    argv, json_path = parse_json_flag(sys.argv[1:])
    sizes = [int(a) for a in argv if a.isdigit()] or [64]
    rows: list[dict] = []
    eng = _engine()
    rng = np.random.default_rng(0)
    print("path,n,mode,submissions,logical_calls,seconds,order_identical")
    for n in sizes:
        keys = as_keys([f"doc {i:04d}" for i in range(n)],
                       list(rng.standard_normal(n)))
        spec = SortSpec("relevance", True, None)
        for path in PATHS:
            out = {}
            for coalesce in (False, True):
                # warm the jit cache so wall-clock measures steady-state
                # serving, not XLA compiles of first-seen shapes
                make_path(path, PathParams(batch_size=4, coalesce=coalesce)
                          ).execute(keys[: min(n, 16)], ModelOracle(eng),
                                    spec)
                oracle = ModelOracle(eng)
                c0 = eng.stats.calls
                t0 = time.perf_counter()
                res = make_path(path, PathParams(batch_size=4,
                                                 coalesce=coalesce)
                                ).execute(keys, oracle, spec)
                out[coalesce] = (eng.stats.calls - c0, oracle.ledger.n_calls,
                                 time.perf_counter() - t0, res.uids())
            same = out[False][3] == out[True][3]
            for coalesce in (False, True):
                subs, calls, secs, _ = out[coalesce]
                mode = "rounds" if coalesce else "sequential"
                print(f"{path},{n},{mode},{subs},{calls},{secs:.3f},{same}")
                rows.append(dict(path=path, n=n, mode=mode, submissions=subs,
                                 logical_calls=calls, seconds=round(secs, 3),
                                 order_identical=same))
            assert out[True][0] <= out[False][0], (path, n)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
