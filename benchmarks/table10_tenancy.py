"""Table 10 (repo-specific): multi-tenant serving — priority classes and
paged-pool preemption.

A saturating bulk decode stream (every row slot and most pool blocks
occupied, plus heavy probe rounds) is mid-drain when short interactive
requests arrive.  Both modes run the SAME unified step loop over the same
engine; they differ only in tenant policy:

 * **fifo** — no tenant classes (the pre-tenancy behavior): the
   interactive request queues behind bulk work and waits for a decode row
   to retire naturally;
 * **priority** — the interactive tenant has ``priority=10`` and a row
   reservation, bulk is preemptible with a probe quota: admission
   suspends a bulk row to the host stash (``KVBlockPool.stash_blocks``),
   the interactive request decodes immediately, and the victim resumes
   byte-identically once capacity returns.

Headline metric: **interactive completion latency in decode steps**
(submission to completion) p50/p99 per mode.  Acceptance (ISSUE 8):
priority p99 strictly improves on fifo p99, preemption actually fires
(``preempt_suspends >= 1``), and every output — bulk rows that were
suspended and resumed included — is token-identical (``==``) to a solo
lockstep run of the same prompt.

As with tables 6/8 the asserted metric is SCHEDULING latency (steps), not
CPU wall-clock; seconds and decode tokens/s are reported for visibility.

    PYTHONPATH=src python -m benchmarks.table10_tenancy [--json OUT] [N ...]
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

MAX_NEW = 16
LIVE_MAX_NEW = 3
LIVE_AT = (2, 6, 10)   # drain steps at which interactive requests arrive


def _engine():
    import jax
    from repro.configs import get_reduced
    from repro.models import LM
    from repro.serving import ServeEngine
    cfg = get_reduced("llama3-8b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    # a tight pool + few rows: bulk saturates, interactive must either
    # wait (fifo) or preempt (priority)
    return ServeEngine(lm, params, max_new_tokens=MAX_NEW,
                       max_decode_rows=3, pool_blocks=20, block_size=16)


def workload(n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [f"Bulk summarization job {i}: " + "x" * int(rng.integers(10, 40))
            for i in range(n)]


def _live_prompt(at: int) -> str:
    return f"Interactive lookup at step {at}: status?"


def run_mode(eng, bulk_prompts, priority: bool) -> dict:
    from repro.serving import BatchScheduler, TenantSpec
    sched = BatchScheduler(eng, max_batch=8)
    if priority:
        sched.register_tenant(TenantSpec("bulk", priority=0, probe_quota=4))
        sched.register_tenant(TenantSpec("live", priority=10,
                                         reserved_rows=1))
        bulk_t, live_t = "bulk", "live"
    else:
        bulk_t = live_t = "default"
    suspends0 = eng.stats.preempt_suspends
    tok0 = eng.stats.decode_tokens
    rids = [sched.submit(p, MAX_NEW, tenant=bulk_t) for p in bulk_prompts]
    submitted_at: dict[int, int] = {}
    done_at: dict[int, int] = {}
    probe_latency: list[int] = []
    arrivals = list(LIVE_AT)
    guard = 0
    t0 = time.perf_counter()
    while sched.work_remaining:
        fut = s0 = None
        if arrivals and sched.steps >= arrivals[0]:
            at = arrivals.pop(0)
            r = sched.submit(_live_prompt(at), LIVE_MAX_NEW, tenant=live_t)
            submitted_at[r] = sched.steps
            fut = sched.submit_probe_round([f"live probe {at}"],
                                           tenant=live_t)
            s0 = sched.steps
        if not all(r in sched.completed for r in rids):
            # bulk probe pressure rides along while bulk decodes drain
            sched.submit_probe_round(
                [f"bulk probe {sched.steps} {j}" for j in range(6)],
                tenant=bulk_t)
        sched.step()
        if fut is not None:
            assert fut.done, "interactive round must resolve next gap"
            probe_latency.append(sched.steps - s0)
        for r in submitted_at:
            if r in sched.completed and r not in done_at:
                done_at[r] = sched.steps
        guard += 1
        assert guard < 2000, "drain did not terminate"
    dt = time.perf_counter() - t0
    lat = [done_at[r] - submitted_at[r] for r in submitted_at]
    outs = {r: sched.completed[r].output
            for r in list(rids) + list(submitted_at)}
    return dict(
        outputs=outs, bulk_rids=rids,
        live=[(r, _live_prompt(at)) for at, r in
              zip(LIVE_AT, submitted_at)],
        p50=float(np.percentile(lat, 50)), p99=float(np.percentile(lat, 99)),
        probe_p99=float(np.percentile(probe_latency, 99)),
        steps=sched.steps, seconds=round(dt, 3),
        suspends=eng.stats.preempt_suspends - suspends0,
        tokens_per_s=round((eng.stats.decode_tokens - tok0) / dt, 1))


def run(sizes: list[int]) -> list[dict]:
    eng = _engine()
    rows: list[dict] = []
    for n in sizes:
        bulk = workload(n)
        solo = {p: eng.generate_lockstep([p], max_new_per=[m])[0]
                for p, m in ([(b, MAX_NEW) for b in bulk]
                             + [(_live_prompt(a), LIVE_MAX_NEW)
                                for a in LIVE_AT])}
        fifo = run_mode(eng, bulk, priority=False)
        prio = run_mode(eng, bulk, priority=True)
        ident = all(
            mode["outputs"][r] == solo[p]
            for mode in (fifo, prio)
            for r, p in (list(zip(mode["bulk_rids"], bulk)) + mode["live"]))
        row = dict(
            n_bulk=n, max_new=MAX_NEW, live_arrivals=len(LIVE_AT),
            fifo_p50=fifo["p50"], fifo_p99=fifo["p99"],
            priority_p50=prio["p50"], priority_p99=prio["p99"],
            fifo_probe_p99=fifo["probe_p99"],
            priority_probe_p99=prio["probe_p99"],
            priority_suspends=prio["suspends"],
            fifo_steps=fifo["steps"], priority_steps=prio["steps"],
            fifo_seconds=fifo["seconds"], priority_seconds=prio["seconds"],
            fifo_tokens_per_s=fifo["tokens_per_s"],
            priority_tokens_per_s=prio["tokens_per_s"],
            token_identical=ident)
        rows.append(row)
        assert row["token_identical"], (
            f"tenant-scheduled outputs diverged from solo lockstep (n={n})")
        assert row["priority_p99"] < row["fifo_p99"], (
            f"priority scheduling must improve interactive p99: "
            f"{row['priority_p99']} vs fifo {row['fifo_p99']} (n={n})")
        assert row["priority_suspends"] >= 1, (
            f"the priority scenario must actually preempt (n={n})")
    return rows


def main() -> None:
    from benchmarks.common import parse_json_flag
    argv, json_path = parse_json_flag(sys.argv[1:])
    sizes = [int(a) for a in argv if a.isdigit()] or [8]
    rows = run(sizes)
    cols = ("n_bulk", "live_arrivals", "fifo_p50", "fifo_p99",
            "priority_p50", "priority_p99", "fifo_probe_p99",
            "priority_probe_p99", "priority_suspends", "fifo_steps",
            "priority_steps", "fifo_seconds", "priority_seconds",
            "fifo_tokens_per_s", "priority_tokens_per_s", "token_identical")
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
