"""Paper Table 1: LLM-call complexity per access path (full sort vs LIMIT K).

Empirical call counts from an exact oracle, ratio-checked against the
asymptotic bound — ``bound_ratio`` near/below 1 means the implementation
matches its advertised complexity."""
from __future__ import annotations

import numpy as np

from repro.core import ExactOracle, PathParams, as_keys, make_path
from repro.core.access_paths.base import _REGISTRY
from repro.core.types import SortSpec

from .common import emit


def main(n: int = 128, k: int = 10, m: int = 4, v: int = 3) -> list[tuple]:
    rng = np.random.default_rng(0)
    keys = as_keys([f"k{i}" for i in range(n)], rng.standard_normal(n))
    rows = [("table1", "path", "mode", "calls_empirical", "calls_bound",
             "bound_ratio")]
    cands = [("pointwise", PathParams()),
             ("ext_pointwise", PathParams(batch_size=m)),
             ("quick", PathParams(votes=1)),
             ("quick", PathParams(votes=v)),
             ("ext_bubble", PathParams(batch_size=m)),
             ("ext_merge", PathParams(batch_size=m))]
    for path, params in cands:
        for mode, limit in (("full", None), (f"limit{k}", k)):
            o = ExactOracle()
            make_path(path, params).execute(keys, o,
                                            SortSpec("v", True, limit))
            bound = _REGISTRY[path].est_calls(n, limit, params)
            label = path if params.votes == 1 else f"{path}_{params.votes}"
            rows.append(("table1", label, mode, o.ledger.n_calls,
                         round(bound, 1),
                         round(o.ledger.n_calls / max(bound, 1), 3)))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
