"""Table 9 (repo-specific): locality-creating probe scheduling.

Three measurements over the REAL ModelOracle backend, comparing the
**reactive** PR 2 scheme (``ServeEngine(locality=False)``, fills on
demand, no prefetch) against the **locality** stack (GGR group-and-reorder
window jobs + executor prefix prefetch pipelining —
serving/locality.py):

 * **quick N=64** — one quicksort query with variable-length keys (the
   per-group suffix windows only pay off when suffix spans straddle
   power-of-two buckets), driven through the probe-plan executor on the
   unified loop;
 * **many4** — the 4-query ``llm_order_by_many`` workload (quick ASC +
   quick DESC twins over one criteria, ext_merge, pointwise) sharing one
   engine;
 * **memo** — a second wave of the same 4 queries arriving later with a
   shared :class:`SemanticMemo`: repeat comparisons/scores are served
   from the cross-query cache under first-requester-pays billing.

Acceptance (ISSUE 6): the reordered+prefetched runs must show strictly
higher prefix hit-rate AND prefill-tokens-saved than the reactive
baseline on BOTH workloads, with per-query orderings and ledgers (memo
wave: *reconciled* ledgers — billed records + recorded cache-hit shadows)
byte-identical (``==``) to solo execution, and a strict prefill-reduction
improvement over the reactive PR 2 baseline.

    PYTHONPATH=src python -m benchmarks.table9_locality [--json OUT] [N ...]
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

from repro.core import PathParams, ProbePlanExecutor, as_keys, make_path
from repro.core.executor import plan_sort_result
from repro.core.operator import OrderQuery, llm_order_by_many
from repro.core.oracles.cache import SemanticMemo
from repro.core.oracles.model_oracle import ModelOracle
from repro.core.types import SortSpec

CRITERIA = "relevance"


def _lm():
    import jax
    from repro.configs import get_reduced
    from repro.models import LM
    cfg = get_reduced("llama3-8b")
    lm = LM(cfg)
    return lm, lm.init(jax.random.PRNGKey(0))


def _engine(lm, params, **kw):
    from repro.serving import ServeEngine
    return ServeEngine(lm, params, max_new_tokens=8, **kw)


def _keys(n: int):
    # variable-length keys: suffix spans straddle power-of-two window
    # buckets, which is where per-group windows beat the class-global one
    rng = np.random.default_rng(0)
    return as_keys([f"doc {'x' * (3 * (i % 11))} {i:03d}" for i in range(n)],
                   list(rng.standard_normal(n)))


def _stats(eng, sched=None, mark=None):
    s = eng.stats
    now = dict(prefill=s.prefill_tokens, hits=s.prefix_hits,
               misses=s.prefix_misses, saved=s.prefix_tokens_saved,
               probe_rows=s.probe_rows, calls=s.calls,
               fills=(sched.fills_serviced if sched else 0))
    if mark is None:
        return now
    d = {k: now[k] - mark[k] for k in now}
    d["hit_rate"] = round(d["hits"] / max(d["hits"] + d["misses"], 1), 4)
    return d


# --------------------------------------------------------- quick N=64
def _run_quick(eng, keys, spec, prefetch: bool) -> tuple[dict, object, list]:
    """One quicksort query through the executor on the unified loop."""
    from repro.serving import BatchScheduler
    sched = BatchScheduler(eng)
    oracle = ModelOracle(eng)
    ex = ProbePlanExecutor(scheduler=sched, prefetch=prefetch)
    mark = _stats(eng, sched)
    t0 = time.perf_counter()
    run = ex.submit_path(make_path("quick", PathParams(batch_size=4)),
                         keys, oracle, spec)
    ex.run()
    res = plan_sort_result(run, spec, len(keys), oracle.prices)
    d = _stats(eng, sched, mark)
    d["seconds"] = round(time.perf_counter() - t0, 3)
    return d, res, list(oracle.ledger.records)


def run_quick(lm, params, n: int) -> dict:
    keys, spec = _keys(n), SortSpec(CRITERIA, True, None)
    # solo reference: the PR 1 synchronous execute (order + ledger oracle)
    eng_solo = _engine(lm, params)
    solo_oracle = ModelOracle(eng_solo)
    solo = make_path("quick", PathParams(batch_size=4)).execute(
        keys, solo_oracle, spec)
    # cache-off denominator for the prefill reduction
    eng_off = _engine(lm, params, prefix_cache_size=0)
    off, res_off, led_off = _run_quick(eng_off, keys, spec, prefetch=False)
    # reactive PR 2 baseline vs the locality stack
    eng_re = _engine(lm, params, locality=False)
    rea, res_re, led_re = _run_quick(eng_re, keys, spec, prefetch=False)
    eng_lo = _engine(lm, params)
    loc, res_lo, led_lo = _run_quick(eng_lo, keys, spec, prefetch=True)

    row = dict(
        workload="quick", n=n,
        prefill_off=off["prefill"], prefill_reactive=rea["prefill"],
        prefill_locality=loc["prefill"],
        reduction_reactive=round(1 - rea["prefill"] / off["prefill"], 4),
        reduction_locality=round(1 - loc["prefill"] / off["prefill"], 4),
        hit_rate_reactive=rea["hit_rate"], hit_rate_locality=loc["hit_rate"],
        tokens_saved_reactive=rea["saved"], tokens_saved_locality=loc["saved"],
        fills_serviced=loc["fills"],
        seconds_reactive=rea["seconds"], seconds_locality=loc["seconds"],
        order_identical=(solo.uids() == res_off.uids() == res_re.uids()
                         == res_lo.uids()),
        ledger_identical=(list(solo_oracle.ledger.records) == led_off
                          == led_re == led_lo),
    )
    assert row["order_identical"], f"quick N={n}: order diverged from solo"
    assert row["ledger_identical"], f"quick N={n}: ledger diverged from solo"
    assert row["hit_rate_locality"] > row["hit_rate_reactive"], (
        f"quick N={n}: locality hit rate {row['hit_rate_locality']} not "
        f"above reactive {row['hit_rate_reactive']}")
    assert row["tokens_saved_locality"] > row["tokens_saved_reactive"], (
        f"quick N={n}: locality saved {row['tokens_saved_locality']} <= "
        f"reactive {row['tokens_saved_reactive']}")
    assert row["reduction_locality"] > row["reduction_reactive"], (
        f"quick N={n}: no prefill-reduction improvement over the reactive "
        f"PR 2 baseline ({row['reduction_locality']:.1%} vs "
        f"{row['reduction_reactive']:.1%})")
    return row


# ------------------------------------------- 4-query llm_order_by_many
def _queries(keys, engine):
    p4 = PathParams(batch_size=4)
    return [
        OrderQuery(keys, CRITERIA, ModelOracle(engine), descending=False,
                   path="quick", params=p4),
        OrderQuery(keys, CRITERIA, ModelOracle(engine), descending=True,
                   path="quick", params=p4),
        OrderQuery(keys[: 3 * len(keys) // 4], CRITERIA, ModelOracle(engine),
                   path="ext_merge", params=p4),
        OrderQuery(keys[: len(keys) // 2], CRITERIA, ModelOracle(engine),
                   path="pointwise"),
    ]


def _solo_refs(lm, params, keys):
    eng = _engine(lm, params)
    refs = []
    for q in _queries(keys, eng):
        spec = SortSpec(q.criteria, q.descending, q.limit)
        oracle = ModelOracle(eng)
        res = make_path(q.path, q.params or PathParams()).execute(
            q.keys, oracle, spec)
        refs.append((res.uids(), list(oracle.ledger.records)))
    return refs


def run_many(lm, params, n: int) -> dict:
    keys = _keys(n)
    solo = _solo_refs(lm, params, keys)

    def one(locality: bool, prefetch: bool):
        eng = _engine(lm, params, locality=locality)
        qs = _queries(keys, eng)
        mark = _stats(eng)
        t0 = time.perf_counter()
        results = llm_order_by_many(qs, prefetch=prefetch)
        d = _stats(eng, mark=mark)
        d["seconds"] = round(time.perf_counter() - t0, 3)
        ok_order = all(r.uids() == s[0] for r, s in zip(results, solo))
        ok_ledger = all(list(q.oracle.ledger.records) == s[1]
                        for q, s in zip(qs, solo))
        return d, ok_order, ok_ledger

    rea, rea_order, rea_ledger = one(locality=False, prefetch=False)
    loc, loc_order, loc_ledger = one(locality=True, prefetch=True)
    row = dict(
        workload="many4", n=n, n_queries=4,
        prefill_reactive=rea["prefill"], prefill_locality=loc["prefill"],
        hit_rate_reactive=rea["hit_rate"], hit_rate_locality=loc["hit_rate"],
        tokens_saved_reactive=rea["saved"], tokens_saved_locality=loc["saved"],
        seconds_reactive=rea["seconds"], seconds_locality=loc["seconds"],
        order_identical=rea_order and loc_order,
        ledger_identical=rea_ledger and loc_ledger,
    )
    assert row["order_identical"], "many4: a query's order diverged from solo"
    assert row["ledger_identical"], "many4: a query's ledger diverged from solo"
    assert row["hit_rate_locality"] > row["hit_rate_reactive"], (
        f"many4: locality hit rate {row['hit_rate_locality']} not above "
        f"reactive {row['hit_rate_reactive']}")
    assert row["tokens_saved_locality"] > row["tokens_saved_reactive"], (
        f"many4: locality saved {row['tokens_saved_locality']} <= reactive "
        f"{row['tokens_saved_reactive']}")
    return row


# --------------------------------------- cross-query semantic memo wave
def run_memo(lm, params, n: int) -> dict:
    keys = _keys(n)
    solo = _solo_refs(lm, params, keys)
    eng = _engine(lm, params)
    memo = SemanticMemo()
    qs1 = _queries(keys, eng)
    m0 = _stats(eng)
    llm_order_by_many(qs1, semantic_memo=memo)
    m1 = _stats(eng, mark=m0)
    # the second wave arrives later (a fresh llm_order_by_many call, fresh
    # oracles): every per-item probe already answered for wave 1 is served
    # from the memo — first-requester-pays, so wave-2 ledgers bill only
    # what the memo could not answer and reconciliation restores the rest
    qs2 = _queries(keys, eng)
    results2 = llm_order_by_many(qs2, semantic_memo=memo)
    m2 = _stats(eng, mark=m0)
    wave2_rows = m2["probe_rows"] - m1["probe_rows"]

    order_ok = all(r.uids() == s[0] for r, s in zip(results2, solo))
    # wave 1 paid for everything it asked first — its billed ledgers ARE
    # the solo ledgers; wave 2 reconciles billed records + hit shadows
    wave1_ledger_ok = all(list(q.oracle.ledger.records) == s[1]
                          for q, s in zip(qs1, solo))
    reconciled_ok = all(q.oracle.reconciled_records() == s[1]
                        for q, s in zip(qs2, solo))
    billed2 = sum(len(q.oracle.ledger.records) for q in qs2)
    shadows2 = sum(len(q.oracle.memo_hit_log) for q in qs2)
    solo_records = sum(len(s[1]) for s in solo)
    row = dict(
        workload="memo", n=n, n_queries=4,
        memo_entries=len(memo), memo_hits=memo.hits, memo_misses=memo.misses,
        wave1_probe_rows=m1["probe_rows"], wave2_probe_rows=wave2_rows,
        wave2_billed_records=billed2, wave2_hit_shadows=shadows2,
        solo_records=solo_records,
        order_identical=order_ok,
        wave1_ledger_identical=wave1_ledger_ok,
        reconciled_identical=reconciled_ok,
        conservation=(billed2 + shadows2 == solo_records),
    )
    assert row["order_identical"], "memo wave 2: order diverged from solo"
    assert row["wave1_ledger_identical"], (
        "memo wave 1 (all first requests) should bill the solo ledgers")
    assert row["reconciled_identical"], (
        "memo wave 2: reconciled records (billed + hit shadows) diverged "
        "from the solo ledgers")
    assert row["conservation"], (
        f"ledger conservation failed: {billed2} billed + {shadows2} hit "
        f"shadows != {solo_records} solo records")
    assert memo.hits > 0, "memo wave 2 produced no cross-query hits"
    assert wave2_rows < m1["probe_rows"], (
        "the memo'd wave should reach the backend with fewer probe rows")
    return row


def run(sizes: list[int]) -> list[dict]:
    lm, params = _lm()
    rows = []
    for n in sizes:
        rows.append(run_quick(lm, params, n))
        rows.append(run_many(lm, params, max(n // 2, 8)))
        rows.append(run_memo(lm, params, max(n // 2, 8)))
    return rows


def main() -> None:
    from benchmarks.common import parse_json_flag
    argv, json_path = parse_json_flag(sys.argv[1:])
    sizes = [int(a) for a in argv if a.isdigit()] or [64]
    rows = run(sizes)
    cols = ("workload", "n", "hit_rate_reactive", "hit_rate_locality",
            "tokens_saved_reactive", "tokens_saved_locality",
            "order_identical", "ledger_identical")
    memo_cols = ("workload", "n", "memo_hits", "wave1_probe_rows",
                 "wave2_probe_rows", "wave2_billed_records",
                 "wave2_hit_shadows", "solo_records", "order_identical",
                 "reconciled_identical", "conservation")
    for r in rows:
        use = memo_cols if r["workload"] == "memo" else cols
        print(",".join(str(c) for c in use))
        print(",".join(str(r[c]) for c in use))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
