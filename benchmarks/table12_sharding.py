"""Table 12 (repo-specific): sharded serving — mesh-parallel paged decode +
data-parallel probe rounds, with identity back to the single-device engine.

A forced 8-device CPU backend (``--xla_force_host_platform_device_count=8``,
set below before jax initializes) stands in for a real pod, so mesh scaling
is testable in CI without a TPU.  For each mesh shape in {1x1, 4x2, 8x1}
(data x model) the SAME mixed workload as table 8 — a judge-rationale
generate stream co-scheduled with an LLM ORDER BY query through one
``BatchScheduler`` step loop — runs on a ``ServeEngine(mesh=...)`` and is
compared against the unsharded engine:

 * **model == 1 shapes (1x1, 8x1)** assert FULL identity: generate outputs
   token-identical (``==``), the query's order and per-query ledger
   byte-identical, and probe logits bitwise equal.  Data-parallel row
   slicing never reduces across devices — each shard computes a contiguous
   row slice and the host-side gather reassembles — so the same row-count
   independence behind the repo-wide batched==sequential contract makes
   sharded execution exact.
 * **model > 1 (4x2)** asserts probe logits within the documented
   tensor-parallel tolerance (``TP_PSUM_RTOL/ATOL``: the row-parallel
   wo/w_down contractions become psums whose reduction order differs from
   the single-device dot — ~1 bf16 ulp through the residual stream).
   Greedy decode can flip a near-tie token under that drift, so
   generate/order/ledger agreement is REPORTED per run, not asserted —
   the same contract stance as the Pallas kernel's allclose switch.

The PERF claim is the data-parallel probe slicing: on the 8x1 mesh the same
probe round is timed with row slicing on (each shard runs 1/8 of the rows)
vs off (``dp_probe_slices=False`` — every shard recomputes ALL rows), and
the sliced-over-replicated wall-clock ratio is asserted under a
conservative floor.  This comparison is hardware-independent — both sides
run on the same 8-device mesh, the sliced program simply does 1/8 the
per-device work — unlike sharded-vs-1-device wall-clock, which on a
single-core CPU host cannot speed up and is REPORTED only (same caveat as
table 8's scheduling-latency-not-seconds framing).  Decode tokens/s per
shape comes from ``benchmarks.common.decode_timing``, shared with table 8.

    PYTHONPATH=src python -m benchmarks.table12_sharding [--json OUT]
"""
from __future__ import annotations

import os

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

import json
import sys
import time

import numpy as np

from repro.core import PathParams, ProbePlanExecutor, as_keys, make_path
from repro.core.executor import plan_sort_result
from repro.core.oracles.model_oracle import ModelOracle
from repro.core.types import SortSpec

from .common import decode_timing, emit, parse_json_flag

MAX_NEW = 16
N_GEN = 8                  # generate requests in the mixed workload
N_KEYS = 16                # ORDER BY keys
SHAPES = [(1, 1), (4, 2), (8, 1)]     # (data, model)
# sliced probe rounds must beat replicated rounds on the same mesh by at
# least this factor; the arithmetic bound is shards x less per-device work
# (0.125 at 8 shards), measured ~0.5 with dispatch overhead — 0.7 leaves
# conservative headroom while still proving the split is real
SLICED_RATIO_FLOOR = 0.7
PROBE_REPEATS = 5


def _build(mesh=None, dp: bool = True):
    import jax
    from repro.configs import get_reduced
    from repro.models import LM
    from repro.serving import ServeEngine
    cfg = get_reduced("llama3-8b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    return ServeEngine(lm, params, max_new_tokens=MAX_NEW,
                       max_decode_rows=8, mesh=mesh, dp_probe_slices=dp)


def _workload():
    rng = np.random.default_rng(0)
    prompts, limits = [], []
    for i in range(N_GEN):
        body = "criteria compliance of candidate ranking " + "x" * int(
            rng.integers(0, 40))
        prompts.append(f"Judge {i}: {body}\nVerdict:")
        limits.append(MAX_NEW if i % 4 == 3 else int(rng.integers(2, 5)))
    keys = as_keys([f"doc {'q' * (i % 5)} {i:03d}" for i in range(N_KEYS)],
                   list(rng.standard_normal(N_KEYS)))
    return prompts, limits, keys, SortSpec("relevance", True, 8)


def _ledger(oracle):
    return (oracle.ledger.n_calls, oracle.ledger.input_tokens,
            oracle.ledger.output_tokens, list(oracle.ledger.records))


def _run_mixed(eng) -> dict:
    """Table 8's unified co-scheduled workload, small: generates and an
    ORDER BY query drive ONE live step loop."""
    from repro.serving import BatchScheduler
    prompts, limits, keys, spec = _workload()
    sched = BatchScheduler(eng, max_batch=8)
    oracle = ModelOracle(eng, scheduler=sched)
    rids = [sched.submit(p, l) for p, l in zip(prompts, limits)]
    ex = ProbePlanExecutor(scheduler=sched)
    run = ex.submit_path(make_path("quick", PathParams(batch_size=4)),
                         keys, oracle, spec, name="orderby")
    with decode_timing(eng) as dt:
        while sched.work_remaining or not run.done:
            if not run.done:
                ex.tick()
            else:
                sched.step()
    res = plan_sort_result(run, spec, len(keys), oracle.prices)
    return dict(outputs=[sched.completed[r].output for r in rids],
                order=[k.text for k in res.order], ledger=_ledger(oracle),
                timing=dt)


def _probe_prompts():
    return [(f"Criteria: relevance\nItem:", f" candidate passage {i:03d}\n"
             f"Rating:") for i in range(32)]


def _probe_round_s(eng) -> float:
    """Median wall-clock of one warmed 32-row probe-round submission."""
    prompts = _probe_prompts()
    eng.submit_probes(prompts)                      # compile + warm
    samples = []
    for _ in range(PROBE_REPEATS):
        t0 = time.perf_counter()
        eng.submit_probes(prompts)
        samples.append(time.perf_counter() - t0)
    return float(np.median(samples))


def run() -> tuple[list[dict], dict]:
    import jax
    from repro.launch.mesh import make_local_mesh
    from repro.serving.engine import TP_PSUM_ATOL, TP_PSUM_RTOL

    base = _build()
    ref = _run_mixed(base)
    ref_probe = base.submit_probes(_probe_prompts())
    base_round_s = _probe_round_s(base)
    base.clear_prefix_cache()      # LRU-pinned runs are occupancy, not leaks
    assert base.pool.blocks_in_use == 0, "baseline leaked pool blocks"

    have = jax.device_count()
    rows: list[dict] = []
    for data, model in SHAPES:
        if data * model > have:
            rows.append(dict(mesh=f"{data}x{model}", skipped=True,
                             note=f"needs {data * model} devices, "
                                  f"{have} visible (backend initialized "
                                  f"before the force flag?)"))
            continue
        eng = _build(mesh=make_local_mesh(data, model))
        got = _run_mixed(eng)
        probe = eng.submit_probes(_probe_prompts())
        round_s = _probe_round_s(eng)
        eng.clear_prefix_cache()
        assert eng.pool.blocks_in_use == 0, \
            f"{data}x{model} leaked pool blocks"

        gen_ok = got["outputs"] == ref["outputs"]
        order_ok = got["order"] == ref["order"]
        ledger_ok = got["ledger"] == ref["ledger"]
        probe_bitwise = bool(np.array_equal(ref_probe, probe))
        argmax_agree = float(
            (ref_probe.argmax(-1) == probe.argmax(-1)).mean())
        if model == 1:
            # pure data parallelism: nothing reduces across devices, so
            # the sharded engine is BITWISE the single-device engine
            assert gen_ok and order_ok and ledger_ok and probe_bitwise, (
                f"{data}x{model}: expected full bitwise identity "
                f"(gen={gen_ok} order={order_ok} ledger={ledger_ok} "
                f"probe={probe_bitwise})")
        else:
            np.testing.assert_allclose(probe, ref_probe,
                                       rtol=TP_PSUM_RTOL,
                                       atol=TP_PSUM_ATOL)
        rows.append(dict(
            mesh=f"{data}x{model}", decode_tokens=got["timing"].decode_tokens,
            decode_tokens_per_s=got["timing"].tokens_per_s,
            wall_s=got["timing"].seconds,
            probe_round_ms=round(round_s * 1e3, 1),
            dp_sharded=eng.stats.dp_sharded_submissions,
            dp_replicated=eng.stats.dp_replicated_submissions,
            gen_identical=gen_ok, order_identical=order_ok,
            ledger_identical=ledger_ok, probe_bitwise=probe_bitwise,
            probe_argmax_agreement=argmax_agree))

    # THE perf assertion: sliced vs replicated probe rounds, same 8x1 mesh
    ratio_row: dict = {}
    if have >= 8:
        mesh = make_local_mesh(8, 1)
        sliced_s = _probe_round_s(_build(mesh=mesh, dp=True))
        repl_s = _probe_round_s(_build(mesh=mesh, dp=False))
        ratio = sliced_s / repl_s
        assert ratio <= SLICED_RATIO_FLOOR, (
            f"data-parallel probe slicing must cut per-round wall-clock: "
            f"sliced {sliced_s * 1e3:.1f}ms / replicated "
            f"{repl_s * 1e3:.1f}ms = {ratio:.2f} > {SLICED_RATIO_FLOOR}")
        ratio_row = dict(sliced_ms=round(sliced_s * 1e3, 1),
                         replicated_ms=round(repl_s * 1e3, 1),
                         ratio=round(ratio, 3),
                         floor=SLICED_RATIO_FLOOR)
    meta = dict(devices=have, baseline_probe_round_ms=round(
        base_round_s * 1e3, 1), baseline_decode_tokens_per_s=ref[
        "timing"].tokens_per_s, sliced_vs_replicated=ratio_row)
    return rows, meta


def main(argv=None) -> None:
    argv, json_out = parse_json_flag(
        argv if argv is not None else sys.argv[1:])
    rows, meta = run()
    emit([("mesh", "decode_tok_per_s", "probe_round_ms", "dp_sharded",
           "gen_id", "order_id", "ledger_id", "probe_bitwise")])
    for r in rows:
        if r.get("skipped"):
            emit([(r["mesh"], "SKIPPED", r["note"], "", "", "", "", "")])
            continue
        emit([(r["mesh"], r["decode_tokens_per_s"], r["probe_round_ms"],
               r["dp_sharded"], r["gen_identical"], r["order_identical"],
               r["ledger_identical"], r["probe_bitwise"])])
    if meta["sliced_vs_replicated"]:
        sv = meta["sliced_vs_replicated"]
        print(f"sliced {sv['sliced_ms']}ms vs replicated "
              f"{sv['replicated_ms']}ms -> ratio {sv['ratio']} "
              f"(floor {sv['floor']})")
    if json_out:
        with open(json_out, "w") as f:
            json.dump(dict(rows=rows, meta=meta), f, indent=2, default=str)
        print(f"wrote {json_out}")


if __name__ == "__main__":
    main()
