"""Shared benchmark helpers: run a static path / the optimizer on a task and
report (quality, cost, calls)."""
from __future__ import annotations

import time
from dataclasses import dataclass

from repro.core import (PathParams, SimulatedOracle, llm_order_by, make_path)
from repro.core.datasets import RankingTask
from repro.core.metrics import graded_relevance, kendall_tau, ndcg_at_k
from repro.core.types import SortSpec


@dataclass
class RunOut:
    quality: float
    cost: float
    calls: int
    seconds: float
    label: str = ""


def task_quality(task: RankingTask, order) -> float:
    if task.metric == "ndcg":
        rel = graded_relevance(task.keys, descending=task.descending)
        return ndcg_at_k(order, rel, k=task.limit or 10)
    return kendall_tau(order, descending=task.descending)


def run_static(task: RankingTask, path: str,
               params: PathParams = PathParams(batch_size=4),
               seed: int = 0) -> RunOut:
    o = SimulatedOracle(task.profile)
    t0 = time.perf_counter()
    res = make_path(path, params).execute(
        task.keys, o, SortSpec(task.criteria, task.descending, task.limit))
    dt = time.perf_counter() - t0
    return RunOut(task_quality(task, res.order), res.cost, res.n_calls, dt,
                  label=path)


def run_optimizer(task: RankingTask, strategy: str = "borda",
                  budget=None, sample_size: int = 20, seed: int = 0) -> tuple:
    o = SimulatedOracle(task.profile)
    t0 = time.perf_counter()
    res, rep = llm_order_by(task.keys, task.criteria, o, path="auto",
                            strategy=strategy, budget=budget,
                            sample_size=sample_size,
                            descending=task.descending, limit=task.limit)
    dt = time.perf_counter() - t0
    return RunOut(task_quality(task, res.order), rep.total_cost,
                  res.n_calls, dt, label=strategy), rep


class decode_timing:
    """Context manager timing a serving-engine drive: wall-clock seconds,
    decode tokens emitted inside the block, and decode tokens/s — the ONE
    throughput read-out shared by table8 (co-scheduling) and table12
    (sharded serving), so their artifacts cannot drift apart.

        with decode_timing(engine) as dt:
            ... drive the engine ...
        dt.seconds / dt.decode_tokens / dt.tokens_per_s
    """

    def __init__(self, engine):
        self.engine = engine

    def __enter__(self) -> "decode_timing":
        self._tok0 = self.engine.stats.decode_tokens
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        dt = time.perf_counter() - self._t0
        self.seconds = round(dt, 3)
        self.decode_tokens = self.engine.stats.decode_tokens - self._tok0
        self.tokens_per_s = (round(self.decode_tokens / dt, 1) if dt > 0
                             else 0.0)
        return False


def emit(rows: list[tuple]) -> None:
    for r in rows:
        print(",".join(str(x) for x in r))


def parse_json_flag(argv: list[str]) -> tuple[list[str], "str | None"]:
    """Pop ``--json OUT`` from an argv list; returns (rest, path_or_None).
    Exits with a usage message when the path operand is missing."""
    if "--json" not in argv:
        return list(argv), None
    i = argv.index("--json")
    if i + 1 >= len(argv):
        raise SystemExit("usage: ... --json OUT [N ...]")
    return argv[:i] + argv[i + 2:], argv[i + 1]
