"""Table 7 (repo-specific): probe-plan executor — interleaved vs serialized.

Two workloads on the REAL ModelOracle backend:

 * **concurrent queries** — 4 LLM ORDER BY queries over one table (including
   an ASC/DESC twin pair whose probe streams coincide and dedup), run
   back-to-back solo vs interleaved through ``llm_order_by_many`` over one
   ``BatchScheduler`` drain per tick.  Asserts per-query orders and ledgers
   are identical and that interleaving issues <= 60% of the serialized
   probe submissions.
 * **optimizer pilot** — the Sec.-5 candidate sample runs (plus the
   membership gate round), serialized candidate-by-candidate vs all pilots
   suspended on one executor.  Asserts identical per-candidate sample
   rankings.

Reported per mode: serving submissions (``engine.stats.calls``), probe row
occupancy (live rows vs padded row slots — the slack is wasted pool
capacity: dummy rows prefilled and thrown away), cross-plan dedup hits, and
wall-clock.

    PYTHONPATH=src python -m benchmarks.table7_executor [--json OUT] [N ...]
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

from repro.core import OrderQuery, PathParams, ProbePlanExecutor, as_keys, \
    llm_order_by_many, make_path
from repro.core.access_paths.base import Ordering
from repro.core.optimizer.cost_model import default_candidates
from repro.core.optimizer.membership import membership_plan
from repro.core.oracles.model_oracle import ModelOracle
from repro.core.types import SortSpec


def _engine(max_new: int = 8):
    import jax
    from repro.configs import get_reduced
    from repro.models import LM
    from repro.serving import ServeEngine
    cfg = get_reduced("llama3-8b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    return ServeEngine(lm, params, max_new_tokens=max_new)


def _snap(eng):
    s = eng.stats
    return (s.calls, s.probe_rows, s.probe_row_slots)


def _delta(eng, before):
    s = eng.stats
    return dict(submissions=s.calls - before[0],
                probe_rows=s.probe_rows - before[1],
                probe_row_slots=s.probe_row_slots - before[2],
                wasted_row_slots=(s.probe_row_slots - before[2])
                - (s.probe_rows - before[1]))


def _qdefs():
    return [("quick", "relevance", True, None),
            ("quick", "relevance", False, None),   # ASC twin: full dedup
            ("ext_merge", "relevance", True, 8),
            ("pointwise", "clarity", False, None)]


def bench_concurrent(eng, keys, rows: list[dict]) -> None:
    from repro.serving.scheduler import BatchScheduler
    qdefs = _qdefs()
    # warm the jit cache on a prefix so wall-clock measures steady state
    for path, crit, desc, limit in qdefs:
        make_path(path, PathParams(batch_size=4)).execute(
            keys[:12], ModelOracle(eng), SortSpec(crit, desc, limit))

    solo_orders = []
    b0, t0 = _snap(eng), time.perf_counter()
    for path, crit, desc, limit in qdefs:
        res = make_path(path, PathParams(batch_size=4)).execute(
            keys, ModelOracle(eng), SortSpec(crit, desc, limit))
        solo_orders.append(res.uids())
    serial = _delta(eng, b0)
    serial.update(mode="serialized", seconds=round(time.perf_counter() - t0, 3),
                  deduped=0)

    sched = BatchScheduler(eng)
    b0, t0 = _snap(eng), time.perf_counter()
    results = llm_order_by_many(
        [OrderQuery(keys, crit, ModelOracle(eng), descending=desc,
                    limit=limit, path=path, params=PathParams(batch_size=4))
         for path, crit, desc, limit in qdefs], scheduler=sched)
    merged = _delta(eng, b0)
    merged.update(mode="interleaved",
                  seconds=round(time.perf_counter() - t0, 3),
                  deduped=sched.probes_deduped)

    identical = [r.uids() for r in results] == solo_orders
    for d in (serial, merged):
        d.update(workload=f"4-queries-n{len(keys)}", n=len(keys),
                 order_identical=identical)
        rows.append(d)
    assert identical, "interleaved execution changed a query's output"
    ratio = merged["submissions"] / max(serial["submissions"], 1)
    print(f"# 4-query submissions: {merged['submissions']} / "
          f"{serial['submissions']} = {ratio:.2f} "
          f"(deduped {merged['deduped']} probe rows)")
    assert ratio <= 0.60, (
        f"interleaved workload must issue <=60% of serialized probe "
        f"submissions, got {ratio:.2f}")


def bench_optimizer_pilot(eng, keys, rows: list[dict]) -> None:
    from repro.serving.scheduler import BatchScheduler
    rng = np.random.default_rng(7)
    sample = [keys[i] for i in sorted(rng.choice(len(keys), size=16,
                                                 replace=False))]
    spec = SortSpec("relevance", True, 8)
    sample_spec = SortSpec("relevance", True, 8)
    cands = default_candidates()

    # serialized: the pre-executor optimizer loop — gate round, then each
    # candidate's sample run back-to-back
    b0, t0 = _snap(eng), time.perf_counter()
    oracle = ModelOracle(eng)
    oracle.inquire_batch(sample, spec.criteria)
    serial_orders = [c.make().execute(sample, oracle, sample_spec).uids()
                     for c in cands]
    serial = _delta(eng, b0)
    serial.update(mode="serialized", seconds=round(time.perf_counter() - t0, 3),
                  deduped=0)

    # interleaved: every pilot + the gate suspended on one executor
    sched = BatchScheduler(eng)
    b0, t0 = _snap(eng), time.perf_counter()
    oracle = ModelOracle(eng)
    ex = ProbePlanExecutor(scheduler=sched)
    ex.submit_plan(membership_plan(sample), Ordering(oracle, spec),
                   name="membership")
    runs = [ex.submit_path(c.make(), sample, oracle, sample_spec,
                           name=c.label) for c in cands]
    ex.run()
    merged_orders = [list(r.result)[:sample_spec.effective_limit(len(sample))]
                     for r in runs]
    merged_orders = [[k.uid for k in o] for o in merged_orders]
    merged = _delta(eng, b0)
    merged.update(mode="interleaved",
                  seconds=round(time.perf_counter() - t0, 3),
                  deduped=sched.probes_deduped)

    identical = merged_orders == serial_orders
    for d in (serial, merged):
        d.update(workload="optimizer-pilot-s16", n=16,
                 order_identical=identical)
        rows.append(d)
    assert identical, "interleaved pilots changed a candidate's sample order"
    print(f"# pilot submissions: {merged['submissions']} / "
          f"{serial['submissions']}, wasted row slots "
          f"{merged['wasted_row_slots']} / {serial['wasted_row_slots']}")


def main() -> None:
    from benchmarks.common import parse_json_flag
    argv, json_path = parse_json_flag(sys.argv[1:])
    sizes = [int(a) for a in argv if a.isdigit()] or [48]
    rows: list[dict] = []
    eng = _engine()
    rng = np.random.default_rng(0)
    for n in sizes:
        keys = as_keys([f"doc {i:04d}" for i in range(n)],
                       list(rng.standard_normal(n)))
        bench_concurrent(eng, keys, rows)
        bench_optimizer_pilot(eng, keys, rows)
    print("workload,mode,submissions,probe_rows,probe_row_slots,"
          "wasted_row_slots,deduped,seconds,order_identical")
    for d in rows:
        print(f"{d['workload']},{d['mode']},{d['submissions']},"
              f"{d['probe_rows']},{d['probe_row_slots']},"
              f"{d['wasted_row_slots']},{d['deduped']},{d['seconds']},"
              f"{d['order_identical']}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
