"""Roofline report: reads the dry-run JSONL (results/dryrun_singlepod.jsonl)
and prints the per-(arch x shape) three-term roofline table."""
from __future__ import annotations

import json
import os

from .common import emit

DEFAULT = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun_singlepod.jsonl")


def load(path: str = DEFAULT) -> list[dict]:
    if not os.path.exists(path):
        return []
    recs = []
    with open(path) as f:
        for line in f:
            recs.append(json.loads(line))
    return recs


def main(path: str = DEFAULT) -> list[tuple]:
    rows = [("roofline", "arch", "shape", "compute_s", "memory_s",
             "collective_s", "dominant", "useful_ratio")]
    recs = load(path)
    if not recs:
        rows.append(("roofline", "NO-DRYRUN-RESULTS", path, "", "", "", "", ""))
        emit(rows)
        return rows
    for r in recs:
        if r.get("multi_pod"):
            continue
        if "skipped" in r:
            rows.append(("roofline", r["arch"], r["shape"], "skip", "skip",
                         "skip", r["skipped"][:40], ""))
            continue
        if "error" in r:
            rows.append(("roofline", r["arch"], r["shape"], "ERR", "ERR",
                         "ERR", r["error"][:40], ""))
            continue
        t = r.get("roofline", {})
        rows.append(("roofline", r["arch"], r["shape"],
                     f"{t.get('compute_s', 0):.4g}",
                     f"{t.get('memory_s', 0):.4g}",
                     f"{t.get('collective_s', 0):.4g}",
                     t.get("dominant", "?").replace("_s", ""),
                     t.get("useful_ratio", "")))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
