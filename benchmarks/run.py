"""Benchmark harness: one module per paper table/figure (tables 1-3 and
the figures reproduce the paper; tables 4-12 track this repo's serving
stack: round batching, prefix-KV cache, paged decode, the probe-plan
executor, unified-loop co-scheduling, locality scheduling, multi-tenant
priority/preemption, model cascades, and sharded serving).  Prints CSV.
Note: importing table12 forces an 8-device CPU backend (XLA_FLAGS) so the
mesh suites are runnable; single-device suites are unaffected.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run table1 fig3
    PYTHONPATH=src python -m benchmarks.run table8     # serving suites run
                                                       # real forward passes
"""
from __future__ import annotations

import sys
import time

from . import (fig1_scaling, fig2_no_universal, fig3_optimizer, fig5_budget,
               roofline, table1_calls, table2_cost_est, table3_samples,
               table4_submissions, table5_prefix_cache, table6_paged_decode,
               table7_executor, table8_cosched, table9_locality,
               table10_tenancy, table11_cascade, table12_sharding)

SUITES = {
    "table1": table1_calls.main,       # LLM-call complexity
    "fig1": fig1_scaling.main,         # cost vs accuracy + scaling fit
    "fig2": fig2_no_universal.main,    # per-query dispersion, oracle gap
    "table2": table2_cost_est.main,    # cost estimation accuracy
    "fig3": fig3_optimizer.main,       # optimizer vs statics, 4 families
    "table3": table3_samples.main,     # sample-size sensitivity
    "fig5": fig5_budget.main,          # budget-constrained selection
    "roofline": roofline.main,         # dry-run roofline table
    "table4": table4_submissions.main, # round batching: serving submissions
    "table5": table5_prefix_cache.main,   # prefix-KV cache: prefill savings
    "table6": table6_paged_decode.main,   # paged decode vs lockstep waste
    "table7": table7_executor.main,       # probe-plan executor merging
    "table8": table8_cosched.main,        # unified-loop co-scheduling latency
    "table9": table9_locality.main,       # locality scheduling + memo
    "table10": table10_tenancy.main,      # priority classes + preemption
    "table11": table11_cascade.main,      # model-cascade probe execution
    "table12": table12_sharding.main,     # sharded serving (forced 8-dev mesh)
}


def main() -> None:
    names = sys.argv[1:] or list(SUITES)
    print("suite,seconds")
    for name in names:
        t0 = time.perf_counter()
        print(f"# ===== {name} =====")
        SUITES[name]()
        print(f"{name},{time.perf_counter() - t0:.2f}")


if __name__ == "__main__":
    main()
