"""Paper Fig. 1: sorting accuracy vs monetary budget per path, on a factual
dataset (NBA-heights-like) and a reasoning dataset (DL19-like), plus the
log-linear test-time-scaling fit (accuracy ~ a + b*log10(cost))."""
from __future__ import annotations


import numpy as np

from repro.core import PathParams
from repro.core.datasets import nba_heights, passages

from .common import emit, run_static

SWEEP = [
    ("pointwise", PathParams()),
    ("ext_pointwise", PathParams(batch_size=4)),
    ("quick", PathParams(votes=1)),
    ("quick", PathParams(votes=3)),
    ("quick", PathParams(votes=5)),
    ("ext_bubble", PathParams(batch_size=4)),
    ("ext_bubble", PathParams(batch_size=8)),
    ("ext_merge", PathParams(batch_size=4)),
    ("ext_merge", PathParams(batch_size=8)),
]


def main(n: int = 100) -> list[tuple]:
    rows = [("fig1", "dataset", "path", "cost_usd", "quality")]
    points = {"factual": [], "reasoning": []}
    for name, task in (("factual", nba_heights(n=n)),
                       ("reasoning", passages(n=n))):
        for path, params in SWEEP:
            out = run_static(task, path, params)
            label = (f"{path}_v{params.votes}" if path == "quick"
                     else f"{path}_m{params.batch_size}")
            rows.append(("fig1", name, label, round(out.cost, 5),
                         round(out.quality, 4)))
            # the paper excludes (likely-memorized) value-based points from
            # the factual fit
            if not (name == "factual" and "point" in path):
                points[name].append((out.cost, out.quality))
    for name, pts in points.items():
        if len(pts) >= 3:
            x = np.log10([max(c, 1e-6) for c, _ in pts])
            y = np.asarray([q for _, q in pts])
            b, a = np.polyfit(x, y, 1)
            resid = y - (a + b * x)
            ss_tot = float(np.sum((y - y.mean()) ** 2)) or 1e-9
            r2 = 1 - float(np.sum(resid ** 2)) / ss_tot
            rows.append(("fig1_fit", name, "loglinear_slope", round(b, 4),
                         f"r2={r2:.3f}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
