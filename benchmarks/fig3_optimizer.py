"""Paper Fig. 3: the dynamic optimizer (borda / judge) vs every static path
on the four benchmark families (population, tweets, movie, passages)."""
from __future__ import annotations

from repro.core import PathParams
from repro.core.datasets import benchmark_suite

from .common import emit, run_optimizer, run_static

STATICS = [("pointwise", PathParams()),
           ("ext_pointwise", PathParams(batch_size=4)),
           ("quick", PathParams(votes=1)),
           ("quick", PathParams(votes=3)),
           ("ext_bubble", PathParams(batch_size=4)),
           ("ext_merge", PathParams(batch_size=4))]


def main() -> list[tuple]:
    rows = [("fig3", "family", "solution", "quality", "cost_usd", "chosen")]
    for task in benchmark_suite():
        best_static = -1.0
        for path, params in STATICS:
            out = run_static(task, path, params)
            label = f"{path}_v{params.votes}" if path == "quick" else path
            best_static = max(best_static, out.quality)
            rows.append(("fig3", task.name, label, round(out.quality, 4),
                         round(out.cost, 4), ""))
        for strat in ("borda", "judge", "consensus"):
            out, rep = run_optimizer(task, strategy=strat)
            rows.append(("fig3", task.name, f"optimizer_{strat}",
                         round(out.quality, 4), round(out.cost, 4),
                         f"{rep.chosen.label}|{rep.reason}"))
        rows.append(("fig3", task.name, "best_static",
                     round(best_static, 4), "", ""))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
