"""Paper Fig. 2: per-query accuracy dispersion across algorithms on a
DL-like multi-query family — and the oracle-best-per-query vs best-static
gap that motivates the dynamic optimizer."""
from __future__ import annotations

import numpy as np

from repro.core import PathParams
from repro.core.datasets import dl_queries

from .common import emit, run_static

PATHS = [("pointwise", PathParams()),
         ("quick", PathParams(votes=1)),
         ("quick", PathParams(votes=3)),
         ("ext_bubble", PathParams(batch_size=4)),
         ("ext_merge", PathParams(batch_size=4))]


def main(n_queries: int = 8, n: int = 60) -> list[tuple]:
    tasks = dl_queries(n_queries=n_queries, n=n)
    per_path: dict[str, list[float]] = {}
    per_query_best = []
    rows = [("fig2", "path", "mean_ndcg", "median", "min", "max")]
    quality = {}
    for path, params in PATHS:
        label = f"{path}_v{params.votes}" if path == "quick" else path
        qs = [run_static(t, path, params).quality for t in tasks]
        per_path[label] = qs
        quality[label] = qs
        rows.append(("fig2", label, round(float(np.mean(qs)), 4),
                     round(float(np.median(qs)), 4),
                     round(float(np.min(qs)), 4),
                     round(float(np.max(qs)), 4)))
    labels = list(per_path)
    for qi in range(n_queries):
        per_query_best.append(max(per_path[l][qi] for l in labels))
    best_static = max(float(np.mean(per_path[l])) for l in labels)
    oracle_best = float(np.mean(per_query_best))
    rows.append(("fig2", "best_static_mean", round(best_static, 4), "", "", ""))
    rows.append(("fig2", "oracle_per_query_mean", round(oracle_best, 4),
                 f"+{oracle_best-best_static:.4f}", "", ""))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
