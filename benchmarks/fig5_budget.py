"""Paper Fig. 5: budget-constrained optimization — tight budgets prune the
expensive paths (quick_3-class) while keeping accuracy high, and total spend
stays under the cap."""
from __future__ import annotations

from repro.core import SimulatedOracle, llm_order_by
from repro.core.datasets import passages

from .common import emit, task_quality


def main(n: int = 80) -> list[tuple]:
    task = passages(n=n, seed=40)
    rows = [("fig5", "budget_usd", "strategy", "chosen", "quality",
             "total_cost_usd", "n_pruned")]
    for budget in (None, 1.5, 0.6, 0.25):
        for strat in ("borda", "judge"):
            o = SimulatedOracle(task.profile)
            res, rep = llm_order_by(task.keys, task.criteria, o, path="auto",
                                    strategy=strat, budget=budget,
                                    descending=True, limit=task.limit)
            pruned = len([1 for _, why in rep.dropped if "over-budget" in why])
            rows.append(("fig5", budget if budget is not None else "inf",
                         strat, rep.chosen.label,
                         round(task_quality(task, res.order), 4),
                         round(rep.total_cost, 4), pruned))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
