"""Table 11 (repo-specific): model-cascade probe execution.

Sweeps the escalation threshold of the cascade oracle
(core/oracles/cascade.py) on a comparison-heavy quicksort workload and
reports, per threshold, the LARGE-model probe tokens spent and the
ranking quality (kendall tau vs latent ground truth) — the draft-first
rounds answer confident probes on the cheap tier and escalate only
low-margin rows.

Acceptance (ISSUE 9):

 * some threshold must save >= 40% of the large-model probe tokens while
   keeping tau within ``TAU_TOL`` of large-only execution;
 * ``threshold=inf`` (escalate-all) must be byte-identical in BOTH
   output and ledger records to a plain large-model oracle;
 * ``threshold=0`` must bill zero large-tier probe tokens.

Default run is the calibrated simulated backend (fast, deterministic);
``--real`` additionally drives two REAL reduced engines from
``configs.registry.ladder()`` through the same contract (identity +
savings; quality is meaningless on random-init weights).

    PYTHONPATH=src python -m benchmarks.table11_cascade \
        [--json OUT] [--real] [N ...]
"""
from __future__ import annotations

import json
import math
import sys
import time

import numpy as np

from repro.core import (CASCADE_70B, REASONING, SimulatedCascadeOracle,
                        SimulatedOracle, as_keys, llm_order_by)
from repro.core.metrics import kendall_tau

CRITERIA = "relevance"
PATH = "quick"
SEEDS = (0, 1, 2)
THRESHOLDS = (0.0, 0.75, 1.5, 2.5, math.inf)
SAVINGS_FLOOR = 0.40     # >= 40% fewer large-model probe tokens ...
TAU_TOL = 0.05           # ... within this tau tolerance of large-only


def _keys(n: int, seed: int):
    rng = np.random.default_rng(seed)
    return as_keys([f"doc {'x' * (i % 7)} {i:03d}" for i in range(n)],
                   list(rng.standard_normal(n)))


def _tier_tokens(records, tier: str) -> int:
    if tier == "large":
        # inf-passthrough bills untiered records — large-model quality
        return sum(r.input_tokens + r.output_tokens
                   for r in records if r.tier != "draft")
    return sum(r.input_tokens + r.output_tokens
               for r in records if r.tier == tier)


# ---------------------------------------------------- simulated sweep
def run_simulated(n: int) -> list[dict]:
    rows = []
    for t in THRESHOLDS:
        taus, large_toks, draft_toks, costs = [], [], [], []
        t0 = time.perf_counter()
        for seed in SEEDS:
            keys = _keys(n, seed)
            o = SimulatedCascadeOracle(threshold=t, prices=CASCADE_70B)
            res, _ = llm_order_by(keys, CRITERIA, o, path=PATH,
                                  descending=True)
            taus.append(kendall_tau(res.order, descending=True))
            large_toks.append(_tier_tokens(o.ledger.records, "large"))
            draft_toks.append(_tier_tokens(o.ledger.records, "draft"))
            costs.append(res.cost)
        rows.append(dict(
            backend="simulated", n=n, threshold=t,
            tau=round(float(np.mean(taus)), 4),
            large_probe_tokens=round(float(np.mean(large_toks)), 1),
            draft_probe_tokens=round(float(np.mean(draft_toks)), 1),
            cost=round(float(np.mean(costs)), 6),
            seconds=round(time.perf_counter() - t0, 3),
        ))
    ref = rows[-1]                                   # threshold=inf
    assert ref["threshold"] == math.inf
    for r in rows:
        r["large_tokens_saved"] = round(
            1.0 - r["large_probe_tokens"] / max(ref["large_probe_tokens"], 1),
            4)
        r["tau_gap"] = round(ref["tau"] - r["tau"], 4)

    # -- identity anchors -------------------------------------------------
    keys = _keys(n, SEEDS[0])
    casc = SimulatedCascadeOracle(threshold=math.inf, prices=CASCADE_70B)
    plain = SimulatedOracle(REASONING, prices=CASCADE_70B)
    rc, _ = llm_order_by(keys, CRITERIA, casc, path=PATH, descending=True)
    rp, _ = llm_order_by(keys, CRITERIA, plain, path=PATH, descending=True)
    assert [k.uid for k in rc.order] == [k.uid for k in rp.order], (
        "escalate-all order diverged from large-only")
    assert casc.ledger.records == plain.ledger.records, (
        "escalate-all ledger diverged from large-only")
    assert rows[0]["threshold"] == 0.0
    assert rows[0]["large_probe_tokens"] == 0, (
        "threshold=0 billed large-model probe tokens")

    # -- headline: savings at quality -------------------------------------
    good = [r for r in rows
            if r["large_tokens_saved"] >= SAVINGS_FLOOR
            and r["tau_gap"] <= TAU_TOL]
    assert good, (
        f"no threshold saved >= {SAVINGS_FLOOR:.0%} large-model probe "
        f"tokens within tau tolerance {TAU_TOL}: "
        + "; ".join(f"t={r['threshold']:g} saved={r['large_tokens_saved']:.0%}"
                    f" gap={r['tau_gap']:.3f}" for r in rows[:-1]))
    for r in rows:
        r["meets_acceptance"] = r in good
    return rows


# ------------------------------------------------- real reduced engines
def run_real(n: int) -> list[dict]:
    import jax
    from repro.configs import get_reduced
    from repro.configs.registry import ladder
    from repro.core.oracles.cascade import CascadeOracle
    from repro.core.oracles.model_oracle import ModelOracle
    from repro.models import LM
    from repro.serving import ServeEngine

    def build(arch, seed):
        lm = LM(get_reduced(arch))
        return ServeEngine(lm, lm.init(jax.random.PRNGKey(seed)),
                           max_new_tokens=8)

    rungs = ladder()
    draft, large = build(rungs[0], 0), build(rungs[1], 1)
    keys = _keys(n, 0)

    # escalate-all identity vs single-model execution
    casc = CascadeOracle(large, draft_engine=draft, threshold=math.inf,
                         prices=CASCADE_70B)
    plain = ModelOracle(large, prices=CASCADE_70B)
    rc, _ = llm_order_by(keys, CRITERIA, casc, path=PATH, descending=True)
    rp, _ = llm_order_by(keys, CRITERIA, plain, path=PATH, descending=True)
    assert [k.uid for k in rc.order] == [k.uid for k in rp.order], (
        "real escalate-all order diverged from large-only")
    assert casc.ledger.records == plain.ledger.records, (
        "real escalate-all ledger diverged from large-only")
    inf_large = _tier_tokens(casc.ledger.records, "large")

    # calibrated mid-rung: half the calibration probes would escalate
    mid = CascadeOracle(large, draft_engine=draft, prices=CASCADE_70B)
    t = mid.calibrate_threshold(keys, CRITERIA, quantile=0.5)
    t0 = time.perf_counter()
    res, _ = llm_order_by(keys, CRITERIA, mid, path=PATH, descending=True)
    secs = time.perf_counter() - t0
    assert sorted(k.uid for k in res.order) == sorted(k.uid for k in keys)
    mid_large = _tier_tokens(mid.ledger.records, "large")
    assert mid_large < inf_large, (
        "calibrated cascade did not reduce large-model probe tokens")
    return [
        dict(backend="real", n=n, threshold=math.inf, draft_probe_tokens=0,
             large_probe_tokens=inf_large, identity=True),
        dict(backend="real", n=n, threshold=round(t, 4),
             draft_probe_tokens=_tier_tokens(mid.ledger.records, "draft"),
             large_probe_tokens=mid_large,
             large_tokens_saved=round(1.0 - mid_large / max(inf_large, 1), 4),
             seconds=round(secs, 3)),
    ]


def main() -> None:
    from benchmarks.common import parse_json_flag
    argv, json_path = parse_json_flag(sys.argv[1:])
    real = "--real" in argv
    argv = [a for a in argv if a != "--real"]
    sizes = [int(a) for a in argv if a.isdigit()] or [48]
    rows = []
    for n in sizes:
        rows.extend(run_simulated(n))
        if real:
            rows.extend(run_real(max(n // 6, 8)))
    cols = ("backend", "n", "threshold", "tau", "tau_gap",
            "draft_probe_tokens", "large_probe_tokens", "large_tokens_saved",
            "cost")
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r.get(c, "")) for c in cols))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
