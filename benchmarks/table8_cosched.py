"""Table 8 (repo-specific): unified step loop — probe/decode co-scheduling.

A mixed workload on one engine: a judge-rationale generate stream (mixed
lengths, long stragglers — table 6's traffic) is mid-drain when an LLM
ORDER BY query arrives.  The query's access-path plan runs as deferred
probe rounds through the probe-plan executor; the headline metric is
**probe-round completion latency in decode steps** — how many steps of the
in-flight generate workload pass between a round's submission and its
resolution:

 * **unified** — the query's executor ticks pump the SAME step loop the
   generates decode through: every round rides the next step gap, so
   latency is ~1 step whatever the drain length, and the generates keep
   decoding one token per step alongside the probe traffic;
 * **alternating** (the pre-unified behavior) — an executor run and a
   generate drain take turns at drain granularity: the round submitted
   mid-drain waits for the WHOLE remaining drain before its first service
   opportunity.

Acceptance (ISSUE 5): a probe round submitted during an in-flight generate
completes within <= 2 decode steps under the unified loop; generate
outputs are token-identical (``==``) to solo lockstep and the query's
order AND ledger are byte-identical to its solo execution, asserted here
and in tests/test_cosched.py.

As with table 6, the asserted metric is SCHEDULING latency, not CPU
wall-clock: the KV arena is donated on every backend now (XLA:CPU honors
the aliasing too), but a CPU "step gap" is not free compute the way a
TPU's is, so the unified mode's extra steps-with-probes can still cost
more seconds than the back-to-back baseline.  The artifact also reports
decode **tokens/s** per mode (decode tokens over wall-clock) so the
donation win is visible in the numbers rather than asserted.

    PYTHONPATH=src python -m benchmarks.table8_cosched [--json OUT] [N ...]
"""
from __future__ import annotations

import json
import sys

import numpy as np

from repro.core import PathParams, ProbePlanExecutor, as_keys, make_path
from repro.core.executor import plan_sort_result
from repro.core.oracles.model_oracle import ModelOracle
from repro.core.types import SortSpec

from .common import decode_timing

MAX_NEW = 24
SUBMIT_AT = 3          # drain step at which the ORDER BY query arrives


def _engine():
    import jax
    from repro.configs import get_reduced
    from repro.models import LM
    from repro.serving import ServeEngine
    cfg = get_reduced("llama3-8b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    return ServeEngine(lm, params, max_new_tokens=MAX_NEW)


def workload(n: int, seed: int = 0):
    """n mixed-length judge requests: ~3/4 short verdicts, ~1/4 long
    rationale stragglers (the full budget) — table 6's traffic shape."""
    rng = np.random.default_rng(seed)
    prompts, limits = [], []
    for i in range(n):
        straggler = i % 4 == 3
        body = "criteria compliance of candidate ranking " + "x" * int(
            rng.integers(0, 40))
        prompts.append(f"Judge {i}: {body}\nVerdict:")
        limits.append(MAX_NEW if straggler else int(rng.integers(2, 5)))
    return prompts, limits


def _ledger(oracle):
    return (oracle.ledger.n_calls, oracle.ledger.input_tokens,
            oracle.ledger.output_tokens, list(oracle.ledger.records))


def _query(n_keys: int):
    keys = as_keys([f"doc {'q' * (i % 5)} {i:03d}" for i in range(n_keys)],
                   list(np.random.default_rng(1).standard_normal(n_keys)))
    return keys, SortSpec("relevance", True, 8)


def run_unified(eng, prompts, limits, keys, spec) -> dict:
    """Generates and the ORDER BY query drive ONE live loop."""
    from repro.serving import BatchScheduler
    sched = BatchScheduler(eng, max_batch=8)
    oracle = ModelOracle(eng, scheduler=sched)
    rids = [sched.submit(p, l) for p, l in zip(prompts, limits)]
    ex = ProbePlanExecutor(scheduler=sched)
    ap = make_path("quick", PathParams(batch_size=4))
    run = None
    latencies: list[int] = []
    with decode_timing(eng) as dt:
        while sched.work_remaining or run is None or not run.done:
            if run is None and sched.steps >= SUBMIT_AT:
                run = ex.submit_path(ap, keys, oracle, spec, name="orderby")
            if run is not None and not run.done:
                s0 = sched.steps
                ex.tick()        # begins the plan's round, pumps ONE step
                latencies.append(sched.steps - s0)
            else:
                sched.step()
    res = plan_sort_result(run, spec, len(keys), oracle.prices)
    outs = [sched.completed[r].output for r in rids]
    return dict(outputs=outs, result=res, oracle=oracle,
                latencies=latencies, total_steps=sched.steps,
                seconds=dt.seconds, decode_tokens=dt.decode_tokens,
                tokens_per_s=dt.tokens_per_s)


def run_alternating(eng, prompts, limits, keys, spec) -> dict:
    """The pre-unified behavior: the generate drain runs to completion,
    THEN the query's executor gets the engine — the round logically
    submitted at step SUBMIT_AT waits out the whole remaining drain."""
    from repro.serving import BatchScheduler
    sched = BatchScheduler(eng, max_batch=8)
    oracle = ModelOracle(eng)
    rids = [sched.submit(p, l) for p, l in zip(prompts, limits)]
    with decode_timing(eng) as dt:
        drained = sched.run()
        drain_steps = sched.steps
        ex = ProbePlanExecutor(scheduler=sched)
        run = ex.submit_path(make_path("quick", PathParams(batch_size=4)),
                             keys, oracle, spec, name="orderby")
        ticks = 0
        while not run.done:
            ex.tick()
            ticks += 1
    res = plan_sort_result(run, spec, len(keys), oracle.prices)
    # the first round's completion latency in decode steps: the remaining
    # drain it had to wait out, plus its own service step
    first_latency = (drain_steps - SUBMIT_AT) + 1
    return dict(outputs=[drained[r] for r in rids], result=res,
                oracle=oracle, first_latency=first_latency,
                drain_steps=drain_steps, ticks=ticks, seconds=dt.seconds,
                decode_tokens=dt.decode_tokens, tokens_per_s=dt.tokens_per_s)


def run(sizes: list[int]) -> list[dict]:
    eng = _engine()
    rows: list[dict] = []
    for n in sizes:
        prompts, limits = workload(n)
        keys, spec = _query(20)
        # solo baselines: generate outputs and the query's order + ledger
        solo_gen = [eng.generate_lockstep([p], max_new_per=[l])[0]
                    for p, l in zip(prompts, limits)]
        solo_oracle = ModelOracle(eng)
        solo_res = make_path("quick", PathParams(batch_size=4)).execute(
            keys, solo_oracle, spec)

        uni = run_unified(eng, prompts, limits, keys, spec)
        alt = run_alternating(eng, prompts, limits, keys, spec)

        row = dict(
            n_generates=n, max_new=MAX_NEW, n_keys=len(keys),
            unified_rounds=len(uni["latencies"]),
            unified_mean_latency=round(float(np.mean(uni["latencies"])), 2),
            unified_max_latency=int(max(uni["latencies"])),
            alternating_first_latency=int(alt["first_latency"]),
            unified_steps=uni["total_steps"],
            alternating_drain_steps=alt["drain_steps"],
            unified_seconds=uni["seconds"],
            alternating_seconds=alt["seconds"],
            unified_tokens_per_s=uni["tokens_per_s"],
            alternating_tokens_per_s=alt["tokens_per_s"],
            token_identical=(uni["outputs"] == solo_gen
                             and alt["outputs"] == solo_gen),
            order_identical=(uni["result"].uids() == solo_res.uids()
                             == alt["result"].uids()),
            ledger_identical=(_ledger(uni["oracle"]) == _ledger(solo_oracle)
                              == _ledger(alt["oracle"])),
        )
        rows.append(row)
        assert row["token_identical"], (
            f"co-scheduled generate outputs diverged from solo lockstep "
            f"(n={n})")
        assert row["order_identical"], (
            f"co-scheduled query order diverged from solo execution (n={n})")
        assert row["ledger_identical"], (
            f"co-scheduled query ledger diverged from solo execution (n={n})")
        assert row["unified_max_latency"] <= 2, (
            f"a probe round took {row['unified_max_latency']} decode steps "
            f"under the unified loop (acceptance: <= 2)")
        assert row["alternating_first_latency"] > row["unified_max_latency"], (
            "alternating drains should strictly delay the mid-drain round")
    return rows


def main() -> None:
    from benchmarks.common import parse_json_flag
    argv, json_path = parse_json_flag(sys.argv[1:])
    sizes = [int(a) for a in argv if a.isdigit()] or [16]
    rows = run(sizes)
    cols = ("n_generates", "n_keys", "unified_rounds", "unified_mean_latency",
            "unified_max_latency", "alternating_first_latency",
            "unified_steps", "alternating_drain_steps", "unified_seconds",
            "alternating_seconds", "unified_tokens_per_s",
            "alternating_tokens_per_s", "token_identical", "order_identical",
            "ledger_identical")
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
