"""Model-cascade probe execution (core/oracles/cascade.py).

Contracts under test (DESIGN.md "Model-cascade oracle"):

 * **identity anchor** — ``threshold=inf`` is byte-identical in BOTH
   output and ledger records to single-model large execution, across all
   five access paths; ``threshold=0`` never escalates, so zero
   large-tier probe records are billed;
 * **tiered billing** — draft and escalated calls land as distinct
   ``CallRecord`` tiers, priced per tier by :class:`TieredPrices`, with
   exact per-query attribution (interleaved == solo);
 * **two-lane scheduling** — ``submit_cascade_round`` runs wave 1 on the
   draft engine and escalated rows on the large engine inside the SAME
   round future; transient engine failures re-queue, escalation-callback
   bugs propagate;
 * **optimizer ladder** — ``path="auto"`` with ``ladder_thresholds``
   explores (path, rung, threshold) candidates under one budget and is a
   no-op for oracles without a cascade ladder.
"""
import math

import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, ladder
from repro.core import (CASCADE_70B, LLAMA70B, REASONING, OrderQuery,
                        SimulatedCascadeOracle, SimulatedOracle, TieredPrices,
                        as_keys, llm_order_by, llm_order_by_many)
from repro.core.oracles.base import STABLELM2, LedgerView
from repro.core.oracles.cascade import probe_margin
from repro.core.optimizer.cost_model import (CandidateSpec, default_candidates,
                                             ladder_candidates)
from repro.serving.scheduler import BatchScheduler, CascadeFuture

ALL_PATHS = ("pointwise", "ext_pointwise", "quick", "ext_bubble", "ext_merge")


def mk(n=24, seed=0):
    rng = np.random.default_rng(seed)
    return as_keys([f"item {i} " + "w" * (i % 5) for i in range(n)],
                   rng.standard_normal(n))


# ------------------------------------------------------------ probe_margin
def test_probe_margin_reads_the_right_token_gaps():
    from repro.serving.engine import (TOK_A, TOK_B, TOK_HI, TOK_LO, TOK_NO,
                                      TOK_YES)
    l = np.zeros(128, np.float32)
    l[TOK_A], l[TOK_B] = 3.0, -1.0
    l[TOK_YES], l[TOK_NO] = 0.5, 2.0
    l[TOK_HI], l[TOK_LO] = 4.0, 1.0
    assert probe_margin("compare", l) == pytest.approx(4.0)
    assert probe_margin("inquire", l) == pytest.approx(1.5)
    for kind in ("score_each", "score_batches", "rank_windows"):
        assert probe_margin(kind, l) == pytest.approx(3.0)


# ------------------------------------------------------------ TieredPrices
def test_tiered_prices_books_each_record_against_its_tier():
    o = SimulatedCascadeOracle(threshold=1.0, prices=CASCADE_70B)
    keys = mk(12, seed=3)
    o.compare_batch([(keys[i], keys[i + 1]) for i in range(10)], "c")
    view = LedgerView(list(o.ledger.records))
    drafts, larges = view.by_tier("draft"), view.by_tier("large")
    assert drafts.records and larges.records
    expect = (STABLELM2.cost(drafts.input_tokens, drafts.output_tokens)
              + LLAMA70B.cost(larges.input_tokens, larges.output_tokens))
    assert view.cost(CASCADE_70B) == pytest.approx(expect)


def test_tiered_prices_unknown_tier_falls_back_to_default():
    tp = TieredPrices((("draft", STABLELM2),), LLAMA70B)
    assert tp.sheet("draft") is STABLELM2
    assert tp.sheet("") is LLAMA70B
    assert tp.sheet("unknown") is LLAMA70B
    # plain sheets keep the aggregate formula bit-for-bit
    assert tp.cost(1000, 10) == LLAMA70B.cost(1000, 10)


# ------------------------------------------------- identity anchor (inf/0)
@pytest.mark.parametrize("path", ALL_PATHS)
def test_escalate_all_is_byte_identical_to_large_only(path):
    keys = mk()
    casc = SimulatedCascadeOracle(threshold=math.inf, prices=CASCADE_70B)
    plain = SimulatedOracle(REASONING, prices=CASCADE_70B)
    rc, _ = llm_order_by(keys, "value", casc, path=path)
    rp, _ = llm_order_by(keys, "value", plain, path=path)
    assert [k.uid for k in rc.order] == [k.uid for k in rp.order]
    assert casc.ledger.records == plain.ledger.records
    assert rc.cost == rp.cost


def test_threshold_zero_bills_no_large_probe_calls():
    keys = mk(20, seed=1)
    for path in ("pointwise", "quick"):
        o = SimulatedCascadeOracle(threshold=0.0, prices=CASCADE_70B)
        res, _ = llm_order_by(keys, "value", o, path=path)
        assert sorted(k.uid for k in res.order) == sorted(k.uid for k in keys)
        assert all(r.tier == "draft" for r in o.ledger.records)


def test_escalations_monotone_in_threshold():
    keys = mk(16, seed=2)
    pairs = [(keys[i], keys[i + 1]) for i in range(15)]

    def non_draft_records(threshold):
        # records billed at large quality: tier="large" escalations in
        # cascade mode, untiered records in inf-passthrough (the identity
        # anchor bills exactly like single-model execution)
        o = SimulatedCascadeOracle(threshold=threshold, prices=CASCADE_70B)
        o.compare_batch(pairs, "c")
        o.score_batch(keys, "c")
        return sum(1 for r in o.ledger.records if r.tier != "draft")

    counts = [non_draft_records(t) for t in (0.0, 0.5, 2.0, math.inf)]
    assert counts[0] == 0
    assert counts == sorted(counts)
    assert counts[-1] > 0


def test_at_threshold_view_shares_the_ledger():
    o = SimulatedCascadeOracle(threshold=math.inf, prices=CASCADE_70B)
    rung = o.at_threshold(0.75)
    assert rung.ledger is o.ledger
    assert rung.threshold == 0.75 and o.threshold == math.inf
    keys = mk(6, seed=4)
    rung.compare(keys[0], keys[1], "c")
    assert o.ledger.records                 # rung spend lands in one book


# ----------------------------------------------- per-query attribution
def test_interleaved_cascade_queries_match_solo_ledgers():
    keys = mk(18, seed=5)
    crits = ("positivity", "relevance")

    def solo(crit):
        o = SimulatedCascadeOracle(threshold=1.0, prices=CASCADE_70B)
        res, _ = llm_order_by(keys, crit, o, path="quick")
        return res, list(o.ledger.records)

    solos = [solo(c) for c in crits]
    oracles = [SimulatedCascadeOracle(threshold=1.0, prices=CASCADE_70B)
               for _ in crits]
    many = llm_order_by_many([
        OrderQuery(keys=keys, criteria=c, oracle=o, path="quick")
        for c, o in zip(crits, oracles)])
    for (sres, srecs), mres, o in zip(solos, many, oracles):
        assert [k.uid for k in mres.order] == [k.uid for k in sres.order]
        assert list(o.ledger.records) == srecs
        assert mres.cost == sres.cost


# ------------------------------------------------------- optimizer ladder
def test_ladder_candidates_expand_pool_with_threshold_variants():
    pool = default_candidates()
    out = ladder_candidates(pool, [0.5, 2.0])
    assert len(out) == 3 * len(pool)
    labels = {c.label for c in out}
    assert "quick@t0.5" in labels and "ext_merge_4@t2" in labels
    t = next(c for c in out if c.label == "quick@t0.5")
    assert t.threshold == 0.5 and t.rung == "t0.5"
    assert CandidateSpec("quick", threshold=2.0).comparison_based


def test_auto_path_explores_the_ladder_under_one_budget():
    keys = mk(30, seed=6)
    o = SimulatedCascadeOracle(prices=CASCADE_70B)   # passthrough base
    res, rep = llm_order_by(keys, "value", o, path="auto", sample_size=10,
                            budget=0.05, ladder_thresholds=[0.5, 2.0])
    assert sorted(k.uid for k in res.order) == sorted(k.uid for k in keys)
    sampled = set(rep.est_costs)
    assert any("@t0.5" in l for l in sampled)
    assert any("@t" not in l for l in sampled)
    # cascade variants of a path must estimate cheaper than large-only:
    # drafts answer at the draft sheet and only low-margin rows re-bill
    for label in sampled:
        if "@t0.5" in label and label.split("@")[0] in sampled:
            assert rep.est_costs[label] < rep.est_costs[label.split("@")[0]]
    # the winner actually executed: total cost includes its full run
    assert rep.total_cost > rep.optimizer_cost >= 0


def test_ladder_ignored_without_cascade_oracle():
    keys = mk(20, seed=7)
    o = SimulatedOracle(REASONING)
    _res, rep = llm_order_by(keys, "value", o, path="auto", sample_size=8,
                             ladder_thresholds=[0.5])
    assert all("@t" not in l for l in rep.est_costs)


def test_ladder_rides_llm_order_by_many():
    keys = mk(24, seed=8)
    o = SimulatedCascadeOracle(prices=CASCADE_70B)
    q = OrderQuery(keys=keys, criteria="value", oracle=o, path="auto",
                   sample_size=8, budget=0.05, ladder_thresholds=[0.5])
    (res,) = llm_order_by_many([q])
    assert sorted(k.uid for k in res.order) == sorted(k.uid for k in keys)
    assert any("@t0.5" in l for l in q.report.est_costs)


# --------------------------------------------- scheduler: two engine lanes
class _TierEngine:
    """Fake engine tagging every logits row with its lane level."""

    paged_enabled = False
    max_probe_batch = 256

    def __init__(self, level):
        self.level = float(level)
        self.submitted = []
        self.fail_next = 0

    def submit_probes(self, prompts, max_batch=None):
        if self.fail_next:
            self.fail_next -= 1
            raise RuntimeError("transient engine failure")
        self.submitted.append(list(prompts))
        out = np.zeros((len(prompts), 4), np.float32)
        for i, p in enumerate(prompts):
            out[i, 0] = self.level
            out[i, 1] = float(len(p))
        return out


def _two_lane():
    draft, large = _TierEngine(1), _TierEngine(2)
    return BatchScheduler(large, draft_engine=draft), draft, large


def test_cascade_round_splits_waves_across_lanes():
    sched, draft, large = _two_lane()
    fut = sched.submit_cascade_round(
        ["a", "bb", "ccc", "dddd"],
        lambda logits: {s for s, l in logits.items() if l[1] % 2 == 0})
    assert isinstance(fut, CascadeFuture) and not fut.done
    sched.pump()
    assert fut.done and fut.escalated == {1, 3}
    rows = fut.result()
    assert [r[0] for r in rows] == [1.0, 2.0, 1.0, 2.0]  # draft/large mix
    assert draft.submitted == [["a", "bb", "ccc", "dddd"]]
    assert large.submitted == [["bb", "dddd"]]           # escalations only
    assert sched.probes_drafted == 4 and sched.probes_escalated == 2


def test_escalations_join_the_same_gap_as_plain_rounds():
    sched, draft, large = _two_lane()
    casc = sched.submit_cascade_round(
        ["aa", "bbb"], lambda logits: set(logits))     # escalate-all
    plain = sched.submit_probe_round(["zzzz"])
    sched.pump()
    assert casc.done and plain.done
    # ONE merged large-lane submission served the plain round AND wave 2
    assert len(large.submitted) == 1
    assert set(large.submitted[0]) == {"aa", "bbb", "zzzz"}
    assert [r[0] for r in casc.result()] == [2.0, 2.0]


def test_draft_wave_failure_requeues_and_retries():
    sched, draft, large = _two_lane()
    fut = sched.submit_cascade_round(["a", "bb"], lambda logits: set())
    draft.fail_next = 1
    with pytest.raises(RuntimeError, match="transient"):
        sched.pump()
    assert len(sched.probe_queue) == 2      # both rows back in the queue
    sched.pump()                            # retry succeeds
    assert fut.done and [r[0] for r in fut.result()] == [1.0, 1.0]


def test_raising_escalate_callback_propagates():
    sched, _draft, _large = _two_lane()

    def bad(_logits):
        raise ValueError("oracle-layer bug")

    sched.submit_cascade_round(["a"], bad)
    with pytest.raises(ValueError, match="oracle-layer bug"):
        sched.pump()


def test_cascade_round_requires_a_draft_lane():
    sched = BatchScheduler(_TierEngine(2))
    with pytest.raises(AssertionError):
        sched.submit_cascade_round(["a"], lambda logits: set())


# ------------------------------------------------------- configs ladder
def test_registry_ladder_rungs_are_known_archs_smallest_first():
    rungs = ladder()
    assert len(rungs) >= 2
    assert all(r in ARCH_IDS for r in rungs)
    assert rungs[0] == "stablelm-1.6b"


def test_registry_ladder_rungs_all_instantiate_reduced_configs():
    from repro.configs import get_reduced
    for arch in ladder():
        cfg = get_reduced(arch)
        assert cfg.n_layers >= 1 and cfg.vocab_size >= 256


# ------------------------------------------- slow: real two-engine cascade
@pytest.fixture(scope="module")
def tier_engines():
    import jax
    from repro.configs import get_reduced
    from repro.models import LM
    from repro.serving import ServeEngine

    def build(arch, seed):
        lm = LM(get_reduced(arch))
        return ServeEngine(lm, lm.init(jax.random.PRNGKey(seed)),
                           max_new_tokens=8)

    rungs = ladder()
    return build(rungs[0], 0), build(rungs[1], 1)   # (draft, large)


@pytest.mark.slow
@pytest.mark.parametrize("path", ("pointwise", "quick"))
def test_real_escalate_all_identity(tier_engines, path):
    from repro.core.oracles.cascade import CascadeOracle
    from repro.core.oracles.model_oracle import ModelOracle
    draft, large = tier_engines
    keys = mk(6, seed=9)
    casc = CascadeOracle(large, draft_engine=draft, threshold=math.inf,
                         prices=CASCADE_70B)
    plain = ModelOracle(large, prices=CASCADE_70B)
    rc, _ = llm_order_by(keys, "value", casc, path=path)
    rp, _ = llm_order_by(keys, "value", plain, path=path)
    assert [k.uid for k in rc.order] == [k.uid for k in rp.order]
    assert casc.ledger.records == plain.ledger.records
    assert rc.cost == rp.cost


@pytest.mark.slow
def test_real_calibrated_cascade_bills_both_tiers(tier_engines):
    from repro.core.oracles.cascade import CascadeOracle
    draft, large = tier_engines
    keys = mk(8, seed=10)
    casc = CascadeOracle(large, draft_engine=draft, prices=CASCADE_70B)
    t = casc.calibrate_threshold(keys, "value", quantile=0.9)
    assert casc.threshold == t and casc._cascading
    res, _ = llm_order_by(keys, "value", casc, path="quick")
    assert sorted(k.uid for k in res.order) == sorted(k.uid for k in keys)
    view = LedgerView(list(casc.ledger.records))
    assert view.by_tier("draft").records
    assert view.by_tier("large").records    # 0.9-quantile: most escalate
    assert len(view.by_tier("large").records) <= \
        len(view.by_tier("draft").records)


@pytest.mark.slow
def test_real_deferred_cascade_matches_sync(tier_engines):
    """The deferred two-wave round (begin → submit_cascade_round →
    escalate callback → finish) produces the SAME answers and the SAME
    ledger record sequence as the synchronous verbs."""
    from repro.core.oracles.cascade import CascadeOracle
    draft, large = tier_engines
    keys = mk(8, seed=11)
    probe = CascadeOracle(large, draft_engine=draft, prices=CASCADE_70B)
    t = probe.calibrate_threshold(keys, "value", quantile=0.5)

    sync = CascadeOracle(large, draft_engine=draft, threshold=t,
                         prices=CASCADE_70B)
    rs, _ = llm_order_by(keys, "value", sync, path="quick")

    deferred = CascadeOracle(large, draft_engine=draft, threshold=t,
                             prices=CASCADE_70B)
    (rd,) = llm_order_by_many([OrderQuery(keys=keys, criteria="value",
                                          oracle=deferred, path="quick")])
    assert [k.uid for k in rd.order] == [k.uid for k in rs.order]
    assert list(deferred.ledger.records) == list(sync.ledger.records)
    assert rd.cost == rs.cost
