"""Oracle semantics: determinism, antisymmetry, billing, caching."""
import numpy as np

from repro.core import (CachingOracle, ExactOracle, LLAMA405B, LLAMA70B,
                        SimulatedOracle, as_keys)
from repro.core.oracles.simulated import FACTUAL, REASONING
from repro.core.types import InvalidOutputError


def mk(n=10, seed=0):
    rng = np.random.default_rng(seed)
    return as_keys([f"text {i} " + "w" * (i % 7) for i in range(n)],
                   rng.standard_normal(n))


def test_temperature_zero_determinism():
    keys = mk()
    o1, o2 = SimulatedOracle(REASONING), SimulatedOracle(REASONING)
    assert o1.score_batch(keys, "c") == o2.score_batch(keys, "c")
    assert o1.compare(keys[0], keys[1], "c") == o2.compare(keys[0], keys[1], "c")
    r1 = [k.uid for k in o1.rank_batch(keys, "c")]
    r2 = [k.uid for k in o2.rank_batch(keys, "c")]
    assert r1 == r2


def test_compare_antisymmetric():
    keys = mk(20, seed=1)
    o = SimulatedOracle(REASONING)
    for a in keys[:5]:
        for b in keys[5:10]:
            assert o.compare(a, b, "c") == -o.compare(b, a, "c")


def test_factual_profile_scores_accurately():
    keys = mk(30, seed=2)
    o = SimulatedOracle(FACTUAL)
    scores = o.score_batch(keys, "height")
    corr = np.corrcoef(scores, [k.latent for k in keys])[0, 1]
    assert corr > 0.95


def test_rank_batch_is_permutation():
    keys = mk(16, seed=3)
    o = SimulatedOracle(REASONING)
    perm = o.rank_batch(keys, "c")
    assert sorted(k.uid for k in perm) == sorted(k.uid for k in keys)


def test_invalid_rate_grows_with_batch():
    o = SimulatedOracle(REASONING)
    fails = {m: 0 for m in (4, 32)}
    for m in fails:
        for seed in range(40):
            keys = mk(m, seed=100 + seed)
            try:
                o.rank_batch(keys, f"crit-{seed}")
            except InvalidOutputError:
                fails[m] += 1
    assert fails[32] >= fails[4]


def test_ledger_token_accounting_and_prices():
    keys = mk(8)
    o = SimulatedOracle(REASONING, prices=LLAMA70B)
    o.score_batch(keys, "c")
    o.compare(keys[0], keys[1], "c")
    led = o.ledger
    assert led.n_calls == 2
    assert led.input_tokens > 0 and led.output_tokens > 0
    c70 = led.cost(LLAMA70B)
    c405 = led.cost(LLAMA405B)
    assert c405 > c70 > 0


def test_cache_hits_are_free():
    keys = mk(6)
    o = CachingOracle(SimulatedOracle(REASONING))
    v1 = o.score_batch(keys, "c")
    calls_after_first = o.ledger.n_calls
    v2 = o.score_batch(keys, "c")
    assert v1 == v2
    assert o.ledger.n_calls == calls_after_first  # no extra billing
    assert o.hits == 1 and o.misses == 1


def test_cache_key_canonicalizes_criteria_whitespace():
    """Regression (ISSUE 6 satellite): memo keys normalize criteria
    whitespace, so logically identical calls spelled with different
    spacing/newlines hit one entry instead of re-billing."""
    keys = mk(4)
    o = CachingOracle(SimulatedOracle(REASONING))
    v1 = o.score_batch(keys, "degree  of\n positivity")
    calls = o.ledger.n_calls
    v2 = o.score_batch(keys, " degree of positivity ")
    assert v1 == v2
    assert o.ledger.n_calls == calls             # second spelling was free
    assert o.hits == 1 and o.misses == 1
    # compare + inquire variants share the same canonicalization
    a, b = keys[0], keys[1]
    r1 = o.compare(a, b, "x\ty")
    r2 = o.compare(a, b, "x y")
    assert r1 == r2 and o.hits == 2
    assert o.inquire(a, "c  c") == o.inquire(a, "c c")
    assert o.hits == 3
    # distinct criteria stay distinct entries
    o.score_batch(keys, "different criteria")
    assert o.misses == 4


def test_cache_key_stable_hash_no_collisions_on_structure():
    """The stable key separates kind / uid tuple / criteria structurally:
    permuted uids or a different verb never alias one entry."""
    from repro.core.oracles.cache import CachingOracle as C
    assert C._ck("score", (1, 2), "c") == C._ck("score", iter((1, 2)), "c")
    assert C._ck("score", (1, 2), "c") != C._ck("score", (2, 1), "c")
    assert C._ck("score", (1, 2), "c") != C._ck("rank", (1, 2), "c")
    assert C._ck("score", (12,), "c") != C._ck("score", (1, 2), "c")


def test_exact_oracle_judge_picks_true_best():
    keys = mk(10, seed=4)
    best = sorted(keys, key=lambda k: k.latent)
    worst = list(reversed(best))
    o = ExactOracle()
    assert o.judge(keys, "c", [worst, best, keys]) == 1
