"""Distributed extras: explicit compressed all-reduce, elastic-mesh
re-lowering, activation-sharding context."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_reduced
from repro.distributed import (ShardingPlan, activation_spec, named,
                               param_specs, sequence_parallel_spec)
from repro.launch.mesh import make_local_mesh
from repro.models import LM
from repro.training.compression import compress_leaf, ef_allreduce
from repro.training.fault_tolerance import elastic_plan


def test_ef_allreduce_roundtrip_single_shard():
    """shard_map int8 psum path: on a 1-wide axis it must equal dequant."""
    mesh = make_local_mesh(1, 1)
    g = jnp.asarray(np.random.default_rng(0).standard_normal(64), jnp.float32)
    q, scale, err = compress_leaf(g, jnp.zeros_like(g))
    with mesh:
        out = ef_allreduce(mesh, ("data",), q, jnp.full((64,), scale))
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(q, np.float32) * float(scale),
                               rtol=1e-6)
    # error feedback bound
    assert float(jnp.max(jnp.abs(err))) <= float(scale) * 1.01


def test_elastic_replan_and_relower():
    """Losing devices: elastic_plan recarves the data axis, the same model
    re-lowers on the smaller mesh (the restart path after a pod loss)."""
    plan = elastic_plan(n_alive=1, model_parallel=1)
    assert plan.n_devices == 1
    cfg = get_reduced("llama3-8b")
    lm = LM(cfg)
    mesh = make_local_mesh(plan.data, plan.model)
    params_shape = jax.eval_shape(lambda: lm.init(jax.random.PRNGKey(0)))
    shardings = named(mesh, param_specs(params_shape, mesh, ShardingPlan()))
    batch = {"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32)}
    with mesh:
        compiled = jax.jit(lm.loss, in_shardings=(shardings, None)) \
            .lower(params_shape, batch).compile()
    assert compiled.cost_analysis() is not None


def test_activation_spec_context_applies_constraint():
    cfg = get_reduced("phi4-mini-3.8b")
    lm = LM(cfg)
    mesh = make_local_mesh(1, 1)
    params = lm.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32)}
    with mesh, activation_spec(sequence_parallel_spec(("data",))):
        loss, _ = jax.jit(lm.loss)(params, batch)
    assert np.isfinite(float(loss))


def test_cache_layout_seq_spec():
    from jax.sharding import AbstractMesh
    from repro.distributed import cache_specs
    mesh = AbstractMesh((("data", 16), ("model", 16)))
    cache = jax.ShapeDtypeStruct((32, 128, 32768, 8, 128), jnp.bfloat16)
    spec = jax.tree.leaves(
        cache_specs(cache, mesh, ShardingPlan(cache_layout="seq")),
        is_leaf=lambda x: isinstance(x, P))[0]
    entries = tuple(spec)
    assert entries[1] in ("data", ("data",))     # batch over data
    assert entries[2] in ("model", ("model",))   # seq over model (ctx parallel)
