"""Sharded serving identity suite (ISSUE 10).

``ServeEngine(mesh=...)`` must be INVISIBLE to results: on a data-parallel
mesh (model axis 1) nothing reduces across devices — each shard computes a
contiguous row slice and the host-side gather reassembles — so generate
outputs, probe logits, query orders, and ledgers are bitwise/byte identical
to the single-device engine.  On a tensor-parallel mesh (model axis > 1)
the row-parallel psums reorder reductions, so probe logits are held to the
documented ``TP_PSUM_RTOL/ATOL`` tolerance instead (the contract stance
documented in benchmarks/table12_sharding.py).

The 8-device suites need ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
in the environment BEFORE jax initializes (CI runs a tier-1 matrix leg with
it); under the default single-device run they skip.  The 1x1-mesh identity
tests, shard-aligned probe chunking, and the sharded-pool fuzz loop (the
REAL KVBlockPool with NamedSharding'd arenas under test_fuzz_loop's driver)
run everywhere.
"""
import math

import numpy as np
import pytest

import jax

from fakes_paged import FakePagedEngine, tiny_pool_lm
from repro.core import OrderQuery, as_keys, llm_order_by, llm_order_by_many
from repro.core.oracles.model_oracle import ModelOracle
from repro.serving import BatchScheduler
from repro.serving.kv_pool import KVBlockPool

DEV = jax.device_count()
needs8 = pytest.mark.skipif(
    DEV < 8, reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

ALL_PATHS = ("pointwise", "ext_pointwise", "quick", "ext_bubble", "ext_merge")
PROBES = [(f"Criteria: relevance\nItem:", f" candidate passage {i:03d}\n"
           f"Rating:") for i in range(16)]
GEN = [(f"Judge {i}: rationale " + "r" * (3 * i), 2 + 2 * i)
       for i in range(4)]


def _keys(n=8, seed=0):
    rng = np.random.default_rng(seed)
    return as_keys([f"doc {'q' * (i % 5)} {i:03d}" for i in range(n)],
                   list(rng.standard_normal(n)))


def _ledger(o):
    return (o.ledger.n_calls, o.ledger.input_tokens, o.ledger.output_tokens,
            list(o.ledger.records))


def _build(mesh=None, arch="llama3-8b", seed=0, dp=True):
    from repro.configs import get_reduced
    from repro.models import LM
    from repro.serving import ServeEngine
    lm = LM(get_reduced(arch))
    return ServeEngine(lm, lm.init(jax.random.PRNGKey(seed)),
                       max_new_tokens=8, mesh=mesh, dp_probe_slices=dp)


@pytest.fixture(scope="module")
def base():
    return _build()


@pytest.fixture(scope="module")
def mesh8():
    if DEV < 8:
        pytest.skip("needs 8 devices")
    from repro.launch.mesh import make_local_mesh
    return _build(mesh=make_local_mesh(8, 1))


# ------------------------------------------------- tier-1: 1x1 mesh identity
def test_mesh_1x1_bitwise_identity(base):
    """The degenerate 1x1 mesh exercises the full sharded code path
    (NamedSharding'd params/arenas, shard_context closures, _put_rows)
    and must be bitwise the unsharded engine."""
    from repro.launch.mesh import make_local_mesh
    eng = _build(mesh=make_local_mesh(1, 1))
    assert np.array_equal(base.submit_probes(PROBES),
                          eng.submit_probes(PROBES))
    prompts = [p for p, _ in GEN]
    limits = [l for _, l in GEN]
    assert (eng.generate_lockstep(prompts, max_new_per=limits)
            == base.generate_lockstep(prompts, max_new_per=limits))


def test_mesh_1x1_query_and_ledger_identity(base):
    from repro.launch.mesh import make_local_mesh
    eng = _build(mesh=make_local_mesh(1, 1))
    keys = _keys()
    ob, os_ = ModelOracle(base), ModelOracle(eng)
    rb, _ = llm_order_by(keys, "relevance", ob, path="quick")
    rs, _ = llm_order_by(keys, "relevance", os_, path="quick")
    assert rs.uids() == rb.uids()
    assert _ledger(os_) == _ledger(ob)


def test_dp_ablation_counts_submissions():
    """dp_probe_slices=False replicates every submission row on every
    shard — the stats counters expose which mode each round took."""
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh(1, 1)
    sliced = _build(mesh=mesh, dp=True)
    sliced.submit_probes(PROBES)
    assert sliced.stats.dp_sharded_submissions > 0
    assert sliced.stats.dp_replicated_submissions == 0
    repl = _build(mesh=mesh, dp=False)
    repl.submit_probes(PROBES)
    assert repl.stats.dp_replicated_submissions > 0
    assert repl.stats.dp_sharded_submissions == 0
    assert np.array_equal(sliced.submit_probes(PROBES),
                          repl.submit_probes(PROBES))


# ---------------------------------------- tier-1: shard-aligned probe chunks
def test_probe_chunk_rounds_up_to_shard_multiple():
    eng = FakePagedEngine()
    sched = BatchScheduler(eng, probe_batch=6)
    assert sched._probe_chunk(eng) == 6          # unsharded: passthrough
    eng.data_shards = 4
    assert sched._probe_chunk(eng) == 8          # ceil(6/4)*4
    sched.probe_batch = None
    eng.max_probe_batch = 10
    assert sched._probe_chunk(eng) == 12         # engine ceiling, aligned
    eng.max_probe_batch = None
    assert sched._probe_chunk(eng) is None       # no ceiling: no rounding


def test_rows_spec_replicates_when_rows_do_not_divide():
    from repro.distributed.sharding import rows_spec
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh(1, 1)
    assert rows_spec(8, 2, mesh)[0] is not None      # divisible: sharded
    assert rows_spec(0, 2, mesh)[0] is None          # empty: replicated
    assert rows_spec(8, 3, mesh, axis=1)[1] is not None
    assert rows_spec(8, 3, mesh, axis=1)[0] is None


# -------------------------------------- sharded-pool fuzz (fakes_paged.py)
class ShardedFakeEngine(FakePagedEngine):
    """fakes_paged's engine with the REAL pool laid out on a mesh: the
    allocator, refcounts, stash/unstash, and preemption paths now move
    NamedSharding'd device arrays, and the fingerprint round-trip catches
    any re-layout that mangles block contents."""
    mesh_shape = (1, 1)

    def __init__(self, num_blocks: int = 33, block_size: int = 4, **kw):
        super().__init__(num_blocks=num_blocks, block_size=block_size, **kw)
        from repro.launch.mesh import make_local_mesh
        mesh = make_local_mesh(*type(self).mesh_shape)
        self.pool = KVBlockPool(tiny_pool_lm(), num_blocks, block_size,
                                mesh=mesh)
        self.data_shards = mesh.shape["data"]


def _fuzz_sharded(shape, seed, n_ops, monkeypatch, fail_rate=0.0):
    import test_fuzz_loop as fl
    cls = type("_Fake", (ShardedFakeEngine,), {"mesh_shape": shape})
    monkeypatch.setattr(fl, "FakePagedEngine", cls)
    fl._fuzz(seed, n_ops, fail_rate=fail_rate)


@pytest.mark.parametrize("seed", range(2))
def test_fuzz_sharded_pool_1x1(seed, monkeypatch):
    _fuzz_sharded((1, 1), seed, n_ops=40, monkeypatch=monkeypatch)


@needs8
@pytest.mark.parametrize("seed", range(3))
def test_fuzz_sharded_pool_8x1(seed, monkeypatch):
    _fuzz_sharded((8, 1), 200 + seed, n_ops=50, monkeypatch=monkeypatch,
                  fail_rate=0.15 if seed == 2 else 0.0)


# --------------------------------------------- 8-device: full identity suite
@needs8
def test_probe_logits_bitwise_8x1(base, mesh8):
    assert np.array_equal(base.submit_probes(PROBES),
                          mesh8.submit_probes(PROBES))


@needs8
@pytest.mark.parametrize("path", ALL_PATHS)
def test_all_paths_sync_identity_8x1(base, mesh8, path):
    keys = _keys()
    ob, os_ = ModelOracle(base), ModelOracle(mesh8)
    rb, _ = llm_order_by(keys, "relevance", ob, path=path)
    rs, _ = llm_order_by(keys, "relevance", os_, path=path)
    assert rs.uids() == rb.uids(), path
    assert _ledger(os_) == _ledger(ob), path
    assert rs.cost == rb.cost


@needs8
def test_all_paths_deferred_identity_8x1(base, mesh8):
    """All five paths as ONE deferred co-scheduled batch on the sharded
    engine: orders and ledgers byte-identical to solo sync on the
    single-device engine, generates token-identical, zero leaked blocks."""
    keys = _keys(12, seed=3)
    solo = []
    for path in ALL_PATHS:
        o = ModelOracle(base)
        r, _ = llm_order_by(keys, "relevance", o, path=path)
        solo.append((r.uids(), _ledger(o)))
    prompts = [p for p, _ in GEN]
    limits = [l for _, l in GEN]
    solo_gen = [base.generate_lockstep([p], max_new_per=[l])[0]
                for p, l in zip(prompts, limits)]

    sched = BatchScheduler(mesh8, max_batch=4)
    rids = [sched.submit(p, l) for p, l in zip(prompts, limits)]
    oracles = [ModelOracle(mesh8) for _ in ALL_PATHS]
    results = llm_order_by_many(
        [OrderQuery(keys=keys, criteria="relevance", oracle=o, path=path)
         for path, o in zip(ALL_PATHS, oracles)], scheduler=sched)
    sched.run()
    assert [sched.completed[r].output for r in rids] == solo_gen
    for (uids, ledger), res, o in zip(solo, results, oracles):
        assert res.uids() == uids
        assert _ledger(o) == ledger
    mesh8.clear_prefix_cache()
    assert mesh8.pool.blocks_in_use == 0, "sharded engine leaked blocks"


@needs8
def test_cascade_threshold_inf_anchor_8x1(mesh8):
    """The cascade identity anchor holds on a sharded engine: a draft
    engine attached at threshold=inf never escalates and the cascade is
    byte-identical to the plain oracle on the same sharded engine."""
    from repro.configs.registry import ladder
    from repro.core import CASCADE_70B
    from repro.core.oracles.cascade import CascadeOracle
    from repro.launch.mesh import make_local_mesh
    draft = _build(mesh=make_local_mesh(8, 1), arch=ladder()[0], seed=1)
    keys = _keys(6, seed=9)
    casc = CascadeOracle(mesh8, draft_engine=draft, threshold=math.inf,
                         prices=CASCADE_70B)
    plain = ModelOracle(mesh8, prices=CASCADE_70B)
    rc, _ = llm_order_by(keys, "value", casc, path="quick")
    rp, _ = llm_order_by(keys, "value", plain, path="quick")
    assert rc.uids() == rp.uids()
    assert list(casc.ledger.records) == list(plain.ledger.records)
    assert rc.cost == rp.cost


@needs8
def test_tensor_parallel_within_tolerance_4x2(base):
    """model>1 meshes psum the row-parallel contractions: logits agree to
    the documented tolerance, not bitwise (see table12's contract note)."""
    from repro.launch.mesh import make_local_mesh
    from repro.serving.engine import TP_PSUM_ATOL, TP_PSUM_RTOL
    eng = _build(mesh=make_local_mesh(4, 2))
    ref, got = base.submit_probes(PROBES), eng.submit_probes(PROBES)
    np.testing.assert_allclose(got, ref, rtol=TP_PSUM_RTOL, atol=TP_PSUM_ATOL)
    assert float((np.asarray(ref).argmax(-1)
                  == np.asarray(got).argmax(-1)).mean()) >= 0.9
