"""Unified step loop: probes, prefix fills, and decode co-scheduled.

Invariants (DESIGN.md "Unified step loop"):

 * **fairness both ways** — a probe round submitted while a long rationale
   decode is in flight resolves in the NEXT step gap (never more than one
   decode step behind), and a probe storm cannot stall decode rows (each
   step decodes exactly once regardless of probe volume);
 * **identity** — generate outputs stay token-identical (``==``) to the
   solo lockstep baseline and concurrent ORDER BY queries' orders AND
   ledgers stay byte-identical to their solo runs, whatever the
   interleaving;
 * **no leaks** — after mixed probe/fill/generate traffic under concurrent
   drivers, the pool holds exactly the prefix LRU's pinned runs, and probe
   block leases are all returned.
"""
import numpy as np
import pytest

from repro.serving.scheduler import (BatchScheduler, PrefixFill, ProbeRequest,
                                     Request)


# ------------------------------------------------- fast: loop mechanics
class _FakeEngine:
    """Deterministic per-prompt logits; records submissions.  Not paged —
    exercises the lockstep pump path of the unified queue."""

    paged_enabled = False
    max_probe_batch = 256

    def __init__(self):
        self.submitted = []
        self.prefetched = []

    def prefetch_prefixes(self, prompts):
        self.prefetched.append(list(prompts))
        return len(prompts)

    def submit_probes(self, prompts, max_batch=None):
        self.submitted.append(list(prompts))
        out = np.zeros((len(prompts), 4), np.float32)
        for i, p in enumerate(prompts):
            key = p if isinstance(p, str) else "".join(p)
            out[i] = (hash(key) % 997) + np.arange(4)
        return out


def _sched():
    return BatchScheduler(_FakeEngine())


def test_unified_queue_holds_typed_work_items():
    sched = _sched()
    sched.submit("gen", max_new=2)
    sched.submit_probe("probe")
    fut = sched.submit_probe_round(["r1", "r2"])
    sched.submit_prefix_fill([("p", "s")])
    kinds = [type(w) for w in sched.work]
    assert kinds == [Request, ProbeRequest, ProbeRequest, ProbeRequest,
                     PrefixFill]
    assert len(sched.queue) == 1 and len(sched.probe_queue) == 3
    assert not fut.done


def test_round_future_resolves_on_pump():
    sched = _sched()
    fut = sched.submit_probe_round(["alpha", "beta"])
    assert not fut.done
    sched.pump()
    assert fut.done
    vals = fut.result()
    assert len(vals) == 2
    direct = sched.engine.submit_probes(["alpha", "beta"])
    assert np.array_equal(vals[0], direct[0])
    assert np.array_equal(vals[1], direct[1])


def test_round_members_dedup_against_singles_and_rounds():
    sched = _sched()
    rid = sched.submit_probe("alpha")
    f1 = sched.submit_probe_round(["alpha", "beta"])
    f2 = sched.submit_probe_round(["beta", "alpha"])
    out = sched.run_probes()
    # one submission of the 2 distinct prompts; the 3 duplicates fan out
    assert sched.engine.submitted == [["alpha", "beta"]]
    assert sched.probes_deduped == 3
    assert f1.done and f2.done
    assert np.array_equal(out[rid], f1.result()[0])
    assert np.array_equal(f1.result()[0], f2.result()[1])
    assert np.array_equal(f1.result()[1], f2.result()[0])


def test_lockstep_pump_services_prefix_fills():
    """Regression: the non-paged pump must service fill work too — a
    PrefixFill left queued would keep work_remaining True forever."""
    sched = _sched()
    sched.submit_prefix_fill([("p", "s"), "plain ignored"])
    assert sched.work_remaining
    sched.pump()
    assert sched.engine.prefetched == [[("p", "s")]]
    assert not sched.work_remaining


def test_resolve_raises_if_round_work_vanished():
    sched = _sched()
    fut = sched.submit_probe_round(["x"])
    sched.work.clear()                       # simulate a lost work item
    with pytest.raises(RuntimeError):
        sched.resolve(fut)


def test_resolve_is_noop_on_done_future():
    sched = _sched()
    fut = sched.submit_probe_round(["x"])
    sched.pump()
    assert sched.resolve(fut) is fut


def test_round_future_preserves_submission_order():
    """A round's result list stays aligned with its submission order even
    when dedup reorders the executed rows."""
    sched = _sched()
    fut = sched.submit_probe_round(["b-prompt", "a-prompt", "b-prompt"])
    sched.pump()
    direct = sched.engine.submit_probes(["b-prompt", "a-prompt"])
    vals = fut.result()
    assert np.array_equal(vals[0], direct[0])
    assert np.array_equal(vals[1], direct[1])
    assert np.array_equal(vals[2], direct[0])


# ---------------------------------------------- slow: real-model co-sched
@pytest.mark.slow
class TestCoScheduling:
    @pytest.fixture(scope="class")
    def lm_params(self):
        import jax
        from repro.configs import get_reduced
        from repro.models import LM
        cfg = get_reduced("llama3-8b")
        lm = LM(cfg)
        return lm, lm.init(jax.random.PRNGKey(0))

    def _engine(self, lm_params, **kw):
        from repro.serving import ServeEngine
        lm, params = lm_params
        kw.setdefault("max_new_tokens", 16)
        return ServeEngine(lm, params, **kw)

    def test_probe_round_resolves_within_one_step_of_long_decode(
            self, lm_params):
        """A round submitted mid-rationale resolves in the next step gap —
        latency <= 1 decode step, not the remaining drain length."""
        eng = self._engine(lm_params)
        sched = BatchScheduler(eng, max_batch=4)
        rid = sched.submit("w" * 45 + " long rationale", max_new=16)
        seen = {}

        def on_step(s):
            if "fut" not in seen and eng.paged_active:
                seen["fut"] = s.submit_probe_round(
                    ["Criteria: c\nItem: thing\nRating:"])
                seen["at"] = s.steps
            elif "fut" in seen and "done_at" not in seen and seen["fut"].done:
                seen["done_at"] = s.steps

        out = sched.run(on_step=on_step)
        assert rid in out
        assert seen["done_at"] - seen["at"] <= 1
        direct = eng.submit_probes(["Criteria: c\nItem: thing\nRating:"])
        assert np.array_equal(seen["fut"].result()[0], direct[0])

    def test_probe_storm_does_not_stall_decode_rows(self, lm_params):
        """Three probe rounds EVERY step gap: the decode row still advances
        one token per step and its output is unperturbed."""
        eng = self._engine(lm_params)
        solo = eng.generate_lockstep(["storm victim " + "v" * 20],
                                     max_new_per=[12])[0]
        sched = BatchScheduler(eng, max_batch=4)
        rid = sched.submit("storm victim " + "v" * 20, max_new=12)
        futs = []

        def on_step(s):
            if eng.paged_active:
                futs.extend(s.submit_probe_round(
                    [f"Criteria: c\nItem: storm {i} {len(futs)}\nRating:"])
                    for i in range(3))

        steps0 = sched.steps
        out = sched.run(on_step=on_step)
        assert out[rid] == solo                      # token-identical
        # the row decodes one token per step: the drain takes the solo step
        # count (+1 admission step slack), however many rounds rode the gaps
        assert sched.steps - steps0 <= 12 + 2
        assert len(futs) >= 10 and all(f.done for f in futs)
        assert eng.pool.blocks_in_use == sum(
            len(e.blocks) for e in eng._prefix_lru.values()
            if e.blocks is not None)

    def test_queries_and_rationales_share_the_live_loop(self, lm_params):
        """Concurrent ORDER BY queries (probe plans) and a judge-rationale
        generate workload drive ONE loop: executor ticks advance the
        generates' decode between probe rounds.  Query orders and ledgers
        stay byte-identical to solo; generate outputs stay ==-identical to
        solo lockstep; no blocks leak."""
        from repro.core import (OrderQuery, PathParams, as_keys,
                                llm_order_by_many, make_path)
        from repro.core.oracles.model_oracle import ModelOracle
        from repro.core.types import SortSpec
        eng = self._engine(lm_params)
        keys = as_keys([f"doc {'z' * (i % 4)} {i:02d}" for i in range(16)],
                       list(np.random.default_rng(3).standard_normal(16)))
        qdefs = [("quick", "relevance", True, None),
                 ("pointwise", "clarity", False, None)]

        def _ledger(o):
            return (o.ledger.n_calls, o.ledger.input_tokens,
                    o.ledger.output_tokens, list(o.ledger.records))

        solo = []
        for path, crit, desc, limit in qdefs:
            o = ModelOracle(eng)
            res = make_path(path, PathParams(batch_size=4)).execute(
                keys, o, SortSpec(crit, desc, limit))
            solo.append((res.uids(), _ledger(o)))
        gen_prompts = [f"Judge {i}: rationale " + "r" * (5 * i) for i in range(4)]
        gen_limits = [4, 16, 8, 12]
        solo_gen = [eng.generate_lockstep([p], max_new_per=[l])[0]
                    for p, l in zip(gen_prompts, gen_limits)]

        sched = BatchScheduler(eng, max_batch=4)
        gen_rids = [sched.submit(p, l) for p, l in zip(gen_prompts,
                                                       gen_limits)]
        oracles = [ModelOracle(eng) for _ in qdefs]
        results = llm_order_by_many(
            [OrderQuery(keys, crit, o, descending=desc, limit=limit,
                        path=path, params=PathParams(batch_size=4))
             for (path, crit, desc, limit), o in zip(qdefs, oracles)],
            scheduler=sched)
        # the queries' ticks pumped the loop, so the generates made decode
        # progress DURING query execution (co-scheduling, not alternation)
        started_during = sum(1 for r in gen_rids if r in sched.completed)
        sched.run()                              # drain whatever remains
        assert [sched.completed[r].output for r in gen_rids] == solo_gen
        assert started_during > 0
        for (uids, ledger), res, o in zip(solo, results, oracles):
            assert res.uids() == uids
            assert _ledger(o) == ledger
        assert eng.paged_active == 0
        lru_blocks = sum(len(e.blocks) for e in eng._prefix_lru.values()
                         if e.blocks is not None)
        assert eng.pool.blocks_in_use == lru_blocks
        eng.clear_prefix_cache()
        assert eng.pool.blocks_in_use == 0

    def test_judge_rationales_pump_shared_scheduler(self, lm_params):
        """ModelOracle.judge with an attached scheduler routes rationale
        generations through the live loop — queued probe rounds are
        answered in the generation's step gaps."""
        from repro.core import as_keys
        from repro.core.oracles.model_oracle import ModelOracle
        eng = self._engine(lm_params)
        sched = BatchScheduler(eng, max_batch=4)
        oracle = ModelOracle(eng, judge_rationale_tokens=8, scheduler=sched)
        fut = sched.submit_probe_round(["Criteria: c\nItem: queued\nRating:"])
        keys = as_keys([f"k{i}" for i in range(6)], list(range(6)))
        cands = [keys, list(reversed(keys))]
        win = oracle.judge(keys, "relevance", cands)
        assert win in (0, 1)
        assert fut.done                  # answered inside the judge's steps
        # identical judge decision without the scheduler (same engine state
        # modulo stats): rationale outputs are loop-invariant
        oracle2 = ModelOracle(eng, judge_rationale_tokens=8)
        assert oracle2.judge(keys, "relevance", cands) == win
        assert eng.paged_active == 0

    def test_prefix_fill_work_item_warms_future_rounds(self, lm_params):
        """A prefix fill scheduled during decode warms the LRU, so the
        round that later needs the region hits instead of filling in its
        own gap."""
        eng = self._engine(lm_params)
        sched = BatchScheduler(eng, max_batch=4)
        prefix = "Criteria: quality\nPassage B: the pivot passage\n"
        probes = [(prefix, f"Passage A: item {i}\nWhich ranks higher? Answer:")
                  for i in range(3)]
        sched.submit("u" * 40 + " long decode", max_new=8)
        sched.submit_prefix_fill(probes)
        state = {}

        def on_step(s):
            if "filled" not in state:
                state["filled"] = len(eng._prefix_lru)
                state["hits0"] = eng.stats.prefix_hits
            elif "fut" not in state:
                state["fut"] = s.submit_probe_round(probes)

        sched.run(on_step=on_step)
        assert state["filled"] >= 1              # fill ran in the first gap
        assert state["fut"].done
        assert eng.stats.prefix_hits > state["hits0"]   # round hit the LRU
        direct = eng.submit_probes(probes)
        for got, want in zip(state["fut"].result(), direct):
            assert np.array_equal(got, want)

    def test_scheduler_generate_scalar_zero_means_engine_default(
            self, lm_params):
        """Regression: scalar ``max_new=0`` through scheduler.generate must
        mean "engine default" on BOTH branches (paged and lockstep
        fallback), matching ServeEngine.generate's pinned contract."""
        eng = self._engine(lm_params, max_new_tokens=4)
        sched = BatchScheduler(eng, max_batch=4)
        a = sched.generate(["scalar zero"], max_new=0)
        b = eng.generate_lockstep(["scalar zero"], max_new=0)
        assert a == b and a[0] != ""
        # per-request zero budgets via submit() stay genuine zero (PR 3)
        rid = sched.submit("zero budget", max_new=0)
        assert sched.run()[rid] == ""

    def test_scheduler_attachment_is_scoped_per_call(self, lm_params):
        """Regression: llm_order_by_many's scheduler auto-attach must not
        outlive the call — a second run with a fresh scheduler re-attaches
        instead of pumping the first call's stale loop."""
        from repro.core import OrderQuery, PathParams, as_keys, \
            llm_order_by_many
        from repro.core.oracles.model_oracle import ModelOracle
        eng = self._engine(lm_params)
        keys = as_keys([f"s{i}" for i in range(8)], list(range(8)))
        oracle = ModelOracle(eng)
        for _ in range(2):
            (res,) = llm_order_by_many([OrderQuery(
                keys, "size", oracle, path="pointwise",
                params=PathParams(batch_size=4))])
            assert sorted(res.uids()) == list(range(8))
            assert oracle.scheduler is None      # detached on exit
        # an explicitly-attached scheduler is the user's and stays
        sched = BatchScheduler(eng)
        oracle2 = ModelOracle(eng, scheduler=sched)
        llm_order_by_many([OrderQuery(keys, "size", oracle2,
                                      path="pointwise",
                                      params=PathParams(batch_size=4))])
        assert oracle2.scheduler is sched

    def test_probe_leases_share_pool_and_return(self, lm_params):
        """Probe rows lease pool blocks for the submission's duration; a
        pool saturated by decode rows degrades to a counted shortfall, and
        every lease is returned."""
        from repro.serving import ServeEngine
        lm, params = lm_params
        eng = ServeEngine(lm, params, max_new_tokens=8)
        probes = [f"Criteria: c\nItem: lease {i}\nRating:" for i in range(4)]
        leased0 = eng.stats.probe_blocks_leased
        eng.submit_probes(probes)
        assert eng.stats.probe_blocks_leased > leased0
        assert eng.pool.total_leased == eng.stats.probe_blocks_leased
        assert eng.pool.blocks_in_use == 0       # all leases returned
        # tiny pool: one decode row holds nearly everything -> shortfall
        tight = ServeEngine(lm, params, max_new_tokens=8, pool_blocks=6,
                            block_size=16, prefix_cache_size=0)
        tight.paged_admit([("occupy " + "o" * 40, 8)])
        short0 = tight.stats.probe_lease_shortfalls
        out = tight.submit_probes(["Criteria: c\nItem: squeezed\nRating:"])
        assert out.shape[0] == 1                 # probe still served
        assert tight.stats.probe_lease_shortfalls > short0
        while tight.paged_active:
            tight.paged_step()
        assert tight.pool.blocks_in_use == 0
