"""Paged continuous-batching decode: token identity, mid-decode admission,
and block reclamation.

Invariants (DESIGN.md "Paged KV pool"):

 * every row of the continuous loop emits EXACTLY the token sequence the
   lockstep baseline produces for that prompt alone (``==`` on the decoded
   strings) — masked pool positions contribute exact zeros to the fp32
   softmax, so batch composition, admission timing, and table padding are
   invisible to results;
 * a late-submitted short request completes while a long generation is
   still decoding (no head-of-line blocking), and probe rounds are answered
   between decode steps;
 * finished rows free their blocks immediately: after mixed probe/generate
   traffic, the only blocks in use are the prefix-cache LRU's pinned runs,
   and clearing the LRU drains the pool to zero.
"""
import jax
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # model forward passes: heavyweight

from repro.configs import get_reduced
from repro.models import LM
from repro.serving import BatchScheduler, ServeEngine


@pytest.fixture(scope="module")
def lm_params():
    cfg = get_reduced("llama3-8b")
    lm = LM(cfg)
    return lm, lm.init(jax.random.PRNGKey(0))


def _engine(lm_params, **kw):
    lm, params = lm_params
    kw.setdefault("max_new_tokens", 8)
    return ServeEngine(lm, params, **kw)


MIXED = ["hi", "a mid-sized prompt here", "x" * 50 + " long tail prompt",
         "another short", "y" * 35 + " second long one", "tiny"]
LIMITS = [2, 5, 8, 3, 7, 1]


def test_paged_token_identical_to_solo_lockstep(lm_params):
    eng = _engine(lm_params)
    assert eng.paged_enabled
    outs = eng.generate(MIXED, max_new_per=LIMITS)
    solo = [eng.generate_lockstep([p], max_new_per=[l])[0]
            for p, l in zip(MIXED, LIMITS)]
    assert outs == solo
    assert eng.pool.blocks_in_use == 0       # every row retired its run


def test_paged_equals_lockstep_batch_same_class(lm_params):
    """Same-class prompts: the lockstep BATCH itself is the baseline (all
    rows share one padded length, so batching is row-independent)."""
    eng = _engine(lm_params)
    prompts = [f"prompt {i}" for i in range(4)]          # one length class
    a = eng.generate(prompts, max_new=6)
    b = eng.generate_lockstep(prompts, max_new=6)
    assert a == b


def test_admission_capacity_waves(lm_params):
    """More requests than decode rows: the loop admits in waves as rows
    retire, and every output still matches the solo baseline."""
    eng = _engine(lm_params, max_decode_rows=2, pool_blocks=32)
    prompts = [f"wave prompt {i}" for i in range(5)]
    limits = [6, 1, 4, 2, 5]
    outs = eng.generate(prompts, max_new_per=limits)
    solo = [eng.generate_lockstep([p], max_new_per=[l])[0]
            for p, l in zip(prompts, limits)]
    assert outs == solo
    assert eng.pool.blocks_in_use == 0


def test_mid_decode_admission_engine_level(lm_params):
    """A short request admitted AFTER a long row started decoding finishes
    first — the lockstep loop cannot do this at all."""
    eng = _engine(lm_params, max_new_tokens=16)
    long_p, short_p = "z" * 40 + " long straggler", "quick"
    rid_long = eng.paged_admit([(long_p, 16)])[0]
    for _ in range(3):
        eng.paged_step()
    assert eng.paged_active == 1
    rid_short = eng.paged_admit([(short_p, 2)])[0]
    fins = {}
    while rid_short not in fins:
        fins.update(eng.paged_step())
    assert rid_long in eng._paged_rows       # straggler still decoding
    while eng.paged_active or eng._paged_finished:
        fins.update(eng.paged_step())
    assert fins[rid_long] == eng.generate_lockstep([long_p],
                                                   max_new_per=[16])[0]
    assert fins[rid_short] == eng.generate_lockstep([short_p],
                                                    max_new_per=[2])[0]
    assert eng.pool.blocks_in_use == 0


def test_scheduler_mid_drain_submission_and_probes(lm_params):
    """Continuous drain: a request submitted mid-drain (via on_step) is
    admitted into vacated capacity and completes in the SAME drain; queued
    probes are answered between decode steps."""
    eng = _engine(lm_params, max_new_tokens=16)
    sched = BatchScheduler(eng, max_batch=4)
    assert sched.paged
    rid_long = sched.submit("q" * 45 + " long generation", max_new=16)
    probe_rid = sched.submit_probe("Criteria: c\nItem: thing\nRating:")
    late = {}

    def on_step(s):
        if not late and eng.paged_active:
            late["rid"] = s.submit("late arrival", max_new=2)

    out = sched.run(on_step=on_step)
    assert set(out) == {rid_long, late["rid"]}
    assert out[late["rid"]] == eng.generate_lockstep(["late arrival"],
                                                     max_new_per=[2])[0]
    assert probe_rid in sched.probe_results  # probe served mid-drain
    direct = eng.submit_probes(["Criteria: c\nItem: thing\nRating:"])
    assert np.allclose(sched.probe_results[probe_rid], direct[0])


def test_structured_prompts_share_prefix_blocks(lm_params):
    """Generate requests with a shared (prefix, suffix) structure append
    onto ONE pinned prefix block run instead of re-materializing it, and
    stay token-identical to the monolithic solo baseline."""
    eng = _engine(lm_params)
    prefix = "Criteria: quality\nSample: alpha beta gamma\n"
    prompts = [(prefix, f"Ranking {i}: a > b > c\nJudge rationale:")
               for i in range(4)]
    outs = eng.generate(prompts, max_new=6)
    solo = [eng.generate_lockstep([p], max_new=6)[0] for p in prompts]
    assert outs == solo
    assert eng.stats.prefix_misses >= 1      # region filled once
    assert eng.stats.prefix_tokens_saved > 0
    lru_blocks = sum(len(e.blocks) for e in eng._prefix_lru.values()
                     if e.blocks is not None)
    assert lru_blocks > 0                    # entry is a pool-backed run
    assert eng.pool.blocks_in_use == lru_blocks   # rows dropped their refs
    hits0 = eng.stats.prefix_hits
    assert eng.generate(prompts, max_new=6) == solo
    assert eng.stats.prefix_hits > hits0     # second wave rides the LRU


def test_zero_leaked_blocks_after_mixed_traffic(lm_params):
    """Mixed probe rounds + generates + a mid-drain admission: afterwards
    the pool holds exactly the LRU's pinned runs; clearing the LRU drains
    it to zero (the leak test the pool's refcounts must pass)."""
    eng = _engine(lm_params)
    probes = [("Criteria: c\nPassage B: pivot\n",
               f"Passage A: item {'x' * (i % 3)}\nWhich ranks higher? Answer:")
              for i in range(6)]
    eng.submit_probes(probes)
    eng.generate(MIXED, max_new_per=LIMITS)
    eng.submit_probes(probes)                # LRU hits while rows retired
    eng.generate([("Criteria: c\nPassage B: pivot\n", "Passage A: gen\n"),
                  ("Criteria: c\nPassage B: pivot\n", "Passage A: gen\n")],
                 max_new=4)
    assert eng.paged_active == 0
    lru_blocks = sum(len(e.blocks) for e in eng._prefix_lru.values()
                     if e.blocks is not None)
    assert eng.pool.blocks_in_use == lru_blocks
    eng.clear_prefix_cache()
    assert eng.pool.blocks_in_use == 0
    assert eng.pool.free_blocks == eng.pool.num_blocks - 1


def test_zero_budget_requests_keep_rids_aligned(lm_params):
    """Regression: a max_new=0 request must not shift later requests' rids
    — paged outputs stay aligned with the submitted order (the lockstep
    loop accepted limit 0 and returned "", so must the paged loop)."""
    eng = _engine(lm_params)
    prompts = ["first", "degenerate", "third"]
    limits = [3, 0, 4]
    outs = eng.generate(prompts, max_new_per=limits)
    solo = [eng.generate_lockstep([p], max_new_per=[l])[0]
            for p, l in zip(prompts, limits)]
    assert outs == solo and outs[1] == ""
    sched = BatchScheduler(eng, max_batch=4)
    rids = [sched.submit(p, max_new=l) for p, l in zip(prompts, limits)]
    drained = sched.run()
    assert [drained[r] for r in rids] == solo


def test_scalar_max_new_zero_means_default_like_lockstep(lm_params):
    """Regression: scalar ``max_new=0`` means "engine default" in lockstep
    (``max_new or self.max_new``); the paged loop must agree rather than
    treating it as a zero budget."""
    eng = _engine(lm_params, max_new_tokens=4)
    a = eng.generate(["scalar zero"], max_new=0)
    b = eng.generate_lockstep(["scalar zero"], max_new=0)
    assert a == b


def test_nested_generate_does_not_steal_scheduler_rows(lm_params):
    """Regression: engine.generate() invoked mid-drain (the judge-rationale
    path runs on the shared engine) must hand the scheduler's finished rows
    back instead of consuming them."""
    eng = _engine(lm_params, max_new_tokens=16)
    sched = BatchScheduler(eng, max_batch=4)
    rids = [sched.submit(f"drain req {i} " + "w" * 20, max_new=6 + i)
            for i in range(3)]
    nested = {}

    def on_step(s):
        if not nested and eng.paged_active:
            nested["out"] = eng.generate(["nested rationale"], max_new=3)

    out = sched.run(on_step=on_step)
    assert set(out) == set(rids)             # nothing stolen or lost
    assert nested["out"] == eng.generate_lockstep(["nested rationale"],
                                                  max_new=3)
    for rid, prompt, mn in zip(rids, [f"drain req {i} " + "w" * 20
                                      for i in range(3)], [6, 7, 8]):
        assert out[rid] == eng.generate_lockstep([prompt],
                                                 max_new_per=[mn])[0]


def test_nested_generate_evicts_lru_instead_of_livelock(lm_params):
    """Regression: a nested generate() whose request needs the prefix
    LRU's blocks must evict them once nothing is in flight — pending
    foreign outputs (endlessly re-stashed) must not defer the eviction
    forever (livelock)."""
    lm, params = lm_params
    eng = ServeEngine(lm, params, max_new_tokens=4, max_decode_rows=4,
                      pool_blocks=8, block_size=16)
    eng.submit_probes([("Criteria: c\nPassage B: pivot\n",
                        f"Passage A: it{i}\nAnswer:") for i in range(2)])
    assert eng.pool.blocks_in_use > 0        # LRU holds a pinned run
    sched = BatchScheduler(eng, max_batch=2)
    rid = sched.submit("drain row " + "w" * 10, max_new=4)
    nested = {}

    def on_step(s):
        if not nested:                       # bigger than current free space
            nested["out"] = eng.generate(["needs eviction " + "z" * 40],
                                         max_new=4)

    out = sched.run(on_step=on_step)
    assert rid in out                        # drain completed, nothing lost
    assert nested["out"] == eng.generate_lockstep(
        ["needs eviction " + "z" * 40], max_new=4)


def test_tight_pool_shared_subblock_region(lm_params):
    """Regression: a FRESH shared region shorter than one block allocates a
    fill block outside paged_room's worst-case budget; admission must
    reclaim it (evict) instead of raising out of generate() on an
    exactly-sized pool."""
    eng = _engine(lm_params, max_new_tokens=4, max_decode_rows=2)
    # two wave-mates sharing a tiny prefix; region = pad + prefix < 16
    prompts = [("ab", "suffix one xx"), ("ab", "suffix two yy")]
    need = sum(eng.paged_block_need(p, 4) for p in prompts)
    lm, params = lm_params
    tight = ServeEngine(lm, params, max_new_tokens=4, max_decode_rows=2,
                        pool_blocks=need + 1, block_size=16)
    outs = tight.generate(prompts, max_new=4)
    solo = [tight.generate_lockstep([p], max_new=4)[0] for p in prompts]
    assert outs == solo
    tight.clear_prefix_cache()
    assert tight.pool.blocks_in_use == 0


def test_pool_disabled_falls_back_to_lockstep(lm_params):
    eng = _engine(lm_params, pool_blocks=0)
    assert not eng.paged_enabled
    outs = eng.generate(["fallback a", "fallback b"], max_new=3)
    assert outs == eng.generate_lockstep(["fallback a", "fallback b"],
                                         max_new=3)


def test_unsupported_arch_falls_back(lm_params):
    cfg = get_reduced("xlstm-1.3b")          # recurrent blocks: no KV pool
    lm = LM(cfg)
    eng = ServeEngine(lm, lm.init(jax.random.PRNGKey(0)), max_new_tokens=4)
    assert not eng.paged_enabled and eng.pool is None
    assert len(eng.generate(["still works"], max_new=2)) == 1
