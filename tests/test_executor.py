"""Probe-plan executor: interleaved-vs-solo equivalence and probe dedup.

Invariants of the executor refactor:

 * every access path, driven as a resumable plan through
   ``ProbePlanExecutor`` alongside arbitrary other plans, produces
   per-query output AND ledger ``==``-identical to its solo synchronous
   ``execute()`` — across all 5 paths x direction x LIMIT, including under
   simulated structural failures mid-plan (split-retry fallback inside a
   suspended plan);
 * per-plan ledger records are exact even when plans share ONE oracle;
 * ``BatchScheduler.run_probes`` dedups identical prompts across concurrent
   submitters (execute once, fan results out) without touching billing;
 * on the ModelOracle backend, interleaving concurrent queries through one
   scheduler drain reduces serving submissions while keeping every query's
   output and ledger identical to its solo run.
"""
import numpy as np
import pytest

from repro.core import (ExactOracle, OrderQuery, PathParams, ProbePlanExecutor,
                        SimulatedOracle, as_keys, available_paths,
                        llm_order_by, llm_order_by_many, make_path)
from repro.core.executor import InquireEach, plan_sort_result
from repro.core.oracles.simulated import FACTUAL, REASONING, OracleProfile
from repro.core.types import SortSpec

PATHS = sorted(available_paths())

# REASONING has mild structural failures; FLAKY forces frequent mid-plan
# window/score failures so the split-retry fallback runs inside suspended
# plans on both sides of the comparison
FLAKY = OracleProfile(name="flaky", invalid_rate=0.5, listwise_noise=0.4,
                      score_noise=0.6)
PROFILES = {"reasoning": REASONING, "factual": FACTUAL, "flaky": FLAKY}


def _keys(n, seed=0):
    rng = np.random.default_rng(seed)
    # variable-length texts: padded-length-class grouping must keep merged
    # execution bit-identical for non-uniform keys too
    return as_keys([f"key {'x' * (i % 7)} {i:03d}" for i in range(n)],
                   list(rng.standard_normal(n)))


def _ledger_tuple(oracle):
    return (oracle.ledger.n_calls, oracle.ledger.input_tokens,
            oracle.ledger.output_tokens, list(oracle.ledger.records))


# ------------------------------------------------- interleaved == solo
@pytest.mark.parametrize("profile", sorted(PROFILES))
@pytest.mark.parametrize("desc,limit", [(False, None), (True, 7)])
def test_interleaved_queries_match_solo_all_paths(profile, desc, limit):
    """One concurrent query per access path: per-query order and ledger are
    ==-identical to running each query alone."""
    prof = PROFILES[profile]
    keys = _keys(33)
    solo = {}
    for path in PATHS:
        o = SimulatedOracle(prof)
        res = make_path(path, PathParams(batch_size=4, votes=3)).execute(
            keys, o, SortSpec("c", desc, limit))
        solo[path] = (res.uids(), _ledger_tuple(o), res.n_calls, res.cost)
    oracles = {path: SimulatedOracle(prof) for path in PATHS}
    queries = [OrderQuery(keys, "c", oracles[path], descending=desc,
                          limit=limit, path=path,
                          params=PathParams(batch_size=4, votes=3))
               for path in PATHS]
    results = llm_order_by_many(queries)
    for path, res in zip(PATHS, results):
        uids, ledger, n_calls, cost = solo[path]
        assert res.uids() == uids, path
        assert _ledger_tuple(oracles[path]) == ledger, path
        assert (res.n_calls, res.cost) == (n_calls, cost), path


def test_interleaved_mixed_specs_match_solo():
    """Queries with different criteria/direction/limit over one table."""
    keys = _keys(24, seed=3)
    qdefs = [("quick", "relevance", True, None, 1),
             ("quick", "relevance", False, None, 1),
             ("ext_merge", "clarity", True, 5, 1),
             ("pointwise", "relevance", False, 3, 1),
             ("quick", "tone", True, None, 3)]
    solo = []
    for path, crit, desc, limit, votes in qdefs:
        o = SimulatedOracle(REASONING)
        res = make_path(path, PathParams(batch_size=4, votes=votes)).execute(
            keys, o, SortSpec(crit, desc, limit))
        solo.append((res.uids(), _ledger_tuple(o)))
    oracles = [SimulatedOracle(REASONING) for _ in qdefs]
    results = llm_order_by_many([
        OrderQuery(keys, crit, o, descending=desc, limit=limit, path=path,
                   params=PathParams(batch_size=4, votes=votes))
        for (path, crit, desc, limit, votes), o in zip(qdefs, oracles)])
    for (uids, ledger), res, o in zip(solo, results, oracles):
        assert res.uids() == uids
        assert _ledger_tuple(o) == ledger


def test_adaptive_batch_size_rides_executor():
    """Alg. 1 (batch_size=0) is a SerialProbe: still exact under the
    executor, including the chosen-m bookkeeping."""
    keys = _keys(40, seed=5)
    o_solo = ExactOracle()
    res_solo = make_path("ext_pointwise", PathParams(batch_size=0)).execute(
        keys, o_solo, SortSpec("v", False, None))
    o_many = ExactOracle()
    (res,) = llm_order_by_many([OrderQuery(keys, "v", o_many,
                                           path="ext_pointwise",
                                           params=PathParams(batch_size=0))])
    assert res.uids() == res_solo.uids()
    assert res.params == res_solo.params          # incl. chosen_batch_size
    assert _ledger_tuple(o_many) == _ledger_tuple(o_solo)


def test_auto_query_rides_many_matches_solo():
    """path="auto" in llm_order_by_many: the whole optimizer pipeline rides
    the shared executor, with result, ledger, AND report identical to a
    solo llm_order_by run."""
    keys = _keys(24, seed=11)
    o_solo = SimulatedOracle(REASONING)
    res_solo, rep_solo = llm_order_by(keys, "c", o_solo, path="auto",
                                      sample_size=8)
    o_many = SimulatedOracle(REASONING)
    q = OrderQuery(keys, "c", o_many, path="auto", sample_size=8)
    (res,) = llm_order_by_many([q])
    assert res.uids() == res_solo.uids()
    assert _ledger_tuple(o_many) == _ledger_tuple(o_solo)
    assert q.report is not None
    assert q.report.chosen.label == rep_solo.chosen.label
    assert q.report.optimizer_cost == rep_solo.optimizer_cost
    assert q.report.execution_cost == rep_solo.execution_cost


def test_auto_query_alongside_static_queries():
    """An auto query and a static query share one executor; both stay
    ==-identical to their solo runs (per-query ledgers are exact)."""
    keys = _keys(24, seed=11)
    o1_solo = SimulatedOracle(REASONING)
    res1_solo, _ = llm_order_by(keys, "c", o1_solo, path="auto",
                                sample_size=8)
    o2_solo = SimulatedOracle(FACTUAL)
    res2_solo = make_path("quick", PathParams(batch_size=4)).execute(
        keys, o2_solo, SortSpec("tone", True, 5))
    o1, o2 = SimulatedOracle(REASONING), SimulatedOracle(FACTUAL)
    r1, r2 = llm_order_by_many([
        OrderQuery(keys, "c", o1, path="auto", sample_size=8),
        OrderQuery(keys, "tone", o2, descending=True, limit=5, path="quick",
                   params=PathParams(batch_size=4))])
    assert r1.uids() == res1_solo.uids()
    assert r2.uids() == res2_solo.uids()
    assert _ledger_tuple(o1) == _ledger_tuple(o1_solo)
    assert _ledger_tuple(o2) == _ledger_tuple(o2_solo)


# --------------------------------------------------- executor mechanics
def test_shared_oracle_per_plan_records_match_solo():
    """Plans sharing ONE oracle still get exact per-plan accounting (the
    basis of the optimizer's per-candidate sampled costs)."""
    keys = _keys(20, seed=7)
    solo = {}
    for path in ("quick", "ext_merge"):
        o = SimulatedOracle(REASONING)
        make_path(path, PathParams(batch_size=4)).execute(
            keys, o, SortSpec("c", True, None))
        solo[path] = [tuple(r.__dict__.items()) for r in o.ledger.records]
    shared = SimulatedOracle(REASONING)
    ex = ProbePlanExecutor()
    spec = SortSpec("c", True, None)
    runs = {path: ex.submit_path(make_path(path, PathParams(batch_size=4)),
                                 keys, shared, spec)
            for path in ("quick", "ext_merge")}
    ex.run()
    total = 0
    for path, run in runs.items():
        assert run.error is None
        got = [tuple(r.__dict__.items()) for r in run.records]
        assert got == solo[path], path
        total += len(run.records)
    # every shared-ledger record is attributed to exactly one plan
    assert total == shared.ledger.n_calls


def test_single_round_plans_share_one_tick():
    """Fairness/tick semantics: every suspended plan is serviced once per
    tick, so N single-round plans complete in ONE tick."""
    keys = _keys(12)
    ex = ProbePlanExecutor()
    o = ExactOracle()
    spec = SortSpec("c", False, None)
    runs = [ex.submit_path(make_path("pointwise"), keys, o, spec)
            for _ in range(4)]
    ex.run()
    assert ex.ticks == 1
    assert all(r.done and r.error is None for r in runs)


def test_cancel_leaves_other_plans_intact():
    keys = _keys(16, seed=9)
    spec = SortSpec("c", False, None)
    solo_oracle = ExactOracle()
    res_solo = make_path("quick").execute(keys, solo_oracle, spec)
    ex = ProbePlanExecutor()
    o1, o2 = ExactOracle(), ExactOracle()
    keep = ex.submit_path(make_path("quick"), keys, o1, spec)
    kill = ex.submit_path(make_path("quick"), keys, o2, spec)

    def on_tick(_ex):
        kill.cancel("test cut")

    ex.run(on_tick=on_tick)
    assert kill.error is not None and kill.done
    assert keep.error is None
    got = plan_sort_result(keep, spec, len(keys), o1.prices)
    assert got.uids() == res_solo.uids()
    assert _ledger_tuple(o1) == _ledger_tuple(solo_oracle)


def test_membership_plan_matches_direct_gate():
    from repro.core.access_paths.base import Ordering
    from repro.core.optimizer.membership import membership_plan, membership_rate
    keys = _keys(15, seed=11)
    o1, o2 = SimulatedOracle(REASONING), SimulatedOracle(REASONING)
    ex = ProbePlanExecutor()
    run = ex.submit_plan(membership_plan(keys), Ordering(o1, SortSpec("c")),
                         name="gate")
    ex.run()
    assert run.result == membership_rate(keys, o2, "c")
    assert _ledger_tuple(o1) == _ledger_tuple(o2)


def test_failing_membership_gate_propagates():
    """Regression: a structurally failing gate must reach the caller (as the
    pre-executor serial flow did), not read as a silent 0.0 rate."""
    from repro.core import AccessPathOptimizer, InvalidOutputError
    from repro.core.types import SortSpec as _SortSpec

    class _BadInquire(ExactOracle):
        def inquire(self, key, criteria):
            self._charge_inquire(key)
            raise InvalidOutputError("malformed inquiry output")

    keys = _keys(30, seed=13)
    with pytest.raises(InvalidOutputError):
        AccessPathOptimizer().choose_and_execute(
            keys, _BadInquire(), _SortSpec("c", True, 5))


def test_inquire_probe_set_resolves_both_modes():
    from repro.core.access_paths.base import Ordering
    from repro.core.executor import resolve_probes
    keys = _keys(6, seed=2)
    o1, o2 = SimulatedOracle(REASONING), SimulatedOracle(REASONING)
    a = resolve_probes(Ordering(o1, SortSpec("c")), InquireEach(keys), True)
    b = resolve_probes(Ordering(o2, SortSpec("c")), InquireEach(keys), False)
    assert a == b
    assert _ledger_tuple(o1) == _ledger_tuple(o2)


# -------------------------------------------- scheduler probe dedup (unit)
class _FakeEngine:
    """Minimal engine facade: deterministic per-prompt logits, records every
    submission so dedup is observable without a model."""

    paged_enabled = False
    max_probe_batch = 256

    def __init__(self):
        self.submitted = []

    def submit_probes(self, prompts, max_batch=None):
        self.submitted.append(list(prompts))
        out = np.zeros((len(prompts), 4), np.float32)
        for i, p in enumerate(prompts):
            key = p if isinstance(p, str) else "".join(p)
            out[i] = (hash(key) % 997) + np.arange(4)
        return out


def test_scheduler_dedups_identical_probes_across_clients():
    from repro.serving.scheduler import BatchScheduler
    eng = _FakeEngine()
    sched = BatchScheduler.__new__(BatchScheduler)
    BatchScheduler.__init__(sched, eng)
    prompts = ["alpha", "beta", "alpha", ("p", "s"), ("p", "s"), "alpha"]
    rids = [sched.submit_probe(p) for p in prompts]
    out = sched.run_probes()
    # one submission containing only the 3 distinct prompts
    assert eng.submitted == [["alpha", "beta", ("p", "s")]]
    assert sched.probes_deduped == 3
    # fan-out: duplicates observe the same logits their own row would have
    assert np.array_equal(out[rids[0]], out[rids[2]])
    assert np.array_equal(out[rids[0]], out[rids[5]])
    assert np.array_equal(out[rids[3]], out[rids[4]])
    assert not np.array_equal(out[rids[0]], out[rids[1]])
    # drained; a later drain re-executes (dedup is per drain)
    assert sched.run_probes() == {}
    sched.submit_probe("alpha")
    sched.run_probes()
    assert eng.submitted[-1] == ["alpha"]


def test_scheduler_dedup_keeps_str_and_pair_forms_distinct():
    from repro.serving.scheduler import _probe_key
    assert _probe_key("ab") != _probe_key(("a", "b"))
    assert _probe_key(("a", "b")) == _probe_key(("a", "b"))


# ------------------------------------------------------- property test
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:                                    # pragma: no cover
    HAVE_HYP = False

if HAVE_HYP:
    latents = st.lists(
        st.floats(min_value=-50, max_value=50, allow_nan=False, width=32),
        min_size=2, max_size=28, unique=True)

    @given(latents=latents,
           paths=st.lists(st.sampled_from(PATHS), min_size=2, max_size=4),
           desc=st.booleans(),
           limit=st.one_of(st.none(), st.integers(1, 8)),
           profile=st.sampled_from(sorted(PROFILES)))
    @settings(max_examples=25, deadline=None)
    def test_property_interleaved_equals_solo(latents, paths, desc, limit,
                                              profile):
        prof = PROFILES[profile]
        keys = as_keys([f"k{i}" for i in range(len(latents))], latents)
        solo = []
        for path in paths:
            o = SimulatedOracle(prof)
            res = make_path(path, PathParams(batch_size=4)).execute(
                keys, o, SortSpec("c", desc, limit))
            solo.append((res.uids(), _ledger_tuple(o)))
        oracles = [SimulatedOracle(prof) for _ in paths]
        results = llm_order_by_many([
            OrderQuery(keys, "c", o, descending=desc, limit=limit, path=path,
                       params=PathParams(batch_size=4))
            for path, o in zip(paths, oracles)])
        for (uids, ledger), res, o in zip(solo, results, oracles):
            assert res.uids() == uids
            assert _ledger_tuple(o) == ledger


# ------------------------------------------------- ModelOracle backend
@pytest.mark.slow
class TestExecutorModelBackend:
    @pytest.fixture(scope="class")
    def engine(self):
        import jax
        from repro.configs import get_reduced
        from repro.models import LM
        from repro.serving import ServeEngine
        cfg = get_reduced("llama3-8b")
        lm = LM(cfg)
        params = lm.init(jax.random.PRNGKey(0))
        return ServeEngine(lm, params, max_new_tokens=8)

    def test_concurrent_queries_identical_and_fewer_submissions(self, engine):
        from repro.core.oracles.model_oracle import ModelOracle
        from repro.serving.scheduler import BatchScheduler
        keys = as_keys([f"doc {'y' * (i % 5)} {i:02d}" for i in range(20)],
                       list(np.random.default_rng(0).standard_normal(20)))
        qdefs = [("quick", "relevance", True, None),
                 ("quick", "relevance", False, None),   # asc twin: dedups
                 ("ext_merge", "relevance", True, 6),
                 ("pointwise", "clarity", False, None)]
        solo, serial_subs = [], 0
        for path, crit, desc, limit in qdefs:
            o = ModelOracle(engine)
            c0 = engine.stats.calls
            res = make_path(path, PathParams(batch_size=4)).execute(
                keys, o, SortSpec(crit, desc, limit))
            serial_subs += engine.stats.calls - c0
            solo.append((res.uids(), _ledger_tuple(o)))
        oracles = [ModelOracle(engine) for _ in qdefs]
        sched = BatchScheduler(engine)
        c0 = engine.stats.calls
        results = llm_order_by_many(
            [OrderQuery(keys, crit, o, descending=desc, limit=limit,
                        path=path, params=PathParams(batch_size=4))
             for (path, crit, desc, limit), o in zip(qdefs, oracles)],
            scheduler=sched)
        merged_subs = engine.stats.calls - c0
        for (uids, ledger), res, o in zip(solo, results, oracles):
            assert res.uids() == uids
            assert _ledger_tuple(o) == ledger
        assert merged_subs < serial_subs
        # the asc/desc twins share their entire probe stream
        assert sched.probes_deduped > 0

    def test_auto_scheduler_engages_for_shared_engine(self, engine):
        from repro.core.executor import auto_scheduler
        from repro.core.oracles.model_oracle import ModelOracle
        sched = auto_scheduler([ModelOracle(engine), ModelOracle(engine)])
        assert sched is not None and sched.engine is engine
        assert auto_scheduler([ExactOracle()]) is None

    def test_optimizer_pilots_ride_one_stream(self, engine):
        """choose_and_execute on the ModelOracle backend: pilots + gate run
        through the shared drain and the result stays valid."""
        from repro.core import llm_order_by
        from repro.core.oracles.model_oracle import ModelOracle
        keys = as_keys([f"row {i:02d}" for i in range(24)],
                       list(np.random.default_rng(1).standard_normal(24)))
        oracle = ModelOracle(engine)
        res, rep = llm_order_by(keys, "relevance", oracle, path="auto",
                                descending=True, limit=6, sample_size=10)
        assert len(res.order) == 6
        assert rep.chosen is not None
        assert rep.total_cost == pytest.approx(oracle.spend(), rel=1e-6)
