"""KVBlockPool allocator/refcount/arena unit tests (no model forwards) and
the paged decode-attention kernel oracle checks."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.kernels import ops, ref
from repro.models import LM
from repro.models.layers import KVCache
from repro.serving import KVBlockPool, PoolExhausted


@pytest.fixture()
def pool():
    lm = LM(get_reduced("llama3-8b"))
    return KVBlockPool(lm, num_blocks=17, block_size=8)


def test_alloc_free_roundtrip(pool):
    assert pool.free_blocks == 16            # block 0 reserved as dummy
    a = pool.alloc(5)
    assert len(a) == 5 and 0 not in a
    assert pool.blocks_in_use == 5 and pool.free_blocks == 11
    pool.decref(a)
    assert pool.blocks_in_use == 0 and pool.free_blocks == 16


def test_refcount_sharing(pool):
    run = pool.alloc(4)
    pool.incref(run)                         # a second owner (e.g. a row)
    pool.decref(run)                         # first owner drops
    assert pool.blocks_in_use == 4           # still held
    pool.decref(run)
    assert pool.blocks_in_use == 0


def test_exhaustion_raises_and_leaves_state_clean(pool):
    a = pool.alloc(10)
    with pytest.raises(PoolExhausted):
        pool.alloc(7)
    assert pool.free_blocks == 6             # failed alloc took nothing
    pool.decref(a)
    assert pool.free_blocks == 16


def test_lease_success_counts_and_returns(pool):
    ids = pool.lease(6)
    assert ids is not None and len(ids) == 6
    assert pool.total_leased == 6 and pool.lease_shortfalls == 0
    assert pool.blocks_in_use == 6
    pool.decref(ids)                             # a lease is a normal run
    assert pool.blocks_in_use == 0 and pool.free_blocks == 16


def test_lease_shortfall_takes_nothing_and_never_raises(pool):
    held = pool.alloc(12)
    got = pool.lease(7)                          # only 4 free
    assert got is None
    assert pool.lease_shortfalls == 1 and pool.total_leased == 0
    assert pool.free_blocks == 4                 # shortfall took nothing
    # the pool stays fully usable after a shortfall
    ok = pool.lease(4)
    assert ok is not None and pool.free_blocks == 0
    pool.decref(ok)
    pool.decref(held)
    assert pool.free_blocks == 16 and pool.blocks_in_use == 0


def test_lease_shortfalls_accumulate(pool):
    pool.alloc(16)
    for i in range(3):
        assert pool.lease(1) is None
    assert pool.lease_shortfalls == 3


def test_blocks_for(pool):
    assert pool.blocks_for(0) == 0
    assert pool.blocks_for(1) == 1
    assert pool.blocks_for(8) == 1
    assert pool.blocks_for(9) == 2


def test_peak_tracking(pool):
    a = pool.alloc(3)
    b = pool.alloc(5)
    pool.decref(a)
    pool.alloc(1)
    assert pool.peak_in_use == 8
    assert pool.blocks_in_use == 6


def test_write_gather_roundtrip(pool):
    """Prefill KV scattered into block runs gathers back bit-identically
    (gather is a copy — this is what makes pool-backed prefix entries
    transparent to the suffix-prefill path)."""
    lm = LM(get_reduced("llama3-8b"))
    cfg = lm.cfg
    rng = np.random.default_rng(0)
    n, b, s = cfg.pattern[0][1], 2, 21       # s deliberately un-aligned
    shape = (n, b, s, cfg.n_kv_heads, cfg.hd)
    k = jnp.asarray(rng.standard_normal(shape), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal(shape), jnp.bfloat16)
    caches = [KVCache(k, v, jnp.broadcast_to(jnp.arange(s), (n, s)))]
    rows = [pool.alloc(pool.blocks_for(s)) for _ in range(b)]
    pool.write(caches, rows)
    for r in range(b):
        got = pool.gather_stacked(rows[r], s)[0]
        assert (np.asarray(got.k[:, 0]) == np.asarray(k[:, r])).all()
        assert (np.asarray(got.v[:, 0]) == np.asarray(v[:, r])).all()
        assert got.k.shape == (n, 1, s, cfg.n_kv_heads, cfg.hd)


def test_stash_unstash_roundtrip_is_bit_identical(pool):
    """The preemption round trip (suspend: gather blocks to a host stash;
    resume: scatter into a DIFFERENT run) is a copy of the stored bits —
    the property that makes a resumed decode row byte-identical."""
    lm = LM(get_reduced("llama3-8b"))
    cfg = lm.cfg
    rng = np.random.default_rng(1)
    src = pool.alloc(3)
    dst = pool.alloc(3)                      # disjoint ids on purpose
    assert not set(src) & set(dst)
    n = cfg.pattern[0][1]
    vals = jnp.asarray(rng.standard_normal(
        (n, 3, pool.block_size, cfg.n_kv_heads, cfg.hd)), jnp.bfloat16)
    a = pool.arenas[0]
    idx = jnp.asarray(np.asarray(src, np.int32))
    pool.arenas[0] = type(a)(k=a.k.at[:, idx].set(vals),
                             v=a.v.at[:, idx].set(-vals))
    stash = pool.stash_blocks(src)
    pool.decref(src)                         # source may die while stashed
    pool.unstash_blocks(stash, dst)
    didx = jnp.asarray(np.asarray(dst, np.int32))
    got = pool.arenas[0]
    assert (np.asarray(got.k[:, didx]) == np.asarray(vals)).all()
    assert (np.asarray(got.v[:, didx]) == np.asarray(-vals)).all()
    assert pool.total_stashed == pool.total_unstashed == 3
    with pytest.raises(AssertionError):      # size mismatch is refused
        pool.unstash_blocks(stash, dst[:2])


def test_freeable_counts_only_unshared(pool):
    run = pool.alloc(4)
    pool.incref(run[:2])                     # two blocks shared with an LRU
    assert pool.freeable(run) == 2
    pool.decref(run[:2])
    assert pool.freeable(run) == 4


def test_write_rejects_unaligned_start(pool):
    lm = LM(get_reduced("llama3-8b"))
    cfg = lm.cfg
    n = cfg.pattern[0][1]
    z = jnp.zeros((n, 1, 16, cfg.n_kv_heads, cfg.hd), jnp.bfloat16)
    caches = [KVCache(z, z, jnp.broadcast_to(jnp.arange(16), (n, 16)))]
    with pytest.raises(AssertionError):
        pool.write(caches, [pool.alloc(1)], start=3)


# ------------------------------------------------------ paged decode kernel
def _paged_case(seed, b, h, kvh, hd, bs, nb, maxb):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, h, hd)), jnp.bfloat16)
    kp = jnp.asarray(rng.standard_normal((nb, bs, kvh, hd)), jnp.bfloat16)
    vp = jnp.asarray(rng.standard_normal((nb, bs, kvh, hd)), jnp.bfloat16)
    # distinct non-dummy blocks per row, 0-padded tables
    ids = rng.permutation(np.arange(1, nb))[: b * maxb].reshape(b, maxb)
    n_blk = rng.integers(1, maxb + 1, size=b)
    tables = np.where(np.arange(maxb)[None, :] < n_blk[:, None], ids, 0)
    ctx = (n_blk - 1) * bs + rng.integers(1, bs + 1, size=b)
    return q, kp, vp, jnp.asarray(tables, jnp.int32), jnp.asarray(ctx, jnp.int32)


@pytest.mark.parametrize("b,h,kvh,hd,bs,nb,maxb", [
    (2, 4, 2, 16, 8, 9, 2),
    (3, 8, 2, 32, 16, 13, 3),
    (1, 4, 4, 16, 8, 5, 4),
])
def test_paged_kernel_matches_ref(b, h, kvh, hd, bs, nb, maxb):
    q, kp, vp, tables, ctx = _paged_case(0, b, h, kvh, hd, bs, nb, maxb)
    r = ref.paged_decode_attention_ref(q, kp, vp, tables, ctx)
    k = ops.paged_decode_attention(q, kp, vp, tables, ctx)
    np.testing.assert_allclose(np.asarray(r, np.float32),
                               np.asarray(k, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_paged_ref_equals_dense_decode_ref():
    """Gathering a block run into a dense cache and masking by position is
    BIT-identical to the dense flash-decode oracle over that cache — the
    paged pool changes memory layout, not math."""
    b, h, kvh, hd, bs, nb, maxb = 3, 4, 2, 16, 8, 12, 3
    q, kp, vp, tables, ctx = _paged_case(1, b, h, kvh, hd, bs, nb, maxb)
    r = ref.paged_decode_attention_ref(q, kp, vp, tables, ctx)
    for i in range(b):
        kg = jnp.take(kp, tables[i], axis=0).reshape(maxb * bs, kvh, hd)
        vg = jnp.take(vp, tables[i], axis=0).reshape(maxb * bs, kvh, hd)
        pos = np.where(np.arange(maxb * bs) < int(ctx[i]),
                       np.arange(maxb * bs), -1).astype(np.int32)
        d = ref.decode_attention_ref(q[i:i + 1], kg[None], vg[None],
                                     jnp.asarray(pos))
        assert (np.asarray(d) == np.asarray(r[i:i + 1])).all()
