"""A paged engine fake for fast scheduler tests: REAL pool, REAL policy.

``FakePagedEngine`` subclasses :class:`~repro.serving.engine.ServeEngine`
and keeps all the scheduler-facing paged machinery REAL — the
:class:`~repro.serving.kv_pool.KVBlockPool` (allocation, refcounts,
stash/unstash), ``paged_suspend``/``paged_resume``, ``paged_room``,
``_alloc_rows``'s rollback, ``_paged_admit_wave``, even ``generate``'s
continuous-batching driver — replacing only the model: admission writes a
per-block fingerprint derived from the prompt into the (real, on-device)
KV arenas, and each decode step reads the row's fingerprint back out of
the arenas to emit the next token.

That wiring makes emitted tokens a pure function of (prompt, step) — so
interleaving-identity and solo-replay assertions are exact — while still
flowing through the pool's actual device arrays: a preemption bug that
corrupts, drops, or misorders stashed KV changes the fingerprint and
therefore the resumed row's tokens, which is precisely what the
suspend/resume tests assert against.
"""
import itertools
from collections import OrderedDict
from types import SimpleNamespace

import numpy as np

from repro.models.layers import PagedKV
from repro.serving.engine import ServeStats, ServeEngine, _PagedRow
from repro.serving.kv_pool import KVBlockPool


def tiny_pool_lm():
    """The minimal cfg surface KVBlockPool reads: two tiny attn stacks."""
    return SimpleNamespace(cfg=SimpleNamespace(
        pattern=[("attn", 2)], n_kv_heads=1, hd=2, dtype="float32"))


def _prompt_hash(text: str) -> int:
    h = 0
    for c in text:
        h = (h * 31 + ord(c)) % 997
    return h


class FakePagedEngine(ServeEngine):
    paged_enabled = True
    prefix_cache_enabled = False

    def __init__(self, num_blocks: int = 33, block_size: int = 4,
                 max_decode_rows: int = 4, max_new: int = 6,
                 max_probe_batch: int = 256):
        # deliberately no ServeEngine.__init__ (no model): only the
        # attributes the paged/scheduler surface reads are set up
        self.max_new = max_new
        self.max_decode_rows = max_decode_rows
        self.max_probe_batch = max_probe_batch
        self.stats = ServeStats()
        self.pool = KVBlockPool(tiny_pool_lm(), num_blocks, block_size)
        self._prefix_lru = OrderedDict()
        self._paged_rows = {}
        self._paged_finished = {}
        self._paged_ids = itertools.count()
        self.submitted = []        # probe submissions, for assertions
        self.prefetched = []       # prefix-fill submissions

    # ------------------------------------------------ model stand-ins
    def _encode_prompt(self, prompt):
        prefix, suffix = self._parts(prompt)
        text = suffix if prefix is None else prefix + suffix
        return [ord(c) % 50 + 1 for c in text]

    def _pad_class(self, length: int) -> int:
        return -(-max(length, 1) // 4) * 4

    def submit_probes(self, prompts, max_batch=None):
        self.submitted.append(list(prompts))
        out = np.zeros((len(prompts), 4), np.float32)
        for i, p in enumerate(prompts):
            key = p if isinstance(p, str) else "".join(p)
            out[i] = _prompt_hash(key) + np.arange(4)
        return out

    def prefetch_prefixes(self, prompts):
        self.prefetched.append(list(prompts))
        return len(prompts)

    # ------------------------------------------- paged decode stand-ins
    def _fingerprint(self, blocks) -> int:
        """Read the row's admission-time fingerprint back OUT of the pool
        arenas — a suspend/resume cycle that mangles KV changes this."""
        slab = np.asarray(self.pool.arenas[0].k[0, :, 0, 0, 0])
        return int(round(float(sum(slab[b] for b in blocks))))

    def paged_admit(self, requests):
        counts, needs = [], []
        for prompt, max_new in requests:
            cls = self._pad_class(len(self._encode_prompt(prompt)))
            needs.append(cls)
            counts.append(self.pool.blocks_for(cls + self._row_limit(max_new)))
        runs = self._alloc_rows(counts)
        rids = []
        arena = self.pool.arenas[0]
        k = arena.k
        for (prompt, max_new), cls, run in zip(requests, needs, runs):
            rid = next(self._paged_ids)
            prefix, suffix = self._parts(prompt)
            h = _prompt_hash(suffix if prefix is None else prefix + suffix)
            for j, b in enumerate(run):
                k = k.at[0, b, 0, 0, 0].set(float((h + 7 * j) % 101))
            self._paged_rows[rid] = _PagedRow(
                rid=rid, cls=cls, limit=self._row_limit(max_new),
                blocks=run, n_shared=0, cur=0, t=0, emitted=[])
            rids.append(rid)
        self.pool.arenas[0] = PagedKV(k=k, v=arena.v)
        self.stats.calls += 1
        return rids

    def paged_step(self):
        done: dict[int, str] = {}
        for rid, row in list(self._paged_rows.items()):
            tok = (self._fingerprint(row.blocks) + 1 + 3 * row.t) % 23
            row.emitted.append(tok)
            row.t += 1
            self.stats.decode_tokens += 1
            if tok == 0 or row.t >= row.limit:
                del self._paged_rows[rid]
                self.pool.decref(row.blocks)
                done[rid] = " ".join(str(t) for t in row.emitted)
        finished, self._paged_finished = self._paged_finished, {}
        finished.update(done)
        return finished
