"""Multi-tenant serving policy: priorities, reservations, preemption,
budgets, quotas — and the starvation regression bounds.

Invariants (DESIGN.md "Multi-tenant serving"):

 * **preemption identity + billing** — a decode row suspended to the host
   stash and resumed later produces ``==``-identical output to a solo run
   that was never preempted, and its tenant is billed exactly the tokens
   a never-preempted run would be (one per ACTIVE row per decode step);
 * **reservations** — ``reserved_rows`` of a tenant with queued decode
   work are held back from other classes as admission debt;
 * **budgets** — ``token_budget`` rejects submissions at the scheduler,
   ``ledger_budget`` cancels plans at the executor;
 * **no starvation** — an interactive (priority > 0) tenant's probe round
   resolves in the very next step gap and its decode work is admitted
   within the starvation bound even under a saturating bulk tenant; the
   ``ServeStats`` starvation alarms stay zero.
"""
import numpy as np
import pytest

from fakes_paged import FakePagedEngine
from repro.core import PathParams, ProbePlanExecutor, SimulatedOracle, as_keys, make_path
from repro.core.executor import PlanCancelled
from repro.core.oracles.simulated import REASONING
from repro.core.types import SortSpec
from repro.serving import BatchScheduler, TenantBudgetExceeded, TenantSpec


def _solo_out(prompt, budget, **eng_kw):
    eng = FakePagedEngine(**eng_kw)
    sched = BatchScheduler(eng)
    rid = sched.submit(prompt, budget)
    return sched.run()[rid]


# ----------------------------------------------------------- preemption
def test_preemption_token_identity_and_billing():
    """A bulk row suspended for a priority request resumes byte-identical,
    bills no tokens while parked, and leaves the pool clean."""
    kw = dict(num_blocks=11, max_decode_rows=3, max_new=12)
    eng = FakePagedEngine(**kw)
    sched = BatchScheduler(eng)
    sched.register_tenant(TenantSpec("bulk", priority=0))
    sched.register_tenant(TenantSpec("live", priority=10))
    b1 = sched.submit("bulk one", 12, tenant="bulk")
    b2 = sched.submit("bulk twoooo", 12, tenant="bulk")
    sched.step()
    l1 = sched.submit("live priority", 12, tenant="live")
    outs = sched.run()
    assert eng.stats.preempt_suspends >= 1
    assert eng.stats.preempt_resumes == eng.stats.preempt_suspends
    assert eng.pool.total_unstashed == eng.pool.total_stashed > 0
    assert sched.tenant_stats["bulk"].preemptions >= 1
    assert sched.tenant_stats["bulk"].resumes >= 1
    for prompt, mn, rid in [("bulk one", 12, b1), ("bulk twoooo", 12, b2),
                            ("live priority", 12, l1)]:
        assert outs[rid] == _solo_out(prompt, mn, **kw)
    # billing convention: tokens_served == decode steps actually taken,
    # with nothing billed while suspended and nothing billed twice
    assert sched.tenant_stats["bulk"].tokens_served == sum(
        len(outs[r].split()) for r in (b1, b2))
    assert sched.tenant_stats["live"].tokens_served == len(outs[l1].split())
    assert eng.pool.blocks_in_use == 0


def test_non_preemptible_class_is_never_suspended():
    kw = dict(num_blocks=11, max_decode_rows=3, max_new=12)
    eng = FakePagedEngine(**kw)
    sched = BatchScheduler(eng)
    sched.register_tenant(TenantSpec("bulk", priority=0, preemptible=False))
    sched.register_tenant(TenantSpec("live", priority=10))
    sched.submit("bulk one", 12, tenant="bulk")
    sched.submit("bulk twoooo", 12, tenant="bulk")
    sched.step()
    sched.submit("live priority", 12, tenant="live")
    sched.run()
    assert eng.stats.preempt_suspends == 0
    assert sched.tenant_stats["bulk"].preemptions == 0
    assert eng.pool.blocks_in_use == 0


# ---------------------------------------------------------- reservations
def test_reserved_rows_hold_capacity_for_queued_tenant():
    """With a reserved tenant queued, a higher-priority class cannot take
    the last row: the reservation is debt against everyone else."""
    eng = FakePagedEngine(num_blocks=33, max_decode_rows=2, max_new=4)
    sched = BatchScheduler(eng)
    sched.register_tenant(TenantSpec("fast", priority=5))
    sched.register_tenant(TenantSpec("resv", priority=0, reserved_rows=1))
    a1 = sched.submit("fast one", 4, tenant="fast")
    a2 = sched.submit("fast two", 4, tenant="fast")
    r1 = sched.submit("reserved", 4, tenant="resv")
    sched.step()
    owners = {req.tenant for erid, req in sched._rid_of_engine.items()
              if erid in eng._paged_rows}
    assert owners == {"fast", "resv"}     # NOT both fast rows
    outs = sched.run()
    assert set(outs) == {a1, a2, r1}
    assert eng.pool.blocks_in_use == 0


def test_liveness_beats_reservations_when_loop_is_empty():
    """Reservation debt larger than the row budget must not deadlock an
    empty loop: the fallback pass ignores reservations before raising."""
    eng = FakePagedEngine(num_blocks=33, max_decode_rows=2, max_new=4)
    sched = BatchScheduler(eng)
    sched.register_tenant(TenantSpec("a", reserved_rows=2))
    sched.register_tenant(TenantSpec("b", reserved_rows=2))
    # both tenants queued: each sees the OTHER's full reservation as debt
    ra = sched.submit("a job", 4, tenant="a")
    rb = sched.submit("b job", 4, tenant="b")
    outs = sched.run()
    assert set(outs) == {ra, rb}
    assert eng.pool.blocks_in_use == 0


# --------------------------------------------------------------- budgets
def test_token_budget_rejects_submissions():
    eng = FakePagedEngine()
    sched = BatchScheduler(eng)
    sched.register_tenant(TenantSpec("metered", token_budget=3))
    fut = sched.submit_probe_round(["p1", "p2", "p3"], tenant="metered")
    sched.step()
    assert fut.done
    assert sched.tenant_stats["metered"].tokens_served == 3
    with pytest.raises(TenantBudgetExceeded):
        sched.submit_probe("p4", tenant="metered")
    with pytest.raises(TenantBudgetExceeded):
        sched.submit("gen", 4, tenant="metered")
    # other tenants are unaffected
    assert sched.submit_probe("p4", tenant="default") >= 0


def test_ledger_budget_cancels_executor_plans():
    """The executor cancels a tenant's plans once their billed ledger
    slices cross the tenant's ledger budget; other tenants keep running."""
    keys = as_keys([f"item {i}" for i in range(12)],
                   list(np.linspace(0.0, 1.0, 12)))
    spec = SortSpec("c", False, None)
    o_bulk, o_live = SimulatedOracle(REASONING), SimulatedOracle(REASONING)
    ex = ProbePlanExecutor(tenant_budgets={"bulk": 10})
    capped = ex.submit_path(make_path("quick", PathParams(batch_size=4)),
                            keys, o_bulk, spec, tenant="bulk")
    free = ex.submit_path(make_path("quick", PathParams(batch_size=4)),
                          keys, o_live, spec, tenant="live")
    ex.run()
    assert isinstance(capped.error, PlanCancelled)
    assert "ledger budget" in str(capped.error)
    assert ex.budget_cancelled == 1
    assert free.error is None and free.result is not None


def test_ledger_budget_falls_back_to_scheduler_tenant_spec():
    from types import SimpleNamespace
    ex = ProbePlanExecutor()
    ex.scheduler = SimpleNamespace(
        tenants={"bulk": TenantSpec("bulk", ledger_budget=5)})
    assert ex._ledger_budget("bulk") == 5
    assert ex._ledger_budget("other") is None
    ex.tenant_budgets["bulk"] = 9         # explicit mapping wins
    assert ex._ledger_budget("bulk") == 9


# ---------------------------------------------------------- probe quotas
def test_probe_quota_defers_whole_rounds_then_ages_them_in():
    eng = FakePagedEngine()
    sched = BatchScheduler(eng, starvation_bound=3)
    sched.register_tenant(TenantSpec("bulk", probe_quota=2))
    big = sched.submit_probe_round([f"b{i}" for i in range(4)],
                                   tenant="bulk")
    small = sched.submit_probe_round(["s0"], tenant="bulk")
    sched.step()
    assert small.done and not big.done    # 4 > quota 2, deferred whole
    assert eng.stats.probe_rounds_deferred == 1
    for _ in range(3):                    # ages starvation_bound gaps ...
        sched.step()
    assert big.done                       # ... then is force-serviced
    assert eng.stats.starved_rounds == 0  # priority-0 aging is benign
    assert sched.tenant_stats["bulk"].max_round_wait >= 3
    # logits identical to a direct submission despite the deferrals
    direct = FakePagedEngine().submit_probes([f"b{i}" for i in range(4)])
    for got, want in zip(big.result(), direct):
        assert np.array_equal(got, want)


# ------------------------------------------------- starvation regression
def test_interactive_tenant_not_starved_by_saturating_bulk():
    """THE regression bound: under a bulk tenant saturating decode rows,
    pool blocks, AND the probe path, an interactive round still resolves
    in the very next step gap, interactive decode work is admitted within
    the starvation bound, and the starvation alarms stay zero."""
    eng = FakePagedEngine(num_blocks=21, max_decode_rows=3, max_new=10)
    sched = BatchScheduler(eng, starvation_bound=4)
    sched.register_tenant(TenantSpec("bulk", priority=0, probe_quota=4))
    sched.register_tenant(TenantSpec("live", priority=5, reserved_rows=1))
    for i in range(6):
        sched.submit(f"bulk job number {i}", 10, tenant="bulk")
    live_decode = None
    for step in range(30):
        sched.submit_probe_round([f"bulk probe {step} {j}"
                                  for j in range(8)], tenant="bulk")
        fut = sched.submit_probe_round([f"live probe {step}"],
                                       tenant="live")
        if step == 5:
            live_decode = sched.submit("live decode", 3, tenant="live")
        sched.step()
        assert fut.done                   # resolved in THIS step's gap
    # live decode was admitted promptly despite full bulk occupancy
    assert sched.tenant_stats["live"].max_admission_wait \
        <= sched.starvation_bound
    assert eng.stats.starved_rounds == 0
    assert eng.stats.starved_admissions == 0
    assert eng.stats.probe_rounds_deferred > 0    # quota actually bound bulk
    guard = 0
    while sched.work_remaining:
        sched.step()
        guard += 1
        assert guard < 500
    assert live_decode in sched.completed
    assert sched.completed[live_decode].output == _solo_out(
        "live decode", 3, num_blocks=21, max_decode_rows=3, max_new=10)
    assert eng.pool.blocks_in_use == 0
