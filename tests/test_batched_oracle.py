"""Round-based batched oracle execution.

Invariants of the round refactor:

 * every batch verb agrees element-for-element with its sequential default on
   all three oracle backends (Exact, Simulated, Model);
 * ledger call/token accounting is identical whether a round is executed
   batched or as point calls (billed as N logical calls, executed as one
   submission);
 * every access path produces byte-identical output order with round
   batching on vs off (``PathParams.coalesce``) under deterministic oracles;
 * on the ModelOracle backend, round batching strictly reduces serving
   submissions (``engine.stats.calls``) while leaving the ledger unchanged.
"""
import numpy as np
import pytest

from repro.core import (ExactOracle, CachingOracle, PathParams,
                        SimulatedOracle, as_keys, available_paths, make_path)
from repro.core.oracles.simulated import FACTUAL, REASONING
from repro.core.types import SortSpec


def _keys(n, seed=0):
    rng = np.random.default_rng(seed)
    return as_keys([f"key {i:03d}" for i in range(n)],
                   list(rng.standard_normal(n)))


def _ledger_tuple(oracle):
    return (oracle.ledger.n_calls, oracle.ledger.input_tokens,
            oracle.ledger.output_tokens,
            [(r.kind, r.n_keys) for r in oracle.ledger.records])


ORACLES = [lambda: ExactOracle(), lambda: SimulatedOracle(REASONING),
           lambda: SimulatedOracle(FACTUAL)]


# ---------------------------------------------------------------- batch verbs
@pytest.mark.parametrize("mk", ORACLES)
def test_compare_batch_matches_sequential(mk):
    keys = _keys(10)
    pairs = [(keys[i], keys[j]) for i in range(10) for j in range(i + 1, 10)]
    o1, o2 = mk(), mk()
    batched = o1.compare_batch(pairs, "c")
    pointwise = [o2.compare(a, b, "c") for a, b in pairs]
    assert batched == pointwise
    assert _ledger_tuple(o1) == _ledger_tuple(o2)


@pytest.mark.parametrize("mk", ORACLES)
def test_inquire_batch_matches_sequential(mk):
    keys = _keys(12)
    o1, o2 = mk(), mk()
    assert o1.inquire_batch(keys, "c") == [o2.inquire(k, "c") for k in keys]
    assert _ledger_tuple(o1) == _ledger_tuple(o2)


@pytest.mark.parametrize("mk", ORACLES)
def test_score_each_matches_sequential(mk):
    keys = _keys(9)
    o1, o2 = mk(), mk()
    assert o1.score_each(keys, "c") == [o2.score_batch([k], "c")[0]
                                        for k in keys]
    assert _ledger_tuple(o1) == _ledger_tuple(o2)


@pytest.mark.parametrize("mk", ORACLES)
def test_score_batches_matches_sequential(mk):
    keys = _keys(9)
    chunks = [keys[:3], keys[3:6], keys[6:]]
    o1, o2 = mk(), mk()
    assert (o1.score_batches(chunks, "c")
            == [o2.score_batch(c, "c") for c in chunks])
    assert _ledger_tuple(o1) == _ledger_tuple(o2)


def test_empty_rounds():
    o = ExactOracle()
    assert o.compare_batch([], "c") == []
    assert o.inquire_batch([], "c") == []
    assert o.score_each([], "c") == []
    assert o.score_batches([], "c") == []
    assert o.ledger.n_calls == 0


def test_caching_oracle_round_verbs_share_point_cache():
    keys = _keys(8)
    inner = ExactOracle()
    c = CachingOracle(inner)
    pairs = [(keys[0], keys[1]), (keys[2], keys[3])]
    seq = [c.compare(a, b, "c") for a, b in pairs]
    calls_after_seq = inner.ledger.n_calls
    assert c.compare_batch(pairs, "c") == seq           # all hits
    assert inner.ledger.n_calls == calls_after_seq       # nothing re-billed
    # misses flow through as one round, then hit
    more = [(keys[4], keys[5]), (keys[0], keys[1])]
    got = c.compare_batch(more, "c")
    assert got[1] == seq[0]
    assert c.inquire_batch(keys[:4], "c") == [c.inquire(k, "c")
                                              for k in keys[:4]]
    assert c.score_each(keys[:4], "c") == [c.score_batch([k], "c")[0]
                                           for k in keys[:4]]


# --------------------------------------------------- coalesce on/off identity
@pytest.mark.parametrize("path", sorted(available_paths()))
@pytest.mark.parametrize("mk", ORACLES)
@pytest.mark.parametrize("desc,limit,votes", [(False, None, 1), (True, 7, 3)])
def test_paths_byte_identical_with_and_without_rounds(path, mk, desc, limit,
                                                      votes):
    keys = _keys(33)
    spec = SortSpec("c", desc, limit)
    o_on, o_off = mk(), mk()
    on = make_path(path, PathParams(batch_size=4, votes=votes,
                                    coalesce=True)).execute(keys, o_on, spec)
    off = make_path(path, PathParams(batch_size=4, votes=votes,
                                     coalesce=False)).execute(keys, o_off, spec)
    assert on.uids() == off.uids()


@pytest.mark.parametrize("path", sorted(available_paths()))
@pytest.mark.parametrize("mk", ORACLES)
def test_paths_ledger_identical_with_and_without_rounds(path, mk):
    """Same logical calls and token totals either way — including under
    SimulatedOracle's structural failures (per-element failure isolation:
    a bad window/chunk is split-retried alone, round-mates aren't
    re-billed).  Record ORDER may differ (lockstep merge interleaves
    windows across run-pairs), so compare the multiset plus totals."""
    keys = _keys(32)
    spec = SortSpec("c", True, None)
    o_on, o_off = mk(), mk()
    make_path(path, PathParams(batch_size=4, votes=3,
                               coalesce=True)).execute(keys, o_on, spec)
    make_path(path, PathParams(batch_size=4, votes=3,
                               coalesce=False)).execute(keys, o_off, spec)

    def norm(o):
        n, i, t, recs = _ledger_tuple(o)
        return n, i, t, sorted(recs)
    assert norm(o_on) == norm(o_off)


class _FlakyScore(ExactOracle):
    """score_batch fails structurally (after billing) when the chunk
    contains ``bad_uid`` — deterministic, like a malformed-output key."""

    def __init__(self, bad_uid):
        super().__init__()
        self.bad_uid = bad_uid

    def score_batch(self, keys, criteria):
        from repro.core.types import InvalidOutputError
        if any(k.uid == self.bad_uid for k in keys):
            self._charge_score(keys)
            raise InvalidOutputError("structural failure")
        return super().score_batch(keys, criteria)


def test_caching_round_duplicate_of_failing_element_rebills():
    """Regression: an intra-round duplicate of a structurally-failing
    element must re-reach (and re-bill) the backend — a sequential loop
    would miss the cache again because None is never cached — instead of
    being counted as a hit and served the uncached None for free."""
    keys = _keys(6)
    bad, good = [keys[0]], [keys[1], keys[2]]
    batched = CachingOracle(_FlakyScore(keys[0].uid))
    got = batched.try_score_batches([bad, good, bad], "c")
    assert got[0] is None and got[2] is None
    assert got[1] == pytest.approx([k.latent for k in good])
    # sequential single-element rounds: the reference ledger + counters
    seq = CachingOracle(_FlakyScore(keys[0].uid))
    ref = [seq.try_score_batches([c], "c")[0] for c in (bad, good, bad)]
    assert [r is None for r in ref] == [g is None for g in got]
    assert _ledger_tuple(batched.inner) == _ledger_tuple(seq.inner)
    assert (batched.hits, batched.misses) == (seq.hits, seq.misses) == (0, 3)
    # duplicates of a SUCCESSFUL element stay free hits, in-round or not
    for oracle in (batched, seq):
        h0, m0, calls0 = oracle.hits, oracle.misses, oracle.inner.ledger.n_calls
        oracle.try_score_batches([good, good], "c")
        assert oracle.inner.ledger.n_calls == calls0     # all served from cache
        assert (oracle.hits, oracle.misses) == (h0 + 2, m0)


def test_before_many_split_fallback_degrades_to_point_calls():
    from repro.core.access_paths.base import Ordering
    from repro.core.types import InvalidOutputError

    class FlakyCompareBatch(ExactOracle):
        def compare_batch(self, pairs, criteria):
            if len(pairs) > 2:
                raise InvalidOutputError(f"round of {len(pairs)}")
            return super().compare_batch(pairs, criteria)

    keys = _keys(8)
    pairs = [(keys[i], keys[i + 1]) for i in range(7)]
    ordering = Ordering(FlakyCompareBatch(), SortSpec("c"))
    exact = Ordering(ExactOracle(), SortSpec("c"))
    assert ordering.before_many(pairs) == [exact.before(a, b)
                                           for a, b in pairs]


# ------------------------------------------------------- ModelOracle backend
@pytest.mark.slow
class TestModelOracleRounds:
    @pytest.fixture(scope="class")
    def engine(self):
        import jax
        from repro.configs import get_reduced
        from repro.models import LM
        from repro.serving import ServeEngine
        cfg = get_reduced("llama3-8b")
        lm = LM(cfg)
        params = lm.init(jax.random.PRNGKey(0))
        return ServeEngine(lm, params, max_new_tokens=8)

    def test_batch_verbs_match_sequential(self, engine):
        from repro.core.oracles.model_oracle import ModelOracle
        # variable-length texts: padded-length-class grouping keeps batched
        # logits bit-identical to sequential point submissions
        keys = as_keys([f"key {'x' * (3 * i)} {i}" for i in range(8)],
                       list(range(8)))
        pairs = [(keys[i], keys[j]) for i in range(4) for j in range(4, 8)]
        o1, o2 = ModelOracle(engine), ModelOracle(engine)
        assert o1.compare_batch(pairs, "c") == [o2.compare(a, b, "c")
                                                for a, b in pairs]
        assert o1.inquire_batch(keys, "c") == [o2.inquire(k, "c")
                                               for k in keys]
        s1 = o1.score_each(keys, "c")
        s2 = [o2.score_batch([k], "c")[0] for k in keys]
        assert s1 == pytest.approx(s2)
        assert _ledger_tuple(o1) == _ledger_tuple(o2)

    def test_rounds_cut_submissions_not_billing(self, engine):
        from repro.core.oracles.model_oracle import ModelOracle
        keys = _keys(24)
        spec = SortSpec("c", True, None)
        out = {}
        for co in (False, True):
            o = ModelOracle(engine)
            c0 = engine.stats.calls
            res = make_path("quick", PathParams(votes=1, coalesce=co)).execute(
                keys, o, spec)
            out[co] = (engine.stats.calls - c0, _ledger_tuple(o), res.uids())
        subs_off, ledger_off, uids_off = out[False]
        subs_on, ledger_on, uids_on = out[True]
        assert subs_on < subs_off
        assert ledger_on == ledger_off
        assert uids_on == uids_off
