"""Serving engine, scheduler, and the real-model ModelOracle path."""
import jax
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # model forward passes: heavyweight

from repro.configs import get_reduced
from repro.core import as_keys, llm_order_by
from repro.core.oracles.model_oracle import ModelOracle
from repro.models import LM
from repro.serving import BatchScheduler, ServeEngine


@pytest.fixture(scope="module")
def engine():
    cfg = get_reduced("llama3-8b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    return ServeEngine(lm, params, max_new_tokens=8)


def test_generate_shapes_and_stats(engine):
    before = engine.stats.prefill_tokens
    outs = engine.generate(["hello world", "rank me"], max_new=4)
    assert len(outs) == 2
    assert engine.stats.prefill_tokens > before
    assert engine.stats.calls >= 1


def test_score_deterministic(engine):
    s1 = engine.score(["aaa", "bbb", "ccc"], "positivity")
    s2 = engine.score(["aaa", "bbb", "ccc"], "positivity")
    assert s1 == s2


def test_compare_antisymmetric_prompt_order(engine):
    # not guaranteed antisymmetric for a random model (prompt asymmetry),
    # but must return +/-1 deterministically
    r = engine.compare("short text", "another text", "quality")
    assert r in (-1, 1)
    assert engine.compare("short text", "another text", "quality") == r


def test_rank_window_is_permutation(engine):
    perm = engine.rank_window([f"item {i}" for i in range(6)], "size")
    assert sorted(perm) == list(range(6))


def test_scheduler_drains_in_batches(engine):
    sched = BatchScheduler(engine, max_batch=2)
    rids = [sched.submit(f"prompt {i}", max_new=2) for i in range(5)]
    out = sched.run()
    assert set(out) == set(rids)
    assert not sched.queue


def test_scheduler_run_returns_only_current_drain(engine):
    sched = BatchScheduler(engine, max_batch=2)
    first = [sched.submit(f"prompt {i}", max_new=2) for i in range(3)]
    d1 = sched.run()
    assert set(d1) == set(first)
    later = sched.submit("another prompt", max_new=2)
    d2 = sched.run()
    assert set(d2) == {later}                      # drain-local, no history
    assert set(sched.completed) == set(first) | {later}


def test_scheduler_sorts_whole_drain_by_length(engine):
    """LOCKSTEP mode: the drain sorts the WHOLE backlog by prompt length
    before chunking, so mixed-length arrival order can't pad every batch up
    to its longest straggler: padded prefill totals equal the ideal sorted
    grouping.  (The paged continuous loop doesn't need the sort at all —
    rows prefill at their own padded-length class; asserted below.)"""
    short = ["hi 1", "hi 2"]
    long_ = ["y" * 40 + " 1", "y" * 40 + " 2"]
    sched = BatchScheduler(engine, max_batch=2, paged=False)
    for p in (short[0], long_[0], short[1], long_[1]):   # interleaved arrival
        sched.submit(p, max_new=2)
    t0 = engine.stats.prefill_tokens
    out = sched.run()
    drain_tokens = engine.stats.prefill_tokens - t0
    assert len(out) == 4
    # ideal grouping: (short, short), (long, long)
    t0 = engine.stats.prefill_tokens
    engine.generate_lockstep(short, max_new=2)
    engine.generate_lockstep(long_, max_new=2)
    ideal_tokens = engine.stats.prefill_tokens - t0
    # arrival-order chunks would pad both batches to the long class
    t0 = engine.stats.prefill_tokens
    engine.generate_lockstep([short[0], long_[0]], max_new=2)
    engine.generate_lockstep([short[1], long_[1]], max_new=2)
    mixed_tokens = engine.stats.prefill_tokens - t0
    assert drain_tokens == ideal_tokens < mixed_tokens
    # the paged loop prefills per class: mixed arrival == ideal grouping
    t0 = engine.stats.prefill_tokens
    engine.generate([short[0], long_[0], short[1], long_[1]], max_new=2)
    assert engine.stats.prefill_tokens - t0 == ideal_tokens


def test_scheduler_probe_pathway(engine):
    sched = BatchScheduler(engine, max_batch=2)
    assert sched.run_probes() == {}
    prompts = [f"Criteria: size\nItem: thing {i}\nRating:" for i in range(5)]
    rids = [sched.submit_probe(p) for p in prompts]
    out = sched.run_probes()
    assert set(out) == set(rids)
    assert not sched.probe_queue
    assert sched.run_probes() == {}                # drained
    # probe logits match the engine's direct probe pathway per prompt
    direct = engine.submit_probes(prompts)
    for rid, l in zip(rids, direct):
        assert np.allclose(out[rid], l)


def test_model_oracle_end_to_end(engine):
    oracle = ModelOracle(engine)
    keys = as_keys([f"entry {i}" for i in range(10)], list(range(10)))
    res, _ = llm_order_by(keys, "numeric size", oracle, path="ext_merge",
                          descending=True)
    assert sorted(res.uids()) == list(range(10))
    assert res.n_calls > 0 and res.cost > 0


def test_batched_run_generation_single_submission(engine):
    """ext_merge Phase 1 rides ONE serving batch under the ModelOracle."""
    from repro.core import PathParams, make_path
    from repro.core.types import SortSpec
    keys = as_keys([f"doc {i}" for i in range(16)], list(range(16)))
    oracle = ModelOracle(engine)
    calls_before = engine.stats.calls
    res = make_path("ext_merge", PathParams(batch_size=4)).execute(
        keys, oracle, SortSpec("size", True, None))
    assert sorted(res.uids()) == list(range(16))
    # 4 phase-1 windows in 1 engine call; ledger still bills 4 logical calls
    rank_calls = oracle.ledger.by_kind("rank").n_calls
    assert rank_calls >= 4
    assert engine.stats.calls - calls_before < rank_calls


def test_rank_batches_matches_sequential():
    """Default (simulated) batched API == per-window calls."""
    import numpy as np
    from repro.core import SimulatedOracle, as_keys
    from repro.core.oracles.simulated import REASONING
    keys = as_keys([f"t{i}" for i in range(12)],
                   list(np.random.default_rng(0).standard_normal(12)))
    batches = [keys[:4], keys[4:8], keys[8:]]
    o1, o2 = SimulatedOracle(REASONING), SimulatedOracle(REASONING)
    a = o1.rank_batches(batches, "c")
    b = [o2.rank_batch(list(x), "c") for x in batches]
    assert [[k.uid for k in r] for r in a] == [[k.uid for k in r] for r in b]
    assert o1.ledger.n_calls == o2.ledger.n_calls


def test_model_oracle_optimizer_runs(engine):
    oracle = ModelOracle(engine)
    keys = as_keys([f"text number {i}" for i in range(12)],
                   list(np.random.default_rng(0).standard_normal(12)))
    res, rep = llm_order_by(keys, "magnitude", oracle, path="auto",
                            strategy="borda", sample_size=6, limit=4)
    assert len(res.order) == 4
    assert rep.chosen is not None
    assert rep.total_cost == pytest.approx(oracle.spend(), rel=1e-6)
