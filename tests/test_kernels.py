"""Per-kernel shape/dtype sweeps asserting allclose against ref.py oracles
(interpret=True executes the kernel bodies in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # model forward passes: heavyweight

from repro.kernels import ref
from repro.kernels.borda_count import borda_count
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mlstm_scan import mlstm_scan
from repro.kernels.moe_gating import moe_gating
from repro.kernels.ssm_scan import ssm_scan
from repro.kernels.topk_scores import topk_scores
from repro.kernels import ops

RNG = jax.random.PRNGKey(0)


@pytest.mark.parametrize("b,h,kv,s,hd,win,bq,bk", [
    (2, 4, 2, 128, 64, 0, 64, 64),
    (1, 4, 4, 256, 32, 0, 128, 64),
    (2, 8, 2, 128, 64, 64, 64, 64),
    (1, 2, 1, 96, 64, 32, 64, 64),
    (1, 2, 2, 160, 128, 0, 64, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(b, h, kv, s, hd, win, bq, bk, dtype):
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (b, h, s, hd), dtype)
    k = jax.random.normal(ks[1], (b, kv, s, hd), dtype)
    v = jax.random.normal(ks[2], (b, kv, s, hd), dtype)
    out = flash_attention(q, k, v, causal=True, window=win,
                          block_q=bq, block_k=bk, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, window=win)
    atol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=atol)


@pytest.mark.parametrize("b,h,kv,sq,sk,hd,bq,bk", [
    (2, 4, 2, 64, 192, 64, 64, 64),
    (1, 4, 4, 96, 256, 32, 64, 64),
    (1, 2, 1, 32, 96, 64, 32, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_prepended_kv(b, h, kv, sq, sk, hd, bq, bk, dtype):
    """Chunked prefill over prepended KV: the kernel with q_offset = Sk - Sq
    must match (a) the offset ref oracle and (b) the suffix rows of a
    monolithic full-sequence flash attention — the prefix-KV cache
    equivalence at the kernel level."""
    ks = jax.random.split(RNG, 3)
    off = sk - sq
    q_full = jax.random.normal(ks[0], (b, h, sk, hd), dtype)
    k = jax.random.normal(ks[1], (b, kv, sk, hd), dtype)
    v = jax.random.normal(ks[2], (b, kv, sk, hd), dtype)
    q = q_full[:, :, off:]
    out = flash_attention(q, k, v, causal=True, q_offset=off,
                          block_q=bq, block_k=bk, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, q_offset=off)
    atol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=atol)
    full = ref.attention_ref(q_full, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(full[:, :, off:], np.float32),
                               atol=atol)


@pytest.mark.parametrize("b,h,kv,s,hd,fill,bk", [
    (2, 8, 2, 256, 64, 256, 64),
    (1, 4, 4, 128, 128, 100, 64),
    (2, 4, 1, 96, 64, 50, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(b, h, kv, s, hd, fill, bk, dtype):
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (b, h, hd), dtype)
    kc = jax.random.normal(ks[1], (b, s, kv, hd), dtype)
    vc = jax.random.normal(ks[2], (b, s, kv, hd), dtype)
    pos = jnp.where(jnp.arange(s) < fill, jnp.arange(s), -1).astype(jnp.int32)
    out = decode_attention(q, kc, vc, pos, block_k=bk, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, pos)
    atol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=atol)


@pytest.mark.parametrize("n,k,bn", [(1000, 10, 256), (4096, 16, 1024),
                                    (77, 5, 64), (128, 1, 32)])
def test_topk(n, k, bn):
    sc = jax.random.normal(RNG, (n,), jnp.float32)
    bv, bi = topk_scores(sc, k, block_n=bn, interpret=True)
    cand_v, cand_i = bv.reshape(-1), bi.reshape(-1)
    vals, sel = jax.lax.top_k(cand_v, k)
    got_i = cand_i[sel]
    rv, ri = ref.topk_ref(sc, k)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rv), atol=1e-6)
    assert (np.asarray(got_i) == np.asarray(ri)).all()


@pytest.mark.parametrize("r,s,n", [(6, 20, 20), (3, 10, 50), (9, 15, 130),
                                   (1, 5, 5)])
def test_borda(r, s, n):
    ballots = np.stack([np.random.default_rng(i).permutation(n)[:s]
                        for i in range(r)]).astype(np.int32)
    if r > 1:
        ballots[0, -2:] = -1  # truncated ballot
    out = borda_count(jnp.asarray(ballots), n, block_items=64,
                      block_ballots=4, interpret=True)
    want = ref.borda_ref(jnp.asarray(ballots), n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("b,s,d,n,bd,ch", [(2, 128, 64, 16, 32, 32),
                                           (1, 64, 128, 8, 128, 16)])
def test_ssm_scan(b, s, d, n, bd, ch):
    ks = jax.random.split(RNG, 4)
    x = jax.random.normal(ks[0], (b, s, d), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, d))) * 0.2
    bt = jax.random.normal(ks[2], (b, s, n), jnp.float32)
    ct = jax.random.normal(ks[3], (b, s, n), jnp.float32)
    a = -jnp.abs(jax.random.normal(RNG, (d, n), jnp.float32))
    y = ssm_scan(x, dt, bt, ct, a, block_d=bd, chunk=ch, interpret=True)
    want, _ = ref.ssm_scan_ref(x, dt, bt, ct, a)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-4)


@pytest.mark.parametrize("b,h,s,dq,dv,ch", [(1, 2, 128, 32, 64, 32),
                                            (2, 2, 64, 16, 16, 16)])
def test_mlstm_scan(b, h, s, dq, dv, ch):
    ks = jax.random.split(RNG, 5)
    q = jax.random.normal(ks[0], (b, h, s, dq), jnp.float32)
    k = jax.random.normal(ks[1], (b, h, s, dq), jnp.float32)
    v = jax.random.normal(ks[2], (b, h, s, dv), jnp.float32)
    ig = jax.random.normal(ks[3], (b, h, s), jnp.float32)
    fg = jax.random.normal(ks[4], (b, h, s), jnp.float32) + 2.0
    y = mlstm_scan(q, k, v, ig, fg, chunk=ch, interpret=True)
    want = ref.mlstm_ref(q, k, v, ig, fg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=2e-3)


@pytest.mark.parametrize("t,e,k,bt", [(100, 8, 2, 32), (256, 16, 4, 64),
                                      (40, 4, 1, 16)])
def test_moe_gating(t, e, k, bt):
    logits = jax.random.normal(RNG, (t, e), jnp.float32)
    idx, g, pos = moe_gating(logits, k, block_t=bt, interpret=True)
    ri, rg, rp, _ = ref.moe_gating_ref(logits, k, capacity=1 << 30)
    assert (np.asarray(idx) == np.asarray(ri)).all()
    np.testing.assert_allclose(np.asarray(g), np.asarray(rg), atol=1e-6)
    assert (np.asarray(pos) == np.asarray(rp)).all()


def test_ops_wrappers_dispatch_interpret_on_cpu():
    assert not ops.on_tpu()
    q = jax.random.normal(RNG, (1, 2, 64, 32), jnp.float32)
    k = jax.random.normal(RNG, (1, 2, 64, 32), jnp.float32)
    out = ops.flash_attention(q, k, k, block_q=32, block_k=32)
    want = ref.attention_ref(q, k, k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)
    vals, idx = ops.topk_scores(jax.random.normal(RNG, (300,)), 7)
    rv, ri = ref.topk_ref(jax.random.normal(RNG, (300,)), 7)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rv), atol=1e-6)
