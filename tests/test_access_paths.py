"""Access-path behaviour: exactness under a perfect comparator, LIMIT-K
pushdown, Table-1 call-count bounds, Alg-1 adaptive batching, invalid-output
fallbacks."""
import math

import numpy as np
import pytest

from repro.core import (ExactOracle, FlakyOracle, PathParams, SimulatedOracle,
                        as_keys, available_paths, llm_order_by, make_path)
from repro.core.access_paths.base import Ordering
from repro.core.access_paths.pointwise import ExternalPointwise
from repro.core.types import SortSpec
from repro.core.oracles.cache import CachingOracle
from repro.core.oracles.simulated import REASONING

PATHS = available_paths()


def keys_n(n, seed=0):
    rng = np.random.default_rng(seed)
    return as_keys([f"key-{i}" for i in range(n)], rng.standard_normal(n))


@pytest.mark.parametrize("path", PATHS)
@pytest.mark.parametrize("desc", [False, True])
def test_exact_oracle_sorts_perfectly(path, desc):
    keys = keys_n(33)
    res, _ = llm_order_by(keys, "value", ExactOracle(), path=path,
                          descending=desc)
    lat = [k.latent for k in res.order]
    assert lat == sorted(lat, reverse=desc)
    assert sorted(res.uids()) == sorted(k.uid for k in keys)


@pytest.mark.parametrize("path", PATHS)
def test_limit_k_is_prefix_of_full_sort(path):
    keys = keys_n(40, seed=3)
    full, _ = llm_order_by(keys, "v", ExactOracle(), path=path, descending=True)
    lim, _ = llm_order_by(keys, "v", ExactOracle(), path=path, descending=True,
                          limit=7)
    assert lim.uids() == full.uids()[:7]
    assert len(lim.order) == 7


def test_limit_k_reduces_calls():
    keys = keys_n(64, seed=1)
    for path in ("quick", "ext_bubble", "ext_merge"):
        o_full, o_lim = ExactOracle(), ExactOracle()
        make_path(path).execute(keys, o_full, SortSpec("v", True, None))
        make_path(path).execute(keys, o_lim, SortSpec("v", True, 5))
        assert o_lim.ledger.n_calls < o_full.ledger.n_calls, path


@pytest.mark.parametrize("coalesce", [False, True])
def test_ext_merge_limit_k_stops_windows_at_cap(coalesce):
    """Alg. 5 + Sec. 3.3: a merge emits at most K items and issues NO
    ranking windows past them; carried-forward odd runs are capped too, so
    run sizes stop growing at K.  Empirical calls must track the Table-1
    LIMIT-K asymptotic (a full-merge-then-truncate implementation lands at
    the unlimited count instead)."""
    from repro.core.access_paths.merge import ExternalMergeSort
    keys = keys_n(65, seed=4)                     # odd run count each round
    params = PathParams(batch_size=4, coalesce=coalesce)
    results, calls = {}, {}
    for k in (4, None):
        o = ExactOracle()
        res = make_path("ext_merge", params).execute(
            keys, o, SortSpec("v", True, k))
        results[k], calls[k] = res.uids(), o.ledger.n_calls
    assert results[4] == results[None][:4]        # identical first-K output
    est_lim = ExternalMergeSort.est_calls(65, 4, params)
    est_full = ExternalMergeSort.est_calls(65, None, params)
    assert calls[4] <= 1.6 * est_lim < est_full <= calls[None] * 1.6
    assert calls[4] < 0.6 * calls[None]


def test_table1_call_bounds():
    """Empirical call counts within a small constant of Table 1."""
    n, m = 64, 4
    keys = keys_n(n, seed=2)
    spec = SortSpec("v", True, None)
    counts = {}
    for path in PATHS:
        o = ExactOracle()
        make_path(path, PathParams(batch_size=m)).execute(keys, o, spec)
        counts[path] = o.ledger.n_calls
    assert counts["pointwise"] == n
    assert counts["ext_pointwise"] <= math.ceil(n / m) + 2 * math.ceil(math.log2(m))
    assert counts["quick"] <= 3 * n * math.log2(n)          # O(N log N)
    assert counts["ext_merge"] <= 4 * (n / m) * (1 + math.log2(n / m))
    assert counts["ext_bubble"] >= counts["ext_merge"]      # N^2/m^2 vs N/m log


def test_quick_votes_uses_more_calls_but_stays_correct():
    keys = keys_n(24, seed=5)
    spec = SortSpec("v", False, None)
    o1, o3 = ExactOracle(), ExactOracle()
    r1 = make_path("quick", PathParams(votes=1)).execute(keys, o1, spec)
    r3 = make_path("quick", PathParams(votes=3)).execute(keys, o3, spec)
    assert r1.uids() == r3.uids()            # exact comparator: same order
    assert o3.ledger.n_calls > o1.ledger.n_calls


def test_quick_majority_voting_beats_vanilla_on_noise():
    """The paper's claim: quick_3 > quick on noisy comparators (mean tau)."""
    from repro.core.metrics import kendall_tau
    taus = {1: [], 3: []}
    for seed in range(6):
        keys = keys_n(40, seed=10 + seed)
        for v in (1, 3):
            o = SimulatedOracle(REASONING)
            res = make_path("quick", PathParams(votes=v)).execute(
                keys, o, SortSpec("v", False, None))
            taus[v].append(kendall_tau(res.order))
    assert np.mean(taus[3]) >= np.mean(taus[1]) - 0.02


def test_adaptive_batch_size_doubles_until_disagreement():
    keys = keys_n(64, seed=7)
    path = ExternalPointwise(PathParams(batch_size=0, max_batch=32))
    cached = CachingOracle(ExactOracle())
    m = path.choose_batch_size(keys, Ordering(cached, SortSpec("v", False)))
    assert m == 16 or m == 32  # exact oracle always agrees -> cap-ish growth
    assert cached.hits > 0     # Alg 1 reuses cached sub-batches


def test_adaptive_batch_stops_on_invalid_output():
    keys = keys_n(64, seed=8)
    path = ExternalPointwise(PathParams(batch_size=0, max_batch=32))
    oracle = CachingOracle(FlakyOracle(fail_above=8))
    m = path.choose_batch_size(keys, Ordering(oracle, SortSpec("v", False)))
    assert m <= 8              # breaks when the 2m-batch goes invalid


def test_invalid_output_fallback_splits_batch():
    keys = keys_n(32, seed=9)
    res, _ = llm_order_by(keys, "v", FlakyOracle(fail_above=4),
                          path="ext_merge",
                          params=PathParams(batch_size=16))
    lat = [k.latent for k in res.order]
    assert lat == sorted(lat)  # still exact despite forced batch splits


@pytest.mark.parametrize("path", PATHS)
def test_noisy_oracle_output_is_permutation(path):
    """Regression: Alg. 5's count-based pointer advance double-emitted items
    when the noisy window ranking inverted same-run items."""
    from collections import Counter
    keys = keys_n(50, seed=33)
    o = SimulatedOracle(REASONING)
    res, _ = llm_order_by(keys, "rel", o, path=path, descending=True)
    counts = Counter(res.uids())
    assert max(counts.values()) == 1
    assert sorted(counts) == sorted(k.uid for k in keys)


def test_ledger_accounting_matches_result():
    keys = keys_n(20)
    o = ExactOracle()
    res = make_path("pointwise").execute(keys, o, SortSpec("v", False, None))
    assert res.n_calls == o.ledger.n_calls == 20
    assert res.input_tokens == o.ledger.input_tokens > 0
    assert res.cost == pytest.approx(o.ledger.cost(o.prices))
