"""Hypothesis property tests on the system's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import ExactOracle, PathParams, as_keys, make_path
from repro.core.optimizer.borda import borda_consensus, borda_matrix, borda_scores
from repro.core.metrics import kendall_tau, kendall_tau_between, ndcg_at_k
from repro.core.types import SortSpec

SETTINGS = dict(max_examples=30, deadline=None)


latents = st.lists(
    st.floats(min_value=-100, max_value=100, allow_nan=False, width=32),
    min_size=1, max_size=48, unique=True)
paths = st.sampled_from(["pointwise", "ext_pointwise", "quick", "ext_bubble",
                         "ext_merge"])


@given(latents=latents, path=paths, desc=st.booleans(),
       m=st.integers(2, 8), v=st.integers(1, 3),
       limit=st.one_of(st.none(), st.integers(1, 10)))
@settings(**SETTINGS)
def test_exact_oracle_invariants(latents, path, desc, m, v, limit):
    """With a perfect comparator every path returns exactly the sorted
    prefix: correct order, correct length, a subset-permutation of input."""
    keys = as_keys([f"k{i}" for i in range(len(latents))], latents)
    res = make_path(path, PathParams(batch_size=m, votes=v)).execute(
        keys, ExactOracle(), SortSpec("c", descending=desc, limit=limit))
    want = sorted(latents, reverse=desc)
    k = len(latents) if limit is None else min(limit, len(latents))
    got = [kk.latent for kk in res.order]
    assert got == want[:k]
    assert len(set(res.uids())) == len(res.order)


@given(latents=latents, desc=st.booleans())
@settings(**SETTINGS)
def test_kendall_tau_bounds_and_perfection(latents, desc):
    keys = as_keys([str(i) for i in range(len(latents))], latents)
    ordered = sorted(keys, key=lambda k: k.latent, reverse=desc)
    assert kendall_tau(ordered, descending=desc) == 1.0
    assert -1.0 <= kendall_tau(keys, descending=desc) <= 1.0
    if len(keys) > 1:
        assert kendall_tau(list(reversed(ordered)), descending=desc) == -1.0


@given(st.lists(st.permutations(list(range(12))), min_size=1, max_size=7),
       st.permutations(list(range(7))))
@settings(**SETTINGS)
def test_borda_ballot_order_invariance(ballots, shuffle_order):
    """Borda consensus is invariant to the order ballots arrive in."""
    universe = list(range(12))
    shuffled = [ballots[i % len(ballots)] for i in shuffle_order]
    assert (borda_consensus(ballots, universe)
            == borda_consensus(ballots[::-1], universe))
    s1 = borda_scores(ballots, universe)
    s2 = borda_scores(ballots[::-1], universe)
    assert s1 == s2


@given(st.integers(2, 10), st.integers(1, 6))
@settings(**SETTINGS)
def test_borda_unanimous_winner_tops(n_items, n_ballots):
    """If every ballot ranks item 0 first, consensus puts it first."""
    base = list(range(n_items))
    ballots = []
    for b in range(n_ballots):
        rest = base[1:]
        rng = np.random.default_rng(b)
        rng.shuffle(rest)
        ballots.append([0] + rest)
    assert borda_consensus(ballots, base)[0] == 0


@given(st.lists(st.permutations(list(range(10))), min_size=1, max_size=5))
@settings(**SETTINGS)
def test_borda_matrix_matches_dict_scores(ballots):
    universe = list(range(10))
    scores = borda_scores(ballots, universe)
    mat = borda_matrix(np.asarray(ballots, np.int32), 10)
    for u in universe:
        assert scores[u] == mat[u]


@given(latents=latents)
@settings(**SETTINGS)
def test_ndcg_perfect_is_one(latents):
    keys = as_keys([str(i) for i in range(len(latents))], latents)
    rel = {k.uid: max(0.0, k.latent) for k in keys}
    best = sorted(keys, key=lambda k: rel[k.uid], reverse=True)
    if sum(rel.values()) > 0:
        assert ndcg_at_k(best, rel, k=10) == 1.0 or abs(
            ndcg_at_k(best, rel, k=10) - 1.0) < 1e-9


@given(st.permutations(list(range(15))))
@settings(**SETTINGS)
def test_kendall_between_self_and_reverse(perm):
    assert kendall_tau_between(perm, perm) == 1.0
    assert kendall_tau_between(perm, perm[::-1]) == -1.0


# --------------------------------------------------- round / cache equivalence
oracle_makers = st.sampled_from(["exact", "reasoning", "factual"])


def _mk_oracle(name):
    from repro.core import SimulatedOracle
    from repro.core.oracles.simulated import FACTUAL, REASONING
    if name == "exact":
        return ExactOracle()
    return SimulatedOracle(REASONING if name == "reasoning" else FACTUAL)


@given(latents=latents, path=paths, mk=oracle_makers, desc=st.booleans(),
       m=st.integers(2, 6), v=st.integers(1, 3),
       limit=st.one_of(st.none(), st.integers(1, 10)))
@settings(**SETTINGS)
def test_batched_rounds_equal_sequential_property(latents, path, mk, desc, m,
                                                  v, limit):
    """PROPERTY: every access path is byte-identical with round batching on
    vs off (``PathParams.coalesce``) on every deterministic backend."""
    keys = as_keys([f"k{i}" for i in range(len(latents))], latents)
    spec = SortSpec("c", descending=desc, limit=limit)
    on = make_path(path, PathParams(batch_size=m, votes=v,
                                    coalesce=True)).execute(keys, _mk_oracle(mk), spec)
    off = make_path(path, PathParams(batch_size=m, votes=v,
                                     coalesce=False)).execute(keys, _mk_oracle(mk), spec)
    assert on.uids() == off.uids()


@given(latents=latents, path=paths, mk=oracle_makers, desc=st.booleans(),
       m=st.integers(2, 6),
       limit=st.one_of(st.none(), st.integers(1, 10)))
@settings(**SETTINGS)
def test_caching_wrapper_is_transparent_property(latents, path, mk, desc, m,
                                                 limit):
    """PROPERTY: wrapping any deterministic backend in CachingOracle (the
    client-side output cache) never changes llm_order_by output — hits serve
    exactly what the backend would recompute at temperature 0."""
    from repro.core.oracles.cache import CachingOracle
    keys = as_keys([f"k{i}" for i in range(len(latents))], latents)
    spec = SortSpec("c", descending=desc, limit=limit)
    params = PathParams(batch_size=m)
    plain = make_path(path, params).execute(keys, _mk_oracle(mk), spec)
    cached = make_path(path, params).execute(
        keys, CachingOracle(_mk_oracle(mk)), spec)
    assert plain.uids() == cached.uids()
