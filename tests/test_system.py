"""End-to-end behaviour tests for the paper's system: the headline claims
reproduced on the simulated-oracle benchmark families."""
import numpy as np
import pytest

from repro.core import (PathParams, SimulatedOracle, llm_order_by, make_path)
from repro.core.datasets import (benchmark_suite, nba_heights, passages,
                                 world_population)
from repro.core.metrics import graded_relevance, kendall_tau, ndcg_at_k
from repro.core.types import SortSpec

STATIC = ["pointwise", "quick", "ext_merge"]


def run_static(task, path, params=PathParams(batch_size=4)):
    o = SimulatedOracle(task.profile)
    res = make_path(path, params).execute(
        task.keys, o, SortSpec(task.criteria, task.descending, task.limit))
    if task.metric == "ndcg":
        rel = graded_relevance(task.keys, descending=task.descending)
        q = ndcg_at_k(res.order, rel, k=task.limit or 10)
    else:
        q = kendall_tau(res.order, descending=task.descending)
    return q, res.cost


def test_no_universal_winner():
    """Sec. 4: pointwise wins factual, comparison-based wins reasoning."""
    factual = nba_heights(n=80)
    reasoning = passages(n=80)
    qf = {p: run_static(factual, p)[0] for p in STATIC}
    qr = {p: run_static(reasoning, p)[0] for p in STATIC}
    assert qf["pointwise"] > max(qf["quick"], qf["ext_merge"])
    assert max(qr["quick"], qr["ext_merge"]) > qr["pointwise"]


def test_merge_sort_cheaper_than_bubble_similar_quality():
    """Sec. 3/4: external merge sort's cost advantage over external bubble."""
    task = passages(n=80, seed=21)
    qm, cm = run_static(task, "ext_merge")
    qb, cb = run_static(task, "ext_bubble")
    assert cm < 0.6 * cb
    assert qm > qb - 0.1


def test_test_time_scaling_on_comparisons():
    """Sec. 4: more compute (votes) -> better quality on average."""
    task = passages(n=60, seed=22)
    pts = []
    for v in (1, 3, 5):
        q, c = run_static(task, "quick", PathParams(votes=v))
        pts.append((c, q))
    costs, quals = zip(*pts)
    assert costs[0] < costs[1] < costs[2]
    assert quals[2] >= quals[0] - 0.02  # no collapse; scaling holds on average


@pytest.mark.slow  # full 4-family optimizer sweep: heavyweight
def test_optimizer_matches_best_static_per_family():
    """Sec. 6 headline: the dynamic optimizer is on par with (>= best - eps)
    the best static path on every benchmark family."""
    eps = 0.06
    for task in benchmark_suite(seed=1):
        statics = {}
        for p in STATIC + ["ext_bubble"]:
            statics[p], _ = run_static(task, p)
        o = SimulatedOracle(task.profile)
        res, rep = llm_order_by(task.keys, task.criteria, o, path="auto",
                                strategy="borda", descending=task.descending,
                                limit=task.limit)
        if task.metric == "ndcg":
            rel = graded_relevance(task.keys, descending=task.descending)
            q = ndcg_at_k(res.order, rel, k=task.limit or 10)
        else:
            q = kendall_tau(res.order, descending=task.descending)
        best = max(statics.values())
        assert q >= best - eps, (task.name, q, statics, rep.chosen.label)


def test_judge_vs_borda_long_context():
    """Sec. 6.2: on long passages Borda is the more stable strategy (judge
    suffers context-length noise).  Statistical: mean over seeds."""
    qj, qb = [], []
    for seed in range(4):
        task = passages(n=60, seed=30 + seed)
        rel = graded_relevance(task.keys, descending=True)
        for strat, acc in (("judge", qj), ("borda", qb)):
            o = SimulatedOracle(task.profile)
            res, _ = llm_order_by(task.keys, task.criteria, o, path="auto",
                                  strategy=strat, descending=True,
                                  limit=task.limit)
            acc.append(ndcg_at_k(res.order, rel, k=10))
    assert np.mean(qb) >= np.mean(qj) - 0.03


def test_world_population_gate_accuracy():
    """Sec. 6.2: membership gate -> pointwise -> tau ~ 0.97 ballpark."""
    task = world_population(n=120)
    o = SimulatedOracle(task.profile)
    res, rep = llm_order_by(task.keys, task.criteria, o, path="auto",
                            descending=True)
    assert rep.reason == "membership"
    assert kendall_tau(res.order, descending=True) > 0.93
