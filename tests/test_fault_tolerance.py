"""Fault tolerance: crash + auto-resume equivalence, straggler watchdog,
elastic re-carve."""
import tempfile
import time

import jax
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # training-loop forward passes: heavyweight

from repro.configs import get_reduced
from repro.data import DataConfig, DataPipeline
from repro.models import LM
from repro.training import OptimConfig, TrainConfig, Trainer
from repro.training.fault_tolerance import (ElasticPlan, SimulatedFailure,
                                            StragglerWatchdog, elastic_plan)


def setup(steps, td):
    cfg = get_reduced("phi4-mini-3.8b")
    lm = LM(cfg)
    tc = TrainConfig(steps=steps, log_every=0, ckpt_dir=td, ckpt_every=5,
                     ckpt_async=False,
                     optim=OptimConfig(lr=3e-3, warmup_steps=2,
                                       total_steps=steps))
    pipe = DataPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                   global_batch=8))
    return lm, tc, pipe


def test_crash_restart_matches_uninterrupted_run():
    with tempfile.TemporaryDirectory() as td1, \
            tempfile.TemporaryDirectory() as td2:
        # uninterrupted reference
        lm, tc, pipe = setup(15, td1)
        tr = Trainer(lm, tc)
        ref = tr.run(tr.init_state(jax.random.PRNGKey(0)), iter(pipe),
                     resume=False)["history"]

        # crashed run: dies at step 8 (after the step-5 checkpoint)
        lm2, tc2, pipe2 = setup(15, td2)
        tr2 = Trainer(lm2, tc2)
        tr2.injector.crash_at_step = 8
        with pytest.raises(SimulatedFailure):
            tr2.run(tr2.init_state(jax.random.PRNGKey(0)), iter(pipe2),
                    resume=False)
        # restart: fresh trainer auto-resumes from step 5
        tr3 = Trainer(lm2, tc2)
        out = tr3.run(tr3.init_state(jax.random.PRNGKey(0)),
                      iter(DataPipeline(DataConfig(
                          vocab_size=get_reduced("phi4-mini-3.8b").vocab_size,
                          seq_len=32, global_batch=8))),
                      resume=True)["history"]
        assert out[0]["step"] == 6  # resumed after the committed step-5 ckpt
        # the resumed trajectory matches the uninterrupted one closely
        ref_tail = {r["step"]: r["loss"] for r in ref}
        for r in out:
            assert r["loss"] == pytest.approx(ref_tail[r["step"]], rel=2e-2)


def test_watchdog_flags_straggler():
    wd = StragglerWatchdog(threshold=3.0)
    for i in range(8):
        wd.start()
        time.sleep(0.005)
        wd.stop(i)
    wd.start()
    time.sleep(0.1)        # simulated slow host step
    wd.stop(99)
    assert any(step == 99 for step, _, _ in wd.flagged)


def test_elastic_plan_shrinks_data_axis_only():
    p = elastic_plan(n_alive=512, model_parallel=16)
    assert p == ElasticPlan(data=32, model=16, dropped_hosts=0)
    # lose 40 chips: data axis shrinks to the next power of two
    p = elastic_plan(n_alive=472, model_parallel=16)
    assert p.model == 16 and p.data == 16
    assert p.n_devices <= 472
    with pytest.raises(RuntimeError):
        elastic_plan(n_alive=8, model_parallel=16)


def test_data_pipeline_restart_determinism():
    """Any host can regenerate any step's shard (restart invariance)."""
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=8, seed=3)
    a = DataPipeline(cfg, n_shards=4, shard_id=2)
    b = DataPipeline(cfg, n_shards=4, shard_id=2)
    np.testing.assert_array_equal(a.batch(17)["tokens"], b.batch(17)["tokens"])
    c = DataPipeline(cfg, n_shards=4, shard_id=3)
    assert not (a.batch(17)["tokens"] == c.batch(17)["tokens"]).all()


# --------------------------------------- serving-loop preemption failures
def _tenancy_pair():
    """The force-preemption shape: two long bulk rows fill the pool, a
    priority request must suspend one of them to fit."""
    from fakes_paged import FakePagedEngine
    from repro.serving import BatchScheduler, TenantSpec

    eng = FakePagedEngine(num_blocks=11, max_decode_rows=3, max_new=12)
    sched = BatchScheduler(eng)
    sched.register_tenant(TenantSpec("bulk", priority=0))
    sched.register_tenant(TenantSpec("live", priority=10))
    return eng, sched


def _solo(prompt, budget):
    from fakes_paged import FakePagedEngine
    from repro.serving import BatchScheduler

    eng = FakePagedEngine(num_blocks=11, max_decode_rows=3, max_new=12)
    s = BatchScheduler(eng)
    rid = s.submit(prompt, budget)
    return s.run()[rid]


def test_step_survives_suspend_failure_mid_preemption():
    """PoolExhausted out of stash_blocks mid-suspend: the victim stays
    active and owned, the step's queue bookkeeping stays consistent, and
    once the fault clears the run drains with solo-identical outputs and
    exact billing."""
    from repro.serving.kv_pool import PoolExhausted

    eng, sched = _tenancy_pair()
    b1 = sched.submit("bulk one", 12, tenant="bulk")
    b2 = sched.submit("bulk twoooo", 12, tenant="bulk")
    sched.step()
    l1 = sched.submit("live priority", 12, tenant="live")
    real_stash = eng.pool.stash_blocks
    calls = []

    def flaky(ids):
        if not calls:
            calls.append(1)
            raise PoolExhausted("injected stash failure")
        return real_stash(ids)

    eng.pool.stash_blocks = flaky
    before = {rid: list(row.blocks) for rid, row in eng._paged_rows.items()}
    with pytest.raises(PoolExhausted, match="injected stash failure"):
        sched.step()
    # stash-first: the would-be victim is still an active owned row with
    # its block run untouched
    assert eng.stats.preempt_suspends == 0
    assert {rid: list(row.blocks)
            for rid, row in eng._paged_rows.items()} == before
    assert eng.pool.blocks_in_use == sum(
        len(r.blocks) for r in eng._paged_rows.values())
    # the finally in step() reassigned the queue: no request is both
    # queued and owning an engine row
    owned = set(map(id, sched._rid_of_engine.values()))
    assert all(id(w) not in owned for w in sched.work)
    outs = sched.run()                     # fault cleared: drain normally
    assert eng.stats.preempt_suspends == 1
    assert eng.stats.preempt_resumes == 1
    for prompt, rid in [("bulk one", b1), ("bulk twoooo", b2),
                        ("live priority", l1)]:
        assert outs[rid] == _solo(prompt, 12)
    assert sched.tenant_stats["bulk"].tokens_served == sum(
        len(outs[r].split()) for r in (b1, b2))
    assert eng.pool.blocks_in_use == 0


def test_step_survives_resume_failure_mid_drain():
    """A transient failure scattering a stash back mid-resume: the
    allocation rolls back (zero stranded pins), the request stays queued
    as suspended, and the next steps resume and finish it byte-identical
    with single billing."""
    eng, sched = _tenancy_pair()
    b1 = sched.submit("bulk one", 12, tenant="bulk")
    b2 = sched.submit("bulk twoooo", 12, tenant="bulk")
    sched.step()
    l1 = sched.submit("live priority", 12, tenant="live")
    sched.step()                           # preemption happens here
    assert eng.stats.preempt_suspends == 1
    real_unstash = eng.pool.unstash_blocks
    calls = []

    def flaky(stash, ids):
        if not calls:
            calls.append(1)
            raise RuntimeError("injected unstash failure")
        return real_unstash(stash, ids)

    eng.pool.unstash_blocks = flaky
    raised = 0
    guard = 0
    while sched.work_remaining:
        try:
            sched.step()
        except RuntimeError as e:
            assert "injected unstash failure" in str(e)
            raised += 1
            # rollback hygiene at the failure point: every pool block is
            # accounted to an active row — the failed resume pinned nothing
            assert eng.pool.blocks_in_use == sum(
                len(r.blocks) for r in eng._paged_rows.values())
            # the victim is back at the queue head, still suspended
            assert sched.work and sched.work[0].suspended is not None
        guard += 1
        assert guard < 200
    assert raised == 1                     # fault was one-shot and surfaced
    assert eng.stats.preempt_resumes == 1
    outs = {r: sched.completed[r].output for r in (b1, b2, l1)}
    for prompt, rid in [("bulk one", b1), ("bulk twoooo", b2),
                        ("live priority", l1)]:
        assert outs[rid] == _solo(prompt, 12)
    assert sched.tenant_stats["bulk"].tokens_served == sum(
        len(outs[r].split()) for r in (b1, b2))
    assert eng.pool.blocks_in_use == 0
