"""Fault tolerance: crash + auto-resume equivalence, straggler watchdog,
elastic re-carve."""
import tempfile
import time

import jax
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # training-loop forward passes: heavyweight

from repro.configs import get_reduced
from repro.data import DataConfig, DataPipeline
from repro.models import LM
from repro.training import OptimConfig, TrainConfig, Trainer
from repro.training.fault_tolerance import (ElasticPlan, SimulatedFailure,
                                            StragglerWatchdog, elastic_plan)


def setup(steps, td):
    cfg = get_reduced("phi4-mini-3.8b")
    lm = LM(cfg)
    tc = TrainConfig(steps=steps, log_every=0, ckpt_dir=td, ckpt_every=5,
                     ckpt_async=False,
                     optim=OptimConfig(lr=3e-3, warmup_steps=2,
                                       total_steps=steps))
    pipe = DataPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                   global_batch=8))
    return lm, tc, pipe


def test_crash_restart_matches_uninterrupted_run():
    with tempfile.TemporaryDirectory() as td1, \
            tempfile.TemporaryDirectory() as td2:
        # uninterrupted reference
        lm, tc, pipe = setup(15, td1)
        tr = Trainer(lm, tc)
        ref = tr.run(tr.init_state(jax.random.PRNGKey(0)), iter(pipe),
                     resume=False)["history"]

        # crashed run: dies at step 8 (after the step-5 checkpoint)
        lm2, tc2, pipe2 = setup(15, td2)
        tr2 = Trainer(lm2, tc2)
        tr2.injector.crash_at_step = 8
        with pytest.raises(SimulatedFailure):
            tr2.run(tr2.init_state(jax.random.PRNGKey(0)), iter(pipe2),
                    resume=False)
        # restart: fresh trainer auto-resumes from step 5
        tr3 = Trainer(lm2, tc2)
        out = tr3.run(tr3.init_state(jax.random.PRNGKey(0)),
                      iter(DataPipeline(DataConfig(
                          vocab_size=get_reduced("phi4-mini-3.8b").vocab_size,
                          seq_len=32, global_batch=8))),
                      resume=True)["history"]
        assert out[0]["step"] == 6  # resumed after the committed step-5 ckpt
        # the resumed trajectory matches the uninterrupted one closely
        ref_tail = {r["step"]: r["loss"] for r in ref}
        for r in out:
            assert r["loss"] == pytest.approx(ref_tail[r["step"]], rel=2e-2)


def test_watchdog_flags_straggler():
    wd = StragglerWatchdog(threshold=3.0)
    for i in range(8):
        wd.start()
        time.sleep(0.005)
        wd.stop(i)
    wd.start()
    time.sleep(0.1)        # simulated slow host step
    wd.stop(99)
    assert any(step == 99 for step, _, _ in wd.flagged)


def test_elastic_plan_shrinks_data_axis_only():
    p = elastic_plan(n_alive=512, model_parallel=16)
    assert p == ElasticPlan(data=32, model=16, dropped_hosts=0)
    # lose 40 chips: data axis shrinks to the next power of two
    p = elastic_plan(n_alive=472, model_parallel=16)
    assert p.model == 16 and p.data == 16
    assert p.n_devices <= 472
    with pytest.raises(RuntimeError):
        elastic_plan(n_alive=8, model_parallel=16)


def test_data_pipeline_restart_determinism():
    """Any host can regenerate any step's shard (restart invariance)."""
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=8, seed=3)
    a = DataPipeline(cfg, n_shards=4, shard_id=2)
    b = DataPipeline(cfg, n_shards=4, shard_id=2)
    np.testing.assert_array_equal(a.batch(17)["tokens"], b.batch(17)["tokens"])
    c = DataPipeline(cfg, n_shards=4, shard_id=3)
    assert not (a.batch(17)["tokens"] == c.batch(17)["tokens"]).all()
