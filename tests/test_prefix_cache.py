"""Prefix-KV cache: equivalence, accounting, and cache-mechanics tests.

Invariants (see DESIGN.md "Prefix-KV cache"):

 * cache-on execution is BIT-identical to cache-off (monolithic prefill) for
   every probe — the cache is keyed on (prefix token ids, absolute start
   position) under the left-pad scheme, and causal KV slicing is exact;
 * therefore ``llm_order_by`` output order and the oracle ledger (calls +
   tokens) are byte-identical with the cache on vs off, across all five
   access paths and descending/LIMIT variants;
 * the cache strictly reduces ``ServeStats.prefill_tokens`` and reports hit
   rate + token savings;
 * the LRU respects its bound; unsupported archs fall back silently.
"""
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # model forward passes: heavyweight

from repro.core import as_keys, llm_order_by, PathParams, available_paths
from repro.core.oracles.model_oracle import ModelOracle


@pytest.fixture(scope="module")
def lm_params():
    import jax
    from repro.configs import get_reduced
    from repro.models import LM
    cfg = get_reduced("llama3-8b")
    lm = LM(cfg)
    return lm, lm.init(jax.random.PRNGKey(0))


def _engine(lm_params, **kw):
    from repro.serving import ServeEngine
    lm, params = lm_params
    return ServeEngine(lm, params, max_new_tokens=8, **kw)


def _ledger_tuple(oracle):
    return (oracle.ledger.n_calls, oracle.ledger.input_tokens,
            oracle.ledger.output_tokens,
            [(r.kind, r.n_keys) for r in oracle.ledger.records])


@pytest.mark.parametrize("path", sorted(available_paths()))
@pytest.mark.parametrize("desc,limit", [(False, None), (True, 5)])
def test_order_and_ledger_identical_cache_on_vs_off(lm_params, path, desc,
                                                    limit):
    """Byte-identical llm_order_by output and identical ledgers with the
    prefix cache on vs off, for every access path and direction/LIMIT."""
    # variable-length keys: exercises per-length prefix starts
    keys = as_keys([f"doc {'w' * (i % 4)}{i}" for i in range(10)],
                   list(np.random.default_rng(7).standard_normal(10)))
    out = {}
    for size in (0, 64):
        eng = _engine(lm_params, prefix_cache_size=size)
        oracle = ModelOracle(eng)
        res, _ = llm_order_by(keys, "relevance", oracle, path=path,
                              params=PathParams(batch_size=3),
                              descending=desc, limit=limit)
        out[size] = (res.uids(), _ledger_tuple(oracle),
                     eng.stats.prefill_tokens)
    uids_off, ledger_off, toks_off = out[0]
    uids_on, ledger_on, toks_on = out[64]
    assert uids_on == uids_off
    assert ledger_on == ledger_off
    assert toks_on < toks_off          # the cache must actually save prefill


def test_probe_logits_bitwise_identical_and_stats(lm_params):
    eng_off = _engine(lm_params, prefix_cache_size=0)
    eng_on = _engine(lm_params)
    assert eng_on.prefix_cache_enabled and not eng_off.prefix_cache_enabled
    # suffix lengths repeat (i % 3), so rows share (prefix, start) entries;
    # a row whose start is unique in the round rides the plain path instead
    # (the routing policy — both paths are bit-identical)
    prompts = [("Criteria: c\nPassage B: pivot text\n",
                f"Passage A: item {'x' * (i % 3)}\nWhich ranks higher? Answer:")
               for i in range(6)]
    a = eng_off.submit_probes(prompts)
    b = eng_on.submit_probes(prompts)
    assert (a == b).all()
    assert eng_on.stats.prefix_misses >= 1
    assert eng_on.stats.prefix_tokens_saved > 0
    # a second round over the same prefixes is served from the LRU
    b2 = eng_on.submit_probes(prompts)
    assert (a == b2).all()
    assert eng_on.stats.prefix_hits >= 1
    assert 0.0 < eng_on.stats.prefix_hit_rate <= 1.0


def test_sequential_equals_batched_with_cache(lm_params):
    eng = _engine(lm_params)
    prompts = [("Criteria: c\nItem:", f" thing {'y' * (2 * i)}\nRating:")
               for i in range(5)]
    batched = eng.submit_probes(prompts)
    single = np.stack([eng.submit_probes([p])[0] for p in prompts])
    assert (batched == single).all()


def test_plain_string_prompts_bypass_cache(lm_params):
    eng = _engine(lm_params)
    p = ["Criteria: c\nItem: a\nRating:", "Criteria: c\nItem: bb\nRating:"]
    logits = eng.submit_probes(p)
    assert logits.shape[0] == 2
    assert eng.stats.prefix_misses == 0 and eng.stats.prefix_hits == 0


def test_structured_equals_plain_concatenation(lm_params):
    """A (prefix, suffix) prompt yields bit-identical logits to the same
    text submitted as one plain string (monolithic equivalence)."""
    eng = _engine(lm_params)
    parts = [("Criteria: c\nItem:", f" thing {i}\nRating:") for i in range(4)]
    a = eng.submit_probes(parts)
    b = eng.submit_probes([pre + suf for pre, suf in parts])
    assert (a == b).all()


def test_lru_bound_and_eviction(lm_params):
    eng = _engine(lm_params, prefix_cache_size=2)
    for i in range(4):  # 4 distinct prefixes, each shared by 2 rows
        eng.submit_probes([(f"Criteria: c{i}\nItem:", " a\nRating:"),
                           (f"Criteria: c{i}\nItem:", " b\nRating:")])
    assert len(eng._prefix_lru) <= 2


def test_round_larger_than_lru_survives_eviction(lm_params):
    """Regression: one round needing more entries than prefix_cache_size
    must not lose in-flight entries to its own evictions — window jobs hold
    direct references, the LRU only serves cross-round reuse."""
    eng_small = _engine(lm_params, prefix_cache_size=2)
    eng_off = _engine(lm_params, prefix_cache_size=0)
    prompts = [(f"Criteria: c{i}\nItem:", f" {t}\nRating:")
               for i in range(3) for t in ("aa", "bb")]   # 3 entries, 2 rows each
    a = eng_small.submit_probes(prompts)
    b = eng_off.submit_probes(prompts)
    assert (a == b).all()
    assert len(eng_small._prefix_lru) <= 2


def test_unsupported_arch_falls_back(lm_params):
    import jax
    from repro.configs import get_reduced
    from repro.models import LM
    from repro.serving import ServeEngine
    cfg = get_reduced("xlstm-1.3b")        # recurrent blocks: no KV regions
    lm = LM(cfg)
    eng = ServeEngine(lm, lm.init(jax.random.PRNGKey(0)), max_new_tokens=4)
    assert not eng.prefix_cache_enabled
    logits = eng.submit_probes([("Criteria: c\nItem:", " a\nRating:")])
    assert logits.shape[0] == 1            # structured prompt still served
