"""Data pipeline + tokenizer."""
import numpy as np

from repro.data import EOS, PAD, ByteTokenizer, DataConfig, DataPipeline


def test_tokenizer_roundtrip():
    tok = ByteTokenizer()
    s = "hello ORDER BY world"
    assert tok.decode(tok.encode(s)) == s
    padded = tok.pad_to(tok.encode("ab"), 8)
    assert len(padded) == 8 and padded[-1] == PAD


def test_pipeline_shapes_and_range():
    cfg = DataConfig(vocab_size=5000, seq_len=64, global_batch=16)
    b = DataPipeline(cfg).batch(0)
    assert b["tokens"].shape == (16, 64)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 5000


def test_pipeline_step_determinism_and_variation():
    cfg = DataConfig(vocab_size=5000, seq_len=32, global_batch=8, seed=1)
    p = DataPipeline(cfg)
    np.testing.assert_array_equal(p.batch(3)["tokens"], p.batch(3)["tokens"])
    assert not (p.batch(3)["tokens"] == p.batch(4)["tokens"]).all()


def test_pipeline_shards_partition_batch():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=8)
    shards = [DataPipeline(cfg, n_shards=4, shard_id=i) for i in range(4)]
    batches = [s.batch(0)["tokens"] for s in shards]
    assert all(b.shape == (2, 8) for b in batches)
    # shards differ
    assert not (batches[0] == batches[1]).all()


def test_corpus_backend_packs_documents():
    docs = ["first document text", "second one", "third piece of text here"]
    cfg = DataConfig(vocab_size=300, seq_len=16, global_batch=4,
                     backend="corpus")
    p = DataPipeline(cfg, corpus=docs)
    b = p.batch(0)
    assert b["tokens"].shape == (4, 16)
    assert (b["tokens"] == EOS).any()  # EOS separators survived packing
