"""Sharding rules: spec validity, divisibility handling, ZeRO-1 extension,
and a real jit execution under a local mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_reduced, list_archs
from repro.distributed import (ShardingPlan, batch_specs, cache_specs, named,
                               param_specs, zero1_specs)
from repro.launch.mesh import make_local_mesh
from repro.models import LM


def fake_mesh_16x16():
    """AbstractMesh stands in for the production mesh (no devices needed)."""
    from jax.sharding import AbstractMesh
    return AbstractMesh((("data", 16), ("model", 16)))


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("fsdp", [False, True])
def test_param_specs_divisible(arch, fsdp):
    """Every sharded dim must be divisible by its axis product (no GSPMD
    padding surprises in the memory accounting)."""
    cfg = get_config(arch)
    lm = LM(cfg)
    params_shape = jax.eval_shape(lambda: lm.init(jax.random.PRNGKey(0)))
    mesh = fake_mesh_16x16()
    specs = param_specs(params_shape, mesh, ShardingPlan(fsdp=fsdp))

    def check(leaf, spec):
        for dim, entry in zip(leaf.shape, tuple(spec)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % total == 0, (leaf.shape, spec)

    jax.tree.map(check, params_shape, specs,
                 is_leaf=lambda x: isinstance(x, P))
    # at least half the parameter bytes must be model-sharded
    total = sharded = 0
    flat_p = jax.tree.leaves(params_shape)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for leaf, spec in zip(flat_p, flat_s):
        b = leaf.size
        total += b
        if any(e is not None for e in tuple(spec)):
            sharded += b
    assert sharded / total > 0.5, f"{arch}: only {sharded/total:.0%} sharded"


def test_zero1_extends_opt_state_sharding():
    cfg = get_config("llama3-8b")
    lm = LM(cfg)
    params_shape = jax.eval_shape(lambda: lm.init(jax.random.PRNGKey(0)))
    mesh = fake_mesh_16x16()
    pspecs = param_specs(params_shape, mesh, ShardingPlan())
    ospecs = zero1_specs(params_shape, pspecs, mesh, ShardingPlan(zero1=True))
    n_extended = 0
    for ps, os_ in zip(jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P)),
                       jax.tree.leaves(ospecs, is_leaf=lambda x: isinstance(x, P))):
        if tuple(os_) != tuple(ps):
            n_extended += 1
    assert n_extended > 0


def test_batch_specs_shard_batch_dim():
    mesh = fake_mesh_16x16()
    bs = batch_specs({"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32),
                      "positions": jax.ShapeDtypeStruct((3, 256, 128), jnp.int32)},
                     mesh)
    # PartitionSpec normalizes 1-tuples to bare names
    assert bs["tokens"] in (P("data"), P(("data",)))
    assert tuple(bs["positions"])[1] in ("data", ("data",))


def test_cache_specs_context_parallel_fallback():
    """B=1 (long_500k): batch unshardable -> seq dim shards over data."""
    mesh = fake_mesh_16x16()
    cache = jax.ShapeDtypeStruct((4, 1, 524288, 5, 64), jnp.bfloat16)
    spec = jax.tree.leaves(cache_specs(cache, mesh),
                           is_leaf=lambda x: isinstance(x, P))[0]
    entries = tuple(spec)
    assert entries[1] is None           # batch=1 not sharded
    assert entries[2] in ("data", ("data",))  # seq sharded (context parallel)


def test_sharded_moe_matches_global_dispatch():
    """shard_map-local MoE dispatch (the collective fix) is numerically
    identical to the global-view scatter on a 1x1 mesh."""
    import dataclasses
    from repro.distributed.context import shard_context
    rng = jax.random.PRNGKey(0)
    cfg_g = dataclasses.replace(get_reduced("mixtral-8x7b"), moe_impl="global")
    cfg_s = dataclasses.replace(get_reduced("mixtral-8x7b"), moe_impl="sharded")
    lm_g, lm_s = LM(cfg_g), LM(cfg_s)
    params = lm_g.init(rng)
    batch = {"tokens": jax.random.randint(rng, (2, 32), 0, cfg_g.vocab_size)}
    loss_g, _ = jax.jit(lm_g.loss)(params, batch)
    mesh = make_local_mesh(1, 1)
    with mesh, shard_context(mesh, ("data",), "model"):
        loss_s, _ = jax.jit(lm_s.loss)(params, batch)
    assert abs(float(loss_g) - float(loss_s)) < 1e-3


def test_sharded_train_step_runs_on_local_mesh():
    """End-to-end: specs drive a real jit on a 1x1 local mesh."""
    cfg = get_reduced("llama3-8b")
    lm = LM(cfg)
    mesh = make_local_mesh(1, 1)
    params = lm.init(jax.random.PRNGKey(0))
    pspecs = param_specs(params, mesh, ShardingPlan())
    shardings = named(mesh, pspecs)
    params = jax.device_put(params, shardings)
    batch = {"tokens": jnp.zeros((4, 32), jnp.int32)}
    with mesh:
        loss, _ = jax.jit(lm.loss, in_shardings=(shardings, None))(params, batch)
    assert np.isfinite(float(loss))
