"""Roofline-derived serving prices feeding the ORDER BY optimizer."""
import pytest

from repro.core import SimulatedOracle, llm_order_by
from repro.core.datasets import passages
from repro.launch.pricing import price_sheet_from_records


def fake_records():
    def rec(arch, shape, bound):
        return {"arch": arch, "shape": shape, "chips": 256, "multi_pod": False,
                "roofline": {"step_time_bound_s": bound}}
    return [rec("llama3-8b", "prefill_32k", 8.28),
            rec("llama3-8b", "decode_32k", 0.341)]


def test_price_sheet_math():
    ps = price_sheet_from_records(fake_records(), "llama3-8b",
                                  chip_hour_usd=1.2, utilization=1.0)
    pod_usd_s = 256 * 1.2 / 3600
    pre_tok_s = 32 * 32768 / 8.28
    assert ps.input_per_mtok == pytest.approx(pod_usd_s / pre_tok_s * 1e6)
    assert ps.output_per_mtok > ps.input_per_mtok  # decode >> prefill $/tok
    assert "self-hosted" in ps.name


def test_optimizer_runs_on_selfhosted_prices():
    ps = price_sheet_from_records(fake_records(), "llama3-8b")
    task = passages(n=40, seed=50)
    oracle = SimulatedOracle(task.profile, prices=ps)
    res, rep = llm_order_by(task.keys, task.criteria, oracle, path="auto",
                            descending=True, limit=10)
    assert rep.total_cost == pytest.approx(oracle.spend(), rel=1e-6)
    assert res.cost > 0


def test_missing_arch_raises():
    with pytest.raises(KeyError):
        price_sheet_from_records(fake_records(), "qwen2-vl-7b")
