"""Seeded fuzz/chaos suite for the unified multi-tenant step loop.

Random interleavings of decode admissions, probe rounds, stand-alone
probes, prefix fills, priority-induced preemptions, and injected
mid-step transient failures are driven through a real
:class:`~repro.serving.scheduler.BatchScheduler` over a REAL
:class:`~repro.serving.kv_pool.KVBlockPool` (see ``fakes_paged``: only
the model is faked; admission, preemption, stash/unstash, and rollback
paths are the production code).  Whatever the interleaving, the end
state must satisfy:

 * **zero leaked blocks** — the pool returns to empty;
 * **all futures resolved** — every round future and stand-alone probe
   delivers, including work reinstated after an injected failure;
 * **solo-replay identity** — every decode output equals a fresh solo
   run of the same prompt, and every round's logits equal a direct
   submission (preemption and deferral are invisible to results);
 * **exact per-tenant ledgers** — each tenant's ``tokens_served`` equals
   the solo-replay token count of its decode work plus its probe rows
   (the no-double-billing convention for preempted rows).

The fast profile is tier-1; the deep profile (more seeds, longer op
sequences) is ``slow``.  When ``hypothesis`` is installed an additional
property test searches the interleaving space adaptively.
"""
import numpy as np
import pytest

from fakes_paged import FakePagedEngine
from repro.serving import BatchScheduler, TenantSpec

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

ENGINE_KW = dict(num_blocks=25, max_decode_rows=3, max_new=8)
TENANTS = [TenantSpec("bulk", priority=0, probe_quota=6),
           TenantSpec("live", priority=10, reserved_rows=1),
           TenantSpec("mid", priority=3)]
NAMES = ["default", "bulk", "live", "mid"]


def _make():
    eng = FakePagedEngine(**ENGINE_KW)
    sched = BatchScheduler(eng, starvation_bound=4)
    for t in TENANTS:
        sched.register_tenant(t)
    return eng, sched


def _solo_out(prompt, budget):
    eng = FakePagedEngine(**ENGINE_KW)
    sched = BatchScheduler(eng)
    rid = sched.submit(prompt, budget)
    return sched.run()[rid]


def _fuzz(seed: int, n_ops: int, fail_rate: float = 0.0) -> None:
    rng = np.random.default_rng(seed)
    eng, sched = _make()
    decode = []        # (tenant, prompt, budget, rid)
    rounds = []        # (future, prompts, tenant)
    singles = []       # (rid, prompt, tenant)
    if fail_rate:
        real_probes = eng.submit_probes
        real_fills = eng.prefetch_prefixes

        def flaky_probes(prompts, max_batch=None):
            if rng.random() < fail_rate:
                raise RuntimeError("transient probe failure")
            return real_probes(prompts, max_batch=max_batch)

        def flaky_fills(prompts):
            if rng.random() < fail_rate:
                raise RuntimeError("transient fill failure")
            return real_fills(prompts)

        eng.submit_probes = flaky_probes
        eng.prefetch_prefixes = flaky_fills

    def step():
        try:
            sched.step()
        except RuntimeError as e:          # injected transient failures only
            assert "transient" in str(e)

    for i in range(n_ops):
        op = rng.random()
        tenant = NAMES[int(rng.integers(len(NAMES)))]
        if op < 0.35:
            prompt = f"gen {tenant} {seed} {i} " + "x" * int(rng.integers(12))
            budget = int(rng.integers(1, 9))
            decode.append((tenant, prompt, budget,
                           sched.submit(prompt, budget, tenant=tenant)))
        elif op < 0.55:
            prompts = [f"probe {seed} {i} {j}"
                       for j in range(int(rng.integers(1, 7)))]
            rounds.append((sched.submit_probe_round(prompts, tenant=tenant),
                           prompts, tenant))
        elif op < 0.65:
            prompt = f"single {seed} {i}"
            singles.append((sched.submit_probe(prompt, tenant=tenant),
                            prompt, tenant))
        elif op < 0.72:
            sched.submit_prefix_fill([(f"pre {i}", f"suf {i}")])
        else:
            step()
    guard = 0
    while sched.work_remaining:
        step()
        guard += 1
        assert guard < 10_000, "drain did not terminate"

    # ---- invariants ----
    assert eng.pool.blocks_in_use == 0, "leaked KV blocks"
    assert eng.stats.preempt_resumes == eng.stats.preempt_suspends
    assert eng.pool.total_unstashed == eng.pool.total_stashed
    for fut, _prompts, _t in rounds:
        assert fut.done, "unresolved round future"
    for rid, _p, _t in singles:
        assert rid in sched.probe_results, "undelivered stand-alone probe"

    expect_tokens: dict = {}
    for tenant, prompt, budget, rid in decode:
        solo = _solo_out(prompt, budget)
        assert sched.completed[rid].output == solo, (seed, prompt)
        expect_tokens[tenant] = (expect_tokens.get(tenant, 0)
                                 + len(solo.split()))
    clean = FakePagedEngine(**ENGINE_KW)
    for fut, prompts, tenant in rounds:
        expect_tokens[tenant] = expect_tokens.get(tenant, 0) + len(prompts)
        direct = clean.submit_probes(prompts)
        for got, want in zip(fut.result(), direct):
            assert np.array_equal(got, want), (seed, prompts)
    for rid, prompt, tenant in singles:
        expect_tokens[tenant] = expect_tokens.get(tenant, 0) + 1
        assert np.array_equal(sched.probe_results[rid],
                              clean.submit_probes([prompt])[0])
    for tenant, n in expect_tokens.items():
        assert sched.tenant_stats[tenant].tokens_served == n, (seed, tenant)


# --------------------------------------------------- tier-1 fast profile
@pytest.mark.parametrize("seed", range(6))
def test_fuzz_interleavings(seed):
    _fuzz(seed, n_ops=60)


@pytest.mark.parametrize("seed", range(4))
def test_fuzz_with_transient_failures(seed):
    _fuzz(100 + seed, n_ops=50, fail_rate=0.25)


def test_fuzz_under_preemption_pressure():
    """A tight pool + long bulk rows + priority bursts: preemption fires
    and all invariants still hold (this seed/shape is chosen to suspend)."""
    rng = np.random.default_rng(7)
    eng = FakePagedEngine(num_blocks=11, max_decode_rows=3, max_new=12)
    sched = BatchScheduler(eng, starvation_bound=4)
    sched.register_tenant(TenantSpec("bulk", priority=0))
    sched.register_tenant(TenantSpec("live", priority=10))
    decode = []
    for i in range(12):
        prompt = f"bulk {i} " + "y" * int(rng.integers(6))
        decode.append(("bulk", prompt, 12,
                       sched.submit(prompt, 12, tenant="bulk")))
        if i % 3 == 2:
            sched.step()
            prompt = f"live burst {i} extra"
            decode.append(("live", prompt, 12,
                           sched.submit(prompt, 12, tenant="live")))
    outs = sched.run()
    assert eng.stats.preempt_suspends >= 1, "scenario must actually preempt"
    assert eng.stats.preempt_resumes == eng.stats.preempt_suspends
    assert eng.pool.blocks_in_use == 0
    for _tenant, prompt, budget, rid in decode:
        eng2 = FakePagedEngine(num_blocks=11, max_decode_rows=3, max_new=12)
        s2 = BatchScheduler(eng2)
        r2 = s2.submit(prompt, budget)
        assert outs[rid] == s2.run()[r2], prompt


# ------------------------------------------------------ slow deep profile
@pytest.mark.slow
@pytest.mark.parametrize("seed", range(20))
def test_fuzz_deep(seed):
    _fuzz(1000 + seed, n_ops=400, fail_rate=0.1)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@pytest.mark.slow
def test_fuzz_hypothesis():
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**16), n_ops=st.integers(10, 150),
           fail=st.sampled_from([0.0, 0.2]))
    def prop(seed, n_ops, fail):
        _fuzz(seed, n_ops, fail_rate=fail)

    prop()
