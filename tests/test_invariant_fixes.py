"""Regression tests for the true positives the invariant linter surfaced
(PR 7): every billed probe round is finished even when the tick errors
mid-flight, and the engine's prefix-fill pins cannot leak pool blocks when
an exception lands between the fill and its consumption.

The executor tests run on fakes (fast, tier-1); the engine tests drive a
real model like tests/test_paged_decode.py and are slow-marked.
"""
import jax
import pytest

from repro.core.access_paths.base import Ordering
from repro.core.executor import ProbePlanExecutor, ScoreEach
from repro.core.types import SortSpec


# ---------------------------------------------------- executor round drain
class _Ledger:
    def __init__(self):
        self.records = []

    def snapshot(self):
        return len(self.records)


class _RoundOracle:
    """Deferred-capable fake: counts begun/finished round tokens."""

    def __init__(self):
        self.ledger = _Ledger()
        self.begun = []
        self.finished = []

    def begin_probe_round(self, kind, payload, criteria, scheduler):
        token = (len(self.begun), len(payload))
        self.begun.append(token)
        return token

    def finish_probe_round(self, token, scheduler):
        self.finished.append(token)
        return [0.0] * token[1]


class _ExplodingScheduler:
    def __init__(self, fail_times=1):
        self.fail_times = fail_times
        self.pumps = 0

    def pump(self):
        self.pumps += 1
        if self.pumps <= self.fail_times:
            raise RuntimeError("injected pump failure")


def _score_plan(keys):
    vals = yield ScoreEach(list(keys))
    return list(vals)


def _submit(execr, oracle, keys):
    return execr.submit_plan(_score_plan(keys),
                             Ordering(oracle, SortSpec("c")),
                             name=f"plan-{keys[0]}")


def test_tick_pump_failure_still_finishes_every_begun_round():
    """Regression (executor.tick): begin_probe_round bills and enqueues the
    round immediately, so a pump() failure mid-tick must not abandon the
    begun tokens — the finally drain finishes them all."""
    oracle = _RoundOracle()
    sched = _ExplodingScheduler(fail_times=1)
    execr = ProbePlanExecutor(scheduler=sched, prefetch=False)
    _submit(execr, oracle, ["a", "b"])
    _submit(execr, oracle, ["c", "d", "e"])
    with pytest.raises(RuntimeError, match="injected pump failure"):
        execr.tick()
    assert len(oracle.begun) == 2
    assert sorted(oracle.finished) == sorted(oracle.begun)


def test_tick_first_finish_failure_drains_later_tokens():
    """A finish_probe_round that raises must not strand its round-mates:
    the failing token counts as consumed, every other token drains."""
    oracle = _RoundOracle()
    calls = {"n": 0}
    orig = oracle.finish_probe_round

    def finish(token, scheduler):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("injected finish failure")
        return orig(token, scheduler)

    oracle.finish_probe_round = finish
    execr = ProbePlanExecutor(scheduler=_ExplodingScheduler(fail_times=0),
                              prefetch=False)
    _submit(execr, oracle, ["a", "b"])
    _submit(execr, oracle, ["c", "d"])
    _submit(execr, oracle, ["e", "f"])
    with pytest.raises(RuntimeError, match="injected finish failure"):
        execr.tick()
    # token 0 failed (consumed either way); tokens 1 and 2 were drained
    assert len(oracle.begun) == 3
    assert sorted(oracle.finished) == sorted(oracle.begun[1:])


def test_tick_success_path_unchanged():
    oracle = _RoundOracle()
    execr = ProbePlanExecutor(scheduler=_ExplodingScheduler(fail_times=0),
                              prefetch=False)
    runs = [_submit(execr, oracle, ["a", "b"]),
            _submit(execr, oracle, ["c", "d", "e"])]
    while execr.tick():
        pass
    assert [r.result for r in runs] == [[0.0, 0.0], [0.0, 0.0, 0.0]]
    assert sorted(oracle.finished) == sorted(oracle.begun)


# ------------------------------------------------------ engine pin hygiene
@pytest.fixture(scope="module")
def lm_params():
    from repro.configs import get_reduced
    from repro.models import LM
    cfg = get_reduced("llama3-8b")
    lm = LM(cfg)
    return lm, lm.init(jax.random.PRNGKey(0))


def _engine(lm_params, **kw):
    from repro.serving import ServeEngine
    lm, params = lm_params
    kw.setdefault("max_new_tokens", 4)
    return ServeEngine(lm, params, **kw)


PREFIX = "Criteria: relevance\nPassage B: the shared pivot block text\n"


def _lru_blocks(eng):
    return sum(len(e.blocks) for e in eng._prefix_lru.values()
               if e.blocks is not None)


@pytest.mark.slow
def test_paged_admit_releases_pins_when_fill_result_is_unusable(lm_params):
    """Regression (engine.paged_admit): an exception between
    _fill_prefix_entries and the admission try-block used to leak the
    round's pins.  Inject a fill that pins but returns no entry: the
    KeyError must propagate AND the pins must be released."""
    eng = _engine(lm_params)
    orig = eng._fill_prefix_entries

    def broken(cls, keys):
        entries, pins = orig(cls, keys)
        assert pins, "fixture must actually pin pool blocks"
        return {}, pins                     # entry lookup will fail

    eng._fill_prefix_entries = broken
    # equal-length suffixes: same padded class AND same (prefix, start)
    # region, so both rows route shared and the fill is actually consulted
    prompts = [(PREFIX, "Passage A: one\n"), (PREFIX, "Passage A: two\n")]
    with pytest.raises(KeyError):
        eng.generate(prompts, max_new=2)
    eng._fill_prefix_entries = orig
    assert eng.paged_active == 0
    assert eng.pool.blocks_in_use == _lru_blocks(eng)  # no stray pins
    eng.clear_prefix_cache()
    assert eng.pool.blocks_in_use == 0


@pytest.mark.slow
def test_prefetch_prefixes_releases_pins_on_exception(lm_params):
    """Regression (engine.prefetch_prefixes): the fill's round pins are now
    released in a finally, so an exception while consuming the fill result
    cannot strand block references."""
    eng = _engine(lm_params)
    orig = eng._fill_prefix_entries

    class _Boom(dict):
        def __len__(self):
            raise RuntimeError("injected consume failure")

    def broken(cls, keys):
        entries, pins = orig(cls, keys)
        assert pins
        return _Boom(entries), pins

    eng._fill_prefix_entries = broken
    with pytest.raises(RuntimeError, match="injected consume failure"):
        eng.prefetch_prefixes([(PREFIX, "Passage A: warm\n")])
    eng._fill_prefix_entries = orig
    assert eng.pool.blocks_in_use == _lru_blocks(eng)
    eng.clear_prefix_cache()
    assert eng.pool.blocks_in_use == 0


@pytest.mark.slow
def test_prefetch_prefixes_leaves_only_lru_pins(lm_params):
    """Happy path: warming regions leaves exactly the LRU's pinned runs —
    round pins from the fill are all returned."""
    eng = _engine(lm_params)
    n = eng.prefetch_prefixes([(PREFIX, f"Passage A: item {i}\n")
                               for i in range(3)])
    assert n >= 1
    assert eng.pool.blocks_in_use == _lru_blocks(eng) > 0
    eng.clear_prefix_cache()
    assert eng.pool.blocks_in_use == 0


# ------------------------------------------------ preemption edge rollback
def _mid_decode_row(eng):
    """Admit one row and advance it two steps without finishing it."""
    [rid] = eng.paged_admit([("preempt rollback probe", 12)])
    for _ in range(2):
        assert not eng.paged_step()
    return rid


def test_paged_suspend_is_stash_first():
    """An exception inside stash_blocks must leave the row ACTIVE: no pool
    mutation, no stats bump, no half-suspended state (the stash copy runs
    before any bookkeeping, so suspend failure is free to retry)."""
    from fakes_paged import FakePagedEngine
    from repro.serving.kv_pool import PoolExhausted

    eng = FakePagedEngine(num_blocks=11, max_decode_rows=3, max_new=12)
    rid = _mid_decode_row(eng)
    in_use = eng.pool.blocks_in_use
    refs = eng.pool._ref.copy()

    def broken(ids):
        raise PoolExhausted("injected stash failure")

    eng.pool.stash_blocks = broken
    with pytest.raises(PoolExhausted, match="injected stash failure"):
        eng.paged_suspend(rid)
    assert rid in eng._paged_rows           # row still active and owned
    assert eng.pool.blocks_in_use == in_use
    assert (eng.pool._ref == refs).all()
    assert eng.stats.preempt_suspends == 0
    assert eng.stats.preempt_blocks_stashed == 0


def test_paged_resume_rolls_back_alloc_on_unstash_failure():
    """A failure scattering the stash back must decref the fresh run (no
    stranded pins), keep the stash intact, and leave resume retryable —
    and the retried row must finish token-identical to never suspending."""
    from fakes_paged import FakePagedEngine

    solo = FakePagedEngine(num_blocks=11, max_decode_rows=3, max_new=12)
    [srid] = solo.paged_admit([("preempt rollback probe", 12)])
    want = None
    while want is None:
        want = solo.paged_step().get(srid)

    eng = FakePagedEngine(num_blocks=11, max_decode_rows=3, max_new=12)
    rid = _mid_decode_row(eng)
    s = eng.paged_suspend(rid)
    assert eng.pool.blocks_in_use == 0      # fully evicted to the host stash
    real_unstash = eng.pool.unstash_blocks

    def broken(stash, ids):
        raise RuntimeError("injected unstash failure")

    eng.pool.unstash_blocks = broken
    with pytest.raises(RuntimeError, match="injected unstash failure"):
        eng.paged_resume(s)
    assert eng.pool.blocks_in_use == 0      # alloc rolled back, nothing pinned
    assert rid not in eng._paged_rows
    assert eng.stats.preempt_resumes == 0
    eng.pool.unstash_blocks = real_unstash
    assert eng.paged_resume(s) == rid       # stash survived: retry succeeds
    got = None
    while got is None:
        got = eng.paged_step().get(rid)
    assert got == want                      # byte-identical continuation
    assert eng.pool.blocks_in_use == 0
